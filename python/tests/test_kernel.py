"""Kernel vs. pure-numpy oracle — the CORE correctness signal.

Every Pallas kernel (interpret=True) is checked against its independent
numpy implementation in ``compile.kernels.ref`` across widths, seeds and
mask densities, including the all-active and all-inactive edges.
"""

import numpy as np
import pytest

from compile.kernels import (
    WINDOW_LEN,
    char_classify,
    coord_parse,
    filter_scale,
    masked_sum,
    segmented_sum,
    sum_region,
    tagged_sum_region,
)
from compile.kernels import ref

from .conftest import make_window, random_mask

WIDTHS = [8, 16, 128]
SEEDS = [0, 1, 2]
DENSITIES = [0.0, 0.5, 1.0]


def _data(w, seed, density):
    rng = np.random.default_rng(seed)
    vals = rng.normal(scale=10.0, size=w).astype(np.float32)
    mask = random_mask(rng, w, density)
    return rng, vals, mask


@pytest.mark.parametrize("w", WIDTHS)
@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("density", DENSITIES)
def test_filter_scale_matches_ref(w, seed, density):
    _, vals, mask = _data(w, seed, density)
    t = np.array([0.5], np.float32)
    ov, om = filter_scale(vals, mask, t)
    rv, rm = ref.filter_scale_ref(vals, mask, t)
    np.testing.assert_allclose(np.asarray(ov), rv, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(om), rm)


@pytest.mark.parametrize("w", WIDTHS)
@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("density", DENSITIES)
def test_masked_sum_matches_ref(w, seed, density):
    _, vals, mask = _data(w, seed, density)
    s, c = masked_sum(vals, mask)
    rs, rc = ref.masked_sum_ref(vals, mask)
    np.testing.assert_allclose(np.asarray(s), rs, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(c), rc)


@pytest.mark.parametrize("w", WIDTHS)
@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("density", DENSITIES)
def test_sum_region_matches_ref(w, seed, density):
    _, vals, mask = _data(w, seed, density)
    t = np.array([-1.0], np.float32)
    s, k = sum_region(vals, mask, t)
    rs, rk = ref.sum_region_ref(vals, mask, t)
    np.testing.assert_allclose(np.asarray(s), rs, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(k), rk)


@pytest.mark.parametrize("w", WIDTHS)
@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("density", DENSITIES)
def test_segmented_sum_matches_ref(w, seed, density):
    rng, vals, mask = _data(w, seed, density)
    seg = rng.integers(0, w, size=w).astype(np.int32)
    s, c = segmented_sum(vals, seg, mask)
    rs, rc = ref.segmented_sum_ref(vals, seg, mask)
    np.testing.assert_allclose(np.asarray(s), rs, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(c), rc)


@pytest.mark.parametrize("w", WIDTHS)
@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("density", DENSITIES)
def test_tagged_sum_region_matches_ref(w, seed, density):
    rng, vals, mask = _data(w, seed, density)
    seg = rng.integers(0, w, size=w).astype(np.int32)
    t = np.array([0.0], np.float32)
    s, c = tagged_sum_region(vals, seg, mask, t)
    rs, rc = ref.tagged_sum_region_ref(vals, seg, mask, t)
    np.testing.assert_allclose(np.asarray(s), rs, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(c), rc)


def test_tagged_sum_region_equals_two_step():
    """The fused kernel is exactly filter_scale ∘ segmented_sum."""
    rng = np.random.default_rng(5)
    w = 32
    vals = rng.normal(size=w).astype(np.float32)
    seg = rng.integers(0, w, size=w).astype(np.int32)
    mask = (rng.random(w) < 0.7).astype(np.int32)
    t = np.array([0.25], np.float32)
    s1, c1 = tagged_sum_region(vals, seg, mask, t)
    fv, fm = filter_scale(vals, mask, t)
    s2, c2 = segmented_sum(fv, seg, fm)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


@pytest.mark.parametrize("w", WIDTHS)
@pytest.mark.parametrize("seed", SEEDS)
def test_char_classify_matches_ref(w, seed):
    rng = np.random.default_rng(seed)
    # realistic char mix: taxi-like text bytes
    text = b'{12.5,-3.9}T42,extra {7,8} pad' * 8
    chars = np.frombuffer(text[:w].ljust(w, b" "), np.uint8).astype(np.int32)
    mask = random_mask(rng, w)
    f, b = char_classify(chars, mask)
    rf, rb = ref.char_classify_ref(chars, mask)
    np.testing.assert_array_equal(np.asarray(f), rf)
    np.testing.assert_array_equal(np.asarray(b), rb)


@pytest.mark.parametrize("w", [8, 16])
def test_coord_parse_matches_ref(w):
    cases = [
        "{12.5,-3.25}",
        "{1,2}",
        "{-116.52,39.93}trailing",
        "{0.0,0.0}",
        "{bad}",
        "{1.2,}",
        "{1,2",            # truncated — no closing brace
        "{--1,2}",         # double sign
        "{1.2.3,4}",       # double dot
        "{.5,1}",          # dot before digit
        "{1,2,3}",         # too many fields
        "{-,1}",           # sign without digits
        "x1,2}",           # doesn't start with '{'
        "{999999,0.125}",
        "{-0.5,-0.5}",
        "{3,4}{5,6}",      # second pair after close ignored
    ]
    wins = np.stack([make_window(c) for c in (cases * ((w // len(cases)) + 1))[:w]])
    mask = np.ones(w, np.int32)
    mask[-1] = 0  # one inactive lane
    x, y, ok = coord_parse(wins, mask)
    rx, ry, rok = ref.coord_parse_ref(wins, mask)
    np.testing.assert_array_equal(np.asarray(ok), rok)
    np.testing.assert_allclose(np.asarray(x), rx, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(y), ry, rtol=1e-6)


def test_coord_parse_swaps_fields():
    wins = np.stack([make_window("{11.5,-42.25}")] * 8)
    mask = np.ones(8, np.int32)
    x, y, ok = coord_parse(wins, mask)
    assert np.asarray(ok)[0] == 1
    assert np.asarray(x)[0] == np.float32(-42.25)  # second field first
    assert np.asarray(y)[0] == np.float32(11.5)


def test_all_inactive_ensemble_is_zero():
    w = 16
    vals = np.full(w, 7.0, np.float32)
    mask = np.zeros(w, np.int32)
    s, c = masked_sum(vals, mask)
    assert np.asarray(s)[0] == 0.0 and np.asarray(c)[0] == 0
    ov, om = filter_scale(vals, mask, np.array([0.0], np.float32))
    assert not np.asarray(om).any()
    sums, counts = segmented_sum(vals, np.zeros(w, np.int32), mask)
    assert not np.asarray(sums).any() and not np.asarray(counts).any()
