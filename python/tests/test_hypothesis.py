"""Hypothesis sweeps: kernel ⇄ ref equivalence over generated shapes,
values, masks and (for the parser) generated grammar strings.
"""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import (
    WINDOW_LEN,
    char_classify,
    coord_parse,
    filter_scale,
    masked_sum,
    segmented_sum,
)
from compile.kernels import ref

from .conftest import make_window

_SETTINGS = dict(max_examples=25, deadline=None)

widths = st.sampled_from([4, 8, 16, 32])
finite_f32 = st.floats(
    min_value=-1e4, max_value=1e4, allow_nan=False, width=32
)


@st.composite
def ensemble(draw):
    w = draw(widths)
    vals = np.array(draw(st.lists(finite_f32, min_size=w, max_size=w)), np.float32)
    mask = np.array(draw(st.lists(st.integers(0, 1), min_size=w, max_size=w)), np.int32)
    return vals, mask


@given(ensemble(), finite_f32)
@settings(**_SETTINGS)
def test_filter_scale_hypothesis(vm, t):
    vals, mask = vm
    th = np.array([t], np.float32)
    ov, om = filter_scale(vals, mask, th)
    rv, rm = ref.filter_scale_ref(vals, mask, th)
    np.testing.assert_allclose(np.asarray(ov), rv, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(om), rm)


@given(ensemble())
@settings(**_SETTINGS)
def test_masked_sum_hypothesis(vm):
    vals, mask = vm
    s, c = masked_sum(vals, mask)
    rs, rc = ref.masked_sum_ref(vals, mask)
    np.testing.assert_allclose(np.asarray(s), rs, rtol=1e-4, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(c), rc)


@given(ensemble(), st.randoms(use_true_random=False))
@settings(**_SETTINGS)
def test_segmented_sum_hypothesis(vm, rnd):
    vals, mask = vm
    w = vals.shape[0]
    seg = np.array([rnd.randrange(w) for _ in range(w)], np.int32)
    s, c = segmented_sum(vals, seg, mask)
    rs, rc = ref.segmented_sum_ref(vals, seg, mask)
    np.testing.assert_allclose(np.asarray(s), rs, rtol=1e-4, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(c), rc)


@given(
    st.lists(st.integers(0, 255), min_size=8, max_size=8),
    st.lists(st.integers(0, 1), min_size=8, max_size=8),
)
@settings(**_SETTINGS)
def test_char_classify_hypothesis(cs, ms):
    chars = np.array(cs, np.int32)
    mask = np.array(ms, np.int32)
    f, b = char_classify(chars, mask)
    rf, rb = ref.char_classify_ref(chars, mask)
    np.testing.assert_array_equal(np.asarray(f), rf)
    np.testing.assert_array_equal(np.asarray(b), rb)


@st.composite
def coord_text(draw):
    """Mix of well-formed pairs and mutated near-misses."""

    def field():
        sign = draw(st.sampled_from(["", "-"]))
        ip = str(draw(st.integers(0, 999999)))
        if draw(st.booleans()):
            return f"{sign}{ip}.{draw(st.integers(0, 99999))}"
        return f"{sign}{ip}"

    s = "{" + field() + "," + field() + "}"
    if draw(st.booleans()):
        # mutate one char to exercise the reject paths
        i = draw(st.integers(0, len(s) - 1))
        c = draw(st.sampled_from("{},.-x9"))
        s = s[:i] + c + s[i + 1 :]
    return s[:WINDOW_LEN]


@given(st.lists(coord_text(), min_size=4, max_size=4))
@settings(**_SETTINGS)
def test_coord_parse_hypothesis(texts):
    wins = np.stack([make_window(t) for t in texts])
    mask = np.ones(4, np.int32)
    x, y, ok = coord_parse(wins, mask)
    rx, ry, rok = ref.coord_parse_ref(wins, mask)
    np.testing.assert_array_equal(np.asarray(ok), rok)
    np.testing.assert_allclose(np.asarray(x), rx, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(y), ry, rtol=1e-6, atol=1e-6)


@given(st.integers(0, 99999), st.integers(0, 9999), st.integers(0, 99999), st.integers(0, 9999))
@settings(**_SETTINGS)
def test_coord_parse_value_correct(ai, af, bi, bf):
    """Parsed value agrees with Python's own float parse (within f32)."""
    a, b = f"{ai}.{af}", f"-{bi}.{bf}"
    wins = np.stack([make_window("{" + a + "," + b + "}")] * 4)
    x, y, ok = coord_parse(wins, np.ones(4, np.int32))
    assert np.asarray(ok)[0] == 1
    np.testing.assert_allclose(np.asarray(y)[0], np.float32(float(a)), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(x)[0], np.float32(float(b)), rtol=1e-5)
