"""AOT path: every L2 entry lowers to loadable-looking HLO text, and the
manifest describes exactly the artifact set (names, widths, shapes)."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot
from compile.model import ENTRIES
from compile.kernels import WINDOW_LEN


@pytest.mark.parametrize("name", sorted(ENTRIES))
def test_entry_lowers_to_hlo_text(name):
    lowered = aot.lower_entry(name, 8)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # all ids must be 32-bit-safe for xla_extension 0.5.1 (the text parser
    # reassigns them, but the text itself must be syntactically complete)
    assert text.strip().endswith("}")


@pytest.mark.parametrize("name", sorted(ENTRIES))
def test_entry_executes_after_roundtrip(name):
    """Lower → HLO text is still a *functioning* module: re-running the
    jitted fn on zeros matches the eager kernel (sanity that lowering
    didn't specialize away inputs)."""
    fn, specs = ENTRIES[name](8)
    args = [np.zeros(s.shape, s.dtype) for s in specs]
    out_jit = jax.jit(fn)(*args)
    out_eager = fn(*args)
    for a, b in zip(out_jit, out_eager):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_build_writes_manifest_and_modules(tmp_path):
    aot.build(str(tmp_path), [8])
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["widths"] == [8]
    assert manifest["window_len"] == WINDOW_LEN
    assert set(manifest["entries"]) == set(ENTRIES)
    for name in ENTRIES:
        p = tmp_path / "w8" / f"{name}.hlo.txt"
        assert p.exists() and p.stat().st_size > 0


def test_manifest_shapes_match_model():
    _, specs = ENTRIES["coord_parse"](128)
    desc = aot.describe_specs(specs)
    assert desc[0] == {"dtype": "int32", "shape": [128, WINDOW_LEN]}
    assert desc[1] == {"dtype": "int32", "shape": [128]}
