"""Shared fixtures/helpers for the REGATTA kernel test suite."""

import numpy as np
import pytest

from compile.kernels import WINDOW_LEN


@pytest.fixture
def rng():
    return np.random.default_rng(0xC0FFEE)


def make_window(s: str) -> np.ndarray:
    """ASCII window padded with NULs to WINDOW_LEN (as the Rust side does)."""
    out = np.zeros(WINDOW_LEN, np.int32)
    bs = s.encode("ascii")[:WINDOW_LEN]
    out[: len(bs)] = np.frombuffer(bs, np.uint8)
    return out


def random_mask(rng, w, p_active=0.75):
    return (rng.random(w) < p_active).astype(np.int32)
