"""Algebraic invariants of the kernels (beyond pointwise ref-equality).

These pin down properties the coordinator relies on: segment sums must be
permutation-invariant, degenerate tag patterns must collapse to the plain
masked reduction, and occupancy masking must behave like padding.
"""

import numpy as np

from compile.kernels import (
    filter_scale,
    masked_sum,
    segmented_sum,
    sum_region,
)


def test_segmented_sum_permutation_invariant(rng):
    w = 32
    vals = rng.normal(size=w).astype(np.float32)
    seg = rng.integers(0, 4, size=w).astype(np.int32)
    mask = np.ones(w, np.int32)
    s1, c1 = segmented_sum(vals, seg, mask)
    perm = rng.permutation(w)
    s2, c2 = segmented_sum(vals[perm], seg[perm], mask)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_segmented_sum_single_tag_equals_masked_sum(rng):
    """One region per ensemble ⇒ tagging degenerates to the sparse design."""
    w = 64
    vals = rng.normal(size=w).astype(np.float32)
    mask = (rng.random(w) < 0.7).astype(np.int32)
    seg = np.zeros(w, np.int32)
    s, c = segmented_sum(vals, seg, mask)
    ms, mc = masked_sum(vals, mask)
    np.testing.assert_allclose(np.asarray(s)[0], np.asarray(ms)[0], rtol=1e-5, atol=1e-5)
    assert np.asarray(c)[0] == np.asarray(mc)[0]
    assert not np.asarray(s)[1:].any()


def test_segmented_sum_totals_match_masked_sum(rng):
    """Sum over segments == masked sum: no item lost or double-counted."""
    w = 128
    vals = rng.normal(size=w).astype(np.float32)
    seg = rng.integers(0, w, size=w).astype(np.int32)
    mask = (rng.random(w) < 0.5).astype(np.int32)
    s, c = segmented_sum(vals, seg, mask)
    ms, mc = masked_sum(vals, mask)
    np.testing.assert_allclose(np.asarray(s).sum(), np.asarray(ms)[0], rtol=1e-4, atol=1e-4)
    assert np.asarray(c).sum() == np.asarray(mc)[0]


def test_mask_is_padding(rng):
    """A partially-full ensemble equals a narrower full one, zero-padded —
    the property that makes occupancy purely a *cost*, never a semantics,
    concern for the coordinator."""
    w, k = 32, 11
    vals = np.zeros(w, np.float32)
    vals[:k] = rng.normal(size=k).astype(np.float32)
    mask = np.zeros(w, np.int32)
    mask[:k] = 1
    t = np.array([0.0], np.float32)
    s_part, k_part = sum_region(vals, mask, t)
    s_full, k_full = sum_region(
        vals[:16].copy() * 0 + np.pad(vals[:k], (0, 16 - k)),
        np.pad(np.ones(k, np.int32), (0, 16 - k)),
        t,
    )
    np.testing.assert_allclose(np.asarray(s_part), np.asarray(s_full), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(k_part), np.asarray(k_full))


def test_filter_scale_idempotent_mask(rng):
    """Output mask of filter_scale is a subset of the input mask."""
    w = 64
    vals = rng.normal(size=w).astype(np.float32)
    mask = (rng.random(w) < 0.6).astype(np.int32)
    _, om = filter_scale(vals, mask, np.array([0.0], np.float32))
    om = np.asarray(om)
    assert ((om == 1) <= (mask == 1)).all()
