"""Layer-2 JAX model: fixed-width ensemble functions over the L1 kernels.

Each entry point is the compute graph that one coordinator node runs per
*firing* — a fixed-shape, width-``w`` batch function. `aot.py` lowers each
entry for every configured width to HLO text; the Rust runtime
(`rust/src/runtime/`) loads and invokes them via PJRT with the lane mask
expressing SIMD occupancy.

All scalars travel as rank-1 single-element arrays so every argument is a
plain buffer on the Rust side.
"""

import jax
import jax.numpy as jnp

from .kernels import (
    WINDOW_LEN,
    char_classify,
    coord_parse,
    filter_scale,
    masked_sum,
    segmented_sum,
    sum_region,
    tagged_sum_region,
)

F32, I32 = jnp.float32, jnp.int32


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# Entry points. Each factory returns (callable, example_args) for a width.
# Every callable returns a tuple (lowered with return_tuple=True).
# ---------------------------------------------------------------------------


def entry_filter_scale(w):
    def fn(vals, mask, threshold):
        return filter_scale(vals, mask, threshold)

    return fn, (_spec((w,), F32), _spec((w,), I32), _spec((1,), F32))


def entry_masked_sum(w):
    def fn(vals, mask):
        return masked_sum(vals, mask)

    return fn, (_spec((w,), F32), _spec((w,), I32))


def entry_sum_region(w):
    def fn(vals, mask, threshold):
        return sum_region(vals, mask, threshold)

    return fn, (_spec((w,), F32), _spec((w,), I32), _spec((1,), F32))


def entry_segmented_sum(w):
    def fn(vals, seg, mask):
        return segmented_sum(vals, seg, mask)

    return fn, (_spec((w,), F32), _spec((w,), I32), _spec((w,), I32))


def entry_tagged_sum_region(w):
    def fn(vals, seg, mask, threshold):
        return tagged_sum_region(vals, seg, mask, threshold)

    return fn, (_spec((w,), F32), _spec((w,), I32), _spec((w,), I32), _spec((1,), F32))


def entry_char_classify(w):
    def fn(chars, mask):
        return char_classify(chars, mask)

    return fn, (_spec((w,), I32), _spec((w,), I32))


def entry_coord_parse(w):
    def fn(windows, mask):
        return coord_parse(windows, mask)

    return fn, (_spec((w, WINDOW_LEN), I32), _spec((w,), I32))


def entry_tagged_char_stage(w):
    """Fused stage for the pure-tagging taxi variant.

    Classifies a full (possibly mixed-region) ensemble of characters AND
    reduces, per region tag present in the ensemble, the count of
    candidate braces — the per-character work plus tag bookkeeping that
    makes the dense representation's overhead real (Fig. 8, x-series).
    """

    def fn(chars, tags, mask):
        flags, bits = char_classify(chars, mask)
        tag_counts_f, _ = segmented_sum(flags.astype(F32), tags, mask)
        return flags, bits, tag_counts_f.astype(I32)

    return fn, (_spec((w,), I32), _spec((w,), I32), _spec((w,), I32))


#: name -> entry factory; the AOT artifact set and the Rust runtime's
#: kernel registry are both driven by this table.
ENTRIES = {
    "filter_scale": entry_filter_scale,
    "masked_sum": entry_masked_sum,
    "sum_region": entry_sum_region,
    "segmented_sum": entry_segmented_sum,
    "tagged_sum_region": entry_tagged_sum_region,
    "char_classify": entry_char_classify,
    "coord_parse": entry_coord_parse,
    "tagged_char_stage": entry_tagged_char_stage,
}
