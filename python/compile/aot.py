"""AOT compile path: lower every L2 entry point to HLO **text**.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids, which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Layout::

    artifacts/
      manifest.json            # widths, kernel names, shape metadata
      w128/<entry>.hlo.txt     # one module per (width, entry)
      w32/...  w64/...  w256/...

Python runs only here (``make artifacts``); the Rust binary is
self-contained afterwards.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .kernels import WINDOW_LEN
from .kernels.filter_scale import SCALE
from .model import ENTRIES

#: Default production width (the paper's CUDA block size) plus the
#: ablation widths swept by `cargo bench --bench ablation_width`.
DEFAULT_WIDTHS = (128, 32, 64, 256)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name, width):
    fn, specs = ENTRIES[name](width)
    return jax.jit(fn).lower(*specs)


def describe_specs(specs):
    return [
        {"dtype": s.dtype.name, "shape": list(s.shape)}
        for s in specs
    ]


def build(out_dir, widths):
    manifest = {
        "format": "hlo-text",
        "widths": sorted(widths),
        "window_len": WINDOW_LEN,
        "scale": SCALE,
        "path_format": "w{width}/{entry}.hlo.txt",
        "entries": {},
    }
    for name in ENTRIES:
        _, specs = ENTRIES[name](widths[0])
        manifest["entries"][name] = {"inputs": describe_specs(specs)}
    n = 0
    for w in widths:
        wdir = os.path.join(out_dir, f"w{w}")
        os.makedirs(wdir, exist_ok=True)
        for name in ENTRIES:
            lowered = lower_entry(name, w)
            text = to_hlo_text(lowered)
            path = os.path.join(wdir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            n += 1
            print(f"  wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"AOT: {n} modules for widths {list(widths)} -> {out_dir}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--widths",
        default=",".join(str(w) for w in DEFAULT_WIDTHS),
        help="comma-separated ensemble widths to compile",
    )
    args = ap.parse_args()
    widths = [int(w) for w in args.widths.split(",") if w]
    build(args.out_dir, widths)


if __name__ == "__main__":
    main()
