"""``segmented_sum`` — per-tag sums within one ensemble.

The in-band *tagging* baseline of the paper's Sec. 5 (CnC-CUDA style):
instead of capping ensembles at region boundaries, every item carries its
region tag, so a full ensemble may mix items from many regions. Each
invocation reduces the ensemble into per-segment partial sums keyed by
the lane's local segment id.

TPU adaptation: the natural GPU implementation is an atomic
scatter-add; scatters are poison on the MXU-era memory system, so we
express the reduction as a one-hot matmul — ``one_hot(seg)ᵀ · vals`` —
which maps straight onto the systolic array. This is the
DESIGN.md §Hardware-Adaptation example of rethinking a CUDA idiom for
TPU rather than porting it.

Cost intuition (and what the Fig. 8 benches measure): full occupancy,
but O(w²) MAC work and a tag per item — representation overhead traded
against occupancy, the paper's central tradeoff.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _segmented_sum_kernel(v_ref, seg_ref, m_ref, s_ref, c_ref):
    v = v_ref[...]
    seg = seg_ref[...]
    m = m_ref[...]
    w = v.shape[0]
    active = m != 0
    vm = jnp.where(active, v, jnp.float32(0.0))
    # one_hot[lane, segment] — inactive lanes select no segment.
    seg_ids = jax.lax.broadcasted_iota(jnp.int32, (w, w), 1)
    one_hot = jnp.logical_and(seg[:, None] == seg_ids, active[:, None])
    one_hot_f = one_hot.astype(jnp.float32)
    s_ref[...] = jnp.dot(vm, one_hot_f, preferred_element_type=jnp.float32)
    c_ref[...] = jnp.sum(one_hot.astype(jnp.int32), axis=0)


@functools.partial(jax.jit, static_argnames=("width",))
def segmented_sum(vals, seg, mask, *, width=None):
    """Per-segment sums over one ensemble via one-hot matmul.

    Args:
      vals: ``f32[w]`` lane values.
      seg: ``i32[w]`` per-lane segment id in ``[0, w)`` (ensemble-local).
      mask: ``i32[w]`` active-lane mask (0/1).

    Returns:
      ``(sums f32[w], counts i32[w])`` — sum and item count per segment
      id; segments not present in the ensemble get 0.
    """
    w = width or vals.shape[0]
    return pl.pallas_call(
        _segmented_sum_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((w,), jnp.float32),
            jax.ShapeDtypeStruct((w,), jnp.int32),
        ),
        interpret=True,
    )(vals, seg, mask)
