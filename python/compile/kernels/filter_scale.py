"""``filter_scale`` — the paper's Fig. 5 filtering node ``f``.

Per active lane: keep ``v`` iff ``isGood(v)`` (here ``v > threshold``) and
emit ``SCALE * v``; inactive or filtered lanes come back with a zeroed
output mask. Irregular dataflow in miniature: each input yields 0 or 1
outputs, and the coordinator compacts the survivors downstream.

TPU notes: a ``w``-lane f32 ensemble is a single sub-tile in VMEM
(w=128 → 512 B/operand); the kernel is a pure VPU elementwise op, no MXU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: The constant the paper's example multiplies surviving values by (Fig. 5).
SCALE = 3.14


def _filter_scale_kernel(v_ref, m_ref, t_ref, ov_ref, om_ref):
    v = v_ref[...]
    m = m_ref[...]
    t = t_ref[0]
    good = jnp.logical_and(v > t, m != 0)
    ov_ref[...] = jnp.where(good, SCALE * v, jnp.float32(0.0))
    om_ref[...] = good.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("width",))
def filter_scale(vals, mask, threshold, *, width=None):
    """Masked filter + scale over one ensemble.

    Args:
      vals: ``f32[w]`` lane values.
      mask: ``i32[w]`` active-lane mask (0/1).
      threshold: ``f32[1]`` — lanes with ``v > threshold`` survive.
      width: static ensemble width (defaults to ``vals.shape[0]``).

    Returns:
      ``(out_vals f32[w], out_mask i32[w])`` — scaled survivors, with
      ``out_mask`` marking lanes that produced an output.
    """
    w = width or vals.shape[0]
    return pl.pallas_call(
        _filter_scale_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((w,), jnp.float32),
            jax.ShapeDtypeStruct((w,), jnp.int32),
        ),
        interpret=True,
    )(vals, mask, threshold)
