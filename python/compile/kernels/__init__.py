"""Layer-1 Pallas kernels for REGATTA.

Each kernel processes one SIMD *ensemble*: a fixed-width batch of ``w``
lanes with an active-lane mask (``i32[w]``, 0/1). The fixed shape is the
point — one invocation costs the same regardless of how many lanes are
active, which is exactly the SIMD-occupancy cost model of the paper.

Every kernel has a pure-jnp/numpy oracle in :mod:`.ref`; pytest (including
hypothesis sweeps) asserts equivalence under ``interpret=True``.

Kernels
-------
``filter_scale``   masked filter ``isGood(v)`` + scale (paper Fig. 5 node f)
``masked_sum``     sum of active lanes (aggregation accumulate, node a)
``sum_region``     fused filter+scale+sum — the sum-app hot path (Figs 6/7)
``segmented_sum``  per-tag sums within an ensemble via one-hot matmul
                   (the in-band tagging baseline of paper Sec. 5)
``tagged_sum_region``  fused filter+scale+segmented-sum (perf pass, see
                   EXPERIMENTS.md §Perf)
``char_classify``  open-brace candidate detection (taxi stage 1)
``coord_parse``    ``{lat,lon}`` parser over per-lane char windows (taxi stage 2)
"""

from .filter_scale import filter_scale, SCALE
from .masked_sum import masked_sum
from .sum_region import sum_region
from .segmented_sum import segmented_sum
from .tagged_sum_region import tagged_sum_region
from .char_classify import char_classify, OPEN_BRACE
from .coord_parse import coord_parse, WINDOW_LEN

__all__ = [
    "filter_scale",
    "masked_sum",
    "sum_region",
    "segmented_sum",
    "tagged_sum_region",
    "char_classify",
    "coord_parse",
    "SCALE",
    "OPEN_BRACE",
    "WINDOW_LEN",
]
