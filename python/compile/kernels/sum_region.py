"""``sum_region`` — fused filter+scale+sum, the sum-app hot path.

One invocation of this kernel is one node firing of the paper's
benchmark computation (Sec. 5, Figs 6/7): filter the ensemble's active
lanes, scale survivors, and reduce to a scalar partial sum — all in one
HLO module so XLA fuses the elementwise chain straight into the
reduction (verified in the perf pass: no intermediate buffer
materialises).

Because the coordinator caps the ensemble at the region boundary
(credit), the partial sum is always confined to a single region; the
fixed-width invocation cost is how reduced SIMD occupancy shows up as
wall-clock time.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .filter_scale import SCALE


def _sum_region_kernel(v_ref, m_ref, t_ref, s_ref, k_ref):
    v = v_ref[...]
    m = m_ref[...]
    t = t_ref[0]
    good = jnp.logical_and(v > t, m != 0)
    s_ref[0] = jnp.sum(jnp.where(good, SCALE * v, jnp.float32(0.0)))
    k_ref[0] = jnp.sum(good.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("width",))
def sum_region(vals, mask, threshold, *, width=None):
    """Fused filter+scale+partial-sum over one ensemble.

    Args:
      vals: ``f32[w]`` lane values.
      mask: ``i32[w]`` active-lane mask (0/1).
      threshold: ``f32[1]`` filter threshold (``v > t`` survives).

    Returns:
      ``(partial_sum f32[1], kept i32[1])``.
    """
    del width
    return pl.pallas_call(
        _sum_region_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ),
        interpret=True,
    )(vals, mask, threshold)
