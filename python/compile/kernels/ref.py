"""Pure numpy oracles for every L1 kernel.

Deliberately *independent* implementations — plain numpy (and, for the
parser, a per-lane Python character loop) rather than a restructuring of
the kernel code — so pytest equivalence is a real correctness signal.
"""

import numpy as np

SCALE = 3.14
OPEN_BRACE = 0x7B
WINDOW_LEN = 32


def filter_scale_ref(vals, mask, threshold):
    vals = np.asarray(vals, np.float32)
    mask = np.asarray(mask, np.int32)
    t = np.float32(np.asarray(threshold).reshape(-1)[0])
    good = (vals > t) & (mask != 0)
    out = np.where(good, np.float32(SCALE) * vals, np.float32(0.0))
    return out.astype(np.float32), good.astype(np.int32)


def masked_sum_ref(vals, mask):
    vals = np.asarray(vals, np.float32)
    mask = np.asarray(mask, np.int32)
    active = mask != 0
    s = np.float32(vals[active].sum(dtype=np.float32))
    return np.array([s], np.float32), np.array([active.sum()], np.int32)


def sum_region_ref(vals, mask, threshold):
    out, omask = filter_scale_ref(vals, mask, threshold)
    s = np.float32(out[omask != 0].sum(dtype=np.float32))
    return np.array([s], np.float32), np.array([(omask != 0).sum()], np.int32)


def segmented_sum_ref(vals, seg, mask):
    vals = np.asarray(vals, np.float32)
    seg = np.asarray(seg, np.int32)
    mask = np.asarray(mask, np.int32)
    w = vals.shape[0]
    sums = np.zeros(w, np.float32)
    counts = np.zeros(w, np.int32)
    for i in range(w):
        if mask[i] != 0:
            sums[seg[i]] += vals[i]
            counts[seg[i]] += 1
    return sums, counts


def char_classify_ref(chars, mask):
    chars = np.asarray(chars, np.int32)
    mask = np.asarray(mask, np.int32)
    active = mask != 0
    is_open = (chars == OPEN_BRACE) & active
    bits = np.zeros_like(chars)
    bits += ((chars >= 0x30) & (chars <= 0x39)).astype(np.int32)
    bits += 2 * (chars == 0x2E).astype(np.int32)
    bits += 4 * (chars == 0x2C).astype(np.int32)
    bits += 8 * (chars == 0x2D).astype(np.int32)
    bits += 16 * (chars == 0x7D).astype(np.int32)
    bits = np.where(active, bits, 0)
    return is_open.astype(np.int32), bits


def _parse_one(window):
    """Parse one '{a,b}' window with an explicit per-char loop.

    Returns (a, b, ok). Mirrors the grammar, not the kernel: single
    optional leading '-', digits, optional '.' digits (dot only after a
    digit), ',' between exactly two fields, '}' terminator. Arithmetic is
    done in float32 steps to match the kernel's accumulation exactly.
    """
    if len(window) == 0 or window[0] != ord("{"):
        return 0.0, 0.0, 0
    f32 = np.float32
    field = 0
    acc_i, acc_f, fdiv, sign = f32(0), f32(0), f32(1), f32(1)
    seen_dot = seen_digit = False
    a = f32(0)
    for c in window[1:]:
        if ord("0") <= c <= ord("9"):
            d = f32(c - ord("0"))
            if seen_dot:
                acc_f = f32(acc_f * f32(10) + d)
                fdiv = f32(fdiv * f32(10))
            else:
                acc_i = f32(acc_i * f32(10) + d)
            seen_digit = True
        elif c == ord("."):
            if seen_dot or not seen_digit:
                return 0.0, 0.0, 0
            seen_dot = True
        elif c == ord("-"):
            if seen_digit or seen_dot or sign < 0:
                return 0.0, 0.0, 0
            sign = f32(-1)
        elif c == ord(","):
            if field != 0 or not seen_digit:
                return 0.0, 0.0, 0
            a = f32(sign * f32(acc_i + f32(acc_f / fdiv)))
            field = 1
            acc_i, acc_f, fdiv, sign = f32(0), f32(0), f32(1), f32(1)
            seen_dot = seen_digit = False
        elif c == ord("}"):
            if field != 1 or not seen_digit:
                return 0.0, 0.0, 0
            b = f32(sign * f32(acc_i + f32(acc_f / fdiv)))
            return float(a), float(b), 1
        else:
            return 0.0, 0.0, 0
    return 0.0, 0.0, 0  # ran out of window without '}'


def coord_parse_ref(windows, mask):
    windows = np.asarray(windows, np.int32)
    mask = np.asarray(mask, np.int32)
    w = windows.shape[0]
    x = np.zeros(w, np.float32)
    y = np.zeros(w, np.float32)
    ok = np.zeros(w, np.int32)
    for i in range(w):
        if mask[i] == 0:
            continue
        a, b, good = _parse_one(list(windows[i]))
        if good:
            # swapped output: x = second field, y = first field
            x[i], y[i], ok[i] = b, a, 1
    return x, y, ok


def tagged_sum_region_ref(vals, seg, mask, threshold):
    out, omask = filter_scale_ref(vals, mask, threshold)
    return segmented_sum_ref(out, seg, omask)
