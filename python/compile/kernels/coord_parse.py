"""``coord_parse`` — taxi stage 2: verify + parse ``{lat,lon}`` pairs.

Each active lane holds a ``WINDOW_LEN``-char window of the raw text
starting at a candidate ``'{'`` (stage 1 output). The kernel verifies
the candidate really is a coordinate pair of the form::

    '{' [-] digits ['.' digits] ',' [-] digits ['.' digits] '}'

and, if so, parses both fields. Per the paper's app, the emitted pair is
**swapped** relative to the text order.

GPU→TPU adaptation: on the GPU each thread runs a divergent char loop;
divergence is free to express but costs lockstep idling. Here the state
machine is *vectorized across lanes* — a ``fori_loop`` over the window
columns carrying per-lane state vectors, every lane advancing in
lockstep through ``jnp.where`` cascades. Same O(w·WINDOW_LEN) work, no
divergence, pure VPU.

State per lane: current field (0/1), integer/fraction accumulators,
fraction divisor, sign, seen-dot / seen-digit flags, done, ok.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Window length in characters; ``{-123.4567890,-123.4567890}`` is 27,
#: so 32 covers any well-formed pair the generator emits.
WINDOW_LEN = 32

_DIGIT_LO, _DIGIT_HI = 0x30, 0x39
_OPEN, _CLOSE, _COMMA, _DOT, _MINUS = 0x7B, 0x7D, 0x2C, 0x2E, 0x2D


def _parse_window(win, active):
    """Vectorized parser. ``win``: i32[w, WINDOW_LEN]; returns (a, b, ok)."""
    w = win.shape[0]
    f32 = jnp.float32
    i32 = jnp.int32
    zf = jnp.zeros((w,), f32)
    zi = jnp.zeros((w,), i32)

    # Lanes whose window does not start with '{' are invalid from the off;
    # inactive lanes are parked as done/not-ok.
    starts_ok = win[:, 0] == _OPEN
    done0 = jnp.logical_or(~active, ~starts_ok)

    state0 = (
        zi,          # field: 0 or 1
        zf,          # acc_int
        zf,          # acc_frac
        jnp.ones((w,), f32),  # frac_div
        jnp.ones((w,), f32),  # sign
        zi,          # seen_dot
        zi,          # seen_digit
        zf,          # a (field 0 value)
        zf,          # b (field 1 value)
        done0,       # done (bool)
        zi,          # ok
    )

    def step(p, state):
        (field, acc_i, acc_f, fdiv, sign, sdot, sdig, a, b, done, ok) = state
        c = win[:, p]
        is_digit = jnp.logical_and(c >= _DIGIT_LO, c <= _DIGIT_HI)
        d = (c - _DIGIT_LO).astype(f32)
        live = ~done

        # digit: accumulate into int or frac part
        dig = jnp.logical_and(live, is_digit)
        grow_frac = jnp.logical_and(dig, sdot != 0)
        grow_int = jnp.logical_and(dig, sdot == 0)
        acc_i = jnp.where(grow_int, acc_i * 10.0 + d, acc_i)
        acc_f = jnp.where(grow_frac, acc_f * 10.0 + d, acc_f)
        fdiv = jnp.where(grow_frac, fdiv * 10.0, fdiv)
        sdig = jnp.where(dig, 1, sdig)

        # '.': only once per field, and only after a digit
        dot = jnp.logical_and(live, c == _DOT)
        dot_ok = jnp.logical_and(dot, jnp.logical_and(sdot == 0, sdig != 0))
        dot_bad = jnp.logical_and(dot, ~jnp.logical_and(sdot == 0, sdig != 0))
        sdot = jnp.where(dot_ok, 1, sdot)

        # '-': only as the first char of a field
        neg = jnp.logical_and(live, c == _MINUS)
        at_start = jnp.logical_and(sdig == 0, jnp.logical_and(sdot == 0, sign > 0))
        neg_ok = jnp.logical_and(neg, at_start)
        neg_bad = jnp.logical_and(neg, ~at_start)
        sign = jnp.where(neg_ok, -jnp.ones((w,), f32), sign)

        value = sign * (acc_i + acc_f / fdiv)

        # ',': close field 0
        comma = jnp.logical_and(live, c == _COMMA)
        comma_ok = jnp.logical_and(comma, jnp.logical_and(field == 0, sdig != 0))
        comma_bad = jnp.logical_and(comma, ~jnp.logical_and(field == 0, sdig != 0))
        a = jnp.where(comma_ok, value, a)
        field = jnp.where(comma_ok, 1, field)
        acc_i = jnp.where(comma_ok, zf, acc_i)
        acc_f = jnp.where(comma_ok, zf, acc_f)
        fdiv = jnp.where(comma_ok, jnp.ones((w,), f32), fdiv)
        sign = jnp.where(comma_ok, jnp.ones((w,), f32), sign)
        sdot = jnp.where(comma_ok, 0, sdot)
        sdig = jnp.where(comma_ok, 0, sdig)

        # '}': close field 1, success
        close = jnp.logical_and(live, c == _CLOSE)
        close_ok = jnp.logical_and(close, jnp.logical_and(field == 1, sdig != 0))
        close_bad = jnp.logical_and(close, ~jnp.logical_and(field == 1, sdig != 0))
        b = jnp.where(close_ok, value, b)
        ok = jnp.where(close_ok, 1, ok)

        # anything else (incl. '{' again, NUL padding) is invalid
        known = is_digit | (c == _DOT) | (c == _MINUS) | (c == _COMMA) | (c == _CLOSE)
        other_bad = jnp.logical_and(live, ~known)

        bad = dot_bad | neg_bad | comma_bad | close_bad | other_bad
        done = done | bad | close_ok
        return (field, acc_i, acc_f, fdiv, sign, sdot, sdig, a, b, done, ok)

    # Perf pass (EXPERIMENTS.md §Perf): unroll the window scan. A
    # fori_loop lowers to an HLO while-loop whose per-iteration dispatch
    # overhead on the CPU backend dwarfs the ~20 vector ops inside; the
    # unrolled straight-line graph fuses into a handful of kernels.
    state = state0
    for p in range(1, WINDOW_LEN):
        state = step(p, state)
    a, b, ok = state[7], state[8], state[10]
    # a window that runs out of chars without hitting '}' is invalid (ok=0)
    a = jnp.where(ok != 0, a, 0.0)
    b = jnp.where(ok != 0, b, 0.0)
    return a, b, ok


def _coord_parse_kernel(w_ref, m_ref, x_ref, y_ref, ok_ref):
    win = w_ref[...]
    active = m_ref[...] != 0
    a, b, ok = _parse_window(win, active)
    # The taxi app emits the pair SWAPPED relative to the text.
    x_ref[...] = b
    y_ref[...] = a
    ok_ref[...] = ok


@functools.partial(jax.jit, static_argnames=("width",))
def coord_parse(windows, mask, *, width=None):
    """Verify + parse one ensemble of candidate windows.

    Args:
      windows: ``i32[w, WINDOW_LEN]`` ASCII windows, each starting at a
        candidate ``'{'`` (pad past end-of-line with 0).
      mask: ``i32[w]`` active-lane mask (0/1).

    Returns:
      ``(x f32[w], y f32[w], ok i32[w])`` — the *swapped* pair per lane
      (``x`` = second field, ``y`` = first field) and a validity flag.
    """
    w = width or windows.shape[0]
    return pl.pallas_call(
        _coord_parse_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((w,), jnp.float32),
            jax.ShapeDtypeStruct((w,), jnp.float32),
            jax.ShapeDtypeStruct((w,), jnp.int32),
        ),
        interpret=True,
    )(windows, mask)
