"""``masked_sum`` — the aggregation accumulate of the paper's node ``a``.

Sums the active lanes of one ensemble into a scalar partial sum. The
coordinator adds partial sums into the per-parent accumulator between
``begin()`` and ``end()`` — the SIMD-parallel reduction the paper notes
node ``a`` would use in practice (Sec. 4.2).

TPU notes: VPU lane reduction; output kept as ``f32[1]`` (SMEM scalar on
real hardware).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _masked_sum_kernel(v_ref, m_ref, o_ref, c_ref):
    v = v_ref[...]
    m = m_ref[...]
    active = m != 0
    o_ref[0] = jnp.sum(jnp.where(active, v, jnp.float32(0.0)))
    c_ref[0] = jnp.sum(active.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("width",))
def masked_sum(vals, mask, *, width=None):
    """Sum of active lanes.

    Args:
      vals: ``f32[w]`` lane values.
      mask: ``i32[w]`` active-lane mask (0/1).

    Returns:
      ``(sum f32[1], count i32[1])`` — partial sum and active-lane count.
    """
    w = width or vals.shape[0]
    del w
    return pl.pallas_call(
        _masked_sum_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ),
        interpret=True,
    )(vals, mask)
