"""``tagged_sum_region`` — fused filter+scale+segmented-sum.

Perf-pass kernel (EXPERIMENTS.md §Perf): the tagged sum app originally
issued two invocations per ensemble (``filter_scale`` then
``segmented_sum``); since each fixed-width invocation costs ~150 µs of
PJRT dispatch regardless of content, fusing them halves the dense
baseline's cost per ensemble. One invocation per ensemble on both sides
of the §5 comparison keeps it honest.

Same TPU adaptation as ``segmented_sum``: the reduction is a one-hot
matmul (MXU-friendly), not a scatter.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .filter_scale import SCALE


def _tagged_sum_region_kernel(v_ref, seg_ref, m_ref, t_ref, s_ref, c_ref):
    v = v_ref[...]
    seg = seg_ref[...]
    m = m_ref[...]
    t = t_ref[0]
    w = v.shape[0]
    good = jnp.logical_and(v > t, m != 0)
    scaled = jnp.where(good, SCALE * v, jnp.float32(0.0))
    seg_ids = jax.lax.broadcasted_iota(jnp.int32, (w, w), 1)
    one_hot = jnp.logical_and(seg[:, None] == seg_ids, good[:, None])
    one_hot_f = one_hot.astype(jnp.float32)
    s_ref[...] = jnp.dot(scaled, one_hot_f, preferred_element_type=jnp.float32)
    c_ref[...] = jnp.sum(one_hot.astype(jnp.int32), axis=0)


@functools.partial(jax.jit, static_argnames=("width",))
def tagged_sum_region(vals, seg, mask, threshold, *, width=None):
    """Fused filter+scale+per-segment-sum over one tagged ensemble.

    Args:
      vals: ``f32[w]`` lane values.
      seg: ``i32[w]`` ensemble-local segment ids in ``[0, w)``.
      mask: ``i32[w]`` active-lane mask (0/1).
      threshold: ``f32[1]`` filter threshold (``v > t`` survives).

    Returns:
      ``(sums f32[w], counts i32[w])`` — per-segment sum of scaled
      survivors and surviving-lane count.
    """
    w = width or vals.shape[0]
    return pl.pallas_call(
        _tagged_sum_region_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((w,), jnp.float32),
            jax.ShapeDtypeStruct((w,), jnp.int32),
        ),
        interpret=True,
    )(vals, seg, mask, threshold)
