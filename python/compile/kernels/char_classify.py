"""``char_classify`` — taxi stage 1: open-brace candidate detection.

The taxi app (paper Sec. 5, DIBS ``tstcsv->csv``) enumerates each text
line's characters and keeps only positions that likely start a
coordinate pair — the ``'{'`` characters. One invocation classifies one
ensemble of characters (passed as their ASCII codes).

Besides the candidate flag the kernel also emits digit/delimiter class
bits, which the tagged taxi variant uses for its per-character work and
which make the "tag every character" overhead of the pure-tagging
baseline honest (Fig. 8, x-series).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: ASCII code of the candidate marker.
OPEN_BRACE = 0x7B  # '{'

_DIGIT_LO, _DIGIT_HI = 0x30, 0x39
_COMMA, _DOT, _MINUS, _CLOSE = 0x2C, 0x2E, 0x2D, 0x7D


def _char_classify_kernel(c_ref, m_ref, f_ref, k_ref):
    c = c_ref[...]
    m = m_ref[...]
    active = m != 0
    is_open = jnp.logical_and(c == OPEN_BRACE, active)
    f_ref[...] = is_open.astype(jnp.int32)
    # class bitmap: 1=digit, 2=dot, 4=comma, 8=minus, 16=close-brace
    is_digit = jnp.logical_and(c >= _DIGIT_LO, c <= _DIGIT_HI)
    k = (
        is_digit.astype(jnp.int32)
        + 2 * (c == _DOT).astype(jnp.int32)
        + 4 * (c == _COMMA).astype(jnp.int32)
        + 8 * (c == _MINUS).astype(jnp.int32)
        + 16 * (c == _CLOSE).astype(jnp.int32)
    )
    k_ref[...] = jnp.where(active, k, 0)


@functools.partial(jax.jit, static_argnames=("width",))
def char_classify(chars, mask, *, width=None):
    """Classify one ensemble of characters.

    Args:
      chars: ``i32[w]`` ASCII codes.
      mask: ``i32[w]`` active-lane mask (0/1).

    Returns:
      ``(is_candidate i32[w], class_bits i32[w])`` — 1 where the lane is
      an active ``'{'``; a small class bitmap for every active lane.
    """
    w = width or chars.shape[0]
    return pl.pallas_call(
        _char_classify_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((w,), jnp.int32),
            jax.ShapeDtypeStruct((w,), jnp.int32),
        ),
        interpret=True,
    )(chars, mask)
