//! Streaming result sinks: the output side of the constant-memory path.
//!
//! `run_stream` collects every output before returning — fine for tests,
//! fatal for out-of-core runs. A [`ResultSink`] instead receives each
//! shard's outputs **incrementally, in stream order** (wired through
//! [`ShardedRunner::run_stream_into`] /
//! [`ShardedRunner::run_stream_with`]), so results land on disk while
//! upstream regions are still being read: end-to-end memory is the
//! ingest budget plus the sink's write buffer.
//!
//! Two encodings ship:
//!
//! * [`JsonlSink`] — one JSON object per record, newline-delimited.
//!   Finite floats are rendered with Rust's shortest-round-trip
//!   formatter, so a parser recovers the exact bits; two runs producing
//!   bit-identical results produce byte-identical files (the
//!   equivalence tests compare the bytes). Non-finite values render as
//!   `null` — `NaN`/`inf` tokens are not legal JSON.
//! * [`BinarySink`] — fixed-size little-endian records behind a small
//!   header (`magic | version | record size`), for downstream tools that
//!   want the raw values back without parsing text.
//!
//! Both reuse one encode buffer across batches (no per-record
//! allocation) and count records/bytes for the [`SinkStats`] returned by
//! [`ResultSink::finish`].
//!
//! File-backed sinks ([`JsonlSink::create`] / [`BinarySink::create`])
//! publish atomically: records stream into `<path>.tmp`
//! ([`super::tmp_path`]) and `finish` renames the flushed file into
//! place — an aborted or faulted run leaves the previous artifact at
//! `path` untouched instead of a half-written replacement. A sink
//! dropped before `finish` published (the run errored or panicked
//! mid-stream) removes its own `.tmp` sibling, so faulted runs leave
//! no stale staging files behind.
//!
//! [`ShardedRunner::run_stream_into`]: crate::exec::ShardedRunner::run_stream_into
//! [`ShardedRunner::run_stream_with`]: crate::exec::ShardedRunner::run_stream_with

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::apps::taxi::TaxiPair;

/// Where results go on a streaming run. Batches arrive in stream order;
/// `finish` flushes and reports totals.
pub trait ResultSink<T> {
    /// Write one shard's outputs (called in stream order, as each
    /// shard's prefix completes).
    fn write_batch(&mut self, outputs: &[T]) -> Result<()>;

    /// Flush buffered bytes and return what was written. Call exactly
    /// once, after the run completes.
    fn finish(&mut self) -> Result<SinkStats>;
}

/// Totals reported by [`ResultSink::finish`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SinkStats {
    /// Records written.
    pub records: u64,
    /// Payload bytes written (headers included).
    pub bytes: u64,
}

/// A record that can render itself as one JSONL line (sans newline).
pub trait JsonRecord {
    /// Append this record's JSON rendering to `line`.
    fn push_json(&self, line: &mut String);
}

/// A record with a fixed-size little-endian binary encoding.
pub trait BinRecord {
    /// Encoded size in bytes (every record identical).
    const RECORD_BYTES: u32;

    /// Append this record's encoding to `out`.
    fn encode(&self, out: &mut Vec<u8>);
}

/// Render one float as a JSON value: Rust's shortest-round-trip `{:?}`
/// for finite values (a parser recovers the exact bits), `null` for the
/// non-finite ones — `NaN`/`inf` tokens are not legal JSON, and a
/// hand-crafted `.rgn` can carry any f32 payload. Kept width-specific
/// so an `f32` prints its own shortest form, not its widened `f64` one.
fn push_json_f64(line: &mut String, v: f64) {
    use std::fmt::Write as _;
    if v.is_finite() {
        let _ = write!(line, "{v:?}");
    } else {
        line.push_str("null");
    }
}

/// [`push_json_f64`], for `f32` records.
fn push_json_f32(line: &mut String, v: f32) {
    use std::fmt::Write as _;
    if v.is_finite() {
        let _ = write!(line, "{v:?}");
    } else {
        line.push_str("null");
    }
}

/// Sum output: `(region id, sum)`.
impl JsonRecord for (u64, f64) {
    fn push_json(&self, line: &mut String) {
        use std::fmt::Write as _;
        let _ = write!(line, "{{\"region\":{},\"sum\":", self.0);
        push_json_f64(line, self.1);
        line.push('}');
    }
}

impl BinRecord for (u64, f64) {
    const RECORD_BYTES: u32 = 16;

    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.0.to_le_bytes());
        out.extend_from_slice(&self.1.to_le_bytes());
    }
}

/// Taxi output: a tagged, swapped coordinate pair.
impl JsonRecord for TaxiPair {
    fn push_json(&self, line: &mut String) {
        use std::fmt::Write as _;
        let _ = write!(line, "{{\"tag\":{},\"x\":", self.tag);
        push_json_f32(line, self.x);
        line.push_str(",\"y\":");
        push_json_f32(line, self.y);
        line.push('}');
    }
}

impl BinRecord for TaxiPair {
    const RECORD_BYTES: u32 = 12;

    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.tag.to_le_bytes());
        out.extend_from_slice(&self.x.to_le_bytes());
        out.extend_from_slice(&self.y.to_le_bytes());
    }
}

/// `(tmp, final)` publication state for a file-backed sink. While the
/// pair is live the sink is still staging into `<path>.tmp`; dropping
/// the guard before `finish` published it removes the unpublished tmp
/// (mirroring `write_rgn_file`'s error path), so a run that errors or
/// panics mid-stream never leaves a stale `.tmp` sibling behind.
#[derive(Default)]
struct PublishGuard(Option<(PathBuf, PathBuf)>);

impl Drop for PublishGuard {
    fn drop(&mut self) {
        if let Some((tmp, _)) = self.0.take() {
            let _ = std::fs::remove_file(&tmp);
        }
    }
}

/// Rename a finished `.tmp` sink file over its final name.
fn publish_sink(publish: &mut PublishGuard) -> Result<()> {
    if let Some((tmp, path)) = publish.0.take() {
        std::fs::rename(&tmp, &path).with_context(|| {
            format!("publishing {} as {}", tmp.display(), path.display())
        })?;
    }
    Ok(())
}

/// Newline-delimited JSON over any writer.
pub struct JsonlSink<W: Write> {
    out: W,
    /// Reusable line buffer.
    line: String,
    /// `(tmp, final)` for file sinks: rename on `finish`, remove the
    /// tmp on drop if never published. Declared after `out` so the
    /// writer flushes and closes before the guard touches the file.
    publish: PublishGuard,
    records: u64,
    bytes: u64,
}

impl JsonlSink<BufWriter<File>> {
    /// Create a `.jsonl` file sink. Records stream into `<path>.tmp`;
    /// `finish` renames it to `path`, so the final name only ever holds
    /// a complete, flushed file.
    pub fn create(path: impl AsRef<Path>) -> Result<JsonlSink<BufWriter<File>>> {
        let path = path.as_ref();
        let tmp = super::tmp_path(path);
        let file = File::create(&tmp)
            .with_context(|| format!("creating result file {}", tmp.display()))?;
        let mut sink = JsonlSink::new(BufWriter::new(file));
        sink.publish = PublishGuard(Some((tmp, path.to_path_buf())));
        Ok(sink)
    }
}

impl<W: Write> JsonlSink<W> {
    /// Create a sink writing JSONL to `out`.
    pub fn new(out: W) -> JsonlSink<W> {
        JsonlSink {
            out,
            line: String::new(),
            publish: PublishGuard::default(),
            records: 0,
            bytes: 0,
        }
    }

    /// Unwrap the underlying writer (after `finish`).
    pub fn into_inner(self) -> W {
        self.out
    }
}

impl<W: Write, T: JsonRecord> ResultSink<T> for JsonlSink<W> {
    fn write_batch(&mut self, outputs: &[T]) -> Result<()> {
        for r in outputs {
            self.line.clear();
            r.push_json(&mut self.line);
            self.line.push('\n');
            self.out
                .write_all(self.line.as_bytes())
                .context("writing JSONL record")?;
            self.records += 1;
            self.bytes += self.line.len() as u64;
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<SinkStats> {
        self.out.flush().context("flushing JSONL sink")?;
        publish_sink(&mut self.publish)?;
        Ok(SinkStats {
            records: self.records,
            bytes: self.bytes,
        })
    }
}

/// Magic opening a binary result file.
pub const RESULT_MAGIC: [u8; 8] = *b"RGNRES.1";

/// Binary result-file format version.
pub const RESULT_VERSION: u32 = 1;

/// Fixed-size binary records over any writer. Layout:
/// `magic "RGNRES.1" | version u32 | record_bytes u32 | records…`
/// (header written lazily with the first batch, so `record_bytes` can
/// come from the record type actually sunk).
pub struct BinarySink<W: Write> {
    out: W,
    buf: Vec<u8>,
    header_written: bool,
    /// `(tmp, final)` for file sinks: rename on `finish`, remove the
    /// tmp on drop if never published. Declared after `out` so the
    /// writer flushes and closes before the guard touches the file.
    publish: PublishGuard,
    records: u64,
    bytes: u64,
}

impl BinarySink<BufWriter<File>> {
    /// Create a binary result file sink. Records stream into
    /// `<path>.tmp`; `finish` renames it to `path`, so the final name
    /// only ever holds a complete, flushed file.
    pub fn create(path: impl AsRef<Path>) -> Result<BinarySink<BufWriter<File>>> {
        let path = path.as_ref();
        let tmp = super::tmp_path(path);
        let file = File::create(&tmp)
            .with_context(|| format!("creating result file {}", tmp.display()))?;
        let mut sink = BinarySink::new(BufWriter::new(file));
        sink.publish = PublishGuard(Some((tmp, path.to_path_buf())));
        Ok(sink)
    }
}

impl<W: Write> BinarySink<W> {
    /// Create a sink writing the binary format to `out`.
    pub fn new(out: W) -> BinarySink<W> {
        BinarySink {
            out,
            buf: Vec::new(),
            header_written: false,
            publish: PublishGuard::default(),
            records: 0,
            bytes: 0,
        }
    }

    /// Unwrap the underlying writer (after `finish`).
    pub fn into_inner(self) -> W {
        self.out
    }

    fn write_header(&mut self, record_bytes: u32) -> Result<()> {
        if self.header_written {
            return Ok(());
        }
        let mut head = [0u8; 16];
        head[..8].copy_from_slice(&RESULT_MAGIC);
        head[8..12].copy_from_slice(&RESULT_VERSION.to_le_bytes());
        head[12..16].copy_from_slice(&record_bytes.to_le_bytes());
        self.out.write_all(&head).context("writing binary result header")?;
        self.header_written = true;
        self.bytes += head.len() as u64;
        Ok(())
    }
}

impl<W: Write, T: BinRecord> ResultSink<T> for BinarySink<W> {
    fn write_batch(&mut self, outputs: &[T]) -> Result<()> {
        self.write_header(T::RECORD_BYTES)?;
        self.buf.clear();
        for r in outputs {
            r.encode(&mut self.buf);
        }
        self.out
            .write_all(&self.buf)
            .context("writing binary result batch")?;
        self.records += outputs.len() as u64;
        self.bytes += self.buf.len() as u64;
        Ok(())
    }

    fn finish(&mut self) -> Result<SinkStats> {
        // an empty run still gets a well-formed header
        self.write_header(T::RECORD_BYTES)?;
        self.out.flush().context("flushing binary sink")?;
        publish_sink(&mut self.publish)?;
        Ok(SinkStats {
            records: self.records,
            bytes: self.bytes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_renders_one_record_per_line() {
        let mut sink = JsonlSink::new(Vec::new());
        ResultSink::<(u64, f64)>::write_batch(&mut sink, &[(0, 1.5), (1, -0.25)]).unwrap();
        ResultSink::<(u64, f64)>::write_batch(&mut sink, &[(2, 3.0)]).unwrap();
        let stats = ResultSink::<(u64, f64)>::finish(&mut sink).unwrap();
        assert_eq!(stats.records, 3);
        let text = String::from_utf8(sink.out).unwrap();
        assert_eq!(
            text,
            "{\"region\":0,\"sum\":1.5}\n{\"region\":1,\"sum\":-0.25}\n\
             {\"region\":2,\"sum\":3.0}\n"
        );
        assert_eq!(stats.bytes as usize, text.len());
    }

    #[test]
    fn jsonl_non_finite_floats_render_as_null() {
        let mut sink = JsonlSink::new(Vec::new());
        sink.write_batch(&[(0u64, f64::NAN), (1, f64::INFINITY)]).unwrap();
        sink.write_batch(&[TaxiPair {
            tag: 2,
            x: f32::NEG_INFINITY,
            y: 1.5,
        }])
        .unwrap();
        let text = String::from_utf8(sink.out).unwrap();
        assert_eq!(
            text,
            "{\"region\":0,\"sum\":null}\n{\"region\":1,\"sum\":null}\n\
             {\"tag\":2,\"x\":null,\"y\":1.5}\n"
        );
    }

    #[test]
    fn jsonl_floats_round_trip_bits() {
        let vals = [0.1f64, 1.0 / 3.0, f64::MIN_POSITIVE, -1e300];
        let mut sink = JsonlSink::new(Vec::new());
        let recs: Vec<(u64, f64)> = vals.iter().enumerate().map(|(i, &v)| (i as u64, v)).collect();
        sink.write_batch(&recs).unwrap();
        let text = String::from_utf8(sink.out).unwrap();
        for (line, &want) in text.lines().zip(&vals) {
            let num = line.split("\"sum\":").nth(1).unwrap().trim_end_matches('}');
            let got: f64 = num.parse().unwrap();
            assert_eq!(got.to_bits(), want.to_bits(), "{line}");
        }
    }

    #[test]
    fn binary_header_and_records_decode() {
        let mut sink = BinarySink::new(Vec::new());
        let pairs = [
            TaxiPair {
                tag: 3,
                x: 1.5,
                y: -2.25,
            },
            TaxiPair {
                tag: 9,
                x: 0.0,
                y: 7.0,
            },
        ];
        sink.write_batch(&pairs).unwrap();
        let stats = ResultSink::<TaxiPair>::finish(&mut sink).unwrap();
        assert_eq!(stats.records, 2);
        let bytes = sink.out;
        assert_eq!(&bytes[..8], b"RGNRES.1");
        assert_eq!(
            u32::from_le_bytes(bytes[12..16].try_into().unwrap()),
            TaxiPair::RECORD_BYTES
        );
        assert_eq!(bytes.len(), 16 + 2 * TaxiPair::RECORD_BYTES as usize);
        let tag = u32::from_le_bytes(bytes[16..20].try_into().unwrap());
        let x = f32::from_le_bytes(bytes[20..24].try_into().unwrap());
        assert_eq!((tag, x.to_bits()), (3, 1.5f32.to_bits()));
    }

    #[test]
    fn empty_binary_run_still_writes_a_header() {
        let mut sink = BinarySink::new(Vec::new());
        let stats = ResultSink::<(u64, f64)>::finish(&mut sink).unwrap();
        assert_eq!(stats.records, 0);
        assert_eq!(sink.out.len(), 16);
    }

    #[test]
    fn file_sinks_publish_only_on_finish() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("regatta_sink_atomic_{}.jsonl", std::process::id()));
        let tmp = crate::io::tmp_path(&path);
        let _ = std::fs::remove_file(&path);
        let mut sink = JsonlSink::create(&path).unwrap();
        sink.write_batch(&[(0u64, 1.5f64)]).unwrap();
        assert!(tmp.exists(), "records stream into the .tmp sibling");
        assert!(!path.exists(), "final name untouched before finish");
        let stats = ResultSink::<(u64, f64)>::finish(&mut sink).unwrap();
        assert_eq!(stats.records, 1);
        assert!(path.exists(), "finish renames into place");
        assert!(!tmp.exists(), "no stale .tmp after publish");
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "{\"region\":0,\"sum\":1.5}\n");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn dropped_jsonl_sink_removes_its_unpublished_tmp() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("regatta_sink_drop_{}.jsonl", std::process::id()));
        let tmp = crate::io::tmp_path(&path);
        let _ = std::fs::remove_file(&path);
        {
            let mut sink = JsonlSink::create(&path).unwrap();
            sink.write_batch(&[(0u64, 1.5f64)]).unwrap();
            assert!(tmp.exists(), "records staged into the .tmp sibling");
            // dropped without finish: the faulted-run path
        }
        assert!(!tmp.exists(), "drop removes the unpublished tmp");
        assert!(!path.exists(), "final name never appears");
    }

    #[test]
    fn dropped_binary_sink_removes_its_unpublished_tmp() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("regatta_sink_drop_{}.bin", std::process::id()));
        let tmp = crate::io::tmp_path(&path);
        let _ = std::fs::remove_file(&path);
        {
            let mut sink = BinarySink::create(&path).unwrap();
            sink.write_batch(&[(0u64, 1.5f64)]).unwrap();
            assert!(tmp.exists(), "records staged into the .tmp sibling");
        }
        assert!(!tmp.exists(), "drop removes the unpublished tmp");
        assert!(!path.exists(), "final name never appears");
    }

    #[test]
    fn finished_sink_drop_leaves_the_published_file_alone() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("regatta_sink_pub_drop_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let mut sink = JsonlSink::create(&path).unwrap();
            sink.write_batch(&[(0u64, 1.5f64)]).unwrap();
            ResultSink::<(u64, f64)>::finish(&mut sink).unwrap();
        }
        assert!(path.exists(), "published artifact survives the drop");
        std::fs::remove_file(&path).unwrap();
    }
}
