//! Line-delimited taxi text files as a [`RegionSource`].
//!
//! The taxi app's regions are *lines*: `T<tag>,{lat,lon},…,<filler>`
//! records keyed by their numeric tag (see [`crate::workload::taxi`]).
//! [`TextSource`] scans a text buffer incrementally and yields one
//! [`TaxiLine`] region per record — start offset, length and the parsed
//! tag key — without ever materializing a line index: index memory is
//! bounded by the executor's ingest budget, not by how many lines the
//! file holds.
//!
//! The raw text itself is loaded once into a shared `Arc<Vec<u8>>` and
//! stays resident for the whole run: it models the paper's device-side
//! input buffer, which every worker processor views (each emitted
//! `TaxiLine` is a `(start, len)` window into it — a few words of index
//! per in-flight region, whatever the line length).
//!
//! Malformed records — a line that does not open with the `T<digits>,`
//! key — are **named errors** carrying the line number, stashed for
//! [`RegionSource::close`] exactly like [`BlobFileSource`]'s I/O errors,
//! so `run_stream*` aborts with the cause instead of silently skipping
//! data.
//!
//! [`BlobFileSource`]: super::blob::BlobFileSource

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::workload::source::RegionSource;
use crate::workload::taxi::TaxiLine;

/// Streaming line scanner over a shared taxi text buffer.
pub struct TextSource {
    text: Arc<Vec<u8>>,
    /// Next unscanned byte.
    pos: usize,
    /// 1-based line number of the next record (for error messages).
    line_no: u64,
    /// Where the bytes came from, for error messages.
    label: String,
    /// A failure ends the stream permanently (reported once).
    failed: bool,
    error: Option<anyhow::Error>,
}

impl TextSource {
    /// Load a taxi text file and stream its records.
    pub fn open(path: impl AsRef<Path>) -> Result<TextSource> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading taxi text file {}", path.display()))?;
        Ok(TextSource::from_text(
            Arc::new(bytes),
            path.display().to_string(),
        ))
    }

    /// Stream records out of an in-memory buffer (tests, generated
    /// workloads). `label` names the source in errors.
    pub fn from_text(text: Arc<Vec<u8>>, label: impl Into<String>) -> TextSource {
        TextSource {
            text,
            pos: 0,
            line_no: 1,
            label: label.into(),
            failed: false,
            error: None,
        }
    }

    /// The shared text buffer — hand this to
    /// [`TaxiFactory`](crate::apps::taxi::TaxiFactory) /
    /// [`TaxiApp::run_streaming`](crate::apps::taxi::TaxiApp::run_streaming)
    /// so workers parse the same bytes the source indexes.
    pub fn text(&self) -> Arc<Vec<u8>> {
        self.text.clone()
    }

    /// Fallible pull (named errors surface here immediately; the
    /// [`RegionSource`] impl stashes them for `close`).
    pub fn try_next(&mut self) -> Result<Option<TaxiLine>> {
        if self.failed || self.error.is_some() {
            return Ok(None);
        }
        let bytes: &[u8] = &self.text;
        if self.pos >= bytes.len() {
            return Ok(None);
        }
        let start = self.pos;
        let len = match bytes[start..].iter().position(|&b| b == b'\n') {
            Some(n) => n,
            None => bytes.len() - start, // final record without a newline
        };
        self.pos = start + len + 1;
        let record = &bytes[start..start + len];
        let Some(tag) = parse_record_key(record) else {
            self.failed = true;
            bail!(
                "{}: malformed taxi record at line {}: expected a `T<digits>,` key, \
                 got {:?}",
                self.label,
                self.line_no,
                String::from_utf8_lossy(&record[..record.len().min(16)])
            );
        };
        self.line_no += 1;
        Ok(Some(TaxiLine {
            text: self.text.clone(),
            start,
            len,
            tag,
        }))
    }
}

/// Parse the `T<digits>,` record key, or `None` if the head is malformed
/// (empty line, missing `T`, no digits, no separator).
fn parse_record_key(record: &[u8]) -> Option<u32> {
    let rest = record.strip_prefix(b"T")?;
    let digits = rest.iter().take_while(|b| b.is_ascii_digit()).count();
    if digits == 0 || rest.get(digits) != Some(&b',') {
        return None;
    }
    std::str::from_utf8(&rest[..digits]).ok()?.parse().ok()
}

impl RegionSource for TextSource {
    type Region = TaxiLine;

    fn next_region(&mut self) -> Option<TaxiLine> {
        match self.try_next() {
            Ok(line) => line,
            Err(e) => {
                self.error = Some(e);
                None
            }
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        // lines average >1 byte, so remaining bytes is a safe upper bound
        (0, Some(self.text.len().saturating_sub(self.pos)))
    }

    fn close(&mut self) -> Result<()> {
        match self.error.take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// Write a workload's text to `path`, repeated `reps` times (the paper
/// scales the DIBS input by replicating the file) — the `regatta gen
/// taxi` entry point. Returns total bytes written. `reps = 0` is a
/// named error, not a silent clamp (same convention as the executor's
/// zero-budget validation).
pub fn write_taxi_file(path: impl AsRef<Path>, text: &[u8], reps: usize) -> Result<u64> {
    use std::io::Write;
    anyhow::ensure!(
        reps >= 1,
        "taxi file replication count = 0 (need at least one replica; \
         pass --replicate >= 1)"
    );
    let path = path.as_ref();
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating taxi text file {}", path.display()))?;
    let mut out = std::io::BufWriter::new(file);
    for _ in 0..reps {
        out.write_all(text)
            .with_context(|| format!("writing {}", path.display()))?;
    }
    out.flush().with_context(|| format!("flushing {}", path.display()))?;
    Ok((text.len() * reps) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::taxi::{generate, parse_tag, TaxiGenConfig};

    fn drain(src: &mut TextSource) -> Result<Vec<TaxiLine>> {
        let mut out = Vec::new();
        while let Some(l) = src.try_next()? {
            out.push(l);
        }
        Ok(out)
    }

    #[test]
    fn scans_generated_workload_identically() {
        let w = generate(
            8,
            TaxiGenConfig {
                avg_pairs: 4,
                avg_line_len: 80,
            },
            11,
        );
        let mut src = TextSource::from_text(w.text.clone(), "<mem>");
        let lines = drain(&mut src).unwrap();
        assert_eq!(lines.len(), w.lines.len());
        for (got, want) in lines.iter().zip(&w.lines) {
            assert_eq!(got.start, want.start);
            assert_eq!(got.len, want.len);
            assert_eq!(got.tag, want.tag);
            assert_eq!(got.bytes(), want.bytes());
            assert_eq!(parse_tag(got), got.tag);
        }
        assert!(src.try_next().unwrap().is_none(), "stays exhausted");
    }

    #[test]
    fn final_record_without_newline_is_kept() {
        let text = Arc::new(b"T0,{1.0,2.0},x\nT1,{3.0,4.0},y".to_vec());
        let mut src = TextSource::from_text(text, "<mem>");
        let lines = drain(&mut src).unwrap();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[1].tag, 1);
        assert_eq!(lines[1].bytes(), b"T1,{3.0,4.0},y");
    }

    #[test]
    fn malformed_record_is_a_named_error_with_line_number() {
        for bad in ["X0,oops\n", "T,missing-digits\n", "Tabc,\n", "\n"] {
            let text = Arc::new(format!("T0,{{1.0,2.0}},x\n{bad}").into_bytes());
            let mut src = TextSource::from_text(text, "<mem>");
            assert!(src.try_next().unwrap().is_some(), "first record parses");
            let err = src.try_next().unwrap_err().to_string();
            assert!(err.contains("line 2"), "{bad:?}: {err}");
            assert!(err.contains("malformed taxi record"), "{bad:?}: {err}");
        }
    }

    #[test]
    fn region_source_stashes_errors_for_close() {
        let text = Arc::new(b"not-a-record\n".to_vec());
        let mut src = TextSource::from_text(text, "<mem>");
        assert!(src.next_region().is_none());
        let err = src.close().unwrap_err();
        assert!(err.to_string().contains("malformed"), "{err}");
        assert!(src.close().is_ok(), "error is reported once");
    }

    #[test]
    fn empty_text_is_an_empty_stream() {
        let mut src = TextSource::from_text(Arc::new(Vec::new()), "<mem>");
        assert!(drain(&mut src).unwrap().is_empty());
        assert!(src.close().is_ok());
    }

    #[test]
    fn zero_replication_is_a_named_error_not_a_clamp() {
        // the ensure fires before the file is created — nothing to clean up
        let path = std::env::temp_dir().join("regatta_test_zero_reps.txt");
        let err = write_taxi_file(&path, b"T0,{1.0,2.0}\n", 0).unwrap_err();
        assert!(err.to_string().contains("replication count = 0"), "{err}");
        assert!(!path.exists());
    }
}
