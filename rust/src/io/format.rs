//! The `.rgn` on-disk region container format (byte-level spec).
//!
//! A `.rgn` file is a header, a sequence of length-prefixed **region
//! frames**, and a footer. All integers are little-endian; the layout is
//! deliberately trivial so any language can read it:
//!
//! ```text
//! header:  magic "RGNBLOB1" (8) | version u32 | payload u32
//! frame:   len u32 | checksum u64 | payload[len]
//!          payload = region id u64 | count u32 | count × f32
//! footer:  sentinel u32 = 0xFFFF_FFFF | magic "RGNEND.1" (8)
//!          | regions u64 | items u64 | checksum u64
//! ```
//!
//! * `len` is the frame payload size in bytes, so a reader skips or
//!   streams frames through one reusable buffer without knowing the
//!   payload schema. The footer is recognized by the `len` sentinel
//!   (`u32::MAX`), which no real frame can carry — a payload always holds
//!   at least the 12-byte `id + count` head and is capped far below it by
//!   [`MAX_FRAME_BYTES`].
//! * Every frame carries an FNV-1a 64 checksum of its payload; the footer
//!   checksums its own `magic | regions | items` bytes. A flipped bit
//!   anywhere is reported as a **named error** (file, frame index,
//!   expected/actual), never a panic or a garbage region.
//! * The footer's `regions`/`items` totals let a reader prove it saw the
//!   whole stream: hitting EOF before the footer is a *truncation* error,
//!   and totals that disagree with the frames actually read are a
//!   *mismatch* error.
//! * The length-prefix chain doubles as a **salvage skeleton**: because
//!   each intact `len` says exactly where the next frame begins, a reader
//!   that finds a bad payload checksum is still positioned correctly to
//!   continue — [`CorruptFramePolicy::Skip`](super::CorruptFramePolicy)
//!   drops exactly the damaged frame(s) and reconciles the footer on
//!   region count. Only damage to the skeleton itself (a corrupted
//!   length, a missing footer) is unsalvageable by design.
//!
//! This module holds the constants and the checksum; the writer/reader
//! live in [`super::blob`].

/// File magic opening every `.rgn` container.
pub const MAGIC: [u8; 8] = *b"RGNBLOB1";

/// Footer magic, after the frame-length sentinel.
pub const FOOTER_MAGIC: [u8; 8] = *b"RGNEND.1";

/// Format version written (and the only one accepted) by this crate.
pub const VERSION: u32 = 1;

/// Payload schema id: `Blob` regions — `id u64 | count u32 | count × f32`.
pub const PAYLOAD_BLOB_F32: u32 = 1;

/// Frame-length sentinel marking the footer record.
pub const FOOTER_SENTINEL: u32 = u32::MAX;

/// Sanity cap on a single frame's payload bytes: a `len` beyond this is
/// treated as corruption (a real region would be gigabytes), so a flipped
/// length byte fails fast instead of attempting an absurd allocation.
pub const MAX_FRAME_BYTES: u32 = 1 << 30;

/// Bytes in the fixed header.
pub const HEADER_BYTES: usize = 16;

/// Minimum frame payload: `id u64 + count u32`.
pub const FRAME_HEAD_BYTES: usize = 12;

/// FNV-1a 64-bit over `bytes` — the per-frame checksum. Not
/// cryptographic; it exists to catch truncation, bit rot and torn writes
/// with zero dependencies and one multiply per byte.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Render the 16-byte header.
pub fn encode_header() -> [u8; HEADER_BYTES] {
    let mut out = [0u8; HEADER_BYTES];
    out[..8].copy_from_slice(&MAGIC);
    out[8..12].copy_from_slice(&VERSION.to_le_bytes());
    out[12..16].copy_from_slice(&PAYLOAD_BLOB_F32.to_le_bytes());
    out
}

/// Footer body (everything after the sentinel): magic, totals, checksum.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Footer {
    /// Region frames in the file.
    pub regions: u64,
    /// Total elements across all regions.
    pub items: u64,
}

/// Bytes in the footer body (after the 4-byte sentinel).
pub const FOOTER_BODY_BYTES: usize = 32;

impl Footer {
    /// Render sentinel + body (the full on-disk footer record).
    pub fn encode(&self) -> [u8; 4 + FOOTER_BODY_BYTES] {
        let mut out = [0u8; 4 + FOOTER_BODY_BYTES];
        out[..4].copy_from_slice(&FOOTER_SENTINEL.to_le_bytes());
        out[4..12].copy_from_slice(&FOOTER_MAGIC);
        out[12..20].copy_from_slice(&self.regions.to_le_bytes());
        out[20..28].copy_from_slice(&self.items.to_le_bytes());
        let sum = fnv1a64(&out[4..28]);
        out[28..36].copy_from_slice(&sum.to_le_bytes());
        out
    }

    /// Parse and verify a footer body (the 32 bytes after the sentinel).
    /// Returns `None` if the magic or checksum is wrong.
    pub fn decode(body: &[u8; FOOTER_BODY_BYTES]) -> Option<Footer> {
        if body[..8] != FOOTER_MAGIC {
            return None;
        }
        let stored = u64::from_le_bytes(body[24..32].try_into().expect("8 bytes"));
        if fnv1a64(&body[..24]) != stored {
            return None;
        }
        Some(Footer {
            regions: u64::from_le_bytes(body[8..16].try_into().expect("8 bytes")),
            items: u64::from_le_bytes(body[16..24].try_into().expect("8 bytes")),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_matches_reference_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn header_layout_is_stable() {
        let h = encode_header();
        assert_eq!(&h[..8], b"RGNBLOB1");
        assert_eq!(u32::from_le_bytes(h[8..12].try_into().unwrap()), VERSION);
        assert_eq!(
            u32::from_le_bytes(h[12..16].try_into().unwrap()),
            PAYLOAD_BLOB_F32
        );
    }

    #[test]
    fn footer_round_trips() {
        let f = Footer {
            regions: 12345,
            items: 987654321,
        };
        let enc = f.encode();
        assert_eq!(
            u32::from_le_bytes(enc[..4].try_into().unwrap()),
            FOOTER_SENTINEL
        );
        let body: [u8; FOOTER_BODY_BYTES] = enc[4..].try_into().unwrap();
        assert_eq!(Footer::decode(&body), Some(f));
    }

    #[test]
    fn footer_rejects_corruption() {
        let enc = Footer {
            regions: 7,
            items: 70,
        }
        .encode();
        for flip in [4usize, 13, 21, 29] {
            let mut bad = enc;
            bad[flip] ^= 0x40;
            let body: [u8; FOOTER_BODY_BYTES] = bad[4..].try_into().unwrap();
            assert_eq!(Footer::decode(&body), None, "flip at byte {flip}");
        }
    }
}
