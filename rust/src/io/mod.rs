//! Out-of-core region storage: on-disk containers, file-backed
//! [`RegionSource`]s, and streaming result sinks.
//!
//! The paper's streams are "massive data sets" that never fit in memory;
//! PR 3 built the executor half of that story (bounded-budget streaming
//! ingest, backpressure, ordered merge) but every run still synthesized
//! its regions in-process. This module is the other half — real readers
//! and writers — closing the constant-memory loop end to end:
//!
//! ```text
//!   .rgn file ─ BlobFileSource ─┐                 ┌─ JsonlSink ─ .jsonl
//!   taxi text ─ TextSource ─────┤ run_stream_into ├─ BinarySink ─ .bin
//!   generator ─ GenBlobSource ──┘ (bounded budget)└─ any ResultSink
//! ```
//!
//! * [`format`] — the `.rgn` byte layout: magic + versioned header,
//!   length-prefixed region frames with per-frame FNV-1a checksums, and
//!   a footer carrying region/item totals so a reader can prove it saw
//!   the whole stream. Truncation and corruption are named errors.
//! * [`blob`] — [`BlobWriter`] (serialize any `RegionSource` of
//!   [`Blob`](crate::coordinator::enumerate::Blob)s; `regatta gen sum`)
//!   and [`BlobFileSource`] (stream a `.rgn` back through one reusable
//!   frame buffer + pool-recycled element containers — steady-state
//!   reads allocate nothing per region).
//! * [`text`] — [`TextSource`]: line-delimited taxi records keyed by
//!   their `T<digits>` tag, scanned incrementally over the shared text
//!   buffer.
//! * [`sink`] — [`ResultSink`] with [`JsonlSink`] and [`BinarySink`],
//!   fed in stream order by
//!   [`ShardedRunner::run_stream_into`](crate::exec::ShardedRunner::run_stream_into).
//!
//! Every file-producing path here is **atomic at the final name**: bytes
//! land in a `<path>.tmp` sibling ([`tmp_path`]) and are renamed into
//! place only after the footer is flushed, so readers never observe a
//! half-written container. Corruption that slips past that (bit rot, a
//! foreign writer) is caught per frame by checksum; `regatta rgn verify`
//! ([`verify_rgn_file`]) audits a container end to end, and readers can
//! opt into salvage with [`CorruptFramePolicy::Skip`].
//!
//! The memory invariant (proved in `rust/tests/io_memory.rs` with the
//! counting allocator): driver-side allocations while streaming a `.rgn`
//! file are governed by the ingest budget, not file size — a 100× larger
//! file adds no measurable driver allocations. Round-trip bit-identity
//! (write → read → run ≡ in-memory run, workers 1–8) is pinned by
//! `rust/tests/io_roundtrip.rs`; see EXPERIMENTS.md §IO for how to
//! regenerate the `BENCH_io.json` throughput artifact.
//!
//! [`RegionSource`]: crate::workload::source::RegionSource

pub mod blob;
pub mod format;
pub mod sink;
pub mod text;

pub use blob::{
    corrupt_frame, peek_rgn_footer, read_rgn_file, verify_rgn_file, write_rgn_file,
    BlobFileSource, BlobStats, BlobWriter, CorruptFramePolicy, VerifyReport,
};
pub use format::Footer;
pub use sink::{BinRecord, BinarySink, JsonRecord, JsonlSink, ResultSink, SinkStats};
pub use text::{write_taxi_file, TextSource};

/// The temporary sibling a file-producing path writes before renaming
/// into place: `<path>.tmp` (extension appended, not replaced, so
/// `out.rgn` publishes from `out.rgn.tmp`).
pub fn tmp_path(path: &std::path::Path) -> std::path::PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    std::path::PathBuf::from(os)
}
