//! `.rgn` writer and file-backed region source.
//!
//! [`BlobWriter`] serializes **any** [`RegionSource`] of [`Blob`] regions
//! (the lazy [`GenBlobSource`](crate::workload::regions::GenBlobSource),
//! a slice replay, another file…) into the container format specified in
//! [`super::format`], streaming: one region in memory at a time, totals
//! accumulated into the footer.
//!
//! [`BlobFileSource`] is the reading half: a [`RegionSource`] over a
//! `.rgn` file (or any `Read`), pulling one frame at a time through a
//! **reusable** payload buffer, with element containers recycled through
//! the executor's [`ContainerPool`] — so steady-state reads perform no
//! per-region heap allocation and driver-side memory is governed by the
//! ingest budget, never by file size (`rust/tests/io_memory.rs` proves
//! this with the counting allocator).
//!
//! I/O errors and corruption cannot surface through
//! [`RegionSource::next_region`] (it returns a bare `Option`), so the
//! source stashes the first failure and ends the stream; the executor
//! calls [`RegionSource::close`] after draining and the stashed error —
//! named with file, frame index and cause — propagates out of
//! `run_stream*`. Direct users can call [`BlobFileSource::try_next`]
//! instead and see errors immediately.
//!
//! ## Salvage
//!
//! Under [`CorruptFramePolicy::Skip`] a checksum or element-count
//! mismatch no longer kills the stream: the frame is dropped and
//! counted ([`BlobFileSource::skipped`]) and reading resumes at the next
//! length prefix. Resync is bounded by the length-prefix chain — each
//! intact prefix says exactly where the next frame starts, so one
//! flipped payload byte costs exactly one region. A corrupted *prefix*
//! cannot be resynced from (the chain itself is broken): absurd lengths,
//! truncation, a bad header and a lying footer stay hard errors under
//! either policy. The footer cross-check is relaxed to
//! `footer.regions == read + skipped` so a salvaged file still
//! reconciles end to end. `regatta rgn verify` drives the same walk via
//! [`verify_rgn_file`].

use std::fs::File;
use std::io::{BufReader, BufWriter, ErrorKind, Read, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use super::format::{
    encode_header, fnv1a64, Footer, FOOTER_BODY_BYTES, FOOTER_SENTINEL, FRAME_HEAD_BYTES,
    HEADER_BYTES, MAGIC, MAX_FRAME_BYTES, PAYLOAD_BLOB_F32, VERSION,
};
use crate::coordinator::enumerate::Blob;
use crate::exec::ingest::ContainerPool;
use crate::workload::source::RegionSource;

/// What a completed write (or a fully validated read) covered.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlobStats {
    /// Region frames written/read.
    pub regions: u64,
    /// Total elements across all regions.
    pub items: u64,
    /// Container bytes, header and footer included.
    pub bytes: u64,
}

/// Streaming `.rgn` writer over any [`Write`].
///
/// `new` emits the header; [`BlobWriter::write_region`] appends one
/// checksummed frame through a reusable encode buffer;
/// [`BlobWriter::finish`] appends the footer and returns the totals.
/// Dropping a writer without `finish` leaves a truncated container —
/// which readers then reject by name, so a crashed producer cannot pass
/// for a complete stream.
pub struct BlobWriter<W: Write> {
    out: W,
    frame: Vec<u8>,
    regions: u64,
    items: u64,
    bytes: u64,
}

impl<W: Write> BlobWriter<W> {
    /// Start a container: writes the header immediately.
    pub fn new(mut out: W) -> Result<BlobWriter<W>> {
        out.write_all(&encode_header()).context("writing .rgn header")?;
        Ok(BlobWriter {
            out,
            frame: Vec::new(),
            regions: 0,
            items: 0,
            bytes: HEADER_BYTES as u64,
        })
    }

    /// Append one region as a checksummed frame.
    pub fn write_region(&mut self, blob: &Blob) -> Result<()> {
        let payload = FRAME_HEAD_BYTES + 4 * blob.elems.len();
        ensure!(
            payload <= MAX_FRAME_BYTES as usize,
            "region {} too large for a .rgn frame: {payload} bytes (cap {MAX_FRAME_BYTES})",
            blob.id
        );
        self.frame.clear();
        self.frame.extend_from_slice(&blob.id.to_le_bytes());
        self.frame.extend_from_slice(&(blob.elems.len() as u32).to_le_bytes());
        for &v in &blob.elems {
            self.frame.extend_from_slice(&v.to_le_bytes());
        }
        let sum = fnv1a64(&self.frame);
        let frame_index = self.regions;
        let write = |out: &mut W, frame: &[u8]| -> std::io::Result<()> {
            out.write_all(&(payload as u32).to_le_bytes())?;
            out.write_all(&sum.to_le_bytes())?;
            out.write_all(frame)
        };
        write(&mut self.out, &self.frame)
            .with_context(|| format!("writing .rgn frame {frame_index}"))?;
        self.regions += 1;
        self.items += blob.elems.len() as u64;
        self.bytes += (4 + 8 + payload) as u64;
        Ok(())
    }

    /// Drain `source` into the container (regions stay in stream order).
    pub fn write_source<S>(&mut self, mut source: S) -> Result<()>
    where
        S: RegionSource<Region = Blob>,
    {
        while let Some(blob) = source.next_region() {
            self.write_region(&blob)?;
        }
        source.close().context("region source failed while writing .rgn")
    }

    /// Append the footer, flush, and return the totals.
    pub fn finish(mut self) -> Result<BlobStats> {
        let footer = Footer {
            regions: self.regions,
            items: self.items,
        };
        self.out.write_all(&footer.encode()).context("writing .rgn footer")?;
        self.out.flush().context("flushing .rgn output")?;
        Ok(BlobStats {
            regions: self.regions,
            items: self.items,
            bytes: self.bytes + 4 + FOOTER_BODY_BYTES as u64,
        })
    }
}

/// Materialize `source` into a `.rgn` file at `path` (the `regatta gen`
/// entry point).
///
/// The write is atomic with respect to the final name: bytes land in
/// `<path>.tmp` and are renamed over `path` only after the footer is
/// flushed, so a crash or error mid-write can never leave a truncated
/// container at the published path (the stale `.tmp` is removed on a
/// best-effort basis).
pub fn write_rgn_file<S>(path: impl AsRef<Path>, source: S) -> Result<BlobStats>
where
    S: RegionSource<Region = Blob>,
{
    let path = path.as_ref();
    let tmp = super::tmp_path(path);
    let result = (|| {
        let file = File::create(&tmp)
            .with_context(|| format!("creating .rgn file {}", tmp.display()))?;
        let mut writer = BlobWriter::new(BufWriter::new(file))?;
        writer
            .write_source(source)
            .with_context(|| format!("writing {}", tmp.display()))?;
        let stats = writer.finish()?;
        std::fs::rename(&tmp, path).with_context(|| {
            format!("publishing {} as {}", tmp.display(), path.display())
        })?;
        Ok(stats)
    })();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

/// What a reader does with a frame whose checksum (or element count)
/// does not match its payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CorruptFramePolicy {
    /// Fail the stream on the first corrupt frame (the default): a named
    /// error carrying file, frame index and cause.
    #[default]
    Fail,
    /// Skip corrupt frames: drop the frame, count it
    /// ([`BlobFileSource::skipped`]), resync at the next length prefix
    /// and keep reading. Structural damage — absurd lengths, truncation,
    /// a bad header or footer — still fails hard; only payload-level
    /// corruption inside an intact frame chain is salvageable.
    Skip,
}

/// At most this many per-frame skip diagnostics are kept
/// ([`BlobFileSource::skip_log`]); the count is always exact.
const SKIP_LOG_CAP: usize = 8;

/// Reader progress.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ReadState {
    /// Frames may follow.
    Active,
    /// Footer seen and validated.
    Finished,
    /// A stashed error ended the stream (reported at `close`).
    Failed,
}

/// File-backed [`RegionSource`]: streams `Blob` regions out of a `.rgn`
/// container one frame at a time.
///
/// Memory contract: one reusable frame buffer (high-water sized by the
/// largest region), element containers taken from an optional shared
/// [`ContainerPool`] (refilled by the executor via
/// [`PipelineFactory::recycle_region`]), and whatever the `Read`
/// implementation buffers ([`BlobFileSource::open`] uses a fixed-size
/// [`BufReader`]). Nothing scales with file length.
///
/// [`PipelineFactory::recycle_region`]: crate::exec::PipelineFactory::recycle_region
pub struct BlobFileSource<R: Read> {
    input: R,
    /// Where the bytes come from, for error messages.
    label: String,
    /// Reusable frame payload buffer.
    frame: Vec<u8>,
    /// Recycled element containers (worker-refilled when wired).
    pool: Option<Arc<ContainerPool<f32>>>,
    policy: CorruptFramePolicy,
    regions: u64,
    items: u64,
    /// Corrupt frames dropped under [`CorruptFramePolicy::Skip`].
    skipped: u64,
    /// First few skip diagnostics (capped at [`SKIP_LOG_CAP`]).
    skip_log: Vec<String>,
    state: ReadState,
    error: Option<anyhow::Error>,
}

impl BlobFileSource<BufReader<File>> {
    /// Open a `.rgn` file, validating the header eagerly (a wrong-format
    /// file fails here, not mid-stream).
    pub fn open(path: impl AsRef<Path>) -> Result<BlobFileSource<BufReader<File>>> {
        let path = path.as_ref();
        let file = File::open(path)
            .with_context(|| format!("opening .rgn file {}", path.display()))?;
        BlobFileSource::from_reader(BufReader::new(file), path.display().to_string())
    }
}

/// Validate a container header, naming `label` in every failure.
fn check_header(label: &str, header: &[u8; HEADER_BYTES]) -> Result<()> {
    ensure!(
        header[..8] == MAGIC,
        "{label}: not a .rgn container (bad magic {:02x?})",
        &header[..8]
    );
    let version = u32::from_le_bytes(header[8..12].try_into().expect("4 bytes"));
    ensure!(
        version == VERSION,
        "{label}: unsupported .rgn version {version} (this build reads {VERSION})"
    );
    let payload = u32::from_le_bytes(header[12..16].try_into().expect("4 bytes"));
    ensure!(
        payload == PAYLOAD_BLOB_F32,
        "{label}: unsupported payload schema {payload} (expected {PAYLOAD_BLOB_F32})"
    );
    Ok(())
}

impl<R: Read> BlobFileSource<R> {
    /// Wrap any reader positioned at the start of a container; validates
    /// the header eagerly. `label` names the source in errors.
    pub fn from_reader(mut input: R, label: impl Into<String>) -> Result<BlobFileSource<R>> {
        let label = label.into();
        let mut header = [0u8; HEADER_BYTES];
        input
            .read_exact(&mut header)
            .with_context(|| format!("{label}: reading .rgn header"))?;
        check_header(&label, &header)?;
        Ok(BlobFileSource {
            input,
            label,
            frame: Vec::new(),
            pool: None,
            policy: CorruptFramePolicy::Fail,
            regions: 0,
            items: 0,
            skipped: 0,
            skip_log: Vec::new(),
            state: ReadState::Active,
            error: None,
        })
    }

    /// Choose what to do with corrupt frames (default:
    /// [`CorruptFramePolicy::Fail`]).
    pub fn with_corrupt_policy(mut self, policy: CorruptFramePolicy) -> BlobFileSource<R> {
        self.policy = policy;
        self
    }

    /// Share an element-container pool: freshly read regions take their
    /// `Vec<f32>` from it instead of allocating, closing the recycling
    /// loop with `SumFactory::with_elem_pool` (workers return containers
    /// after each shard).
    pub fn with_pool(mut self, pool: Arc<ContainerPool<f32>>) -> BlobFileSource<R> {
        self.pool = Some(pool);
        self
    }

    /// Regions read so far.
    pub fn regions_read(&self) -> u64 {
        self.regions
    }

    /// Elements read so far.
    pub fn items_read(&self) -> u64 {
        self.items
    }

    /// Corrupt frames dropped so far (always 0 under
    /// [`CorruptFramePolicy::Fail`]).
    pub fn skipped(&self) -> u64 {
        self.skipped
    }

    /// Diagnostics for the first [`SKIP_LOG_CAP`] skipped frames;
    /// [`skipped`](BlobFileSource::skipped) stays exact past the cap.
    pub fn skip_log(&self) -> &[String] {
        &self.skip_log
    }

    /// Fallible pull: the next region, `Ok(None)` after a validated
    /// footer, or a named error on truncation/corruption. Unlike
    /// [`RegionSource::next_region`] the failure is returned here
    /// directly, for callers outside the executor.
    pub fn try_next(&mut self) -> Result<Option<Blob>> {
        match self.state {
            ReadState::Active => {}
            ReadState::Finished | ReadState::Failed => return Ok(None),
        }
        match self.read_frame() {
            Ok(blob) => Ok(blob),
            Err(e) => {
                self.state = ReadState::Failed;
                Err(e)
            }
        }
    }

    /// Record a corrupt frame under [`CorruptFramePolicy::Skip`]: count
    /// it, keep the first few diagnostics, and let the caller resync at
    /// the next length prefix.
    fn skip_frame(&mut self, detail: String) {
        if self.skip_log.len() < SKIP_LOG_CAP {
            self.skip_log.push(detail);
        }
        self.skipped += 1;
    }

    fn read_frame(&mut self) -> Result<Option<Blob>> {
        loop {
            // frame index for messages: every frame consumed so far,
            // readable or skipped
            let index = self.regions + self.skipped;
            let mut len4 = [0u8; 4];
            if let Err(e) = self.input.read_exact(&mut len4) {
                if e.kind() == ErrorKind::UnexpectedEof {
                    bail!(
                        "{}: truncated .rgn container: end of file after {} region(s) \
                         with no footer (incomplete write?)",
                        self.label,
                        index
                    );
                }
                return Err(e).with_context(|| format!("{}: reading frame length", self.label));
            }
            let len = u32::from_le_bytes(len4);
            if len == FOOTER_SENTINEL {
                return self.read_footer().map(|()| None);
            }
            // A broken length prefix breaks the resync chain itself, so
            // this stays a hard error under either corrupt-frame policy.
            ensure!(
                (FRAME_HEAD_BYTES as u32..=MAX_FRAME_BYTES).contains(&len),
                "{}: corrupted frame {}: absurd payload length {len} bytes \
                 (valid: {FRAME_HEAD_BYTES}..={MAX_FRAME_BYTES})",
                self.label,
                index
            );
            let mut sum8 = [0u8; 8];
            self.read_body(&mut sum8, "frame checksum")?;
            let stored = u64::from_le_bytes(sum8);
            self.frame.resize(len as usize, 0);
            let mut frame = std::mem::take(&mut self.frame);
            let body = self.read_body(&mut frame, "frame payload");
            self.frame = frame;
            body?;
            // From here the full frame body has been consumed, so the
            // reader sits exactly at the next length prefix: Skip can
            // drop the frame and continue without losing alignment.
            let actual = fnv1a64(&self.frame);
            if actual != stored {
                let detail = format!(
                    "{}: corrupted frame {index}: checksum mismatch \
                     (stored {stored:#018x}, computed {actual:#018x})",
                    self.label
                );
                match self.policy {
                    CorruptFramePolicy::Fail => bail!(detail),
                    CorruptFramePolicy::Skip => {
                        self.skip_frame(detail);
                        continue;
                    }
                }
            }
            let id = u64::from_le_bytes(self.frame[..8].try_into().expect("8 bytes"));
            let count =
                u32::from_le_bytes(self.frame[8..12].try_into().expect("4 bytes")) as usize;
            if len as usize != FRAME_HEAD_BYTES + 4 * count {
                let detail = format!(
                    "{}: corrupted frame {index}: element count {count} disagrees with \
                     payload length {len}",
                    self.label
                );
                match self.policy {
                    CorruptFramePolicy::Fail => bail!(detail),
                    CorruptFramePolicy::Skip => {
                        self.skip_frame(detail);
                        continue;
                    }
                }
            }
            let mut elems = self
                .pool
                .as_ref()
                .and_then(|p| p.take())
                .unwrap_or_default();
            elems.extend(
                self.frame[FRAME_HEAD_BYTES..]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes"))),
            );
            self.regions += 1;
            self.items += count as u64;
            return Ok(Some(Blob { id, elems }));
        }
    }

    fn read_body(&mut self, buf: &mut [u8], what: &str) -> Result<()> {
        self.input.read_exact(buf).with_context(|| {
            format!(
                "{}: truncated .rgn container: end of file inside {what} of frame {}",
                self.label,
                self.regions + self.skipped
            )
        })
    }

    fn read_footer(&mut self) -> Result<()> {
        let mut body = [0u8; FOOTER_BODY_BYTES];
        self.read_body(&mut body, "the footer")?;
        let footer = Footer::decode(&body).with_context(|| {
            format!("{}: corrupted .rgn footer (bad magic or checksum)", self.label)
        })?;
        if self.skipped == 0 {
            ensure!(
                footer.regions == self.regions && footer.items == self.items,
                "{}: .rgn footer disagrees with the stream: footer says \
                 {} region(s) / {} item(s), file held {} / {}",
                self.label,
                footer.regions,
                footer.items,
                self.regions,
                self.items
            );
        } else {
            // Skipped frames are unreadable, so their item counts are
            // unknowable — reconcile region counts only.
            ensure!(
                footer.regions == self.regions + self.skipped,
                "{}: .rgn footer disagrees with the stream even counting skipped \
                 frames: footer says {} region(s), file held {} readable + {} corrupt",
                self.label,
                footer.regions,
                self.regions,
                self.skipped
            );
        }
        // trailing garbage after the footer is also a malformed container
        let mut one = [0u8; 1];
        match self.input.read(&mut one) {
            Ok(0) => {}
            Ok(_) => bail!("{}: trailing bytes after the .rgn footer", self.label),
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("{}: reading past the footer", self.label));
            }
        }
        self.state = ReadState::Finished;
        Ok(())
    }
}

impl<R: Read> RegionSource for BlobFileSource<R> {
    type Region = Blob;

    fn next_region(&mut self) -> Option<Blob> {
        match self.try_next() {
            Ok(blob) => blob,
            Err(e) => {
                self.error = Some(e);
                None
            }
        }
    }

    fn close(&mut self) -> Result<()> {
        match self.error.take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// Read just the footer of a `.rgn` file by seeking to the end: cheap
/// totals (region/item counts) for logging and validation before
/// streaming the frames — and an up-front truncation check, since an
/// interrupted writer never wrote one.
pub fn peek_rgn_footer(path: impl AsRef<Path>) -> Result<Footer> {
    use std::io::{Seek, SeekFrom};
    let path = path.as_ref();
    let mut file = File::open(path)
        .with_context(|| format!("opening .rgn file {}", path.display()))?;
    let len = file
        .metadata()
        .with_context(|| format!("inspecting {}", path.display()))?
        .len();
    let record = (4 + FOOTER_BODY_BYTES) as u64;
    ensure!(
        len >= HEADER_BYTES as u64 + record,
        "{}: too short to be a .rgn container ({len} bytes)",
        path.display()
    );
    // Validate the header first so a wrong-format file is named as such
    // (and a future-version container is rejected) instead of its tail
    // bytes being trusted as a footer.
    let mut header = [0u8; HEADER_BYTES];
    file.read_exact(&mut header)
        .with_context(|| format!("{}: reading .rgn header", path.display()))?;
    check_header(&path.display().to_string(), &header)?;
    file.seek(SeekFrom::End(-(record as i64)))
        .with_context(|| format!("seeking to the footer of {}", path.display()))?;
    let mut buf = [0u8; 4 + FOOTER_BODY_BYTES];
    file.read_exact(&mut buf)
        .with_context(|| format!("reading the footer of {}", path.display()))?;
    ensure!(
        u32::from_le_bytes(buf[..4].try_into().expect("4 bytes")) == FOOTER_SENTINEL,
        "{}: missing .rgn footer (truncated or interrupted write?)",
        path.display()
    );
    let body: [u8; FOOTER_BODY_BYTES] = buf[4..].try_into().expect("32 bytes");
    Footer::decode(&body).with_context(|| {
        format!("{}: corrupted .rgn footer (bad magic or checksum)", path.display())
    })
}

/// Materialize a whole `.rgn` file (verification paths and small inputs;
/// the streaming executor should use [`BlobFileSource`] directly).
pub fn read_rgn_file(path: impl AsRef<Path>) -> Result<Vec<Blob>> {
    let mut source = BlobFileSource::open(path)?;
    let mut blobs = Vec::new();
    while let Some(blob) = source.try_next()? {
        blobs.push(blob);
    }
    Ok(blobs)
}

/// What [`verify_rgn_file`] found: readable totals, corrupt-frame count
/// and the diagnostics behind them.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VerifyReport {
    /// Frames that decoded and checksummed clean.
    pub regions: u64,
    /// Elements across the clean frames.
    pub items: u64,
    /// Frames whose checksum or element count was wrong.
    pub corrupt_frames: u64,
    /// Per-frame diagnostics (first few corrupt frames) plus any
    /// structural error (truncation, bad footer) that ended the walk.
    pub errors: Vec<String>,
}

impl VerifyReport {
    /// Did the container verify clean end to end?
    pub fn ok(&self) -> bool {
        self.corrupt_frames == 0 && self.errors.is_empty()
    }
}

/// Walk every frame of a `.rgn` file — checksum each one, then
/// reconcile the footer against what was actually read — without
/// materializing the regions. Structural damage (truncation, a lying
/// footer) is reported in [`VerifyReport::errors`] rather than as an
/// `Err`, so callers get one unified report; only failure to open or
/// recognize the container at all returns `Err`. Backs
/// `regatta rgn verify`.
pub fn verify_rgn_file(path: impl AsRef<Path>) -> Result<VerifyReport> {
    let mut source =
        BlobFileSource::open(path)?.with_corrupt_policy(CorruptFramePolicy::Skip);
    let mut errors = Vec::new();
    loop {
        match source.try_next() {
            Ok(Some(_)) => {}
            Ok(None) => break,
            Err(e) => {
                errors.push(format!("{e:#}"));
                break;
            }
        }
    }
    let mut report = VerifyReport {
        regions: source.regions_read(),
        items: source.items_read(),
        corrupt_frames: source.skipped(),
        errors: source.skip_log().to_vec(),
    };
    report.errors.extend(errors);
    Ok(report)
}

/// Flip one payload byte of frame `frame` in an in-memory `.rgn`
/// container, walking the length-prefix chain to find it — the
/// fault-injection half of the salvage tests and `bench faults`. The
/// damage is confined to that frame's payload (its length prefix stays
/// intact), so a [`CorruptFramePolicy::Skip`] reader loses exactly this
/// one region.
pub fn corrupt_frame(bytes: &mut [u8], frame: usize) -> Result<()> {
    let mut off = HEADER_BYTES;
    for crossed in 0..=frame {
        ensure!(
            off + 4 <= bytes.len(),
            "container ends before frame {frame} ({crossed} frame(s) present)"
        );
        let len = u32::from_le_bytes(bytes[off..off + 4].try_into().expect("4 bytes"));
        ensure!(
            len != FOOTER_SENTINEL,
            "container holds only {crossed} frame(s); cannot corrupt frame {frame}"
        );
        if crossed == frame {
            let target = off + 4 + 8; // first payload byte (the region id)
            ensure!(target < bytes.len(), "frame {frame} has no payload byte to flip");
            bytes[target] ^= 0x01;
            return Ok(());
        }
        off += 4 + 8 + len as usize;
    }
    unreachable!("loop returns or errors before falling through");
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn sample_blobs() -> Vec<Blob> {
        vec![
            Blob::from_vec(0, vec![1.0, -2.5, 0.25]),
            Blob::from_vec(1, vec![]),
            Blob::from_vec(7, (0..100).map(|i| i as f32 / 3.0).collect()),
        ]
    }

    fn encode_finished(blobs: &[Blob]) -> (Vec<u8>, BlobStats) {
        struct Probe<'a>(&'a mut Vec<u8>);
        impl Write for Probe<'_> {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                self.0.extend_from_slice(buf);
                Ok(buf.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut bytes = Vec::new();
        let mut w = BlobWriter::new(Probe(&mut bytes)).unwrap();
        for b in blobs {
            w.write_region(b).unwrap();
        }
        let stats = w.finish().unwrap();
        (bytes, stats)
    }

    fn encode(blobs: &[Blob]) -> Vec<u8> {
        encode_finished(blobs).0
    }

    fn drain(bytes: Vec<u8>) -> Result<Vec<Blob>> {
        let mut src = BlobFileSource::from_reader(Cursor::new(bytes), "<mem>")?;
        let mut out = Vec::new();
        while let Some(b) = src.try_next()? {
            out.push(b);
        }
        Ok(out)
    }

    #[test]
    fn round_trip_in_memory() {
        let blobs = sample_blobs();
        let (bytes, stats) = encode_finished(&blobs);
        assert_eq!(stats.regions, 3);
        assert_eq!(stats.items, 103);
        assert_eq!(stats.bytes as usize, bytes.len());
        let got = drain(bytes).unwrap();
        assert_eq!(got, blobs);
    }

    #[test]
    fn empty_container_round_trips() {
        let (bytes, stats) = encode_finished(&[]);
        assert_eq!(stats.regions, 0);
        assert!(drain(bytes).unwrap().is_empty());
    }

    #[test]
    fn bad_magic_is_named() {
        let mut bytes = encode(&sample_blobs());
        bytes[0] = b'X';
        let err = BlobFileSource::from_reader(Cursor::new(bytes), "<mem>").unwrap_err();
        assert!(err.to_string().contains("not a .rgn container"), "{err}");
    }

    #[test]
    fn corrupted_payload_is_named() {
        let mut bytes = encode(&sample_blobs());
        // flip a bit inside the first frame's payload (header 16 + len 4
        // + checksum 8 puts payload at 28)
        bytes[30] ^= 0x01;
        let err = drain(bytes).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("corrupted frame 0"), "{msg}");
        assert!(msg.contains("checksum mismatch"), "{msg}");
    }

    #[test]
    fn truncation_is_named() {
        let full = encode(&sample_blobs());
        // cut inside the last frame (before the footer)
        let cut = full.len() - (4 + FOOTER_BODY_BYTES) - 10;
        let err = drain(full[..cut].to_vec()).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        // cut exactly at a frame boundary (footer missing entirely)
        let cut = full.len() - (4 + FOOTER_BODY_BYTES);
        let err = drain(full[..cut].to_vec()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("no footer"), "{msg}");
    }

    #[test]
    fn footer_total_mismatch_is_named() {
        // valid frames + a footer that lies about the totals (its own
        // checksum is valid, so only the cross-check can catch it)
        let full = encode(&sample_blobs());
        let mut bytes = full[..full.len() - (4 + FOOTER_BODY_BYTES)].to_vec();
        bytes.extend_from_slice(
            &Footer {
                regions: 4,
                items: 103,
            }
            .encode(),
        );
        let err = drain(bytes).unwrap_err();
        assert!(err.to_string().contains("footer disagrees"), "{err}");
    }

    #[test]
    fn trailing_garbage_is_named() {
        let mut bytes = encode(&sample_blobs());
        bytes.push(0xEE);
        let err = drain(bytes).unwrap_err();
        assert!(err.to_string().contains("trailing bytes"), "{err}");
    }

    #[test]
    fn absurd_frame_length_is_named() {
        let mut bytes = encode(&sample_blobs());
        // overwrite the first frame's length with a huge value
        bytes[16..20].copy_from_slice(&(MAX_FRAME_BYTES + 1).to_le_bytes());
        let err = drain(bytes).unwrap_err();
        assert!(err.to_string().contains("absurd payload length"), "{err}");
    }

    #[test]
    fn region_source_stashes_errors_for_close() {
        let mut bytes = encode(&sample_blobs());
        bytes[30] ^= 0x01;
        let mut src = BlobFileSource::from_reader(Cursor::new(bytes), "<mem>").unwrap();
        assert!(src.next_region().is_none(), "error ends the stream");
        let err = src.close().unwrap_err();
        assert!(err.to_string().contains("corrupted frame 0"), "{err}");
        assert!(src.close().is_ok(), "error is reported once");
    }

    #[test]
    fn skip_policy_salvages_around_a_corrupt_frame() {
        let blobs = sample_blobs();
        let mut bytes = encode(&blobs);
        corrupt_frame(&mut bytes, 1).unwrap();
        let mut src = BlobFileSource::from_reader(Cursor::new(bytes), "<mem>")
            .unwrap()
            .with_corrupt_policy(CorruptFramePolicy::Skip);
        let mut got = Vec::new();
        while let Some(b) = src.try_next().unwrap() {
            got.push(b);
        }
        assert_eq!(got, vec![blobs[0].clone(), blobs[2].clone()]);
        assert_eq!(src.skipped(), 1);
        assert_eq!(src.skip_log().len(), 1);
        assert!(src.skip_log()[0].contains("corrupted frame 1"), "{:?}", src.skip_log());
        assert!(src.skip_log()[0].contains("checksum mismatch"), "{:?}", src.skip_log());
    }

    #[test]
    fn skip_policy_survives_every_frame_corrupt() {
        let blobs = sample_blobs();
        let mut bytes = encode(&blobs);
        for f in 0..blobs.len() {
            corrupt_frame(&mut bytes, f).unwrap();
        }
        let mut src = BlobFileSource::from_reader(Cursor::new(bytes), "<mem>")
            .unwrap()
            .with_corrupt_policy(CorruptFramePolicy::Skip);
        assert!(src.try_next().unwrap().is_none(), "nothing salvageable");
        assert_eq!(src.skipped(), 3);
        assert_eq!(src.regions_read(), 0);
    }

    #[test]
    fn skip_policy_still_fails_on_structural_damage() {
        // truncation is not salvageable
        let full = encode(&sample_blobs());
        let cut = full.len() - (4 + FOOTER_BODY_BYTES) - 10;
        let mut src = BlobFileSource::from_reader(Cursor::new(full[..cut].to_vec()), "<mem>")
            .unwrap()
            .with_corrupt_policy(CorruptFramePolicy::Skip);
        let err = loop {
            match src.try_next() {
                Ok(Some(_)) => {}
                Ok(None) => panic!("truncated stream must not validate"),
                Err(e) => break e,
            }
        };
        assert!(err.to_string().contains("truncated"), "hard error: {err}");
        // a lying footer is caught even with skips in the ledger
        let full = encode(&sample_blobs());
        let mut bytes = full[..full.len() - (4 + FOOTER_BODY_BYTES)].to_vec();
        corrupt_frame(&mut bytes, 0).unwrap();
        bytes.extend_from_slice(
            &Footer {
                regions: 9,
                items: 103,
            }
            .encode(),
        );
        let mut src = BlobFileSource::from_reader(Cursor::new(bytes), "<mem>")
            .unwrap()
            .with_corrupt_policy(CorruptFramePolicy::Skip);
        let err = loop {
            match src.try_next() {
                Ok(Some(_)) => {}
                Ok(None) => panic!("lying footer must not validate"),
                Err(e) => break e,
            }
        };
        assert!(
            err.to_string().contains("even counting skipped"),
            "{err}"
        );
    }

    #[test]
    fn salvaged_footer_reconciles_on_region_count() {
        // one corrupt frame, honest footer: Skip must finish clean
        let mut bytes = encode(&sample_blobs());
        corrupt_frame(&mut bytes, 2).unwrap();
        let mut src = BlobFileSource::from_reader(Cursor::new(bytes), "<mem>")
            .unwrap()
            .with_corrupt_policy(CorruptFramePolicy::Skip);
        while src.try_next().unwrap().is_some() {}
        assert_eq!(src.regions_read(), 2);
        assert_eq!(src.skipped(), 1);
    }

    #[test]
    fn corrupt_frame_helper_is_bounded() {
        let mut bytes = encode(&sample_blobs());
        assert!(corrupt_frame(&mut bytes, 3).is_err(), "only 3 frames exist");
        let err = corrupt_frame(&mut bytes, 9).unwrap_err();
        assert!(err.to_string().contains("cannot corrupt frame 9"), "{err}");
    }

    #[test]
    fn write_rgn_file_is_atomic_and_verify_reconciles() {
        use crate::workload::source::SliceSource;
        let dir = std::env::temp_dir();
        let path = dir.join(format!("regatta_blob_atomic_{}.rgn", std::process::id()));
        let tmp = crate::io::tmp_path(&path);
        let blobs = sample_blobs();
        let stats = write_rgn_file(&path, SliceSource::new(&blobs)).unwrap();
        assert_eq!(stats.regions, 3);
        assert!(path.exists(), "published at the final name");
        assert!(!tmp.exists(), "no stale .tmp after success");
        // clean file verifies clean
        let report = verify_rgn_file(&path).unwrap();
        assert!(report.ok(), "{report:?}");
        assert_eq!(report.regions, 3);
        assert_eq!(report.items, 103);
        // corrupt one frame on disk: verify names it and counts it
        let mut bytes = std::fs::read(&path).unwrap();
        corrupt_frame(&mut bytes, 1).unwrap();
        std::fs::write(&path, &bytes).unwrap();
        let report = verify_rgn_file(&path).unwrap();
        assert!(!report.ok());
        assert_eq!(report.corrupt_frames, 1);
        assert_eq!(report.regions, 2);
        assert!(report.errors[0].contains("corrupted frame 1"), "{report:?}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn pooled_containers_are_reused() {
        let blobs = vec![
            Blob::from_vec(0, vec![1.0; 16]),
            Blob::from_vec(1, vec![2.0; 16]),
        ];
        let (bytes, _) = encode_finished(&blobs);
        let pool = Arc::new(ContainerPool::new());
        let seeded: Vec<f32> = Vec::with_capacity(64);
        let seeded_ptr = seeded.as_ptr();
        pool.put(seeded);
        let mut src = BlobFileSource::from_reader(Cursor::new(bytes), "<mem>")
            .unwrap()
            .with_pool(pool.clone());
        let first = src.try_next().unwrap().unwrap();
        assert_eq!(first.elems.as_ptr(), seeded_ptr, "container came from the pool");
        assert_eq!(first.elems, vec![1.0; 16]);
        pool.put(first.elems);
        let second = src.try_next().unwrap().unwrap();
        assert_eq!(second.elems.as_ptr(), seeded_ptr, "recycled again");
        assert!(src.try_next().unwrap().is_none());
    }
}
