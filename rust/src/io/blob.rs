//! `.rgn` writer and file-backed region source.
//!
//! [`BlobWriter`] serializes **any** [`RegionSource`] of [`Blob`] regions
//! (the lazy [`GenBlobSource`](crate::workload::regions::GenBlobSource),
//! a slice replay, another file…) into the container format specified in
//! [`super::format`], streaming: one region in memory at a time, totals
//! accumulated into the footer.
//!
//! [`BlobFileSource`] is the reading half: a [`RegionSource`] over a
//! `.rgn` file (or any `Read`), pulling one frame at a time through a
//! **reusable** payload buffer, with element containers recycled through
//! the executor's [`ContainerPool`] — so steady-state reads perform no
//! per-region heap allocation and driver-side memory is governed by the
//! ingest budget, never by file size (`rust/tests/io_memory.rs` proves
//! this with the counting allocator).
//!
//! I/O errors and corruption cannot surface through
//! [`RegionSource::next_region`] (it returns a bare `Option`), so the
//! source stashes the first failure and ends the stream; the executor
//! calls [`RegionSource::close`] after draining and the stashed error —
//! named with file, frame index and cause — propagates out of
//! `run_stream*`. Direct users can call [`BlobFileSource::try_next`]
//! instead and see errors immediately.

use std::fs::File;
use std::io::{BufReader, BufWriter, ErrorKind, Read, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use super::format::{
    encode_header, fnv1a64, Footer, FOOTER_BODY_BYTES, FOOTER_SENTINEL, FRAME_HEAD_BYTES,
    HEADER_BYTES, MAGIC, MAX_FRAME_BYTES, PAYLOAD_BLOB_F32, VERSION,
};
use crate::coordinator::enumerate::Blob;
use crate::exec::ingest::ContainerPool;
use crate::workload::source::RegionSource;

/// What a completed write (or a fully validated read) covered.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlobStats {
    /// Region frames written/read.
    pub regions: u64,
    /// Total elements across all regions.
    pub items: u64,
    /// Container bytes, header and footer included.
    pub bytes: u64,
}

/// Streaming `.rgn` writer over any [`Write`].
///
/// `new` emits the header; [`BlobWriter::write_region`] appends one
/// checksummed frame through a reusable encode buffer;
/// [`BlobWriter::finish`] appends the footer and returns the totals.
/// Dropping a writer without `finish` leaves a truncated container —
/// which readers then reject by name, so a crashed producer cannot pass
/// for a complete stream.
pub struct BlobWriter<W: Write> {
    out: W,
    frame: Vec<u8>,
    regions: u64,
    items: u64,
    bytes: u64,
}

impl<W: Write> BlobWriter<W> {
    /// Start a container: writes the header immediately.
    pub fn new(mut out: W) -> Result<BlobWriter<W>> {
        out.write_all(&encode_header()).context("writing .rgn header")?;
        Ok(BlobWriter {
            out,
            frame: Vec::new(),
            regions: 0,
            items: 0,
            bytes: HEADER_BYTES as u64,
        })
    }

    /// Append one region as a checksummed frame.
    pub fn write_region(&mut self, blob: &Blob) -> Result<()> {
        let payload = FRAME_HEAD_BYTES + 4 * blob.elems.len();
        ensure!(
            payload <= MAX_FRAME_BYTES as usize,
            "region {} too large for a .rgn frame: {payload} bytes (cap {MAX_FRAME_BYTES})",
            blob.id
        );
        self.frame.clear();
        self.frame.extend_from_slice(&blob.id.to_le_bytes());
        self.frame.extend_from_slice(&(blob.elems.len() as u32).to_le_bytes());
        for &v in &blob.elems {
            self.frame.extend_from_slice(&v.to_le_bytes());
        }
        let sum = fnv1a64(&self.frame);
        let frame_index = self.regions;
        let write = |out: &mut W, frame: &[u8]| -> std::io::Result<()> {
            out.write_all(&(payload as u32).to_le_bytes())?;
            out.write_all(&sum.to_le_bytes())?;
            out.write_all(frame)
        };
        write(&mut self.out, &self.frame)
            .with_context(|| format!("writing .rgn frame {frame_index}"))?;
        self.regions += 1;
        self.items += blob.elems.len() as u64;
        self.bytes += (4 + 8 + payload) as u64;
        Ok(())
    }

    /// Drain `source` into the container (regions stay in stream order).
    pub fn write_source<S>(&mut self, mut source: S) -> Result<()>
    where
        S: RegionSource<Region = Blob>,
    {
        while let Some(blob) = source.next_region() {
            self.write_region(&blob)?;
        }
        source.close().context("region source failed while writing .rgn")
    }

    /// Append the footer, flush, and return the totals.
    pub fn finish(mut self) -> Result<BlobStats> {
        let footer = Footer {
            regions: self.regions,
            items: self.items,
        };
        self.out.write_all(&footer.encode()).context("writing .rgn footer")?;
        self.out.flush().context("flushing .rgn output")?;
        Ok(BlobStats {
            regions: self.regions,
            items: self.items,
            bytes: self.bytes + 4 + FOOTER_BODY_BYTES as u64,
        })
    }
}

/// Materialize `source` into a `.rgn` file at `path` (the `regatta gen`
/// entry point).
pub fn write_rgn_file<S>(path: impl AsRef<Path>, source: S) -> Result<BlobStats>
where
    S: RegionSource<Region = Blob>,
{
    let path = path.as_ref();
    let file = File::create(path)
        .with_context(|| format!("creating .rgn file {}", path.display()))?;
    let mut writer = BlobWriter::new(BufWriter::new(file))?;
    writer
        .write_source(source)
        .with_context(|| format!("writing {}", path.display()))?;
    writer.finish()
}

/// Reader progress.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ReadState {
    /// Frames may follow.
    Active,
    /// Footer seen and validated.
    Finished,
    /// A stashed error ended the stream (reported at `close`).
    Failed,
}

/// File-backed [`RegionSource`]: streams `Blob` regions out of a `.rgn`
/// container one frame at a time.
///
/// Memory contract: one reusable frame buffer (high-water sized by the
/// largest region), element containers taken from an optional shared
/// [`ContainerPool`] (refilled by the executor via
/// [`PipelineFactory::recycle_region`]), and whatever the `Read`
/// implementation buffers ([`BlobFileSource::open`] uses a fixed-size
/// [`BufReader`]). Nothing scales with file length.
///
/// [`PipelineFactory::recycle_region`]: crate::exec::PipelineFactory::recycle_region
pub struct BlobFileSource<R: Read> {
    input: R,
    /// Where the bytes come from, for error messages.
    label: String,
    /// Reusable frame payload buffer.
    frame: Vec<u8>,
    /// Recycled element containers (worker-refilled when wired).
    pool: Option<Arc<ContainerPool<f32>>>,
    regions: u64,
    items: u64,
    state: ReadState,
    error: Option<anyhow::Error>,
}

impl BlobFileSource<BufReader<File>> {
    /// Open a `.rgn` file, validating the header eagerly (a wrong-format
    /// file fails here, not mid-stream).
    pub fn open(path: impl AsRef<Path>) -> Result<BlobFileSource<BufReader<File>>> {
        let path = path.as_ref();
        let file = File::open(path)
            .with_context(|| format!("opening .rgn file {}", path.display()))?;
        BlobFileSource::from_reader(BufReader::new(file), path.display().to_string())
    }
}

/// Validate a container header, naming `label` in every failure.
fn check_header(label: &str, header: &[u8; HEADER_BYTES]) -> Result<()> {
    ensure!(
        header[..8] == MAGIC,
        "{label}: not a .rgn container (bad magic {:02x?})",
        &header[..8]
    );
    let version = u32::from_le_bytes(header[8..12].try_into().expect("4 bytes"));
    ensure!(
        version == VERSION,
        "{label}: unsupported .rgn version {version} (this build reads {VERSION})"
    );
    let payload = u32::from_le_bytes(header[12..16].try_into().expect("4 bytes"));
    ensure!(
        payload == PAYLOAD_BLOB_F32,
        "{label}: unsupported payload schema {payload} (expected {PAYLOAD_BLOB_F32})"
    );
    Ok(())
}

impl<R: Read> BlobFileSource<R> {
    /// Wrap any reader positioned at the start of a container; validates
    /// the header eagerly. `label` names the source in errors.
    pub fn from_reader(mut input: R, label: impl Into<String>) -> Result<BlobFileSource<R>> {
        let label = label.into();
        let mut header = [0u8; HEADER_BYTES];
        input
            .read_exact(&mut header)
            .with_context(|| format!("{label}: reading .rgn header"))?;
        check_header(&label, &header)?;
        Ok(BlobFileSource {
            input,
            label,
            frame: Vec::new(),
            pool: None,
            regions: 0,
            items: 0,
            state: ReadState::Active,
            error: None,
        })
    }

    /// Share an element-container pool: freshly read regions take their
    /// `Vec<f32>` from it instead of allocating, closing the recycling
    /// loop with `SumFactory::with_elem_pool` (workers return containers
    /// after each shard).
    pub fn with_pool(mut self, pool: Arc<ContainerPool<f32>>) -> BlobFileSource<R> {
        self.pool = Some(pool);
        self
    }

    /// Regions read so far.
    pub fn regions_read(&self) -> u64 {
        self.regions
    }

    /// Elements read so far.
    pub fn items_read(&self) -> u64 {
        self.items
    }

    /// Fallible pull: the next region, `Ok(None)` after a validated
    /// footer, or a named error on truncation/corruption. Unlike
    /// [`RegionSource::next_region`] the failure is returned here
    /// directly, for callers outside the executor.
    pub fn try_next(&mut self) -> Result<Option<Blob>> {
        match self.state {
            ReadState::Active => {}
            ReadState::Finished | ReadState::Failed => return Ok(None),
        }
        match self.read_frame() {
            Ok(blob) => Ok(blob),
            Err(e) => {
                self.state = ReadState::Failed;
                Err(e)
            }
        }
    }

    fn read_frame(&mut self) -> Result<Option<Blob>> {
        let mut len4 = [0u8; 4];
        if let Err(e) = self.input.read_exact(&mut len4) {
            if e.kind() == ErrorKind::UnexpectedEof {
                bail!(
                    "{}: truncated .rgn container: end of file after {} region(s) \
                     with no footer (incomplete write?)",
                    self.label,
                    self.regions
                );
            }
            return Err(e).with_context(|| format!("{}: reading frame length", self.label));
        }
        let len = u32::from_le_bytes(len4);
        if len == FOOTER_SENTINEL {
            return self.read_footer().map(|()| None);
        }
        ensure!(
            (FRAME_HEAD_BYTES as u32..=MAX_FRAME_BYTES).contains(&len),
            "{}: corrupted frame {}: absurd payload length {len} bytes \
             (valid: {FRAME_HEAD_BYTES}..={MAX_FRAME_BYTES})",
            self.label,
            self.regions
        );
        let mut sum8 = [0u8; 8];
        self.read_body(&mut sum8, "frame checksum")?;
        let stored = u64::from_le_bytes(sum8);
        self.frame.resize(len as usize, 0);
        let mut frame = std::mem::take(&mut self.frame);
        let body = self.read_body(&mut frame, "frame payload");
        self.frame = frame;
        body?;
        let actual = fnv1a64(&self.frame);
        ensure!(
            actual == stored,
            "{}: corrupted frame {}: checksum mismatch \
             (stored {stored:#018x}, computed {actual:#018x})",
            self.label,
            self.regions
        );
        let id = u64::from_le_bytes(self.frame[..8].try_into().expect("8 bytes"));
        let count = u32::from_le_bytes(self.frame[8..12].try_into().expect("4 bytes")) as usize;
        ensure!(
            len as usize == FRAME_HEAD_BYTES + 4 * count,
            "{}: corrupted frame {}: element count {count} disagrees with \
             payload length {len}",
            self.label,
            self.regions
        );
        let mut elems = self
            .pool
            .as_ref()
            .and_then(|p| p.take())
            .unwrap_or_default();
        elems.extend(
            self.frame[FRAME_HEAD_BYTES..]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes"))),
        );
        self.regions += 1;
        self.items += count as u64;
        Ok(Some(Blob { id, elems }))
    }

    fn read_body(&mut self, buf: &mut [u8], what: &str) -> Result<()> {
        self.input.read_exact(buf).with_context(|| {
            format!(
                "{}: truncated .rgn container: end of file inside {what} of frame {}",
                self.label, self.regions
            )
        })
    }

    fn read_footer(&mut self) -> Result<()> {
        let mut body = [0u8; FOOTER_BODY_BYTES];
        self.read_body(&mut body, "the footer")?;
        let footer = Footer::decode(&body).with_context(|| {
            format!("{}: corrupted .rgn footer (bad magic or checksum)", self.label)
        })?;
        ensure!(
            footer.regions == self.regions && footer.items == self.items,
            "{}: .rgn footer disagrees with the stream: footer says \
             {} region(s) / {} item(s), file held {} / {}",
            self.label,
            footer.regions,
            footer.items,
            self.regions,
            self.items
        );
        // trailing garbage after the footer is also a malformed container
        let mut one = [0u8; 1];
        match self.input.read(&mut one) {
            Ok(0) => {}
            Ok(_) => bail!("{}: trailing bytes after the .rgn footer", self.label),
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("{}: reading past the footer", self.label));
            }
        }
        self.state = ReadState::Finished;
        Ok(())
    }
}

impl<R: Read> RegionSource for BlobFileSource<R> {
    type Region = Blob;

    fn next_region(&mut self) -> Option<Blob> {
        match self.try_next() {
            Ok(blob) => blob,
            Err(e) => {
                self.error = Some(e);
                None
            }
        }
    }

    fn close(&mut self) -> Result<()> {
        match self.error.take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// Read just the footer of a `.rgn` file by seeking to the end: cheap
/// totals (region/item counts) for logging and validation before
/// streaming the frames — and an up-front truncation check, since an
/// interrupted writer never wrote one.
pub fn peek_rgn_footer(path: impl AsRef<Path>) -> Result<Footer> {
    use std::io::{Seek, SeekFrom};
    let path = path.as_ref();
    let mut file = File::open(path)
        .with_context(|| format!("opening .rgn file {}", path.display()))?;
    let len = file
        .metadata()
        .with_context(|| format!("inspecting {}", path.display()))?
        .len();
    let record = (4 + FOOTER_BODY_BYTES) as u64;
    ensure!(
        len >= HEADER_BYTES as u64 + record,
        "{}: too short to be a .rgn container ({len} bytes)",
        path.display()
    );
    // Validate the header first so a wrong-format file is named as such
    // (and a future-version container is rejected) instead of its tail
    // bytes being trusted as a footer.
    let mut header = [0u8; HEADER_BYTES];
    file.read_exact(&mut header)
        .with_context(|| format!("{}: reading .rgn header", path.display()))?;
    check_header(&path.display().to_string(), &header)?;
    file.seek(SeekFrom::End(-(record as i64)))
        .with_context(|| format!("seeking to the footer of {}", path.display()))?;
    let mut buf = [0u8; 4 + FOOTER_BODY_BYTES];
    file.read_exact(&mut buf)
        .with_context(|| format!("reading the footer of {}", path.display()))?;
    ensure!(
        u32::from_le_bytes(buf[..4].try_into().expect("4 bytes")) == FOOTER_SENTINEL,
        "{}: missing .rgn footer (truncated or interrupted write?)",
        path.display()
    );
    let body: [u8; FOOTER_BODY_BYTES] = buf[4..].try_into().expect("32 bytes");
    Footer::decode(&body).with_context(|| {
        format!("{}: corrupted .rgn footer (bad magic or checksum)", path.display())
    })
}

/// Materialize a whole `.rgn` file (verification paths and small inputs;
/// the streaming executor should use [`BlobFileSource`] directly).
pub fn read_rgn_file(path: impl AsRef<Path>) -> Result<Vec<Blob>> {
    let mut source = BlobFileSource::open(path)?;
    let mut blobs = Vec::new();
    while let Some(blob) = source.try_next()? {
        blobs.push(blob);
    }
    Ok(blobs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn sample_blobs() -> Vec<Blob> {
        vec![
            Blob::from_vec(0, vec![1.0, -2.5, 0.25]),
            Blob::from_vec(1, vec![]),
            Blob::from_vec(7, (0..100).map(|i| i as f32 / 3.0).collect()),
        ]
    }

    fn encode_finished(blobs: &[Blob]) -> (Vec<u8>, BlobStats) {
        struct Probe<'a>(&'a mut Vec<u8>);
        impl Write for Probe<'_> {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                self.0.extend_from_slice(buf);
                Ok(buf.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut bytes = Vec::new();
        let mut w = BlobWriter::new(Probe(&mut bytes)).unwrap();
        for b in blobs {
            w.write_region(b).unwrap();
        }
        let stats = w.finish().unwrap();
        (bytes, stats)
    }

    fn encode(blobs: &[Blob]) -> Vec<u8> {
        encode_finished(blobs).0
    }

    fn drain(bytes: Vec<u8>) -> Result<Vec<Blob>> {
        let mut src = BlobFileSource::from_reader(Cursor::new(bytes), "<mem>")?;
        let mut out = Vec::new();
        while let Some(b) = src.try_next()? {
            out.push(b);
        }
        Ok(out)
    }

    #[test]
    fn round_trip_in_memory() {
        let blobs = sample_blobs();
        let (bytes, stats) = encode_finished(&blobs);
        assert_eq!(stats.regions, 3);
        assert_eq!(stats.items, 103);
        assert_eq!(stats.bytes as usize, bytes.len());
        let got = drain(bytes).unwrap();
        assert_eq!(got, blobs);
    }

    #[test]
    fn empty_container_round_trips() {
        let (bytes, stats) = encode_finished(&[]);
        assert_eq!(stats.regions, 0);
        assert!(drain(bytes).unwrap().is_empty());
    }

    #[test]
    fn bad_magic_is_named() {
        let mut bytes = encode(&sample_blobs());
        bytes[0] = b'X';
        let err = BlobFileSource::from_reader(Cursor::new(bytes), "<mem>").unwrap_err();
        assert!(err.to_string().contains("not a .rgn container"), "{err}");
    }

    #[test]
    fn corrupted_payload_is_named() {
        let mut bytes = encode(&sample_blobs());
        // flip a bit inside the first frame's payload (header 16 + len 4
        // + checksum 8 puts payload at 28)
        bytes[30] ^= 0x01;
        let err = drain(bytes).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("corrupted frame 0"), "{msg}");
        assert!(msg.contains("checksum mismatch"), "{msg}");
    }

    #[test]
    fn truncation_is_named() {
        let full = encode(&sample_blobs());
        // cut inside the last frame (before the footer)
        let cut = full.len() - (4 + FOOTER_BODY_BYTES) - 10;
        let err = drain(full[..cut].to_vec()).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        // cut exactly at a frame boundary (footer missing entirely)
        let cut = full.len() - (4 + FOOTER_BODY_BYTES);
        let err = drain(full[..cut].to_vec()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("no footer"), "{msg}");
    }

    #[test]
    fn footer_total_mismatch_is_named() {
        // valid frames + a footer that lies about the totals (its own
        // checksum is valid, so only the cross-check can catch it)
        let full = encode(&sample_blobs());
        let mut bytes = full[..full.len() - (4 + FOOTER_BODY_BYTES)].to_vec();
        bytes.extend_from_slice(
            &Footer {
                regions: 4,
                items: 103,
            }
            .encode(),
        );
        let err = drain(bytes).unwrap_err();
        assert!(err.to_string().contains("footer disagrees"), "{err}");
    }

    #[test]
    fn trailing_garbage_is_named() {
        let mut bytes = encode(&sample_blobs());
        bytes.push(0xEE);
        let err = drain(bytes).unwrap_err();
        assert!(err.to_string().contains("trailing bytes"), "{err}");
    }

    #[test]
    fn absurd_frame_length_is_named() {
        let mut bytes = encode(&sample_blobs());
        // overwrite the first frame's length with a huge value
        bytes[16..20].copy_from_slice(&(MAX_FRAME_BYTES + 1).to_le_bytes());
        let err = drain(bytes).unwrap_err();
        assert!(err.to_string().contains("absurd payload length"), "{err}");
    }

    #[test]
    fn region_source_stashes_errors_for_close() {
        let mut bytes = encode(&sample_blobs());
        bytes[30] ^= 0x01;
        let mut src = BlobFileSource::from_reader(Cursor::new(bytes), "<mem>").unwrap();
        assert!(src.next_region().is_none(), "error ends the stream");
        let err = src.close().unwrap_err();
        assert!(err.to_string().contains("corrupted frame 0"), "{err}");
        assert!(src.close().is_ok(), "error is reported once");
    }

    #[test]
    fn pooled_containers_are_reused() {
        let blobs = vec![
            Blob::from_vec(0, vec![1.0; 16]),
            Blob::from_vec(1, vec![2.0; 16]),
        ];
        let (bytes, _) = encode_finished(&blobs);
        let pool = Arc::new(ContainerPool::new());
        let seeded: Vec<f32> = Vec::with_capacity(64);
        let seeded_ptr = seeded.as_ptr();
        pool.put(seeded);
        let mut src = BlobFileSource::from_reader(Cursor::new(bytes), "<mem>")
            .unwrap()
            .with_pool(pool.clone());
        let first = src.try_next().unwrap().unwrap();
        assert_eq!(first.elems.as_ptr(), seeded_ptr, "container came from the pool");
        assert_eq!(first.elems, vec![1.0; 16]);
        pool.put(first.elems);
        let second = src.try_next().unwrap().unwrap();
        assert_eq!(second.elems.as_ptr(), seeded_ptr, "recycled again");
        assert!(src.try_next().unwrap().is_none());
    }
}
