//! Minimal property-based testing harness (offline substitute for
//! `proptest`).
//!
//! Properties are functions over a [`Gen`]; the harness runs each property
//! many times with a *growing size parameter*, so the first failing case is
//! naturally small (sized generation in lieu of shrinking). Failures panic
//! with the seed and iteration, and `REGATTA_CHECK_SEED` /
//! `REGATTA_CHECK_RUNS` reproduce or extend a run.
//!
//! ```no_run
//! use regatta::util::minicheck::Checker;
//! Checker::new("reverse-roundtrip").runs(200).check(|g| {
//!     let xs = g.vec_u32(64, 1000);
//!     let mut r = xs.clone();
//!     r.reverse();
//!     r.reverse();
//!     if r == xs { Ok(()) } else { Err(format!("mismatch for {xs:?}")) }
//! });
//! ```

use crate::util::prng::Prng;

/// Sized random-input generator handed to properties.
pub struct Gen {
    prng: Prng,
    size: usize,
}

impl Gen {
    /// Current size (grows from 1 over a run; use to scale structures).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Uniform usize in `[0, cap)`, additionally capped by size scaling.
    pub fn below(&mut self, cap: usize) -> usize {
        let eff = cap.min(self.size.max(1));
        self.prng.below(eff.max(1))
    }

    /// Uniform usize in `[lo, hi]` (NOT size-scaled).
    pub fn int_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.prng.below(hi - lo + 1)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.prng.range_f32(lo, hi)
    }

    /// Bernoulli.
    pub fn chance(&mut self, p: f64) -> bool {
        self.prng.chance(p)
    }

    /// Length ≤ max_len (size-scaled) vector of u32 < bound.
    pub fn vec_u32(&mut self, max_len: usize, bound: u32) -> Vec<u32> {
        let len = self.below(max_len + 1);
        (0..len)
            .map(|_| (self.prng.next_u64() % bound as u64) as u32)
            .collect()
    }

    /// Length ≤ max_len (size-scaled) vector of f32 in [-scale, scale).
    pub fn vec_f32(&mut self, max_len: usize, scale: f32) -> Vec<f32> {
        let len = self.below(max_len + 1);
        (0..len).map(|_| self.prng.range_f32(-scale, scale)).collect()
    }

    /// Uniformly chosen element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.prng.choice(xs)
    }

    /// Access the raw PRNG (for custom generators).
    pub fn rng(&mut self) -> &mut Prng {
        &mut self.prng
    }
}

/// Property runner.
pub struct Checker {
    name: String,
    runs: usize,
    seed: u64,
    max_size: usize,
}

impl Checker {
    /// New checker; honours `REGATTA_CHECK_SEED`/`REGATTA_CHECK_RUNS`.
    pub fn new(name: &str) -> Self {
        let seed = std::env::var("REGATTA_CHECK_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0x5EED_0000_u64);
        let runs = std::env::var("REGATTA_CHECK_RUNS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(256);
        Checker {
            name: name.to_string(),
            runs,
            seed,
            max_size: 100,
        }
    }

    /// Number of cases to run.
    pub fn runs(mut self, runs: usize) -> Self {
        self.runs = runs;
        self
    }

    /// Override the base seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Cap for the size parameter.
    pub fn max_size(mut self, max_size: usize) -> Self {
        self.max_size = max_size;
        self
    }

    /// Run the property; panics with a reproducible report on failure.
    pub fn check<F>(&self, prop: F)
    where
        F: Fn(&mut Gen) -> Result<(), String>,
    {
        for i in 0..self.runs {
            // size ramps up over the run so failures tend to be small
            let size = 1 + (i * self.max_size) / self.runs.max(1);
            let case_seed = self.seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let mut g = Gen {
                prng: Prng::new(case_seed),
                size,
            };
            if let Err(msg) = prop(&mut g) {
                panic!(
                    "property '{}' failed at iteration {i} (size {size}):\n  {msg}\n\
                     reproduce with REGATTA_CHECK_SEED={} and iteration {i}",
                    self.name, self.seed
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        Checker::new("add-commutes").runs(64).check(|g| {
            let a = g.int_in(0, 1000);
            let b = g.int_in(0, 1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports() {
        Checker::new("always-fails").runs(8).check(|_| Err("nope".into()));
    }

    #[test]
    fn sizes_ramp_up() {
        let mut max_seen = 0usize;
        let mut min_seen = usize::MAX;
        Checker::new("size-ramp").runs(100).check(|g| {
            let s = g.size();
            // record via a static-free trick: sizes are deterministic,
            // so we just sanity-check the bounds here.
            if s == 0 || s > 100 {
                return Err(format!("size {s} out of range"));
            }
            Ok(())
        });
        // re-derive explicitly for assertion clarity
        for i in 0..100usize {
            let size = 1 + (i * 100) / 100;
            max_seen = max_seen.max(size);
            min_seen = min_seen.min(size);
        }
        assert_eq!(min_seen, 1);
        assert_eq!(max_seen, 100);
    }

    #[test]
    fn vec_generators_respect_caps() {
        Checker::new("vec-caps").runs(64).check(|g| {
            let xs = g.vec_u32(16, 10);
            if xs.len() > 16 {
                return Err(format!("len {}", xs.len()));
            }
            if xs.iter().any(|&x| x >= 10) {
                return Err("element out of bound".into());
            }
            let fs = g.vec_f32(8, 2.0);
            if fs.iter().any(|&f| !(-2.0..2.0).contains(&f)) {
                return Err("f32 out of range".into());
            }
            Ok(())
        });
    }
}
