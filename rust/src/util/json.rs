//! Minimal JSON parser — enough to read `artifacts/manifest.json`.
//!
//! Offline substitute for `serde_json`. Supports the full JSON value
//! grammar (objects, arrays, strings with escapes, numbers, bools, null);
//! no serialization beyond what the manifest needs.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// A boolean.
    Bool(bool),
    /// A number (all JSON numbers parse as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object with sorted keys.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value truncated to `usize`, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The members, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        match self.bump() {
            Some(b) if b == c => Ok(()),
            other => bail!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.pos.saturating_sub(1),
                other.map(|b| b as char)
            ),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                other => bail!("expected ',' or '}}' in object, found {other:?}"),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                other => bail!("expected ',' or ']' in array, found {other:?}"),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => bail!("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().map(|b| b as char);
                            let d = c.and_then(|c| c.to_digit(16));
                            match d {
                                Some(d) => code = code * 16 + d,
                                None => bail!("bad \\u escape"),
                            }
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => bail!("bad escape {other:?}"),
                },
                Some(b) => out.push(b as char),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0], Json::Num(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
        assert!(j.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn parses_manifest_shape() {
        let j = Json::parse(
            r#"{"widths": [32, 128], "window_len": 32,
                "entries": {"sum_region": {"inputs": [{"dtype": "float32", "shape": [128]}]}}}"#,
        )
        .unwrap();
        let widths: Vec<usize> = j
            .get("widths")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(widths, vec![32, 128]);
        assert_eq!(j.get("window_len").unwrap().as_usize(), Some(32));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }
}
