//! Self-contained utility substrates.
//!
//! This build is fully offline: only the `xla` crate's dependency closure is
//! vendored, so the usual ecosystem crates (clap, rand, serde, criterion,
//! proptest, …) are unavailable. Everything the framework needs beyond that
//! closure is implemented here as small, tested modules:
//!
//! * [`alloc_count`] — per-thread allocation counting (the zero-alloc
//!   firing-path proof and the `bench hotpath` allocs-per-firing metric).
//! * [`cli`] — argument parsing for the launcher.
//! * [`config`] — TOML-subset config loader for launch configs.
//! * [`json`] — minimal JSON parser (reads `artifacts/manifest.json`).
//! * [`prng`] — splitmix64/xoshiro256** PRNG for workloads and tests.
//! * [`stats`] — summary statistics for metrics and the bench harness.
//! * [`minicheck`] — property-based testing harness (sized generation,
//!   seed-reproducible failures).

pub mod alloc_count;
pub mod cli;
pub mod config;
pub mod json;
pub mod minicheck;
pub mod prng;
pub mod stats;
