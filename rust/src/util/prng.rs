//! Deterministic PRNG: splitmix64 seeding + xoshiro256** core.
//!
//! Offline substitute for the `rand` crate. Deterministic by construction —
//! every workload, property test and benchmark takes an explicit seed so
//! runs are reproducible across machines.

/// xoshiro256** generator (Blackman & Vigna), seeded via splitmix64.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Prng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s }
    }

    /// Derive an independent stream (for per-worker generators).
    pub fn fork(&mut self, stream: u64) -> Prng {
        Prng::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Next 32 bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform usize in `[0, bound)`. `bound` must be > 0.
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        // Lemire's multiply-shift rejection-free-enough mapping.
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Uniform u64 in `[lo, hi]` inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        let span = hi - lo;
        if span == u64::MAX {
            return self.next_u64();
        }
        lo + ((self.next_u64() as u128 * (span as u128 + 1)) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn unit_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.unit_f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal_f32(&mut self) -> f32 {
        let u1 = self.unit_f64().max(1e-300);
        let u2 = self.unit_f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.unit_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Uniformly chosen element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Prng::new(7);
        for bound in [1usize, 2, 3, 10, 128, 1000] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn unit_f32_in_range_and_spread() {
        let mut r = Prng::new(9);
        let xs: Vec<f32> = (0..1000).map(|_| r.unit_f32()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        assert!((mean - 0.5).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn range_u64_inclusive() {
        let mut r = Prng::new(11);
        let mut hit_lo = false;
        let mut hit_hi = false;
        for _ in 0..2000 {
            let v = r.range_u64(3, 6);
            assert!((3..=6).contains(&v));
            hit_lo |= v == 3;
            hit_hi |= v == 6;
        }
        assert!(hit_lo && hit_hi);
    }

    #[test]
    fn normal_has_plausible_moments() {
        let mut r = Prng::new(13);
        let xs: Vec<f32> = (0..4000).map(|_| r.normal_f32()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.08, "mean={mean}");
        assert!((var - 1.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(17);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Prng::new(23);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
