//! Per-thread heap-allocation counting through a wrapping global
//! allocator.
//!
//! [`CountingAllocator`] (installed as the crate's `#[global_allocator]`
//! in `lib.rs`) forwards every request to the system allocator and bumps
//! a thread-local counter on each `alloc`/`alloc_zeroed`/`realloc`. The
//! counter is **per thread**, so a test can assert allocation behaviour
//! of its own code without interference from sibling tests running
//! concurrently in the same binary.
//!
//! This is how the suite *proves* the tentpole invariant — the
//! steady-state firing path performs **zero heap allocations per
//! ensemble** (see `tests/hotpath_alloc.rs`) — and how `bench hotpath`
//! reports allocations-per-firing.
//!
//! Overhead: one thread-local increment per allocation; frees are not
//! counted (a steady state is defined by not *requesting* memory).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Heap allocations made by the calling thread since it started
/// (monotonic; take deltas around the code under measurement).
pub fn thread_allocations() -> u64 {
    ALLOCS.try_with(|c| c.get()).unwrap_or(0)
}

#[inline]
fn bump() {
    // try_with: the TLS slot may be unavailable during thread teardown
    let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
}

/// System allocator wrapper that counts per-thread allocation requests.
pub struct CountingAllocator;

// SAFETY: pure pass-through to `System`; the counter is a const-initialized
// thread-local Cell, so no allocation or locking happens on the count path.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg(feature = "count-allocs")]
    fn counts_this_threads_allocations() {
        let before = thread_allocations();
        let v: Vec<u64> = Vec::with_capacity(32);
        let after = thread_allocations();
        assert!(after > before, "Vec::with_capacity must register");
        drop(v);
        // frees don't count
        assert_eq!(thread_allocations(), after);
    }

    #[test]
    fn counter_is_monotonic_and_cheap_for_alloc_free_code() {
        let before = thread_allocations();
        let mut x = 0u64;
        for i in 0..1000u64 {
            x = x.wrapping_add(i);
        }
        std::hint::black_box(x);
        assert_eq!(thread_allocations(), before);
    }
}
