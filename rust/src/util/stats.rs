//! Summary statistics for metrics collection and the bench harness.

/// Online mean/variance accumulator (Welford) plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    /// Create an empty summary.
    pub fn new() -> Self {
        Summary {
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    /// Fold one sample in.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Samples folded in.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean of the samples.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Variance of the folded samples.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Standard deviation of the folded samples.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest sample.
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest sample.
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Percentile over a sample (interpolated, `q` in `[0, 1]`).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Median of an unsorted sample (copies).
pub fn median(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile(&v, 0.5)
}

/// Human duration formatting for reports (`1.234 ms`, `12.3 s`, …).
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// Human count formatting (`1.2M`, `34.5k`).
pub fn fmt_count(n: f64) -> String {
    if n >= 1e9 {
        format!("{:.2}G", n / 1e9)
    } else if n >= 1e6 {
        format!("{:.2}M", n / 1e6)
    } else if n >= 1e3 {
        format!("{:.1}k", n / 1e3)
    } else {
        format!("{:.0}", n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn empty_summary_is_safe() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.stddev(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
    }

    #[test]
    fn percentiles() {
        let v = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
        assert_eq!(percentile(&v, 0.5), 2.5);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_duration(0.001234), "1.234 ms");
        assert_eq!(fmt_duration(2.5), "2.500 s");
        assert_eq!(fmt_count(1_500_000.0), "1.50M");
        assert_eq!(fmt_count(999.0), "999");
    }
}
