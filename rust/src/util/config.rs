//! TOML-subset configuration loader for launch configs (`configs/*.toml`).
//!
//! Offline substitute for `toml`/`serde`. Supported grammar:
//!
//! ```text
//! # comment
//! [section]
//! key = "string"
//! key = 123            # integer
//! key = 1.5            # float
//! key = true | false
//! key = [1, 2, 3]      # homogeneous scalar list
//! ```
//!
//! Keys outside any section live in the "" (root) section. Values are kept
//! as typed [`Value`]s with convenience accessors that name the key in
//! error messages.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// A configuration value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// A string value.
    Str(String),
    /// An integer value.
    Int(i64),
    /// A float value.
    Float(f64),
    /// A boolean value.
    Bool(bool),
    /// A list of values.
    List(Vec<Value>),
}

impl Value {
    fn parse(raw: &str, line_no: usize) -> Result<Value> {
        let s = raw.trim();
        if s.is_empty() {
            bail!("empty value on line {line_no}");
        }
        if let Some(body) = s.strip_prefix('"') {
            let body = body
                .strip_suffix('"')
                .ok_or_else(|| anyhow!("unterminated string on line {line_no}"))?;
            return Ok(Value::Str(body.replace("\\\"", "\"").replace("\\\\", "\\")));
        }
        if let Some(body) = s.strip_prefix('[') {
            let body = body
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("unterminated list on line {line_no}"))?;
            let items = body
                .split(',')
                .map(str::trim)
                .filter(|p| !p.is_empty())
                .map(|p| Value::parse(p, line_no))
                .collect::<Result<Vec<_>>>()?;
            return Ok(Value::List(items));
        }
        match s {
            "true" => return Ok(Value::Bool(true)),
            "false" => return Ok(Value::Bool(false)),
            _ => {}
        }
        if let Ok(i) = s.parse::<i64>() {
            return Ok(Value::Int(i));
        }
        if let Ok(f) = s.parse::<f64>() {
            return Ok(Value::Float(f));
        }
        bail!("cannot parse value {s:?} on line {line_no}")
    }
}

/// A parsed config: section → key → value.
#[derive(Debug, Clone, Default)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Config {
    /// Parse config text.
    pub fn parse(text: &str) -> Result<Config> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (i, raw_line) in text.lines().enumerate() {
            let line_no = i + 1;
            // strip comments: first '#' preceded by an even number of
            // quotes (i.e. not inside a string literal)
            let line = match raw_line
                .char_indices()
                .find(|&(pos, c)| {
                    c == '#' && raw_line[..pos].matches('"').count() % 2 == 0
                })
                .map(|(pos, _)| pos)
            {
                Some(pos) => &raw_line[..pos],
                None => raw_line,
            };
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("bad section header on line {line_no}"))?;
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("expected key = value on line {line_no}"))?;
            let value = Value::parse(val, line_no)?;
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(key.trim().to_string(), value);
        }
        Ok(cfg)
    }

    /// Load and parse a config file.
    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Config::parse(&text).with_context(|| format!("parsing config {}", path.display()))
    }

    /// All section names (the root section is "").
    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(String::as_str)
    }

    /// Raw value lookup.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    fn want<T>(
        &self,
        section: &str,
        key: &str,
        conv: impl Fn(&Value) -> Option<T>,
    ) -> Result<Option<T>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(v) => conv(v)
                .map(Some)
                .ok_or_else(|| anyhow!("config key [{section}] {key} has wrong type: {v:?}")),
        }
    }

    /// String at `[section] key`, or `default` if absent.
    pub fn str_or(&self, section: &str, key: &str, default: &str) -> Result<String> {
        Ok(self
            .want(section, key, |v| match v {
                Value::Str(s) => Some(s.clone()),
                _ => None,
            })?
            .unwrap_or_else(|| default.to_string()))
    }

    /// Integer at `[section] key`, or `default` if absent.
    pub fn int_or(&self, section: &str, key: &str, default: i64) -> Result<i64> {
        Ok(self
            .want(section, key, |v| match v {
                Value::Int(i) => Some(*i),
                _ => None,
            })?
            .unwrap_or(default))
    }

    /// Non-negative integer at `[section] key`, or `default` if absent.
    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> Result<usize> {
        let v = self.int_or(section, key, default as i64)?;
        usize::try_from(v).with_context(|| format!("[{section}] {key} must be non-negative"))
    }

    /// Float (or integer) at `[section] key`, or `default` if absent.
    pub fn float_or(&self, section: &str, key: &str, default: f64) -> Result<f64> {
        Ok(self
            .want(section, key, |v| match v {
                Value::Float(f) => Some(*f),
                Value::Int(i) => Some(*i as f64),
                _ => None,
            })?
            .unwrap_or(default))
    }

    /// Boolean at `[section] key`, or `default` if absent.
    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> Result<bool> {
        Ok(self
            .want(section, key, |v| match v {
                Value::Bool(b) => Some(*b),
                _ => None,
            })?
            .unwrap_or(default))
    }

    /// Integer list at `[section] key`, or `default` if absent.
    pub fn int_list_or(&self, section: &str, key: &str, default: &[i64]) -> Result<Vec<i64>> {
        Ok(self
            .want(section, key, |v| match v {
                Value::List(xs) => xs
                    .iter()
                    .map(|x| match x {
                        Value::Int(i) => Some(*i),
                        _ => None,
                    })
                    .collect::<Option<Vec<_>>>(),
                _ => None,
            })?
            .unwrap_or_else(|| default.to_vec()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# launcher config
name = "sum-fixed"

[workload]
items = 1000000
region_size = 96
sizes = [32, 64, 128]
fraction = 0.5
shuffle = false
label = "fixed regions"  # inline comment
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("", "name", "?").unwrap(), "sum-fixed");
        assert_eq!(c.usize_or("workload", "items", 0).unwrap(), 1_000_000);
        assert_eq!(c.int_or("workload", "region_size", 0).unwrap(), 96);
        assert_eq!(c.float_or("workload", "fraction", 0.0).unwrap(), 0.5);
        assert!(!c.bool_or("workload", "shuffle", true).unwrap());
        assert_eq!(
            c.int_list_or("workload", "sizes", &[]).unwrap(),
            vec![32, 64, 128]
        );
        assert_eq!(c.str_or("workload", "label", "?").unwrap(), "fixed regions");
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.usize_or("x", "y", 7).unwrap(), 7);
        assert!(c.bool_or("x", "y", true).unwrap());
    }

    #[test]
    fn type_errors_name_the_key() {
        let c = Config::parse("[s]\nk = \"str\"").unwrap();
        let err = c.int_or("s", "k", 0).unwrap_err().to_string();
        assert!(err.contains("[s] k"), "{err}");
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[unclosed").is_err());
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("k = ").is_err());
        assert!(Config::parse("k = \"unterminated").is_err());
    }
}
