//! Minimal command-line argument parser for the launcher.
//!
//! Offline substitute for `clap`. Grammar:
//!
//! ```text
//! regatta <subcommand> [positional...] [--key value | --key=value | --flag]
//! ```
//!
//! Typed accessors return `anyhow` errors naming the offending option so the
//! launcher can print a useful message plus usage text.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional arguments (subcommand first, if any).
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options, in definition order.
    options: BTreeMap<String, String>,
    /// Bare `--flag`s.
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I, S>(raw: I) -> Result<Args>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut out = Args::default();
        let mut iter = raw.into_iter().map(Into::into).peekable();
        while let Some(arg) = iter.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    bail!("unexpected bare `--`");
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse the process's own arguments.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// The subcommand (first positional), if present.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(String::as_str)
    }

    /// Raw option lookup.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// Was `--flag` given? (A valued option also counts as present.)
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.options.contains_key(key)
    }

    /// String option with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    /// Typed option, erroring with the option name on parse failure.
    pub fn get<T>(&self, key: &str) -> Result<Option<T>>
    where
        T: std::str::FromStr,
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        match self.opt(key) {
            None => Ok(None),
            Some(s) => Ok(Some(
                s.parse::<T>()
                    .with_context(|| format!("invalid value {s:?} for --{key}"))?,
            )),
        }
    }

    /// Typed option with default.
    pub fn get_or<T>(&self, key: &str, default: T) -> Result<T>
    where
        T: std::str::FromStr,
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        Ok(self.get(key)?.unwrap_or(default))
    }

    /// Required typed option.
    pub fn require<T>(&self, key: &str) -> Result<T>
    where
        T: std::str::FromStr,
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        self.get(key)?
            .ok_or_else(|| anyhow!("missing required option --{key}"))
    }

    /// Comma-separated list option, e.g. `--widths 32,64,128`.
    pub fn list_or<T>(&self, key: &str, default: &[T]) -> Result<Vec<T>>
    where
        T: std::str::FromStr + Clone,
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        match self.opt(key) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .filter(|p| !p.is_empty())
                .map(|p| {
                    p.trim()
                        .parse::<T>()
                        .with_context(|| format!("invalid list element {p:?} for --{key}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().copied()).unwrap()
    }

    #[test]
    fn positional_and_subcommand() {
        let a = parse(&["run", "sum-fixed"]);
        assert_eq!(a.subcommand(), Some("run"));
        assert_eq!(a.positional, vec!["run", "sum-fixed"]);
    }

    #[test]
    fn options_space_and_equals() {
        let a = parse(&["bench", "--n", "1000", "--width=128"]);
        assert_eq!(a.get_or::<usize>("n", 0).unwrap(), 1000);
        assert_eq!(a.get_or::<usize>("width", 0).unwrap(), 128);
    }

    #[test]
    fn flags() {
        let a = parse(&["run", "--stats", "--n", "5"]);
        assert!(a.flag("stats"));
        assert!(!a.flag("quiet"));
        assert!(a.flag("n")); // valued option counts as present
    }

    #[test]
    fn typed_errors_name_the_option() {
        let a = parse(&["--n", "abc"]);
        let err = a.get::<usize>("n").unwrap_err().to_string();
        assert!(err.contains("--n"), "{err}");
    }

    #[test]
    fn required_missing() {
        let a = parse(&[]);
        assert!(a.require::<usize>("n").is_err());
    }

    #[test]
    fn lists() {
        let a = parse(&["--widths", "32,64,128"]);
        assert_eq!(a.list_or("widths", &[1usize]).unwrap(), vec![32, 64, 128]);
        assert_eq!(a.list_or("other", &[7usize]).unwrap(), vec![7]);
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse(&["--threshold", "-1.5"]);
        // "-1.5" does not start with "--" so it is taken as the value
        assert_eq!(a.get_or::<f32>("threshold", 0.0).unwrap(), -1.5);
    }
}
