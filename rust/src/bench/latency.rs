//! `bench latency` — end-to-end region latency under streaming load.
//!
//! Throughput benches (`bench hotpath`, `bench ingest`) answer "how fast
//! does the whole stream finish"; this sweep answers the streaming
//! question the paper's §4 dataflow raises: **how long does one region
//! wait** between ingest submit and its in-order merge emit, and how is
//! that tail shaped by worker count and the in-flight budget? Each leg
//! runs the streamed sum app with live metrics
//! ([`ExecConfig::with_metrics`](crate::exec::ExecConfig::with_metrics))
//! and reports the per-region e2e p50/p99/max alongside queue-wait and
//! service quantiles from the same [`MetricsReport`].
//!
//! Two claims are asserted, not eyeballed, on every leg:
//!
//! * outputs are bit-identical to the unmetered run (metering never
//!   perturbs scheduling);
//! * the report reconciles — every submitted region was emitted and the
//!   e2e histogram saw exactly one sample per region.
//!
//! The headline numbers are **informational** — latency on shared CI
//! boxes is too noisy to ratchet, so CI uploads `BENCH_latency.json` as
//! an artifact for trend inspection instead of gating on it.

use anyhow::{ensure, Result};

use crate::apps::sum::{SumConfig, SumFactory};
use crate::exec::{ExecConfig, KernelSpawn, ShardedRunner};
use crate::metrics::MetricsReport;
use crate::workload::regions::{GenBlobSource, RegionSpec};

use super::{BenchConfig, Table};

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct LatencyConfig {
    /// SIMD ensemble width.
    pub width: usize,
    /// Total stream items.
    pub items: usize,
    /// Worker counts to sweep (one leg each).
    pub workers: Vec<usize>,
    /// Streaming in-flight region budget.
    pub budget: usize,
    /// Workload PRNG seed.
    pub seed: u64,
    /// Iteration counts for timing (the last iteration's report is kept).
    pub bench: BenchConfig,
}

impl LatencyConfig {
    /// CI smoke shape: small stream, two worker counts.
    pub fn smoke() -> LatencyConfig {
        LatencyConfig {
            width: 32,
            items: 1 << 14,
            workers: vec![1, 4],
            budget: 256,
            seed: 0x1A7E,
            bench: BenchConfig {
                warmup_iters: 1,
                iters: 2,
            },
        }
    }
}

impl Default for LatencyConfig {
    fn default() -> Self {
        LatencyConfig {
            width: 128,
            items: 1 << 17,
            workers: vec![1, 2, 4, 8],
            budget: 1024,
            seed: 0x1A7E,
            bench: BenchConfig::from_env(),
        }
    }
}

/// One measured leg: a worker count with its latency quantiles (ms).
#[derive(Debug, Clone)]
pub struct LatencyRow {
    /// Worker threads in this leg.
    pub workers: usize,
    /// Wall-clock seconds of the metered run.
    pub seconds: f64,
    /// Regions emitted per second.
    pub rate: f64,
    /// End-to-end per-region latency quantiles, milliseconds.
    pub e2e_p50_ms: f64,
    /// End-to-end p99, milliseconds.
    pub e2e_p99_ms: f64,
    /// End-to-end maximum, milliseconds.
    pub e2e_max_ms: f64,
    /// Shard queue-wait p99, milliseconds.
    pub queue_p99_ms: f64,
    /// Shard service-time p99, milliseconds.
    pub service_p99_ms: f64,
}

/// Full report (also the `BENCH_latency.json` payload).
#[derive(Debug, Clone)]
pub struct LatencyReport {
    /// Total stream items.
    pub items: usize,
    /// Regions in the stream.
    pub regions: usize,
    /// Streaming in-flight budget.
    pub budget: usize,
    /// Measured legs, one per worker count.
    pub rows: Vec<LatencyRow>,
}

fn ms(ns: u64) -> f64 {
    ns as f64 / 1e6
}

fn factory(cfg: &LatencyConfig) -> SumFactory {
    SumFactory::new(
        SumConfig {
            width: cfg.width,
            ..Default::default()
        },
        KernelSpawn::Native,
    )
}

fn source(cfg: &LatencyConfig) -> GenBlobSource {
    GenBlobSource::new(cfg.items, RegionSpec::Uniform { max: 2 * cfg.width }, cfg.seed)
}

/// Run the sweep and print the table.
pub fn run(cfg: &LatencyConfig) -> Result<LatencyReport> {
    ensure!(!cfg.workers.is_empty(), "bench latency: empty worker sweep");
    let mut rows = Vec::new();
    let mut regions = 0usize;
    for &workers in &cfg.workers {
        let exec = ExecConfig::new(workers).streaming(cfg.budget);
        // the unmetered oracle: metering must not change a single bit
        let plain = ShardedRunner::new(exec.clone()).run_stream(&factory(cfg), source(cfg))?;

        let metered_runner = ShardedRunner::new(exec.with_metrics(true));
        let mut last = None;
        for _ in 0..cfg.bench.warmup_iters + cfg.bench.iters.max(1) {
            last = Some(metered_runner.run_stream(&factory(cfg), source(cfg))?);
        }
        let report = last.expect("at least one iteration");
        ensure!(
            report.outputs.len() == plain.outputs.len(),
            "latency[{workers}w]: {} outputs vs unmetered {}",
            report.outputs.len(),
            plain.outputs.len()
        );
        for (i, ((gi, gv), (wi, wv))) in report.outputs.iter().zip(&plain.outputs).enumerate() {
            ensure!(
                gi == wi && gv.to_bits() == wv.to_bits(),
                "latency[{workers}w]: output {i} diverged from the unmetered run"
            );
        }
        let m: &MetricsReport = report
            .metrics_report
            .as_ref()
            .expect("metered run attaches a MetricsReport");
        let t = &m.totals;
        ensure!(
            t.submitted_regions == t.emitted_regions,
            "latency[{workers}w]: {} submitted vs {} emitted",
            t.submitted_regions,
            t.emitted_regions
        );
        ensure!(
            t.e2e.count == t.emitted_regions,
            "latency[{workers}w]: e2e saw {} samples for {} regions",
            t.e2e.count,
            t.emitted_regions
        );
        regions = t.emitted_regions as usize;
        rows.push(LatencyRow {
            workers,
            seconds: report.elapsed,
            rate: m.emit_rate(),
            e2e_p50_ms: ms(t.e2e.quantile_ns(0.5)),
            e2e_p99_ms: ms(t.e2e.quantile_ns(0.99)),
            e2e_max_ms: ms(t.e2e.max_ns),
            queue_p99_ms: ms(t.queue_wait.quantile_ns(0.99)),
            service_p99_ms: ms(t.service.quantile_ns(0.99)),
        });
    }

    let mut t = Table::new(&[
        "workers",
        "time_s",
        "regions/s",
        "e2e_p50_ms",
        "e2e_p99_ms",
        "e2e_max_ms",
        "queue_p99_ms",
        "service_p99_ms",
    ]);
    for r in &rows {
        t.row(&[
            r.workers.to_string(),
            format!("{:.4}", r.seconds),
            format!("{:.0}", r.rate),
            format!("{:.3}", r.e2e_p50_ms),
            format!("{:.3}", r.e2e_p99_ms),
            format!("{:.3}", r.e2e_max_ms),
            format!("{:.3}", r.queue_p99_ms),
            format!("{:.3}", r.service_p99_ms),
        ]);
    }
    println!("== Latency: submit -> in-order emit, per region (informational) ==");
    t.print();

    Ok(LatencyReport {
        items: cfg.items,
        regions,
        budget: cfg.budget,
        rows,
    })
}

/// Render the report as the `BENCH_latency.json` artifact.
pub fn to_json(report: &LatencyReport) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"latency\",\n");
    s.push_str("  \"informational\": true,\n");
    s.push_str(&format!("  \"items\": {},\n", report.items));
    s.push_str(&format!("  \"regions\": {},\n", report.regions));
    s.push_str(&format!("  \"budget\": {},\n", report.budget));
    s.push_str("  \"rows\": [\n");
    for (i, r) in report.rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"workers\": {}, \"seconds\": {:.6}, \"rate\": {:.1}, \
             \"e2e_p50_ms\": {:.4}, \"e2e_p99_ms\": {:.4}, \"e2e_max_ms\": {:.4}, \
             \"queue_p99_ms\": {:.4}, \"service_p99_ms\": {:.4}}}{}\n",
            r.workers,
            r.seconds,
            r.rate,
            r.e2e_p50_ms,
            r.e2e_p99_ms,
            r.e2e_max_ms,
            r.queue_p99_ms,
            r.service_p99_ms,
            if i + 1 < report.rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n");
    s.push_str("}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn sweep_measures_and_emits_json() {
        let cfg = LatencyConfig {
            width: 8,
            items: 1 << 10,
            workers: vec![1, 2],
            budget: 64,
            seed: 3,
            bench: BenchConfig {
                warmup_iters: 0,
                iters: 1,
            },
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.rows.len(), 2);
        assert!(report.regions > 0);
        for r in &report.rows {
            assert!(r.e2e_max_ms >= r.e2e_p99_ms);
            assert!(r.e2e_p99_ms >= r.e2e_p50_ms);
            assert!(r.rate > 0.0);
        }
        let js = to_json(&report);
        let parsed = Json::parse(&js).expect("emitted JSON parses");
        assert_eq!(
            parsed.get("rows").and_then(Json::as_arr).map(Vec::len),
            Some(2)
        );
        assert_eq!(parsed.get("informational"), Some(&Json::Bool(true)));
    }

    #[test]
    fn empty_worker_sweep_is_a_named_error() {
        let cfg = LatencyConfig {
            workers: vec![],
            ..LatencyConfig::smoke()
        };
        let err = run(&cfg).unwrap_err();
        assert!(err.to_string().contains("empty worker sweep"), "{err}");
    }
}
