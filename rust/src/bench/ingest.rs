//! `bench ingest` — streaming shard ingest + work stealing sweep.
//!
//! Crosses region-size **distribution** (uniform vs heavy-tailed skewed)
//! with worker count and executor mode:
//!
//! * `cursor` — materialized plan, legacy single atomic cursor (the
//!   pre-stealing baseline, kept exactly for this comparison);
//! * `steal` — materialized plan, per-worker deques with LIFO-local /
//!   FIFO-steal claiming;
//! * `stream-nosteal` — streaming ingest onto per-worker deques, no
//!   stealing (isolates what stealing buys once ingest is online);
//! * `stream-steal` — the full v2 path: bounded-budget streaming ingest
//!   plus stealing.
//!
//! Skewed streams put most of the weight into a few huge regions, so
//! static round-robin dealing strands work behind them — the
//! configuration where stealing should win. Every mode's sum outputs are
//! asserted **bit-identical** to the cursor baseline before its time is
//! recorded, so the sweep doubles as an equivalence check.
//!
//! A third distribution, `giant`, is the planner's absolute worst case:
//! **one region spans the whole stream**, so without intra-region
//! splitting every worker but one idles (stealing can't help — there is
//! nothing to steal). Its two modes compare `stream-nosplit` (the 1×
//! straggler baseline) against `stream-split`
//! ([`ExecConfig::max_region_items`] = width, the finest ensemble-aligned
//! cut), asserting the split outputs bit-identical to the unsplit run;
//! [`giant_region_speedup`] is the headline.
//!
//! Results are emitted as `BENCH_ingest.json` and uploaded as a CI
//! artifact (`--smoke` runs a small shape in the pipeline).

use anyhow::{ensure, Result};

use crate::apps::sum::{SumConfig, SumFactory};
use crate::exec::{ClaimMode, ExecConfig, KernelSpawn, ShardedRunner};
use crate::util::stats::fmt_count;
use crate::workload::regions::{gen_blobs, RegionSpec};
use crate::workload::source::SliceSource;

use super::{time_fn, BenchConfig, Table};

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct IngestConfig {
    /// SIMD ensemble width.
    pub width: usize,
    /// Total stream items per point.
    pub items: usize,
    /// Worker counts to sweep.
    pub workers: Vec<usize>,
    /// Streaming in-flight budget (regions).
    pub buffer_regions: usize,
    /// Iteration counts for timing.
    pub bench: BenchConfig,
    /// Workload PRNG seed.
    pub seed: u64,
}

impl IngestConfig {
    /// CI smoke shape: small stream, warmed medians.
    pub fn smoke() -> IngestConfig {
        IngestConfig {
            width: 32,
            items: 1 << 14,
            workers: vec![2, 4],
            buffer_regions: 256,
            bench: BenchConfig {
                warmup_iters: 1,
                iters: 3,
            },
            seed: 0xF16,
        }
    }
}

impl Default for IngestConfig {
    fn default() -> Self {
        IngestConfig {
            width: 128,
            items: 1 << 18,
            workers: vec![1, 2, 4, 8],
            buffer_regions: 1024,
            bench: BenchConfig::from_env(),
            seed: 0xF16,
        }
    }
}

/// One measured point.
#[derive(Debug, Clone)]
pub struct IngestRow {
    /// Region-size distribution label.
    pub dist: &'static str,
    /// Worker threads.
    pub workers: usize,
    /// Executor mode label.
    pub mode: &'static str,
    /// Median seconds per run.
    pub seconds: f64,
    /// Items per second.
    pub items_per_sec: f64,
    /// Shards the stream was cut into.
    pub shards: usize,
    /// Successful steals observed.
    pub steals: usize,
    /// Mean worker busy fraction.
    pub utilization: f64,
}

/// Full report (also the JSON payload).
#[derive(Debug, Clone)]
pub struct IngestReport {
    /// Total stream items per point.
    pub items: usize,
    /// Streaming in-flight budget (regions).
    pub buffer_regions: usize,
    /// Measured points.
    pub rows: Vec<IngestRow>,
}

fn distributions(width: usize) -> [(&'static str, RegionSpec); 2] {
    [
        ("uniform", RegionSpec::Uniform { max: 2 * width }),
        ("skewed", RegionSpec::Skewed { max: 16 * width }),
    ]
}

/// Run the sweep and print the table.
pub fn run(cfg: &IngestConfig) -> Result<IngestReport> {
    let mut rows = Vec::new();
    for (dist, spec) in distributions(cfg.width) {
        let blobs = gen_blobs(cfg.items, spec, cfg.seed);
        let factory = SumFactory::new(
            SumConfig {
                width: cfg.width,
                ..Default::default()
            },
            KernelSpawn::Native,
        );
        for &workers in &cfg.workers {
            let mut baseline: Option<Vec<(u64, f64)>> = None;
            for (mode, claim, streamed) in [
                ("cursor", ClaimMode::Cursor, false),
                ("steal", ClaimMode::Steal, false),
                ("stream-nosteal", ClaimMode::NoSteal, true),
                ("stream-steal", ClaimMode::Steal, true),
            ] {
                let exec = ExecConfig::new(workers)
                    .with_shards_per_worker(4)
                    .streaming(cfg.buffer_regions)
                    .with_claim(claim);
                let runner = ShardedRunner::new(exec);
                let mut last = None;
                let m = time_fn(cfg.bench, || {
                    // streamed rows replay the SAME materialized blobs
                    // through a SliceSource, so the mode comparison
                    // measures the executor, not stream generation (the
                    // per-region clone is the minimal owned-region cost
                    // any real source pays; lazy generation itself is
                    // GenBlobSource's job and is covered by the tests)
                    let report = if streamed {
                        runner
                            .run_stream(&factory, SliceSource::new(&blobs))
                            .expect("streamed ingest run")
                    } else {
                        runner.run(&factory, &blobs).expect("materialized run")
                    };
                    last = Some(report);
                });
                let report = last.expect("at least one iteration");
                ensure!(
                    report.outputs.len() == blobs.len(),
                    "{dist}/{mode}/{workers}w: lost regions: {} of {}",
                    report.outputs.len(),
                    blobs.len()
                );
                // every mode must be bit-identical to the cursor baseline
                // (region-local pipeline: sharding must change nothing)
                match &baseline {
                    None => baseline = Some(report.outputs.clone()),
                    Some(base) => {
                        for (i, ((gi, gv), (bi, bv))) in
                            report.outputs.iter().zip(base).enumerate()
                        {
                            ensure!(
                                gi == bi && gv.to_bits() == bv.to_bits(),
                                "{dist}/{mode}/{workers}w: output {i} diverged from cursor"
                            );
                        }
                    }
                }
                rows.push(IngestRow {
                    dist,
                    workers,
                    mode,
                    seconds: m.median(),
                    items_per_sec: cfg.items as f64 / m.median(),
                    shards: report.shards,
                    steals: report.steals,
                    utilization: report.utilization(),
                });
            }
        }
    }

    // The giant leg: one region spans the whole stream. Stealing is
    // powerless here (there is exactly one unit of work), so the modes
    // compare the unsplit straggler baseline against intra-region
    // splitting at the finest ensemble-aligned threshold (= width).
    {
        let dist = "giant";
        let blobs = gen_blobs(cfg.items, RegionSpec::Fixed { size: cfg.items }, cfg.seed);
        ensure!(
            blobs.len() == 1,
            "giant leg expects one region spanning the stream, got {}",
            blobs.len()
        );
        let factory = SumFactory::new(
            SumConfig {
                width: cfg.width,
                ..Default::default()
            },
            KernelSpawn::Native,
        );
        for &workers in &cfg.workers {
            let mut baseline: Option<Vec<(u64, f64)>> = None;
            for (mode, max_region_items) in
                [("stream-nosplit", 0usize), ("stream-split", cfg.width)]
            {
                let exec = ExecConfig::new(workers)
                    .with_shards_per_worker(4)
                    .streaming(cfg.buffer_regions)
                    .with_max_region_items(max_region_items);
                let runner = ShardedRunner::new(exec);
                let mut last = None;
                let m = time_fn(cfg.bench, || {
                    let report = runner
                        .run_stream(&factory, SliceSource::new(&blobs))
                        .expect("giant-region run");
                    last = Some(report);
                });
                let report = last.expect("at least one iteration");
                ensure!(
                    report.outputs.len() == 1,
                    "giant/{mode}/{workers}w: expected one folded region sum, got {}",
                    report.outputs.len()
                );
                if max_region_items > 0 {
                    ensure!(
                        report.split_regions == 1,
                        "giant/{mode}/{workers}w: the giant region was not split"
                    );
                }
                // the split run must be bit-identical to the unsplit one
                match &baseline {
                    None => baseline = Some(report.outputs.clone()),
                    Some(base) => {
                        let ((gi, gv), (bi, bv)) = (&report.outputs[0], &base[0]);
                        ensure!(
                            gi == bi && gv.to_bits() == bv.to_bits(),
                            "giant/{mode}/{workers}w: split sum diverged from unsplit"
                        );
                    }
                }
                rows.push(IngestRow {
                    dist,
                    workers,
                    mode,
                    seconds: m.median(),
                    items_per_sec: cfg.items as f64 / m.median(),
                    shards: report.shards,
                    steals: report.steals,
                    utilization: report.utilization(),
                });
            }
        }
    }

    let mut t = Table::new(&[
        "dist", "workers", "mode", "time_s", "items/s", "shards", "steals", "util%",
    ]);
    for r in &rows {
        t.row(&[
            r.dist.to_string(),
            r.workers.to_string(),
            r.mode.to_string(),
            format!("{:.4}", r.seconds),
            fmt_count(r.items_per_sec),
            r.shards.to_string(),
            r.steals.to_string(),
            format!("{:.0}", 100.0 * r.utilization),
        ]);
    }
    println!("== Ingest: streaming + stealing vs materialized cursor ==");
    t.print();

    Ok(IngestReport {
        items: cfg.items,
        buffer_regions: cfg.buffer_regions,
        rows,
    })
}

/// Headline metric: skewed-distribution speedup of the full streaming +
/// stealing path over the legacy cursor at the largest measured worker
/// count (`None` if either point is missing).
pub fn skew_speedup(report: &IngestReport) -> Option<f64> {
    let max_workers = report.rows.iter().map(|r| r.workers).max()?;
    let pick = |mode: &str| {
        report
            .rows
            .iter()
            .find(|r| r.dist == "skewed" && r.workers == max_workers && r.mode == mode)
            .map(|r| r.seconds)
    };
    Some(pick("cursor")? / pick("stream-steal")?)
}

/// Headline metric: on the one-giant-region stream, speedup of
/// intra-region splitting over the unsplit straggler baseline at the
/// largest measured worker count (`None` if either point is missing).
pub fn giant_region_speedup(report: &IngestReport) -> Option<f64> {
    let max_workers = report
        .rows
        .iter()
        .filter(|r| r.dist == "giant")
        .map(|r| r.workers)
        .max()?;
    let pick = |mode: &str| {
        report
            .rows
            .iter()
            .find(|r| r.dist == "giant" && r.workers == max_workers && r.mode == mode)
            .map(|r| r.seconds)
    };
    Some(pick("stream-nosplit")? / pick("stream-split")?)
}

/// Render the report as the `BENCH_ingest.json` artifact.
pub fn to_json(report: &IngestReport) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"ingest\",\n");
    s.push_str(&format!("  \"items\": {},\n", report.items));
    s.push_str(&format!(
        "  \"buffer_regions\": {},\n",
        report.buffer_regions
    ));
    s.push_str("  \"rows\": [\n");
    for (i, r) in report.rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"dist\": \"{}\", \"workers\": {}, \"mode\": \"{}\", \
             \"seconds\": {:.6}, \"items_per_sec\": {:.1}, \"shards\": {}, \
             \"steals\": {}, \"utilization\": {:.4}}}{}\n",
            r.dist,
            r.workers,
            r.mode,
            r.seconds,
            r.items_per_sec,
            r.shards,
            r.steals,
            r.utilization,
            if i + 1 < report.rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!(
        "  \"skew_steal_vs_cursor_speedup\": {:.4},\n",
        skew_speedup(report).unwrap_or(0.0)
    ));
    s.push_str(&format!(
        "  \"giant_region_speedup\": {:.4}\n",
        giant_region_speedup(report).unwrap_or(0.0)
    ));
    s.push_str("}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn tiny_cfg() -> IngestConfig {
        IngestConfig {
            width: 8,
            items: 1 << 10,
            workers: vec![1, 2],
            buffer_regions: 32,
            bench: BenchConfig {
                warmup_iters: 0,
                iters: 1,
            },
            seed: 7,
        }
    }

    #[test]
    fn sweep_produces_rows_and_json() {
        let report = run(&tiny_cfg()).unwrap();
        // dists x workers x modes, plus the giant leg's workers x 2 modes
        assert_eq!(report.rows.len(), 2 * 2 * 4 + 2 * 2);
        for r in &report.rows {
            assert!(r.items_per_sec > 0.0, "{}/{}", r.dist, r.mode);
            assert!(r.shards > 0);
        }
        let js = to_json(&report);
        let parsed = Json::parse(&js).expect("emitted JSON parses");
        assert!(parsed.get("rows").is_some());
        assert!(parsed.get("skew_steal_vs_cursor_speedup").is_some());
        assert!(parsed.get("giant_region_speedup").is_some());
        assert!(skew_speedup(&report).is_some());
        assert!(giant_region_speedup(&report).is_some());
    }

    #[test]
    fn giant_leg_splits_and_reports_both_modes() {
        let report = run(&tiny_cfg()).unwrap();
        let giant: Vec<_> = report.rows.iter().filter(|r| r.dist == "giant").collect();
        assert_eq!(giant.len(), 2 * 2, "workers x {{nosplit, split}}");
        for r in &giant {
            match r.mode {
                // one region, one shard: the straggler baseline
                "stream-nosplit" => assert_eq!(r.shards, 1, "{}w", r.workers),
                // split at width => many parts => more than one shard
                "stream-split" => assert!(r.shards > 1, "{}w: {} shards", r.workers, r.shards),
                other => panic!("unexpected giant mode {other}"),
            }
        }
    }
}
