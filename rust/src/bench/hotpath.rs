//! `bench hotpath` — the zero-allocation firing-path sweep.
//!
//! Two measurement tiers, both on the native backend (the XLA backend's
//! PJRT boundary owns its own buffers and would mask the coordinator
//! cost this PR optimizes):
//!
//! 1. **Firing-path microbench** (`firing_path`): a two-stage
//!    filter→sum flow over real [`DataQueue`]s, region by region, in two
//!    modes:
//!    * `legacy` — the pre-PR behaviour: per-item queue pops/pushes and
//!      `Vec`-allocating scalar kernels ([`native::scalar`]);
//!    * `hot` — the rewritten path: bulk `pop_into`/`push_slice`,
//!      staging buffers, in-place branchless kernels.
//!    The two modes produce bit-identical sums (asserted), so the
//!    speedup isolates the overhead this PR removes. These are the
//!    before/after numbers the acceptance criterion quotes.
//! 2. **App sweep** (`app_rows`): full `SumApp` runs across
//!    width × region size × scheduling policy, reporting items/sec,
//!    occupancy and allocations-per-firing (per-thread allocation
//!    counter over the whole run, construction included — the
//!    steady-state zero is pinned exactly by `tests/hotpath_alloc.rs`).
//! 3. **Rebuild-vs-reuse sweep** (`reuse`): the same region stream cut
//!    into shards at several granularities, run once building a fresh
//!    pipeline per shard (the pre-reuse executor behaviour) and once
//!    resetting a persistent [`SumPipeline`] — outputs asserted
//!    bit-identical, so the speedup isolates the graph-rebuild overhead.
//!    The `reuse_vs_rebuild_speedup` headline (finest granularity =
//!    many small shards) is gated by the baseline's
//!    `min_reuse_speedup`.
//! 4. **Trace-overhead tier** (`trace_overhead`): the same sharded sum
//!    run through the executor untraced and with event tracing on
//!    (`ExecConfig::with_trace`), outputs asserted bit-identical.
//!    *Informational only* — tracing is opt-in and off by default, so
//!    the cost is reported, not gated; it keeps the "cheap when on"
//!    claim honest in every benchmark artifact.
//!
//! Results are emitted as `BENCH_hotpath.json` (hand-rolled writer; the
//! vendored JSON module only parses) and checked against
//! `rust/benches/baselines/hotpath_baseline.json` in CI: the firing-path
//! speedup at the widest measured width must stay within 20% of the
//! recorded baseline.

use std::rc::Rc;

use anyhow::{ensure, Context, Result};

use crate::apps::sum::{SumApp, SumConfig, SumFactory, SumMode, SumPipeline, SumShape};
use crate::apps::prefix_mask;
use crate::coordinator::queue::DataQueue;
use crate::coordinator::scheduler::Policy;
use crate::exec::{ExecConfig, KernelSpawn, ShardedRunner};
use crate::runtime::kernels::{Backend, KernelSet};
use crate::runtime::native;
use crate::trace::TraceOptions;
use crate::util::alloc_count;
use crate::util::json::Json;
use crate::util::stats::fmt_count;
use crate::workload::regions::{gen_blobs, RegionSpec};

use super::{time_fn, BenchConfig, Table};

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct HotpathConfig {
    /// Ensemble widths to sweep.
    pub widths: Vec<usize>,
    /// Total stream items per point.
    pub items: usize,
    /// Scheduling policies to cross with the widths.
    pub policies: Vec<Policy>,
    /// Shard granularities (regions per shard) for the rebuild-vs-reuse
    /// sweep — smallest first = the many-small-shards headline point.
    pub reuse_granules: Vec<usize>,
    /// Iteration counts for timing.
    pub bench: BenchConfig,
    /// Workload PRNG seed.
    pub seed: u64,
}

impl HotpathConfig {
    /// CI smoke shape: small stream, but enough iterations (1 warmup +
    /// median of 3) that the regression gate compares warmed medians,
    /// not single cold samples.
    pub fn smoke() -> HotpathConfig {
        HotpathConfig {
            widths: vec![32, 128],
            items: 1 << 14,
            policies: vec![Policy::GreedyOccupancy],
            reuse_granules: vec![1, 4, 16],
            bench: BenchConfig {
                warmup_iters: 1,
                iters: 3,
            },
            seed: 0xF16,
        }
    }
}

impl Default for HotpathConfig {
    fn default() -> Self {
        HotpathConfig {
            widths: vec![32, 128],
            items: 1 << 18,
            policies: vec![
                Policy::GreedyOccupancy,
                Policy::DeepestFirst,
                Policy::RoundRobin,
            ],
            reuse_granules: vec![1, 4, 16, 64],
            bench: BenchConfig::from_env(),
            seed: 0xF16,
        }
    }
}

/// One firing-path comparison point.
#[derive(Debug, Clone)]
pub struct FiringRow {
    /// SIMD ensemble width.
    pub width: usize,
    /// Region size (items).
    pub region: usize,
    /// Throughput of the legacy rebuild-per-shard firing path.
    pub legacy_items_per_sec: f64,
    /// Throughput of the allocation-free hot firing path.
    pub hot_items_per_sec: f64,
    /// Hot over legacy throughput.
    pub speedup: f64,
    /// Heap allocations per firing on the legacy path.
    pub legacy_allocs_per_firing: f64,
    /// Heap allocations per firing on the hot path.
    pub hot_allocs_per_firing: f64,
}

/// One full-app sweep point.
#[derive(Debug, Clone)]
pub struct AppRow {
    /// SIMD ensemble width.
    pub width: usize,
    /// Region size (items).
    pub region: usize,
    /// Scheduling policy label.
    pub policy: &'static str,
    /// Items per second.
    pub items_per_sec: f64,
    /// Mean ensemble occupancy.
    pub occupancy: f64,
    /// Heap allocations per firing at steady state.
    pub allocs_per_firing: f64,
}

/// One rebuild-vs-reuse comparison point (persistent-pipeline sweep).
#[derive(Debug, Clone)]
pub struct ReuseRow {
    /// Shard granularity: regions per shard.
    pub regions_per_shard: usize,
    /// Shards the stream was cut into.
    pub shards: usize,
    /// Throughput when rebuilding the pipeline for every shard.
    pub rebuild_items_per_sec: f64,
    /// Throughput when resetting the persistent pipeline instead.
    pub reuse_items_per_sec: f64,
    /// rebuild time / reuse time (> 1 = reuse wins).
    pub speedup: f64,
}

/// One trace-overhead comparison point. Informational — tracing is
/// opt-in and off by default, so this row is reported, never gated.
#[derive(Debug, Clone)]
pub struct TraceRow {
    /// Worker threads.
    pub workers: usize,
    /// Throughput with tracing disabled.
    pub untraced_items_per_sec: f64,
    /// Throughput with tracing enabled.
    pub traced_items_per_sec: f64,
    /// `traced time / untraced time - 1`, as a percentage (> 0 = the
    /// traced run was slower).
    pub overhead_pct: f64,
}

/// Full report (also the JSON payload).
#[derive(Debug, Clone)]
pub struct HotpathReport {
    /// Total stream items per point.
    pub items: usize,
    /// Firing-path comparison rows.
    pub firing: Vec<FiringRow>,
    /// App-level policy rows.
    pub apps: Vec<AppRow>,
    /// Pipeline rebuild-vs-reuse rows.
    pub reuse: Vec<ReuseRow>,
    /// Trace-overhead rows.
    pub trace: Vec<TraceRow>,
}

/// Run the sweep and print the tables.
pub fn run(cfg: &HotpathConfig) -> Result<HotpathReport> {
    let mut firing = Vec::new();
    let mut apps = Vec::new();
    for &width in &cfg.widths {
        for region in [width / 2, width, 4 * width] {
            if region == 0 {
                continue;
            }
            firing.push(firing_path_point(cfg, width, region)?);
            for &policy in &cfg.policies {
                apps.push(app_point(cfg, width, region, policy)?);
            }
        }
    }
    // rebuild-vs-reuse at the widest measured width only: the sweep
    // isolates coordinator-graph construction cost, which does not vary
    // with width nearly as much as it does with shard granularity
    let mut reuse = Vec::new();
    if let Some(&width) = cfg.widths.iter().max() {
        for &granule in &cfg.reuse_granules {
            reuse.push(reuse_point(cfg, width, granule)?);
        }
    }
    // trace overhead at the widest width, inline and threaded
    let mut trace = Vec::new();
    if let Some(&width) = cfg.widths.iter().max() {
        for workers in [1usize, 4] {
            trace.push(trace_point(cfg, width, workers)?);
        }
    }

    let mut t = Table::new(&[
        "width", "region", "legacy/s", "hot/s", "speedup", "allocs/firing L", "allocs/firing H",
    ]);
    for r in &firing {
        t.row(&[
            r.width.to_string(),
            r.region.to_string(),
            fmt_count(r.legacy_items_per_sec),
            fmt_count(r.hot_items_per_sec),
            format!("{:.2}x", r.speedup),
            format!("{:.2}", r.legacy_allocs_per_firing),
            format!("{:.3}", r.hot_allocs_per_firing),
        ]);
    }
    println!("== Hotpath: firing path, legacy (per-item + alloc) vs hot (bulk + in-place) ==");
    t.print();

    let mut t = Table::new(&["width", "region", "policy", "items/s", "occ%", "allocs/firing"]);
    for r in &apps {
        t.row(&[
            r.width.to_string(),
            r.region.to_string(),
            r.policy.to_string(),
            fmt_count(r.items_per_sec),
            format!("{:.1}", 100.0 * r.occupancy),
            format!("{:.3}", r.allocs_per_firing),
        ]);
    }
    println!("== Hotpath: full sum app, width x region x policy ==");
    t.print();

    let mut t = Table::new(&["regions/shard", "shards", "rebuild/s", "reuse/s", "speedup"]);
    for r in &reuse {
        t.row(&[
            r.regions_per_shard.to_string(),
            r.shards.to_string(),
            fmt_count(r.rebuild_items_per_sec),
            fmt_count(r.reuse_items_per_sec),
            format!("{:.2}x", r.speedup),
        ]);
    }
    println!("== Hotpath: per-shard pipeline, rebuild vs reset-and-reuse ==");
    t.print();

    let mut t = Table::new(&["workers", "untraced/s", "traced/s", "overhead%"]);
    for r in &trace {
        t.row(&[
            r.workers.to_string(),
            fmt_count(r.untraced_items_per_sec),
            fmt_count(r.traced_items_per_sec),
            format!("{:+.1}", r.overhead_pct),
        ]);
    }
    println!("== Hotpath: event tracing off vs on (informational, no gate) ==");
    t.print();

    Ok(HotpathReport {
        items: cfg.items,
        firing,
        apps,
        reuse,
        trace,
    })
}

/// One trace-overhead point: the same materialized sum stream through
/// the sharded executor untraced and with tracing on, outputs asserted
/// bit-identical so the delta isolates the recording cost (a clock read
/// plus a 32-byte store per firing/shard event).
fn trace_point(cfg: &HotpathConfig, width: usize, workers: usize) -> Result<TraceRow> {
    let blobs = gen_blobs(cfg.items, RegionSpec::Fixed { size: width }, cfg.seed);
    let factory = SumFactory::new(
        SumConfig {
            width,
            mode: SumMode::Enumerated,
            shape: SumShape::Fused,
            ..Default::default()
        },
        KernelSpawn::from_backend(Backend::Native),
    );
    let untraced = ShardedRunner::new(ExecConfig::new(workers));
    let traced = ShardedRunner::new(
        ExecConfig::new(workers).with_trace(Some(TraceOptions { capacity: 1 << 16 })),
    );
    let mut out_off: Vec<(u64, f64)> = Vec::new();
    let m_off = time_fn(cfg.bench, || {
        out_off = untraced.run(&factory, &blobs).expect("untraced run").outputs;
    });
    let mut out_on: Vec<(u64, f64)> = Vec::new();
    let m_on = time_fn(cfg.bench, || {
        out_on = traced.run(&factory, &blobs).expect("traced run").outputs;
    });
    ensure!(
        out_off.len() == out_on.len(),
        "trace sweep: output counts diverged ({} vs {})",
        out_off.len(),
        out_on.len()
    );
    for ((gi, gv), (wi, wv)) in out_on.iter().zip(&out_off) {
        ensure!(
            gi == wi && gv.to_bits() == wv.to_bits(),
            "trace sweep: outputs diverged at region {gi} ({gv} vs {wv})"
        );
    }
    Ok(TraceRow {
        workers,
        untraced_items_per_sec: cfg.items as f64 / m_off.median(),
        traced_items_per_sec: cfg.items as f64 / m_on.median(),
        overhead_pct: 100.0 * (m_on.median() / m_off.median() - 1.0),
    })
}

/// One rebuild-vs-reuse point: the same region stream cut into shards of
/// `regions_per_shard` items each, run (a) building a fresh pipeline per
/// shard — the pre-reuse executor behaviour — and (b) resetting one
/// persistent [`SumPipeline`]. Outputs are asserted bit-identical, so
/// the speedup isolates exactly the graph-rebuild overhead.
fn reuse_point(cfg: &HotpathConfig, width: usize, regions_per_shard: usize) -> Result<ReuseRow> {
    // small regions: per-shard compute is tiny, so the rebuild series is
    // dominated by the construction cost this sweep isolates
    let region = (width / 4).max(1);
    let blobs = gen_blobs(cfg.items, RegionSpec::Fixed { size: region }, cfg.seed);
    let granule = regions_per_shard.max(1);
    let sum_cfg = SumConfig {
        width,
        mode: SumMode::Enumerated,
        shape: SumShape::Fused,
        ..Default::default()
    };
    let kernels = Rc::new(KernelSet::native(width));
    let app = SumApp::new(sum_cfg, kernels.clone());

    let mut rebuild_out: Vec<(u64, f64)> = Vec::new();
    let m_rebuild = time_fn(cfg.bench, || {
        rebuild_out.clear();
        for shard in blobs.chunks(granule) {
            let r = app.run(shard).expect("rebuild shard run");
            rebuild_out.extend(r.outputs);
        }
    });

    let mut pipeline = SumPipeline::build(sum_cfg, kernels);
    let mut reuse_out: Vec<(u64, f64)> = Vec::new();
    let m_reuse = time_fn(cfg.bench, || {
        reuse_out.clear();
        for shard in blobs.chunks(granule) {
            let (outputs, _metrics) = pipeline.run_shard(shard).expect("reuse shard run");
            reuse_out.extend(outputs);
        }
    });

    ensure!(
        rebuild_out.len() == reuse_out.len(),
        "reuse sweep: output counts diverged ({} vs {})",
        rebuild_out.len(),
        reuse_out.len()
    );
    for ((gi, gv), (wi, wv)) in reuse_out.iter().zip(&rebuild_out) {
        ensure!(
            gi == wi && gv.to_bits() == wv.to_bits(),
            "reuse sweep: outputs diverged at region {gi} ({gv} vs {wv})"
        );
    }

    Ok(ReuseRow {
        regions_per_shard: granule,
        shards: blobs.chunks(granule).count(),
        rebuild_items_per_sec: cfg.items as f64 / m_rebuild.median(),
        reuse_items_per_sec: cfg.items as f64 / m_reuse.median(),
        speedup: m_rebuild.median() / m_reuse.median(),
    })
}

/// The firing-path microbench: two-stage filter→sum over real queues.
fn firing_path_point(cfg: &HotpathConfig, width: usize, region: usize) -> Result<FiringRow> {
    let blobs = gen_blobs(cfg.items, RegionSpec::Fixed { size: region }, cfg.seed);
    let (legacy_secs, legacy_allocs, legacy_firings, legacy_sum) =
        firing_loop(cfg, width, &blobs, true);
    let (hot_secs, hot_allocs, hot_firings, hot_sum) = firing_loop(cfg, width, &blobs, false);
    // the two paths are bit-identical by construction (property-tested);
    // a mismatch here means the bench itself diverged
    ensure!(
        legacy_sum.to_bits() == hot_sum.to_bits(),
        "firing-path modes disagree: legacy {legacy_sum} vs hot {hot_sum}"
    );
    // allocations are counted over warmup + timed iterations; firings are
    // per iteration
    let iters = (cfg.bench.warmup_iters + cfg.bench.iters.max(1)) as u64;
    Ok(FiringRow {
        width,
        region,
        legacy_items_per_sec: cfg.items as f64 / legacy_secs,
        hot_items_per_sec: cfg.items as f64 / hot_secs,
        speedup: legacy_secs / hot_secs,
        legacy_allocs_per_firing: legacy_allocs as f64 / (legacy_firings * iters).max(1) as f64,
        hot_allocs_per_firing: hot_allocs as f64 / (hot_firings * iters).max(1) as f64,
    })
}

/// One mode of the microbench. Returns (median secs, allocations during
/// the timed+warmup iterations, firings per iteration, checksum).
fn firing_loop(
    cfg: &HotpathConfig,
    width: usize,
    blobs: &[crate::coordinator::enumerate::Blob],
    legacy: bool,
) -> (f64, u64, u64, f64) {
    let mut q1: DataQueue<f32> = DataQueue::new(width);
    let mut q2: DataQueue<f32> = DataQueue::new(width);
    let mut vals = vec![0.0f32; width];
    let mut mask: Vec<i32> = Vec::with_capacity(width);
    let mut ov = vec![0.0f32; width];
    let mut om = vec![0i32; width];
    let mut scratch: Vec<f32> = Vec::with_capacity(width);
    let mut stage: Vec<f32> = Vec::with_capacity(width);
    let mut firings = 0u64;
    let mut sum = 0.0f64;
    let a0 = alloc_count::thread_allocations();
    let m = time_fn(cfg.bench, || {
        firings = 0;
        sum = 0.0;
        for blob in blobs {
            for chunk in blob.elems.chunks(width) {
                // ---- feed the stage-1 queue ----
                if legacy {
                    for &v in chunk {
                        q1.push(v);
                    }
                } else {
                    q1.push_slice(chunk);
                }
                // ---- firing f: filter+scale ----
                let take = if legacy {
                    scratch.clear();
                    while let Some(v) = q1.pop() {
                        scratch.push(v);
                    }
                    scratch.len()
                } else {
                    q1.pop_into(width, &mut scratch)
                };
                vals[..take].copy_from_slice(&scratch[..take]);
                for s in vals[take..].iter_mut() {
                    *s = 0.0;
                }
                prefix_mask(&mut mask, take, width);
                let kept = if legacy {
                    // pre-PR kernels: fresh output Vecs per firing,
                    // per-item pushes downstream
                    let (lov, lom) = native::scalar::filter_scale(&vals, &mask, 0.0);
                    let mut kept = 0usize;
                    for i in 0..take {
                        if lom[i] != 0 {
                            q2.push(lov[i]);
                            kept += 1;
                        }
                    }
                    kept
                } else {
                    native::filter_scale_into(&vals, &mask, 0.0, &mut ov, &mut om);
                    stage.clear();
                    for i in 0..take {
                        if om[i] != 0 {
                            stage.push(ov[i]);
                        }
                    }
                    let kept = stage.len();
                    q2.push_slice(&stage);
                    kept
                };
                firings += 1;
                // ---- firing a: masked reduction ----
                let take2 = if legacy {
                    scratch.clear();
                    while let Some(v) = q2.pop() {
                        scratch.push(v);
                    }
                    scratch.len()
                } else {
                    q2.pop_into(width, &mut scratch)
                };
                debug_assert_eq!(take2, kept);
                vals[..take2].copy_from_slice(&scratch[..take2]);
                for s in vals[take2..].iter_mut() {
                    *s = 0.0;
                }
                prefix_mask(&mut mask, take2, width);
                let (partial, _n) = if legacy {
                    native::scalar::masked_sum(&vals, &mask)
                } else {
                    native::masked_sum(&vals, &mask)
                };
                sum += partial as f64;
                firings += 1;
            }
        }
        std::hint::black_box(sum);
    });
    let allocs = alloc_count::thread_allocations() - a0;
    (m.median(), allocs, firings, sum)
}

/// One full-app sweep point (native backend).
fn app_point(cfg: &HotpathConfig, width: usize, region: usize, policy: Policy) -> Result<AppRow> {
    let blobs = gen_blobs(cfg.items, RegionSpec::Fixed { size: region }, cfg.seed);
    let app = SumApp::new(
        SumConfig {
            width,
            mode: SumMode::Enumerated,
            shape: SumShape::TwoStage,
            policy,
            ..Default::default()
        },
        Rc::new(KernelSet::native(width)),
    );
    let mut last = None;
    let mut runs = 0u64;
    let a0 = alloc_count::thread_allocations();
    let m = time_fn(cfg.bench, || {
        last = Some(app.run(&blobs).expect("hotpath sum run"));
        runs += 1;
    });
    let allocs = alloc_count::thread_allocations() - a0;
    let report = last.expect("at least one iteration");
    // `runs` counted every closure call (warmup + timed), matching the
    // window the allocation delta covers
    let firings_per_run: u64 = report.metrics.nodes.iter().map(|(_, m)| m.firings).sum();
    let total_firings = firings_per_run * runs;
    Ok(AppRow {
        width,
        region,
        policy: policy.label(),
        items_per_sec: cfg.items as f64 / m.median(),
        occupancy: report.metrics.occupancy(),
        allocs_per_firing: allocs as f64 / total_firings.max(1) as f64,
    })
}

/// Render the report as the `BENCH_hotpath.json` artifact.
pub fn to_json(report: &HotpathReport) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"hotpath\",\n");
    s.push_str(&format!("  \"items\": {},\n", report.items));
    s.push_str("  \"firing_path\": [\n");
    for (i, r) in report.firing.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"width\": {}, \"region\": {}, \"legacy_items_per_sec\": {:.1}, \
             \"hot_items_per_sec\": {:.1}, \"speedup\": {:.4}, \
             \"legacy_allocs_per_firing\": {:.4}, \"hot_allocs_per_firing\": {:.4}}}{}\n",
            r.width,
            r.region,
            r.legacy_items_per_sec,
            r.hot_items_per_sec,
            r.speedup,
            r.legacy_allocs_per_firing,
            r.hot_allocs_per_firing,
            if i + 1 < report.firing.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"app_sweep\": [\n");
    for (i, r) in report.apps.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"width\": {}, \"region\": {}, \"policy\": \"{}\", \
             \"items_per_sec\": {:.1}, \"occupancy\": {:.4}, \"allocs_per_firing\": {:.4}}}{}\n",
            r.width,
            r.region,
            r.policy,
            r.items_per_sec,
            r.occupancy,
            r.allocs_per_firing,
            if i + 1 < report.apps.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"reuse\": [\n");
    for (i, r) in report.reuse.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"regions_per_shard\": {}, \"shards\": {}, \
             \"rebuild_items_per_sec\": {:.1}, \"reuse_items_per_sec\": {:.1}, \
             \"speedup\": {:.4}}}{}\n",
            r.regions_per_shard,
            r.shards,
            r.rebuild_items_per_sec,
            r.reuse_items_per_sec,
            r.speedup,
            if i + 1 < report.reuse.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"trace_overhead\": [\n");
    for (i, r) in report.trace.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"workers\": {}, \"untraced_items_per_sec\": {:.1}, \
             \"traced_items_per_sec\": {:.1}, \"overhead_pct\": {:.4}}}{}\n",
            r.workers,
            r.untraced_items_per_sec,
            r.traced_items_per_sec,
            r.overhead_pct,
            if i + 1 < report.trace.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!(
        "  \"reuse_vs_rebuild_speedup\": {:.4},\n",
        reuse_vs_rebuild_speedup(report).unwrap_or(0.0)
    ));
    s.push_str(&format!(
        "  \"best_speedup_at_max_width\": {:.4}\n",
        best_speedup_at_max_width(report).unwrap_or(0.0)
    ));
    s.push_str("}\n");
    s
}

/// The reuse headline: speedup at the finest shard granularity measured
/// (many small shards — where rebuild overhead bites hardest and the
/// persistent-pipeline contract matters most).
pub fn reuse_vs_rebuild_speedup(report: &HotpathReport) -> Option<f64> {
    report
        .reuse
        .iter()
        .min_by_key(|r| r.regions_per_shard)
        .map(|r| r.speedup)
}

/// The acceptance metric: best firing-path speedup among the rows at the
/// widest measured width.
pub fn best_speedup_at_max_width(report: &HotpathReport) -> Option<f64> {
    let w = report.firing.iter().map(|r| r.width).max()?;
    report
        .firing
        .iter()
        .filter(|r| r.width == w)
        .map(|r| r.speedup)
        .fold(None, |acc, s| Some(acc.map_or(s, |a: f64| a.max(s))))
}

/// CI regression gate: the measured best firing-path speedup must stay
/// within 20% of the checked-in baseline's `min_speedup`, and — when the
/// baseline carries `min_reuse_speedup` — the rebuild-vs-reuse headline
/// must meet it outright (the acceptance floor, not a ratchet value, so
/// no slack factor).
pub fn check_against(report: &HotpathReport, baseline_path: &str) -> Result<()> {
    let text = std::fs::read_to_string(baseline_path)
        .with_context(|| format!("reading hotpath baseline {baseline_path}"))?;
    let json = Json::parse(&text).with_context(|| format!("parsing {baseline_path}"))?;
    let min_speedup = json
        .get("min_speedup")
        .and_then(Json::as_f64)
        .context("baseline missing numeric 'min_speedup'")?;
    let measured = best_speedup_at_max_width(report).context("no firing-path rows measured")?;
    let floor = 0.8 * min_speedup;
    ensure!(
        measured >= floor,
        "hotpath regression: firing-path speedup {measured:.2}x is below {floor:.2}x \
         (80% of the checked-in baseline {min_speedup:.2}x)"
    );
    println!("hotpath check: {measured:.2}x >= {floor:.2}x (baseline {min_speedup:.2}x) OK");
    if let Some(min_reuse) = json.get("min_reuse_speedup").and_then(Json::as_f64) {
        let reuse = reuse_vs_rebuild_speedup(report)
            .context("baseline demands a reuse gate but no reuse rows were measured")?;
        ensure!(
            reuse >= min_reuse,
            "reuse regression: rebuild-vs-reuse speedup {reuse:.2}x on the \
             many-small-shards configuration is below the {min_reuse:.2}x floor"
        );
        println!("reuse check: {reuse:.2}x >= {min_reuse:.2}x OK");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> HotpathConfig {
        HotpathConfig {
            widths: vec![8],
            items: 1 << 10,
            policies: vec![Policy::GreedyOccupancy],
            reuse_granules: vec![1, 8],
            bench: BenchConfig {
                warmup_iters: 0,
                iters: 1,
            },
            seed: 7,
        }
    }

    #[test]
    fn smoke_sweep_produces_rows_and_json() {
        let report = run(&tiny_cfg()).unwrap();
        assert!(!report.firing.is_empty());
        assert!(!report.apps.is_empty());
        assert_eq!(report.reuse.len(), 2);
        for r in &report.firing {
            assert!(r.hot_items_per_sec > 0.0);
            assert!(r.speedup > 0.0);
        }
        for r in &report.reuse {
            assert!(r.shards >= 1);
            assert!(r.speedup > 0.0);
        }
        // headline = the finest-granularity (many-small-shards) row
        let fine = report.reuse.iter().min_by_key(|r| r.regions_per_shard).unwrap();
        assert_eq!(reuse_vs_rebuild_speedup(&report), Some(fine.speedup));
        // trace tier: inline + threaded point, both with live throughput
        assert_eq!(report.trace.len(), 2);
        for r in &report.trace {
            assert!(r.untraced_items_per_sec > 0.0);
            assert!(r.traced_items_per_sec > 0.0);
        }
        let js = to_json(&report);
        let parsed = Json::parse(&js).expect("emitted JSON parses");
        assert!(parsed.get("firing_path").is_some());
        assert!(parsed.get("app_sweep").is_some());
        assert!(parsed.get("reuse").is_some());
        assert!(parsed.get("trace_overhead").is_some());
        assert!(parsed.get("reuse_vs_rebuild_speedup").is_some());
    }

    #[test]
    #[cfg(feature = "count-allocs")] // without the counting allocator both ratios read 0
    fn hot_mode_allocates_nothing_in_the_loop() {
        // after the report's own warmup the hot firing loop must be
        // allocation-free: its per-firing ratio is ~0 even counting the
        // one-time buffer growth
        let report = run(&tiny_cfg()).unwrap();
        for r in &report.firing {
            assert!(
                r.hot_allocs_per_firing < 0.5,
                "hot path allocs/firing {} at width {} region {}",
                r.hot_allocs_per_firing,
                r.width,
                r.region
            );
            assert!(
                r.legacy_allocs_per_firing >= 1.0,
                "legacy path should allocate every firing, got {}",
                r.legacy_allocs_per_firing
            );
        }
    }

    #[test]
    fn check_against_accepts_and_rejects() {
        let report = run(&tiny_cfg()).unwrap();
        let dir = std::env::temp_dir();
        let ok = dir.join("hotpath_baseline_ok.json");
        std::fs::write(&ok, "{\"min_speedup\": 0.0001}").unwrap();
        check_against(&report, ok.to_str().unwrap()).unwrap();
        let bad = dir.join("hotpath_baseline_bad.json");
        std::fs::write(&bad, "{\"min_speedup\": 1e9}").unwrap();
        assert!(check_against(&report, bad.to_str().unwrap()).is_err());
    }

    #[test]
    fn reuse_gate_accepts_and_rejects() {
        let report = run(&tiny_cfg()).unwrap();
        let dir = std::env::temp_dir();
        let ok = dir.join("hotpath_baseline_reuse_ok.json");
        std::fs::write(
            &ok,
            "{\"min_speedup\": 0.0001, \"min_reuse_speedup\": 0.0001}",
        )
        .unwrap();
        check_against(&report, ok.to_str().unwrap()).unwrap();
        let bad = dir.join("hotpath_baseline_reuse_bad.json");
        std::fs::write(&bad, "{\"min_speedup\": 0.0001, \"min_reuse_speedup\": 1e9}").unwrap();
        let err = check_against(&report, bad.to_str().unwrap()).unwrap_err();
        assert!(err.to_string().contains("reuse regression"), "{err}");
    }
}
