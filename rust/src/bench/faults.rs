//! `bench faults` — fault-injection recovery sweep.
//!
//! Measures what fault tolerance costs and proves what it promises, in
//! one deterministic harness:
//!
//! * `baseline` — fault-free fail-fast run (the reference time and the
//!   reference outputs);
//! * `retry` — the same stream under [`FaultPolicy::retry`] with a
//!   seeded [`FaultPlan`] injecting panics/errors into live shards. The
//!   run must **recover bit-identically**: outputs equal the baseline's
//!   to the last bit, the report's retry count equals the plan's shot
//!   count exactly, and the plan is fully consumed;
//! * `retry-traced` — one traced recovery run asserting the trace's
//!   `Fault`/`Retry` event totals reconcile with the report;
//! * `part-retry` — a shard poisoned twice under retry: after the first
//!   whole-shard failure the pool narrows to single-region re-runs, so
//!   only the poisoned part pays the second fault. The run stays
//!   bit-identical and the report's `rerun_regions` proves the
//!   narrowing happened (the `part_retry_savings` headline compares it
//!   against what whole-shard re-runs would have cost);
//! * `quarantine` — a planned panic on one shard; the run keeps going
//!   and the report names exactly that shard;
//! * `degraded` — a worker whose guarded pipeline rebuild *also* panics
//!   retires; its shard is re-dealt untouched to the survivors and the
//!   run completes bit-identically on N−1 workers with an empty fault
//!   ledger (skipped when the pool has a single worker — there is no
//!   survivor to take the work);
//! * `salvage` — a `.rgn` container with deterministically corrupted
//!   frames read back under [`CorruptFramePolicy::Skip`]: every
//!   uncorrupted frame survives bit-identically, every corrupted frame
//!   is counted.
//!
//! The headline metric is the retry run's elapsed time over the
//! baseline's — the price of recovery including the injected faults
//! themselves. Results are emitted as `BENCH_faults.json` and uploaded
//! as a CI artifact (`--smoke` runs a small shape in the pipeline).
//!
//! [`FaultPolicy::retry`]: crate::exec::FaultPolicy::retry
//! [`CorruptFramePolicy::Skip`]: crate::io::CorruptFramePolicy

use std::io::Cursor;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::apps::sum::{SumConfig, SumFactory};
use crate::exec::{
    ExecConfig, FaultPlan, FaultPolicy, FaultyFactory, KernelSpawn, ShardedRunner,
};
use crate::io::{corrupt_frame, BlobFileSource, BlobWriter, CorruptFramePolicy};
use crate::trace::TraceOptions;
use crate::util::prng::Prng;
use crate::workload::regions::{gen_blobs, RegionSpec};

use super::{time_fn, BenchConfig, Table};

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct FaultsConfig {
    /// SIMD ensemble width.
    pub width: usize,
    /// Total stream items.
    pub items: usize,
    /// Worker threads.
    pub workers: usize,
    /// Per-shard (and per-frame) fault probability for the seeded plan.
    pub fault_rate: f64,
    /// Workload PRNG seed.
    pub seed: u64,
    /// Iteration counts for timing.
    pub bench: BenchConfig,
}

impl FaultsConfig {
    /// CI smoke shape: small stream, warmed medians.
    pub fn smoke() -> FaultsConfig {
        FaultsConfig {
            width: 32,
            items: 1 << 14,
            workers: 4,
            fault_rate: 0.25,
            seed: 0xFA_17,
            bench: BenchConfig {
                warmup_iters: 1,
                iters: 3,
            },
        }
    }
}

impl Default for FaultsConfig {
    fn default() -> Self {
        FaultsConfig {
            width: 128,
            items: 1 << 17,
            workers: 4,
            fault_rate: 0.25,
            seed: 0xFA_17,
            bench: BenchConfig::from_env(),
        }
    }
}

/// One measured leg.
#[derive(Debug, Clone)]
pub struct FaultsRow {
    /// Fault-handling leg this row measures.
    pub leg: &'static str,
    /// Median seconds per run.
    pub seconds: f64,
    /// Extra shard attempts the run made (retry legs).
    pub retries: u64,
    /// Shards dropped into the fault ledger (quarantine leg).
    pub quarantined: usize,
    /// What the leg proved (already asserted before the row is built).
    pub check: String,
}

/// Full report (also the JSON payload).
#[derive(Debug, Clone)]
pub struct FaultsReport {
    /// Total stream items.
    pub items: usize,
    /// Worker threads.
    pub workers: usize,
    /// Shards the stream was cut into.
    pub shards: usize,
    /// Faults the seeded plan injected into the retry legs.
    pub injected: usize,
    /// Regions in the generated stream (the part population).
    pub regions: usize,
    /// Single-region re-runs the part-retry leg paid while narrowing.
    pub rerun_regions: u64,
    /// Workers the degraded leg retired mid-run (0 when skipped).
    pub dead_workers: usize,
    /// Measured legs.
    pub rows: Vec<FaultsRow>,
    /// Salvage leg: frames written / corrupted / read back intact.
    pub frames: usize,
    /// Frames corrupted in place before readback.
    pub corrupted: usize,
    /// Frames read back intact after salvage.
    pub recovered: usize,
}

fn factory(cfg: &FaultsConfig) -> SumFactory {
    SumFactory::new(
        SumConfig {
            width: cfg.width,
            ..Default::default()
        },
        KernelSpawn::Native,
    )
}

fn exec(cfg: &FaultsConfig) -> ExecConfig {
    ExecConfig::new(cfg.workers).with_shards_per_worker(4)
}

/// Outputs must match the baseline to the last bit — the retry-recovery
/// determinism claim, checked not eyeballed.
fn ensure_bit_identical(leg: &str, got: &[(u64, f64)], want: &[(u64, f64)]) -> Result<()> {
    ensure!(
        got.len() == want.len(),
        "{leg}: {} outputs vs baseline's {}",
        got.len(),
        want.len()
    );
    for (i, ((gi, gv), (bi, bv))) in got.iter().zip(want).enumerate() {
        ensure!(
            gi == bi && gv.to_bits() == bv.to_bits(),
            "{leg}: output {i} diverged from the fault-free baseline"
        );
    }
    Ok(())
}

/// Run the sweep and print the table.
pub fn run(cfg: &FaultsConfig) -> Result<FaultsReport> {
    let blobs = gen_blobs(cfg.items, RegionSpec::Uniform { max: 2 * cfg.width }, cfg.seed);
    let mut rows = Vec::new();

    // -- baseline: fault-free fail-fast ---------------------------------
    let runner = ShardedRunner::new(exec(cfg));
    let mut last = None;
    let m = time_fn(cfg.bench, || {
        last = Some(runner.run(&factory(cfg), &blobs).expect("fault-free baseline"));
    });
    let base = last.expect("at least one iteration");
    let shards = base.shards;
    ensure!(base.retries == 0 && base.faults.is_empty(), "baseline saw faults");
    rows.push(FaultsRow {
        leg: "baseline",
        seconds: m.median(),
        retries: 0,
        quarantined: 0,
        check: format!("{} shard(s), fault-free", shards),
    });

    // -- retry: seeded injection, bit-identical recovery ----------------
    // An unlucky (seed, rate) pair may draw an empty plan; recovery with
    // nothing to recover proves nothing, so guarantee at least one shot.
    let mut plan = FaultPlan::seeded(cfg.seed, shards, cfg.fault_rate);
    if plan.is_empty() {
        plan = plan.panic_at(0);
    }
    let injected = plan.injected();
    let retry_runner = ShardedRunner::new(exec(cfg).with_fault(FaultPolicy::retry(3)));
    let mut last = None;
    let m = time_fn(cfg.bench, || {
        let faulty = FaultyFactory::new(factory(cfg), &plan);
        let report = retry_runner.run(&faulty, &blobs).expect("retry run recovers");
        last = Some((report, faulty.remaining()));
    });
    let (retry, remaining) = last.expect("at least one iteration");
    ensure_bit_identical("retry", &retry.outputs, &base.outputs)?;
    ensure!(
        retry.retries == injected as u64,
        "retry: report counts {} retries, plan injected {injected}",
        retry.retries
    );
    ensure!(remaining == 0, "retry: {remaining} planned shot(s) never fired");
    ensure!(retry.faults.is_empty(), "retry: recovered run must not quarantine");
    rows.push(FaultsRow {
        leg: "retry",
        seconds: m.median(),
        retries: retry.retries,
        quarantined: 0,
        check: format!("{injected} injected, bit-identical"),
    });

    // -- retry-traced: trace totals reconcile with the report -----------
    let traced_runner = ShardedRunner::new(
        exec(cfg)
            .with_fault(FaultPolicy::retry(3))
            .with_trace(Some(TraceOptions::default())),
    );
    let t0 = Instant::now();
    let traced = traced_runner.run(&FaultyFactory::new(factory(cfg), &plan), &blobs)?;
    let traced_s = t0.elapsed().as_secs_f64();
    ensure_bit_identical("retry-traced", &traced.outputs, &base.outputs)?;
    let trace = traced.trace.as_ref().expect("trace attached when configured");
    ensure!(
        trace.retries() == traced.retries,
        "retry-traced: {} Retry events vs report's {} retries",
        trace.retries(),
        traced.retries
    );
    ensure!(
        trace.faults() == injected as u64,
        "retry-traced: {} Fault events vs {injected} injected",
        trace.faults()
    );
    ensure!(
        trace.shards() == traced.shards as u64,
        "retry-traced: {} Shard events vs {} shards",
        trace.shards(),
        traced.shards
    );
    rows.push(FaultsRow {
        leg: "retry-traced",
        seconds: traced_s,
        retries: traced.retries,
        quarantined: 0,
        check: "trace/report reconciled".to_string(),
    });

    // -- part-retry: narrowing re-runs only the poisoned slice ----------
    // Two shots on one shard: the first fails the whole-slice attempt,
    // the second lands inside the narrowing pass so exactly one region
    // pays the extra re-run. Whole-shard retry would have re-run every
    // region of the shard twice.
    let pr_target = shards / 2;
    let pr_plan = FaultPlan::new().panic_at(pr_target).with_times(2);
    let pr_runner = ShardedRunner::new(exec(cfg).with_fault(FaultPolicy::retry(3)));
    let t0 = Instant::now();
    let pr_faulty = FaultyFactory::new(factory(cfg), &pr_plan);
    let pr = pr_runner.run(&pr_faulty, &blobs)?;
    let pr_s = t0.elapsed().as_secs_f64();
    ensure_bit_identical("part-retry", &pr.outputs, &base.outputs)?;
    ensure!(
        pr.retries == 2,
        "part-retry: report counts {} retries, plan injected 2",
        pr.retries
    );
    ensure!(pr_faulty.remaining() == 0, "part-retry: planned shot(s) never fired");
    ensure!(
        pr.rerun_regions >= 2,
        "part-retry: narrowing must pay single-region re-runs, report counts {}",
        pr.rerun_regions
    );
    ensure!(
        pr.rerun_regions as usize <= blobs.len() + 1,
        "part-retry: {} single-region re-runs exceed the {}-region stream",
        pr.rerun_regions,
        blobs.len()
    );
    rows.push(FaultsRow {
        leg: "part-retry",
        seconds: pr_s,
        retries: pr.retries,
        quarantined: 0,
        check: format!("{} single-region re-run(s), bit-identical", pr.rerun_regions),
    });
    let rerun_regions = pr.rerun_regions;

    // -- quarantine: one poisoned shard, run survives, ledger names it --
    let target = shards / 2;
    let q_runner = ShardedRunner::new(exec(cfg).with_fault(FaultPolicy::Quarantine));
    let t0 = Instant::now();
    let q = q_runner
        .run(&FaultyFactory::new(factory(cfg), &FaultPlan::new().panic_at(target)), &blobs)?;
    let q_s = t0.elapsed().as_secs_f64();
    ensure!(
        q.faults.len() == 1 && q.faults[0].shard == target,
        "quarantine: expected exactly shard {target} in the ledger, got {:?}",
        q.faults.iter().map(|f| f.shard).collect::<Vec<_>>()
    );
    ensure!(
        q.outputs.len() < base.outputs.len(),
        "quarantine: the dropped shard must cost its output slot"
    );
    rows.push(FaultsRow {
        leg: "quarantine",
        seconds: q_s,
        retries: 0,
        quarantined: q.faults.len(),
        check: format!("shard {target} dropped, run survived"),
    });

    // -- degraded: rebuild dies too, worker retires, survivors finish ---
    // The quarantined panic forces a pipeline rebuild; the rebuild shot
    // kills that too, so the worker retires and its shard is re-dealt
    // untouched to a survivor — the run must finish bit-identically on
    // N−1 workers with nothing quarantined.
    let mut dead_workers = 0;
    if cfg.workers >= 2 {
        let d_target = shards / 2;
        let d_runner = ShardedRunner::new(exec(cfg).with_fault(FaultPolicy::Quarantine));
        let t0 = Instant::now();
        let d = d_runner.run(
            &FaultyFactory::new(
                factory(cfg),
                &FaultPlan::new().panic_at(d_target).panic_on_rebuild(),
            ),
            &blobs,
        )?;
        let d_s = t0.elapsed().as_secs_f64();
        ensure_bit_identical("degraded", &d.outputs, &base.outputs)?;
        dead_workers = d.per_worker.iter().filter(|w| w.dead).count();
        ensure!(
            dead_workers == 1,
            "degraded: expected exactly one retired worker, saw {dead_workers}"
        );
        ensure!(
            d.faults.is_empty(),
            "degraded: the re-dealt shard must finish clean, not quarantine"
        );
        rows.push(FaultsRow {
            leg: "degraded",
            seconds: d_s,
            retries: d.retries,
            quarantined: 0,
            check: format!("1 worker retired, {} survivor(s), bit-identical", cfg.workers - 1),
        });
    } else {
        println!("(degraded leg skipped: a 1-worker pool has no survivor to re-deal to)");
    }

    // -- salvage: corrupted .rgn frames skipped, survivors bit-exact ----
    let mut bytes: Vec<u8> = Vec::new();
    let mut writer = BlobWriter::new(&mut bytes)?;
    for b in &blobs {
        writer.write_region(b)?;
    }
    writer.finish()?;
    let mut rng = Prng::new(cfg.seed ^ 0xD15C);
    let mut corrupt: Vec<usize> =
        (0..blobs.len()).filter(|_| rng.chance(cfg.fault_rate)).collect();
    if corrupt.is_empty() {
        corrupt.push(0);
    }
    for &f in &corrupt {
        corrupt_frame(&mut bytes, f)?;
    }
    let t0 = Instant::now();
    let mut src = BlobFileSource::from_reader(Cursor::new(&bytes[..]), "bench-salvage")?
        .with_corrupt_policy(CorruptFramePolicy::Skip);
    let mut survivors = Vec::new();
    while let Some(b) = src.try_next()? {
        survivors.push(b);
    }
    let salvage_s = t0.elapsed().as_secs_f64();
    ensure!(
        src.skipped() == corrupt.len() as u64,
        "salvage: skipped {} frame(s), corrupted {}",
        src.skipped(),
        corrupt.len()
    );
    let intact: Vec<&crate::coordinator::enumerate::Blob> = blobs
        .iter()
        .enumerate()
        .filter(|(i, _)| !corrupt.contains(i))
        .map(|(_, b)| b)
        .collect();
    ensure!(
        survivors.len() == intact.len(),
        "salvage: read {} of {} intact frame(s)",
        survivors.len(),
        intact.len()
    );
    for (got, want) in survivors.iter().zip(&intact) {
        ensure!(got == *want, "salvage: surviving region {} diverged", got.id);
    }
    rows.push(FaultsRow {
        leg: "salvage",
        seconds: salvage_s,
        retries: 0,
        quarantined: corrupt.len(),
        check: format!("{}/{} frames recovered", survivors.len(), blobs.len()),
    });

    let mut t = Table::new(&["leg", "time_s", "retries", "dropped", "check"]);
    for r in &rows {
        t.row(&[
            r.leg.to_string(),
            format!("{:.4}", r.seconds),
            r.retries.to_string(),
            r.quarantined.to_string(),
            r.check.clone(),
        ]);
    }
    println!("== Faults: recovery overhead and determinism ==");
    t.print();

    Ok(FaultsReport {
        items: cfg.items,
        workers: cfg.workers,
        shards,
        injected,
        regions: blobs.len(),
        rerun_regions,
        dead_workers,
        rows,
        frames: blobs.len(),
        corrupted: corrupt.len(),
        recovered: survivors.len(),
    })
}

/// Headline metric: retry-policy elapsed over the fault-free baseline —
/// what recovery (faults included) costs in wall clock. `None` if either
/// leg is missing.
pub fn retry_overhead(report: &FaultsReport) -> Option<f64> {
    let pick = |leg: &str| report.rows.iter().find(|r| r.leg == leg).map(|r| r.seconds);
    let base = pick("baseline")?;
    if base <= 0.0 {
        return None;
    }
    Some(pick("retry")? / base)
}

/// Headline metric: how much region work part-granular narrowing saved
/// over whole-shard retry — planned whole-shard re-run cost (retries ×
/// average regions per shard) over the single-region re-runs actually
/// paid. \>1 means narrowing re-ran less than whole-shard retry would
/// have. `None` if the part-retry leg is missing.
pub fn part_retry_savings(report: &FaultsReport) -> Option<f64> {
    let row = report.rows.iter().find(|r| r.leg == "part-retry")?;
    if report.rerun_regions == 0 || report.shards == 0 {
        return None;
    }
    let whole_shard = row.retries as f64 * report.regions as f64 / report.shards as f64;
    Some(whole_shard / report.rerun_regions as f64)
}

/// Render the report as the `BENCH_faults.json` artifact.
pub fn to_json(report: &FaultsReport) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"faults\",\n");
    s.push_str(&format!("  \"items\": {},\n", report.items));
    s.push_str(&format!("  \"workers\": {},\n", report.workers));
    s.push_str(&format!("  \"shards\": {},\n", report.shards));
    s.push_str(&format!("  \"injected\": {},\n", report.injected));
    s.push_str(&format!("  \"regions\": {},\n", report.regions));
    s.push_str(&format!("  \"rerun_regions\": {},\n", report.rerun_regions));
    s.push_str(&format!("  \"dead_workers\": {},\n", report.dead_workers));
    s.push_str("  \"rows\": [\n");
    for (i, r) in report.rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"leg\": \"{}\", \"seconds\": {:.6}, \"retries\": {}, \
             \"quarantined\": {}, \"check\": \"{}\"}}{}\n",
            r.leg,
            r.seconds,
            r.retries,
            r.quarantined,
            r.check,
            if i + 1 < report.rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!(
        "  \"salvage\": {{\"frames\": {}, \"corrupted\": {}, \"recovered\": {}}},\n",
        report.frames, report.corrupted, report.recovered
    ));
    s.push_str(&format!(
        "  \"retry_overhead\": {:.4},\n",
        retry_overhead(report).unwrap_or(0.0)
    ));
    s.push_str(&format!(
        "  \"part_retry_savings\": {:.4}\n",
        part_retry_savings(report).unwrap_or(0.0)
    ));
    s.push_str("}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn sweep_recovers_and_emits_json() {
        let cfg = FaultsConfig {
            width: 8,
            items: 1 << 10,
            workers: 2,
            fault_rate: 0.3,
            seed: 7,
            bench: BenchConfig {
                warmup_iters: 0,
                iters: 1,
            },
        };
        let report = run(&cfg).unwrap();
        assert_eq!(
            report.rows.len(),
            7,
            "baseline/retry/traced/part-retry/quarantine/degraded/salvage"
        );
        assert!(report.injected >= 1, "the plan always injects something");
        assert!(report.corrupted >= 1, "the salvage leg always corrupts something");
        assert_eq!(report.recovered, report.frames - report.corrupted);
        assert!(report.rerun_regions >= 2, "the part-retry leg narrowed");
        assert_eq!(report.dead_workers, 1, "the degraded leg retired one worker");
        let js = to_json(&report);
        let parsed = Json::parse(&js).expect("emitted JSON parses");
        assert!(parsed.get("rows").is_some());
        assert!(parsed.get("salvage").is_some());
        assert!(parsed.get("retry_overhead").is_some());
        assert!(parsed.get("part_retry_savings").is_some());
        assert!(retry_overhead(&report).is_some());
        let savings = part_retry_savings(&report).expect("part-retry leg present");
        assert!(savings > 0.0, "savings ratio is a positive number, got {savings}");
    }
}
