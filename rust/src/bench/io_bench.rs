//! `bench io` — file-backed vs in-memory streaming ingest throughput.
//!
//! Materializes one synthetic blob stream to a temporary `.rgn`
//! container (the write is timed too), then crosses the ingest **buffer
//! budget** with three sources feeding the same streaming executor:
//!
//! * `mem-slice` — a materialized stream replayed through `SliceSource`
//!   (the all-in-memory upper bound: no generation, no decode);
//! * `mem-gen` — the lazy `GenBlobSource` generator with pooled element
//!   containers (in-memory, but paying per-region production);
//! * `file` — `BlobFileSource` over the `.rgn` file with the same pool
//!   (the out-of-core path: read + checksum + decode per region).
//!
//! Every row's sum outputs are asserted **bit-identical** to a
//! materialized single-pass baseline before its time is recorded, so the
//! sweep doubles as a round-trip equivalence check. The interesting
//! read-out is the `file`/`mem-gen` throughput ratio across budgets: if
//! the file path tracks the generator within a small factor, ingest is
//! compute-bound, not I/O-bound, and the constant-memory path is free.
//!
//! Results are emitted as `BENCH_io.json` and uploaded as a CI artifact
//! (`--smoke` runs a small shape in the pipeline).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::apps::sum::{SumConfig, SumFactory};
use crate::exec::{ContainerPool, ExecConfig, KernelSpawn, ShardedRunner};
use crate::io::{write_rgn_file, BlobFileSource, BlobStats};
use crate::util::stats::fmt_count;
use crate::workload::regions::{gen_blobs, GenBlobSource, RegionSpec};
use crate::workload::source::SliceSource;

use super::{time_fn, BenchConfig, Table};

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct IoConfig {
    /// SIMD ensemble width.
    pub width: usize,
    /// Total stream items.
    pub items: usize,
    /// Worker threads (fixed; the budget is the swept axis).
    pub workers: usize,
    /// Ingest buffer budgets (regions) to cross with each source.
    pub budgets: Vec<usize>,
    /// Iteration counts for timing.
    pub bench: BenchConfig,
    /// Workload PRNG seed.
    pub seed: u64,
}

impl IoConfig {
    /// CI smoke shape: small stream, warmed medians.
    pub fn smoke() -> IoConfig {
        IoConfig {
            width: 32,
            items: 1 << 14,
            workers: 2,
            budgets: vec![64, 256],
            bench: BenchConfig {
                warmup_iters: 1,
                iters: 3,
            },
            seed: 0xF16,
        }
    }
}

impl Default for IoConfig {
    fn default() -> Self {
        IoConfig {
            width: 128,
            items: 1 << 18,
            workers: 4,
            budgets: vec![256, 1024, 4096],
            bench: BenchConfig::from_env(),
            seed: 0xF16,
        }
    }
}

/// One measured point.
#[derive(Debug, Clone)]
pub struct IoRow {
    /// Input source label.
    pub source: &'static str,
    /// Ingest buffer budget (regions).
    pub budget: usize,
    /// Median seconds per run.
    pub seconds: f64,
    /// Items per second.
    pub items_per_sec: f64,
    /// Shards the stream was cut into.
    pub shards: usize,
}

/// Full report (also the JSON payload).
#[derive(Debug, Clone)]
pub struct IoReport {
    /// Total stream items.
    pub items: usize,
    /// Worker threads.
    pub workers: usize,
    /// Stats of the materialized `.rgn` container.
    pub file: BlobStats,
    /// Seconds to write the container (one pass).
    pub write_seconds: f64,
    /// Measured points.
    pub rows: Vec<IoRow>,
}

/// Best-effort self-deleting temp path.
struct TempPath(PathBuf);

impl Drop for TempPath {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

/// Run the sweep and print the table.
pub fn run(cfg: &IoConfig) -> Result<IoReport> {
    ensure!(!cfg.budgets.is_empty(), "bench io needs at least one budget");
    let spec = RegionSpec::Uniform { max: 2 * cfg.width };
    let path = TempPath(std::env::temp_dir().join(format!(
        "regatta_bench_io_{}_{}.rgn",
        std::process::id(),
        cfg.seed
    )));

    let t0 = Instant::now();
    let file = write_rgn_file(&path.0, GenBlobSource::new(cfg.items, spec, cfg.seed))
        .context("materializing the bench .rgn container")?;
    let write_seconds = t0.elapsed().as_secs_f64();

    let blobs = gen_blobs(cfg.items, spec, cfg.seed);
    let sum_cfg = SumConfig {
        width: cfg.width,
        ..Default::default()
    };
    let plain = SumFactory::new(sum_cfg, KernelSpawn::Native);
    // one materialized single-threaded pass is the bit-identity oracle
    let baseline = ShardedRunner::with_workers(1).run(&plain, &blobs)?.outputs;

    let mut rows = Vec::new();
    for &budget in &cfg.budgets {
        let exec = ExecConfig::new(cfg.workers)
            .with_shards_per_worker(4)
            .streaming(budget);
        let runner = ShardedRunner::new(exec);
        for source in ["mem-slice", "mem-gen", "file"] {
            // gen/file circulate element containers with the workers;
            // the slice replay has nowhere to return them, so it runs
            // the plain factory
            let pool = Arc::new(ContainerPool::new());
            let pooled = SumFactory::new(sum_cfg, KernelSpawn::Native)
                .with_elem_pool(pool.clone());
            let mut last = None;
            let m = time_fn(cfg.bench, || {
                let report = match source {
                    "mem-slice" => runner
                        .run_stream(&plain, SliceSource::new(&blobs))
                        .expect("mem-slice run"),
                    "mem-gen" => runner
                        .run_stream(
                            &pooled,
                            GenBlobSource::new(cfg.items, spec, cfg.seed)
                                .with_pool(pool.clone()),
                        )
                        .expect("mem-gen run"),
                    _ => runner
                        .run_stream(
                            &pooled,
                            BlobFileSource::open(&path.0)
                                .expect("open bench .rgn")
                                .with_pool(pool.clone()),
                        )
                        .expect("file run"),
                };
                last = Some(report);
            });
            let report = last.expect("at least one iteration");
            ensure!(
                report.outputs.len() == baseline.len(),
                "{source}/{budget}: lost regions: {} of {}",
                report.outputs.len(),
                baseline.len()
            );
            for (i, ((gi, gv), (bi, bv))) in report.outputs.iter().zip(&baseline).enumerate() {
                ensure!(
                    gi == bi && gv.to_bits() == bv.to_bits(),
                    "{source}/{budget}: output {i} diverged from the materialized baseline"
                );
            }
            rows.push(IoRow {
                source,
                budget,
                seconds: m.median(),
                items_per_sec: cfg.items as f64 / m.median(),
                shards: report.shards,
            });
        }
    }

    let mut t = Table::new(&["source", "budget", "time_s", "items/s", "shards"]);
    for r in &rows {
        t.row(&[
            r.source.to_string(),
            r.budget.to_string(),
            format!("{:.4}", r.seconds),
            fmt_count(r.items_per_sec),
            r.shards.to_string(),
        ]);
    }
    println!(
        "== IO: file-backed vs in-memory streaming ingest ({} items, {} worker(s), \
         .rgn = {} bytes written in {:.3}s) ==",
        cfg.items, cfg.workers, file.bytes, write_seconds
    );
    t.print();

    Ok(IoReport {
        items: cfg.items,
        workers: cfg.workers,
        file,
        write_seconds,
        rows,
    })
}

/// Headline metric: file-backed over lazy-generator throughput at the
/// largest measured budget (`None` if either point is missing). Near
/// 1.0 means the out-of-core path costs ~nothing over in-memory.
pub fn file_vs_mem_ratio(report: &IoReport) -> Option<f64> {
    let max_budget = report.rows.iter().map(|r| r.budget).max()?;
    let pick = |source: &str| {
        report
            .rows
            .iter()
            .find(|r| r.budget == max_budget && r.source == source)
            .map(|r| r.items_per_sec)
    };
    Some(pick("file")? / pick("mem-gen")?)
}

/// Render the report as the `BENCH_io.json` artifact.
pub fn to_json(report: &IoReport) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"io\",\n");
    s.push_str(&format!("  \"items\": {},\n", report.items));
    s.push_str(&format!("  \"workers\": {},\n", report.workers));
    s.push_str(&format!(
        "  \"file\": {{\"regions\": {}, \"items\": {}, \"bytes\": {}}},\n",
        report.file.regions, report.file.items, report.file.bytes
    ));
    s.push_str(&format!("  \"write_seconds\": {:.6},\n", report.write_seconds));
    s.push_str("  \"rows\": [\n");
    for (i, r) in report.rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"source\": \"{}\", \"budget\": {}, \"seconds\": {:.6}, \
             \"items_per_sec\": {:.1}, \"shards\": {}}}{}\n",
            r.source,
            r.budget,
            r.seconds,
            r.items_per_sec,
            r.shards,
            if i + 1 < report.rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!(
        "  \"file_vs_memgen_throughput_ratio\": {:.4}\n",
        file_vs_mem_ratio(report).unwrap_or(0.0)
    ));
    s.push_str("}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn tiny_cfg() -> IoConfig {
        IoConfig {
            width: 8,
            items: 1 << 10,
            workers: 2,
            budgets: vec![16, 64],
            bench: BenchConfig {
                warmup_iters: 0,
                iters: 1,
            },
            seed: 7,
        }
    }

    #[test]
    fn sweep_produces_rows_and_json_and_cleans_up() {
        let cfg = tiny_cfg();
        let report = run(&cfg).unwrap();
        assert_eq!(report.rows.len(), 2 * 3, "budgets x sources");
        for r in &report.rows {
            assert!(r.items_per_sec > 0.0, "{}/{}", r.source, r.budget);
            assert!(r.shards > 0);
        }
        assert!(report.file.regions > 0);
        assert!(report.file.items as usize == cfg.items);
        let js = to_json(&report);
        let parsed = Json::parse(&js).expect("emitted JSON parses");
        assert!(parsed.get("rows").is_some());
        assert!(parsed.get("file_vs_memgen_throughput_ratio").is_some());
        assert!(file_vs_mem_ratio(&report).is_some());
        // the temp container is gone
        let leftover = std::env::temp_dir().join(format!(
            "regatta_bench_io_{}_{}.rgn",
            std::process::id(),
            cfg.seed
        ));
        assert!(!leftover.exists(), "temp .rgn was removed");
    }
}
