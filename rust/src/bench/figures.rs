//! Figure-regeneration sweeps (paper §5), shared by the `cargo bench`
//! targets and the `regatta bench` CLI subcommand.
//!
//! Each function reproduces one figure/table of the paper's evaluation:
//! same axes, same series — scaled to this testbed (CPU PJRT instead of a
//! GTX 1080Ti; see DESIGN.md). The *shape* is the reproduction target:
//! who wins, by roughly what factor, where the crossovers/minima fall.

use std::rc::Rc;

use anyhow::Result;

use crate::apps::sum::{SumApp, SumConfig, SumFactory, SumMode, SumShape};
use crate::apps::taxi::{TaxiApp, TaxiConfig, TaxiVariant};
use crate::coordinator::scheduler::Policy;
use crate::exec::{ExecConfig, KernelSpawn, ShardedRunner};
use crate::runtime::kernels::KernelSet;
use crate::runtime::{ArtifactStore, Engine};
use crate::util::stats::fmt_duration;
use crate::workload::regions::{gen_blobs, RegionSpec};
use crate::workload::taxi::{generate, replicate, TaxiGenConfig};

use super::{time_fn, BenchConfig, Table};

/// Kernel backend selection for a sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendSel {
    /// AOT artifacts via PJRT — the measured configuration.
    Xla,
    /// Pure-Rust mirror — for quick shape checks without artifacts.
    Native,
}

impl std::str::FromStr for BackendSel {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "xla" => Ok(BackendSel::Xla),
            "native" => Ok(BackendSel::Native),
            other => anyhow::bail!("unknown backend {other:?} (use xla|native)"),
        }
    }
}

impl From<BackendSel> for KernelSpawn {
    fn from(sel: BackendSel) -> KernelSpawn {
        match sel {
            BackendSel::Native => KernelSpawn::Native,
            BackendSel::Xla => KernelSpawn::Xla,
        }
    }
}

/// Keeps the PJRT engine alive alongside the kernels compiled from it.
pub struct KernelProvider {
    _engine: Option<Engine>,
    /// Kernels every benchmark in the sweep shares.
    pub kernels: Rc<KernelSet>,
}

/// Build a kernel set on the selected backend.
pub fn provider(backend: BackendSel, width: usize) -> Result<KernelProvider> {
    match backend {
        BackendSel::Native => Ok(KernelProvider {
            _engine: None,
            kernels: Rc::new(KernelSet::native(width)),
        }),
        BackendSel::Xla => {
            let engine = Engine::new(ArtifactStore::discover()?)?;
            let kernels = Rc::new(KernelSet::xla(&engine, width)?);
            Ok(KernelProvider {
                _engine: Some(engine),
                kernels,
            })
        }
    }
}

/// Sweep parameters common to the figure benches.
#[derive(Debug, Clone, Copy)]
pub struct SweepConfig {
    /// SIMD ensemble width.
    pub width: usize,
    /// Total stream items.
    pub items: usize,
    /// Kernel backend to spawn.
    pub backend: BackendSel,
    /// Workload PRNG seed.
    pub seed: u64,
    /// Iteration counts for timing.
    pub bench: BenchConfig,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            width: 128,
            items: 1 << 18, // paper: 512 M; scaled to the CPU testbed
                            // (512 Ki used for the EXPERIMENTS.md tables;
                            // override with REGATTA_BENCH_ITEMS)
            backend: BackendSel::Xla,
            seed: 0xF16,
            bench: BenchConfig::from_env(),
        }
    }
}

/// The region-size axis of Figs 6/7: sub-width sizes, the width and its
/// multiples, and the paper's "just past a multiple" worst cases.
pub fn region_size_axis(width: usize) -> Vec<usize> {
    let w = width;
    let mut v = vec![
        w / 4,
        w / 2,
        3 * w / 4,
        w - 8,
        w,
        w + 8,
        w + w / 2,
        2 * w,
        2 * w + 8,
        3 * w,
        4 * w,
        4 * w + 8,
        6 * w,
        8 * w,
    ];
    v.retain(|&s| s > 0);
    v.dedup();
    v
}

/// One measured row of a sum-app sweep.
#[derive(Debug, Clone)]
pub struct SumRow {
    /// Region size (items).
    pub region: usize,
    /// Median seconds per run.
    pub seconds: f64,
    /// Items per second.
    pub throughput: f64, // items/sec
    /// Mean ensemble occupancy.
    pub occupancy: f64,
    /// Kernel invocations spent.
    pub invocations: u64,
}

fn run_sum_point(
    cfg: &SweepConfig,
    spec: RegionSpec,
    mode: SumMode,
    kernels: Rc<KernelSet>,
) -> Result<SumRow> {
    let blobs = gen_blobs(cfg.items, spec, cfg.seed);
    let app = SumApp::new(
        SumConfig {
            width: cfg.width,
            mode,
            shape: SumShape::Fused,
            ..Default::default()
        },
        kernels,
    );
    let mut last = None;
    let m = time_fn(cfg.bench, || {
        last = Some(app.run(&blobs).expect("sum app run"));
    });
    let report = last.unwrap();
    let node = match mode {
        SumMode::Enumerated => "sum",
        SumMode::Tagged => "tagsum",
    };
    Ok(SumRow {
        region: match spec {
            RegionSpec::Fixed { size } => size,
            RegionSpec::Uniform { max } => max,
        },
        seconds: m.median(),
        throughput: cfg.items as f64 / m.median(),
        occupancy: report.metrics.node(node).map(|n| n.occupancy()).unwrap_or(0.0),
        invocations: report.invocations,
    })
}

fn sum_sweep_table(title: &str, rows: &[SumRow]) -> Table {
    let mut t = Table::new(&["region", "time", "items/s", "occ%", "kernel_invocations"]);
    for r in rows {
        t.row(&[
            r.region.to_string(),
            fmt_duration(r.seconds),
            format!("{:.2e}", r.throughput),
            format!("{:.1}", 100.0 * r.occupancy),
            r.invocations.to_string(),
        ]);
    }
    println!("== {title} ==");
    t
}

/// **Figure 6**: execution time vs fixed region size.
pub fn fig6(cfg: &SweepConfig) -> Result<Vec<SumRow>> {
    let p = provider(cfg.backend, cfg.width)?;
    let mut rows = Vec::new();
    for size in region_size_axis(cfg.width) {
        rows.push(run_sum_point(
            cfg,
            RegionSpec::Fixed { size },
            SumMode::Enumerated,
            p.kernels.clone(),
        )?);
    }
    sum_sweep_table("Fig 6: sum app, fixed-size regions", &rows).print();
    Ok(rows)
}

/// **Figure 7**: execution time vs max region size (uniform random).
pub fn fig7(cfg: &SweepConfig) -> Result<Vec<SumRow>> {
    let p = provider(cfg.backend, cfg.width)?;
    let mut rows = Vec::new();
    for max in region_size_axis(cfg.width) {
        rows.push(run_sum_point(
            cfg,
            RegionSpec::Uniform { max },
            SumMode::Enumerated,
            p.kernels.clone(),
        )?);
    }
    sum_sweep_table("Fig 7: sum app, variable-size regions", &rows).print();
    Ok(rows)
}

/// One measured row of the taxi sweep.
#[derive(Debug, Clone)]
pub struct TaxiRow {
    /// Pipeline variant measured.
    pub variant: TaxiVariant,
    /// Workload scale factor (number of lines).
    pub scale: usize,
    /// Total text bytes processed.
    pub chars: usize,
    /// Median seconds per run.
    pub seconds: f64,
    /// Stage-1 full-ensemble firing fraction.
    pub stage1_full: f64,
    /// Stage-2 full-ensemble firing fraction.
    pub stage2_full: f64,
    /// Coordinate pairs parsed.
    pub pairs: usize,
}

/// **Figure 8**: taxi app, three implementations vs input size; also
/// prints the §5 occupancy statistic (91 % / 9 % split).
pub fn fig8(cfg: &SweepConfig, base_lines: usize, scales: &[usize]) -> Result<Vec<TaxiRow>> {
    let p = provider(cfg.backend, cfg.width)?;
    let base = generate(base_lines, TaxiGenConfig::default(), cfg.seed);
    let mut rows = Vec::new();
    for &scale in scales {
        let w = replicate(&base, scale);
        let chars: usize = w.lines.iter().map(|l| l.len).sum();
        for variant in TaxiVariant::all() {
            let app = TaxiApp::new(
                TaxiConfig {
                    width: cfg.width,
                    variant,
                    // paper-scale queues: candidate queues sized so stage-2
                    // backpressure cannot fragment stage 1 (see §Perf log)
                    data_cap: 65536,
                    signal_cap: 8192,
                    ..Default::default()
                },
                p.kernels.clone(),
            );
            let mut last = None;
            let m = time_fn(cfg.bench, || {
                last = Some(app.run(&w).expect("taxi run"));
            });
            let report = last.unwrap();
            anyhow::ensure!(
                report.pairs.len() == w.total_pairs,
                "{variant:?} parsed {} of {} pairs",
                report.pairs.len(),
                w.total_pairs
            );
            rows.push(TaxiRow {
                variant,
                scale,
                chars,
                seconds: m.median(),
                stage1_full: report
                    .metrics
                    .node("classify")
                    .map(|n| n.full_fraction())
                    .unwrap_or(0.0),
                stage2_full: report
                    .metrics
                    .node("parse")
                    .map(|n| n.full_fraction())
                    .unwrap_or(0.0),
                pairs: report.pairs.len(),
            });
        }
    }
    let mut t = Table::new(&[
        "scale", "chars", "variant", "time", "s1_full%", "s2_full%", "pairs",
    ]);
    for r in &rows {
        t.row(&[
            r.scale.to_string(),
            r.chars.to_string(),
            r.variant.label().to_string(),
            fmt_duration(r.seconds),
            format!("{:.1}", 100.0 * r.stage1_full),
            format!("{:.1}", 100.0 * r.stage2_full),
            r.pairs.to_string(),
        ]);
    }
    println!("== Fig 8: taxi app, three context strategies ==");
    t.print();
    Ok(rows)
}

/// One measured row of the shard-scaling sweep.
#[derive(Debug, Clone)]
pub struct ScaleRow {
    /// Region size (items).
    pub region: usize,
    /// Worker threads.
    pub workers: usize,
    /// Shards the stream was cut into.
    pub shards: usize,
    /// Median seconds per run.
    pub seconds: f64,
    /// Items per second.
    pub throughput: f64, // items/sec
    /// Speedup over the 1-worker row at the same region size.
    pub speedup: f64,
    /// Busy-time utilization of the workers that ran.
    pub utilization: f64,
}

/// Shard-scaling sweep (the L3.5 baseline curve): sum-app throughput vs
/// worker count × region size. Region size is the paper's Fig. 6/7 axis —
/// it sets the region-boundary frequency, and with it both per-pipeline
/// occupancy *and* how finely the shard planner can balance the stream.
///
/// Each timed iteration includes per-worker pipeline construction (the
/// runner builds workers lazily inside the run), which is the honest cost
/// of a sharded run on the native backend. On the XLA backend it also
/// includes per-worker engine spin-up and kernel compilation — dominant at
/// small stream sizes — so XLA scaling curves here measure end-to-end run
/// cost, not steady-state pipeline throughput (a per-worker engine cache
/// is a ROADMAP item).
pub fn scaling_shards(
    cfg: &SweepConfig,
    workers_axis: &[usize],
    region_sizes: &[usize],
) -> Result<Vec<ScaleRow>> {
    let spawn = KernelSpawn::from(cfg.backend);
    let mut rows = Vec::new();
    for &region in region_sizes {
        let blobs = gen_blobs(cfg.items, RegionSpec::Fixed { size: region }, cfg.seed);
        let factory = SumFactory::new(
            SumConfig {
                width: cfg.width,
                ..Default::default()
            },
            spawn,
        );
        let mut series = Vec::with_capacity(workers_axis.len());
        for &workers in workers_axis {
            // a few shards per worker gives the pool slack to balance
            let runner = ShardedRunner::new(ExecConfig::new(workers).with_shards_per_worker(4));
            let mut last = None;
            let m = time_fn(cfg.bench, || {
                last = Some(runner.run(&factory, &blobs).expect("sharded sum run"));
            });
            let report = last.unwrap();
            anyhow::ensure!(
                report.outputs.len() == blobs.len(),
                "lost regions: {} of {}",
                report.outputs.len(),
                blobs.len()
            );
            series.push((workers, m.median(), report.shards, report.utilization()));
        }
        // speedup baseline: the 1-worker row if the axis has one, else the
        // slowest row (so reordering the axis can't silently skew the curve)
        let base = series
            .iter()
            .find(|&&(workers, ..)| workers == 1)
            .map(|&(_, seconds, ..)| seconds)
            .unwrap_or_else(|| series.iter().map(|&(_, s, ..)| s).fold(0.0, f64::max));
        for (workers, seconds, shards, utilization) in series {
            rows.push(ScaleRow {
                region,
                workers,
                shards,
                seconds,
                throughput: cfg.items as f64 / seconds,
                speedup: base / seconds,
                utilization,
            });
        }
    }
    let mut t = Table::new(&[
        "region", "workers", "shards", "time", "items/s", "speedup", "util%",
    ]);
    for r in &rows {
        t.row(&[
            r.region.to_string(),
            r.workers.to_string(),
            r.shards.to_string(),
            fmt_duration(r.seconds),
            format!("{:.2e}", r.throughput),
            format!("{:.2}x", r.speedup),
            format!("{:.0}", 100.0 * r.utilization),
        ]);
    }
    println!("== Scaling: sharded sum app, workers × region size ==");
    t.print();
    Ok(rows)
}

/// Render scaling rows as the `BENCH_scaling_shards.json` artifact
/// (uploaded by CI next to the hotpath and ingest ones).
pub fn scaling_to_json(rows: &[ScaleRow]) -> String {
    let mut s = String::new();
    s.push_str("{\n  \"bench\": \"scaling_shards\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"region\": {}, \"workers\": {}, \"shards\": {}, \"seconds\": {:.6}, \
             \"items_per_sec\": {:.1}, \"speedup\": {:.4}, \"utilization\": {:.4}}}{}\n",
            r.region,
            r.workers,
            r.shards,
            r.seconds,
            r.throughput,
            r.speedup,
            r.utilization,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// §5 "abstraction penalty" check: an app that uses no signals pays ~0 for
/// the machinery. Compares a coordinator pipeline (signal queues present
/// but idle) against a raw kernel loop over the same ensembles.
pub fn abstraction_penalty(cfg: &SweepConfig) -> Result<(f64, f64, f64)> {
    use crate::coordinator::aggregate::Aggregator;
    use crate::coordinator::topology::PipelineBuilder;
    use std::cell::RefCell;

    let p = provider(cfg.backend, cfg.width)?;
    let n = cfg.items;
    let vals: Vec<f32> = {
        let mut rng = crate::util::prng::Prng::new(cfg.seed);
        (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect()
    };
    let w = cfg.width;

    // (a) raw loop: no coordinator at all
    let ks = p.kernels.clone();
    let mask = vec![1i32; w];
    let raw = time_fn(cfg.bench, || {
        let mut total = 0.0f64;
        let mut buf = vec![0.0f32; w];
        let mut pm = Vec::new();
        for chunk in vals.chunks(w) {
            buf[..chunk.len()].copy_from_slice(chunk);
            for s in buf[chunk.len()..].iter_mut() {
                *s = 0.0;
            }
            let m: &[i32] = if chunk.len() == w {
                &mask
            } else {
                crate::apps::prefix_mask(&mut pm, chunk.len(), w);
                &pm
            };
            let (s, _) = ks.sum_region(&buf, m, 0.0).unwrap();
            total += s as f64;
        }
        std::hint::black_box(total);
    });

    // (b) coordinator pipeline, signals never used
    let ks2 = p.kernels.clone();
    let coord = time_fn(cfg.bench, || {
        let mut b = PipelineBuilder::new(w);
        let src = b.source_with_cap::<f32>(8192);
        let scratch = RefCell::new(vec![0.0f32; w]);
        let mscratch = RefCell::new(Vec::new());
        let ksr = ks2.clone();
        let _sums = b.sink(
            "sum",
            &src,
            Aggregator::new(
                0.0f64,
                move |acc: &mut f64, items: &[f32], _| {
                    let mut buf = scratch.borrow_mut();
                    let mut m = mscratch.borrow_mut();
                    buf[..items.len()].copy_from_slice(items);
                    for s in buf[items.len()..].iter_mut() {
                        *s = 0.0;
                    }
                    crate::apps::prefix_mask(&mut m, items.len(), w);
                    let (s, _) = ksr.sum_region(&buf, &m, 0.0).unwrap();
                    *acc += s as f64;
                    Ok(())
                },
                |acc: &mut f64, _| Ok(Some(*acc)),
            ),
        );
        let mut pipe = b.build();
        let mut fed = 0usize;
        while fed < vals.len() {
            while fed < vals.len() && src.data_space() > 0 {
                src.push(vals[fed]);
                fed += 1;
            }
            pipe.run().unwrap();
        }
    });

    // (c) the same pipeline with one region per `w` items (signals ACTIVE)
    let blobs = gen_blobs(n, RegionSpec::Fixed { size: w }, cfg.seed);
    let app = SumApp::new(
        SumConfig {
            width: w,
            ..Default::default()
        },
        p.kernels.clone(),
    );
    let signals = time_fn(cfg.bench, || {
        app.run(&blobs).unwrap();
    });

    let (ra, co, si) = (raw.median(), coord.median(), signals.median());
    let mut t = Table::new(&["configuration", "time", "vs raw"]);
    t.row(&["raw kernel loop".into(), fmt_duration(ra), "1.00x".into()]);
    t.row(&[
        "coordinator, signals unused".into(),
        fmt_duration(co),
        format!("{:.2}x", co / ra),
    ]);
    t.row(&[
        "coordinator, aligned regions".into(),
        fmt_duration(si),
        format!("{:.2}x", si / ra),
    ]);
    println!("== Abstraction penalty (paper: negligible when unused) ==");
    t.print();
    Ok((ra, co, si))
}

/// Ablation A2: the Fig 6 sweep at several SIMD widths — the minima track
/// the width, confirming the occupancy mechanism.
pub fn ablation_width(cfg: &SweepConfig, widths: &[usize]) -> Result<Vec<(usize, Vec<SumRow>)>> {
    let mut out = Vec::new();
    for &w in widths {
        let mut c = *cfg;
        c.width = w;
        let p = provider(cfg.backend, w)?;
        let mut rows = Vec::new();
        for size in [w / 2, w, w + 8, 2 * w, 4 * w] {
            if size == 0 {
                continue;
            }
            rows.push(run_sum_point(
                &c,
                RegionSpec::Fixed { size },
                SumMode::Enumerated,
                p.kernels.clone(),
            )?);
        }
        out.push((w, rows));
    }
    let mut t = Table::new(&["width", "region", "time", "occ%"]);
    for (w, rows) in &out {
        for r in rows {
            t.row(&[
                w.to_string(),
                r.region.to_string(),
                fmt_duration(r.seconds),
                format!("{:.1}", 100.0 * r.occupancy),
            ]);
        }
    }
    println!("== Ablation: SIMD width sweep ==");
    t.print();
    Ok(out)
}

/// Ablation A3 (paper §6 future work): per-lane context (dense tags +
/// segmented reduction, signal-free) vs signal-delimited ensembles, as a
/// function of region size. Also covers the §5 sum-app comparison.
pub fn ablation_lanectx(cfg: &SweepConfig) -> Result<Vec<(usize, f64, f64)>> {
    let p = provider(cfg.backend, cfg.width)?;
    let w = cfg.width;
    let mut out = Vec::new();
    for size in [w / 8, w / 4, w / 2, w, 2 * w, 4 * w] {
        if size == 0 {
            continue;
        }
        let enum_row = run_sum_point(
            cfg,
            RegionSpec::Fixed { size },
            SumMode::Enumerated,
            p.kernels.clone(),
        )?;
        let tag_row = run_sum_point(
            cfg,
            RegionSpec::Fixed { size },
            SumMode::Tagged,
            p.kernels.clone(),
        )?;
        out.push((size, enum_row.seconds, tag_row.seconds));
    }
    let mut t = Table::new(&["region", "signals(enum)", "lane-ctx(tagged)", "winner"]);
    for &(size, e, tg) in &out {
        t.row(&[
            size.to_string(),
            fmt_duration(e),
            fmt_duration(tg),
            if e < tg { "signals" } else { "lane-ctx" }.to_string(),
        ]);
    }
    println!("== Ablation: signal-delimited vs per-lane context ==");
    t.print();
    Ok(out)
}

/// Scheduling-policy ablation (design-choice bench): occupancy and time
/// for the three policies on the hybrid taxi app.
pub fn ablation_policy(cfg: &SweepConfig, lines: usize) -> Result<()> {
    let p = provider(cfg.backend, cfg.width)?;
    let w = generate(lines, TaxiGenConfig::default(), cfg.seed);
    let mut t = Table::new(&["policy", "time", "stage2_full%"]);
    for (name, policy) in [
        ("greedy-occupancy", Policy::GreedyOccupancy),
        ("deepest-first", Policy::DeepestFirst),
        ("round-robin", Policy::RoundRobin),
    ] {
        let app = TaxiApp::new(
            TaxiConfig {
                width: cfg.width,
                variant: TaxiVariant::Hybrid,
                policy,
                ..Default::default()
            },
            p.kernels.clone(),
        );
        let mut last = None;
        let m = time_fn(cfg.bench, || {
            last = Some(app.run(&w).expect("taxi run"));
        });
        let r = last.unwrap();
        t.row(&[
            name.to_string(),
            fmt_duration(m.median()),
            format!(
                "{:.1}",
                100.0
                    * r.metrics
                        .node("parse")
                        .map(|n| n.full_fraction())
                        .unwrap_or(0.0)
            ),
        ]);
    }
    println!("== Ablation: scheduling policy (hybrid taxi) ==");
    t.print();
    Ok(())
}
