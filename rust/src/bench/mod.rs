//! Benchmark harness (offline substitute for criterion).
//!
//! Used by every `rust/benches/*.rs` target: warmup + timed iterations,
//! summary statistics, and aligned table output matching the rows/series
//! of the paper's figures.

pub mod faults;
pub mod figures;
pub mod hotpath;
pub mod ingest;
pub mod io_bench;
pub mod latency;

use std::time::Instant;

use crate::util::stats::{fmt_duration, median, Summary};

/// Timing configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Untimed warmup iterations before measurement.
    pub warmup_iters: usize,
    /// Timed iterations (the median is reported).
    pub iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 1,
            iters: 3,
        }
    }
}

impl BenchConfig {
    /// Read iteration counts from env (`REGATTA_BENCH_ITERS`,
    /// `REGATTA_BENCH_WARMUP`) for quick CI runs.
    pub fn from_env() -> BenchConfig {
        let mut cfg = BenchConfig::default();
        if let Some(n) = std::env::var("REGATTA_BENCH_ITERS").ok().and_then(|s| s.parse().ok()) {
            cfg.iters = n;
        }
        if let Some(n) = std::env::var("REGATTA_BENCH_WARMUP").ok().and_then(|s| s.parse().ok()) {
            cfg.warmup_iters = n;
        }
        cfg
    }
}

/// One measurement: median/mean/min over the timed iterations.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Seconds per timed iteration.
    pub samples: Vec<f64>,
}

impl Measurement {
    /// Median of the samples.
    pub fn median(&self) -> f64 {
        median(&self.samples)
    }

    /// Mean of the samples.
    pub fn mean(&self) -> f64 {
        let mut s = Summary::new();
        for &x in &self.samples {
            s.add(x);
        }
        s.mean()
    }

    /// Fastest sample.
    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }
}

/// Time `f` under `cfg`, returning per-iteration seconds.
pub fn time_fn<F: FnMut()>(cfg: BenchConfig, mut f: F) -> Measurement {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    Measurement { samples }
}

/// Aligned-table printer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with the given column headers.
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header count).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with per-column alignment.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Print the table with aligned columns.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Convenience: format seconds for table cells.
pub fn cell_time(secs: f64) -> String {
    fmt_duration(secs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_produces_samples() {
        let m = time_fn(
            BenchConfig {
                warmup_iters: 1,
                iters: 3,
            },
            || {
                std::hint::black_box((0..1000).sum::<u64>());
            },
        );
        assert_eq!(m.samples.len(), 3);
        assert!(m.min() >= 0.0);
        assert!(m.median() >= m.min());
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["region", "time"]);
        t.row(&["32".into(), "1.0 ms".into()]);
        t.row(&["1024".into(), "0.5 ms".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("region"));
        assert!(lines[2].ends_with("1.0 ms"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
