//! # REGATTA — region-based state for streaming computations on SIMD architectures
//!
//! A reproduction of *Timcheck & Buhler, "Streaming Computations with
//! Region-Based State on SIMD Architectures" (PARMA-DITAM 2020)* as a
//! layered Rust + JAX + Pallas stack:
//!
//! * **Layer 3.5 ([`exec`])** — the sharded multi-worker executor: any
//!   coordinator pipeline, replicated across OS threads. An input stream
//!   is partitioned into shards **only at region boundaries** (a
//!   `Blob`/`Composite` is never split), each worker runs a private
//!   single-threaded pipeline, and a deterministic merger reassembles
//!   outputs in original stream order with a global metrics fold.
//!   Streams can be materialized up front or ingested incrementally from
//!   a [`workload::source::RegionSource`] under a bounded in-flight
//!   budget, with per-worker deques and LIFO-local/FIFO-steal work
//!   stealing absorbing skewed region sizes.
//! * **Layer 3 ([`coordinator`])** — the streaming *coordinator*: compute
//!   nodes connected by bounded data queues and out-of-band signal queues,
//!   the paper's **credit protocol** for precise signal delivery under
//!   irregular dataflow (§3), the **enumeration / aggregation** abstraction
//!   for region-based contextual state (§4), a non-preemptive scheduler,
//!   and a SIMD machine model in which each node firing processes a
//!   fixed-width *ensemble* of lanes.
//! * **Layer 2 (python/compile/model.py)** — JAX ensemble functions, AOT
//!   lowered to HLO text at build time (`make artifacts`).
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels called by L2.
//!
//! At runtime the coordinator executes ensembles by invoking the AOT
//! artifacts through PJRT ([`runtime`]); Python is never on the data path.
//! Without artifacts, the pure-Rust native kernel mirror runs everywhere.
//!
//! ## Quick start
//!
//! ```no_run
//! use std::rc::Rc;
//! use regatta::prelude::*;
//! use regatta::runtime::kernels::KernelSet;
//! use regatta::apps::sum::SumConfig;
//!
//! // The paper's Fig. 3 pipeline: enumerate Blobs, filter+scale their
//! // elements, and aggregate one sum per Blob.
//! let blobs: Vec<Blob> = (0..4).map(|i| Blob::from_vec(i, vec![1.0; 100])).collect();
//! let cfg = SumConfig { width: 128, ..Default::default() };
//! let app = SumApp::new(cfg, Rc::new(KernelSet::native(128)));
//! let report = app.run(&blobs).unwrap();
//! println!("{} sums, occupancy {:.1}%", report.outputs.len(),
//!          100.0 * report.metrics.occupancy());
//!
//! // Scale the same pipeline across 8 workers (L3.5): shards cut at
//! // region boundaries, outputs bit-identical and in stream order.
//! let report = app.run_sharded(&blobs, 8).unwrap();
//! assert_eq!(report.outputs.len(), 4);
//! ```
//!
//! See `examples/` for runnable applications (`sharded_scaling` for the
//! executor layer) and `rust/benches/` for the harnesses that regenerate
//! every figure of the paper's evaluation plus the `scaling_shards`
//! worker-scaling curve.

#![warn(missing_docs)]

pub mod apps;
pub mod bench;
pub mod coordinator;
pub mod exec;
pub mod io;
pub mod metrics;
pub mod runtime;
pub mod simd;
pub mod trace;
pub mod util;
pub mod workload;

/// Counting wrapper over the system allocator: lets the test suite prove
/// the zero-allocation steady-state firing path and `bench hotpath`
/// report allocations-per-firing (see [`util::alloc_count`]). Pure
/// pass-through plus one thread-local increment per allocation.
///
/// Gated behind the default-on `count-allocs` feature so embedders can
/// opt out (`default-features = false`) and keep their own global
/// allocator; without it [`util::alloc_count::thread_allocations`]
/// reports a constant 0 and the allocation-proof tests become vacuous.
#[cfg(feature = "count-allocs")]
#[global_allocator]
static GLOBAL_ALLOCATOR: util::alloc_count::CountingAllocator =
    util::alloc_count::CountingAllocator;

pub mod prelude {
    //! One-stop imports for application authors.
    pub use crate::apps::sum::{
        SumApp, SumConfig, SumFactory, SumMode, SumPipeline, SumReport, SumShape,
    };
    pub use crate::apps::taxi::{
        TaxiApp, TaxiConfig, TaxiFactory, TaxiPair, TaxiPipeline, TaxiReport, TaxiVariant,
    };
    pub use crate::coordinator::{
        aggregate::{Aggregator, FilterMapLogic, MapLogic},
        channel::Channel,
        enumerate::{Blob, Composite, Enumerator},
        metrics::{NodeMetrics, PipelineMetrics},
        node::{Emitter, Node, NodeLogic, NodeOps},
        queue::{DataQueue, SignalQueue},
        scheduler::{Policy, Scheduler},
        signal::{parent_as, Credit, ParentRef, Signal, SignalKind},
        tagging::Tagged,
        topology::{Pipeline, PipelineBuilder},
    };
    pub use crate::exec::{
        ClaimMode, ExecConfig, ExecReport, IngestPolicy, KernelSpawn, PipelineFactory,
        ShardOutput, ShardPlan, ShardPolicy, ShardWorker, ShardedRunner, WorkerPool,
        WorkerStats,
    };
    pub use crate::io::{
        BinarySink, BlobFileSource, BlobWriter, JsonlSink, ResultSink, TextSource,
    };
    pub use crate::metrics::{
        Heartbeat, LaneMetrics, LatencyHist, MetricsHub, MetricsReport, MetricsSpec,
    };
    pub use crate::runtime::kernels::{Backend, KernelSet};
    pub use crate::runtime::{ArtifactStore, Engine, KernelName};
    pub use crate::simd::{ChunkSource, SimdConfig, SimdMachine};
    pub use crate::trace::{Trace, TraceEvent, TraceOptions, TraceSink, TraceSpec, WorkerTrace};
    pub use crate::workload::regions::{GenBlobSource, RegionSpec};
    pub use crate::workload::source::{IterSource, RegionSource, SliceSource};
    pub use crate::workload::taxi::TaxiWorkload;
}
