//! The SIMD machine model: fixed-width processors competing for a shared
//! input stream (paper §2.2).
//!
//! The paper instantiates the pipeline once per GPU processor (SM) and
//! lets the instances compete to consume a common input stream with atomic
//! claims. We reproduce that mapping: each *worker thread* owns a full
//! pipeline instance (plus its own PJRT engine — client handles are
//! thread-confined) and claims input chunks from a shared, lock-free
//! cursor until the stream is exhausted.
//!
//! The SIMD width `w` is the ensemble size of every node firing; one
//! fixed-shape XLA invocation per ensemble is the machine's cost unit, so
//! occupancy loss (partial ensembles forced by region boundaries) shows up
//! directly as wall-clock time.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, Result};

/// Machine shape.
#[derive(Debug, Clone, Copy)]
pub struct SimdConfig {
    /// SIMD width (lanes per ensemble). The paper's CUDA block size: 128.
    pub width: usize,
    /// Number of processors (worker threads; the paper's 28 SMs).
    pub workers: usize,
}

impl Default for SimdConfig {
    fn default() -> Self {
        SimdConfig {
            width: 128,
            workers: 1,
        }
    }
}

/// Shared input stream: workers claim chunks by atomic cursor bump
/// (the paper's "pipelines compete to consume data from a common input
/// stream ... atomic operations but no locking").
pub struct ChunkSource<C> {
    chunks: Vec<C>,
    cursor: AtomicUsize,
}

impl<C> ChunkSource<C> {
    /// Create a shared source over the given chunks.
    pub fn new(chunks: Vec<C>) -> Arc<ChunkSource<C>> {
        Arc::new(ChunkSource {
            chunks,
            cursor: AtomicUsize::new(0),
        })
    }

    /// Claim the next unprocessed chunk, if any.
    pub fn claim(&self) -> Option<&C> {
        let i = self.cursor.fetch_add(1, Ordering::Relaxed);
        self.chunks.get(i)
    }

    /// Total chunks.
    pub fn len(&self) -> usize {
        self.chunks.len()
    }

    /// Whether there are no chunks.
    pub fn is_empty(&self) -> bool {
        self.chunks.is_empty()
    }
}

/// The machine: runs one pipeline instance per worker over a chunked
/// input stream.
pub struct SimdMachine {
    /// Machine configuration.
    pub cfg: SimdConfig,
}

impl SimdMachine {
    /// Create a machine with the given config.
    pub fn new(cfg: SimdConfig) -> SimdMachine {
        SimdMachine { cfg }
    }

    /// Run `worker_fn(worker_id, source)` on every worker thread and
    /// collect the per-worker results in worker order.
    ///
    /// `worker_fn` typically builds a pipeline (and a PJRT engine) inside
    /// the thread, then loops `source.claim()` → feed → run-to-quiescence.
    /// Chunks are only handed out once, so the input stream is consumed
    /// exactly once across the machine.
    pub fn run<C, R, F>(&self, source: Arc<ChunkSource<C>>, worker_fn: F) -> Result<Vec<R>>
    where
        C: Sync + Send,
        R: Send,
        F: Fn(usize, Arc<ChunkSource<C>>) -> Result<R> + Sync,
    {
        if self.cfg.workers <= 1 {
            return Ok(vec![worker_fn(0, source)?]);
        }
        let results: Vec<Result<R>> = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(self.cfg.workers);
            for wid in 0..self.cfg.workers {
                let src = source.clone();
                let f = &worker_fn;
                handles.push(scope.spawn(move || f(wid, src)));
            }
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .map_err(|_| anyhow!("worker thread panicked"))
                        .and_then(|r| r)
                })
                .collect()
        });
        results.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_claimed_exactly_once() {
        let src = ChunkSource::new((0..100).collect::<Vec<u32>>());
        let machine = SimdMachine::new(SimdConfig {
            width: 4,
            workers: 4,
        });
        let sums = machine
            .run(src, |_wid, src| {
                let mut sum = 0u64;
                while let Some(&c) = src.claim() {
                    sum += c as u64;
                }
                Ok(sum)
            })
            .unwrap();
        assert_eq!(sums.len(), 4);
        assert_eq!(sums.iter().sum::<u64>(), (0..100u64).sum());
    }

    #[test]
    fn single_worker_runs_inline() {
        let src = ChunkSource::new(vec![1u32, 2, 3]);
        let machine = SimdMachine::new(SimdConfig {
            width: 4,
            workers: 1,
        });
        let out = machine
            .run(src, |wid, src| {
                assert_eq!(wid, 0);
                let mut v = Vec::new();
                while let Some(&c) = src.claim() {
                    v.push(c);
                }
                Ok(v)
            })
            .unwrap();
        assert_eq!(out[0], vec![1, 2, 3]);
    }

    #[test]
    fn worker_errors_propagate() {
        let src = ChunkSource::new(vec![1u32]);
        let machine = SimdMachine::new(SimdConfig {
            width: 4,
            workers: 2,
        });
        let res: Result<Vec<()>> = machine.run(src, |wid, _src| {
            if wid == 1 {
                anyhow::bail!("boom")
            } else {
                Ok(())
            }
        });
        assert!(res.is_err());
    }
}
