//! Tree topologies (paper Fig. 1b): a broadcast node duplicating its
//! input stream — data *and* signals, precisely interleaved — to several
//! children.
//!
//! The paper's contributions "also apply to tree-structured topologies":
//! each child edge is an independent [`Channel`], so the emitter credit
//! rules run per child and every child observes the same precise
//! data/signal interleaving. (DAGs with convergent edges remain out of
//! scope, as in the paper — see its §2.1 discussion of [9].)

use std::rc::Rc;

use anyhow::Result;

use super::channel::Channel;
use super::metrics::NodeMetrics;
use super::node::NodeOps;
use super::signal::SignalKind;

/// Fan-out node: one input channel, `k` cloned output channels.
pub struct Broadcast<T: Clone + 'static> {
    name: String,
    input: Rc<Channel<T>>,
    outputs: Vec<Rc<Channel<T>>>,
    /// Receiver-side credit counter (same §3.1 rules as a compute node).
    credit: u64,
    width: usize,
    metrics: NodeMetrics,
    scratch: Vec<T>,
}

impl<T: Clone + 'static> Broadcast<T> {
    /// Create a broadcast from one input to `outputs` cloned children.
    pub fn new(
        name: impl Into<String>,
        width: usize,
        input: Rc<Channel<T>>,
        outputs: Vec<Rc<Channel<T>>>,
    ) -> Broadcast<T> {
        assert!(!outputs.is_empty(), "broadcast needs at least one child");
        Broadcast {
            name: name.into(),
            input,
            outputs,
            credit: 0,
            width,
            metrics: NodeMetrics::new(width),
            scratch: Vec::with_capacity(width),
        }
    }

    fn min_child_data_space(&self) -> usize {
        self.outputs.iter().map(|c| c.data_space()).min().unwrap_or(0)
    }

    fn min_child_signal_space(&self) -> usize {
        self.outputs.iter().map(|c| c.signal_space()).min().unwrap_or(0)
    }

    fn data_limit(&mut self) -> usize {
        let avail = self.input.data_len();
        if avail == 0 {
            return 0;
        }
        let mut limit = avail.min(self.width);
        if self.input.signal_len() > 0 {
            if self.credit == 0 {
                self.credit = self.input.take_head_signal_credit();
            }
            limit = limit.min(self.credit as usize);
        }
        limit.min(self.min_child_data_space())
    }
}

impl<T: Clone + 'static> NodeOps for Broadcast<T> {
    fn name(&self) -> &str {
        &self.name
    }

    fn has_pending(&self) -> bool {
        self.input.has_pending()
    }

    fn fireable(&self) -> bool {
        let data = self.input.data_len();
        let sigs = self.input.signal_len();
        if data == 0 && sigs == 0 {
            return false;
        }
        if data > 0 && self.min_child_data_space() >= 1 {
            let credit_ok = if sigs > 0 {
                self.credit > 0 || self.input.head_signal_credit() > 0
            } else {
                true
            };
            if credit_ok {
                return true;
            }
        }
        sigs > 0
            && self.credit == 0
            && self.input.head_signal_credit() == 0
            && self.min_child_signal_space() >= 1
    }

    fn fire(&mut self) -> Result<bool> {
        self.metrics.firings += 1;
        let mut worked = false;

        // data phase: one ensemble, cloned to every child
        let limit = self.data_limit();
        if limit > 0 {
            let take = self.input.pop_data_into(limit, &mut self.scratch);
            for child in &self.outputs {
                child.push_slice(&self.scratch[..take])?;
            }
            if self.credit > 0 {
                self.credit -= take as u64;
            }
            self.metrics.record_ensemble(take);
            worked = true;
        }

        // signal phase: duplicate signals to every child
        if self.credit == 0 {
            while self.input.signal_len() > 0 {
                let c = self.input.take_head_signal_credit();
                if c > 0 {
                    self.credit = c;
                    break;
                }
                if self.min_child_signal_space() == 0 {
                    break;
                }
                let sig = self.input.pop_signal().expect("len checked");
                for child in &self.outputs {
                    // each child channel re-derives credit for its own
                    // queue state (emitter rules are per edge)
                    child.emit_signal(match &sig.kind {
                        SignalKind::RegionBegin { parent } => SignalKind::RegionBegin {
                            parent: parent.clone(),
                        },
                        SignalKind::RegionEnd { parent } => SignalKind::RegionEnd {
                            parent: parent.clone(),
                        },
                        SignalKind::Custom(id) => SignalKind::Custom(*id),
                    });
                    self.metrics.signals_emitted += 1;
                }
                self.metrics.signals_consumed += 1;
                worked = true;
            }
        }
        Ok(worked)
    }

    fn reset(&mut self) {
        self.input.reset();
        self.credit = 0;
        self.scratch.clear();
        self.metrics.reset();
    }

    fn metrics(&self) -> &NodeMetrics {
        &self.metrics
    }

    fn ready_hint(&self) -> usize {
        let avail = self.input.data_len();
        if avail == 0 {
            return 0;
        }
        let mut limit = avail.min(self.width);
        if self.input.signal_len() > 0 {
            let credit = self.credit.max(self.input.head_signal_credit());
            limit = limit.min(credit as usize);
        }
        limit.min(self.min_child_data_space())
    }

    fn input_pressure(&self) -> bool {
        self.input.data_space() < self.width
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::signal::{ParentRef, Signal};

    fn drain<T>(ch: &Channel<T>) -> (Vec<T>, Vec<Signal>) {
        let mut items = Vec::new();
        let mut buf = Vec::new();
        let mut sigs = Vec::new();
        loop {
            // respect interleaving: consume data up to next signal credit
            let credit = ch.take_head_signal_credit() as usize;
            if ch.signal_len() > 0 {
                ch.pop_data_into(credit, &mut buf);
                items.append(&mut buf);
                sigs.push(ch.pop_signal().unwrap());
            } else {
                ch.pop_data_into(usize::MAX, &mut buf);
                items.append(&mut buf);
                break;
            }
        }
        (items, sigs)
    }

    #[test]
    fn duplicates_data_and_signals_to_all_children() {
        let input: Rc<Channel<u32>> = Channel::new(64, 16);
        let c1: Rc<Channel<u32>> = Channel::new(64, 16);
        let c2: Rc<Channel<u32>> = Channel::new(64, 16);
        input.push(1);
        input.push(2);
        input.emit_signal(SignalKind::Custom(7)); // after 2 items
        input.push(3);

        let mut b = Broadcast::new("tee", 4, input, vec![c1.clone(), c2.clone()]);
        while b.fireable() {
            b.fire().unwrap();
        }
        for child in [&c1, &c2] {
            let (items, sigs) = drain(child);
            assert_eq!(items, vec![1, 2, 3]);
            assert_eq!(sigs.len(), 1);
            assert!(matches!(sigs[0].kind, SignalKind::Custom(7)));
        }
        assert_eq!(b.metrics().signals_consumed, 1);
        assert_eq!(b.metrics().signals_emitted, 2);
    }

    #[test]
    fn per_child_credit_is_recomputed() {
        // children at different consumption states get different credits
        let input: Rc<Channel<u32>> = Channel::new(64, 16);
        let c1: Rc<Channel<u32>> = Channel::new(64, 16);
        let c2: Rc<Channel<u32>> = Channel::new(64, 16);
        input.push(1);
        input.push(2);
        let mut b = Broadcast::new("tee", 4, input.clone(), vec![c1.clone(), c2.clone()]);
        b.fire().unwrap(); // both children now hold items 1,2
        let mut buf = Vec::new();
        c1.pop_data_into(2, &mut buf); // child 1 consumed everything
        input.emit_signal(SignalKind::Custom(0));
        while b.fireable() {
            b.fire().unwrap();
        }
        // rule (1) per edge: c1 had 0 queued -> credit 0; c2 had 2 -> 2
        assert_eq!(c1.head_signal_credit(), 0);
        assert_eq!(c2.head_signal_credit(), 2);
    }

    #[test]
    fn region_parents_shared_across_children() {
        let input: Rc<Channel<u32>> = Channel::new(64, 16);
        let c1: Rc<Channel<u32>> = Channel::new(64, 16);
        let c2: Rc<Channel<u32>> = Channel::new(64, 16);
        let p: ParentRef = Rc::new(42u64);
        input.emit_signal(SignalKind::RegionBegin { parent: p.clone() });
        input.push(5);
        input.emit_signal(SignalKind::RegionEnd { parent: p });
        let mut b = Broadcast::new("tee", 4, input, vec![c1.clone(), c2.clone()]);
        while b.fireable() {
            b.fire().unwrap();
        }
        for child in [&c1, &c2] {
            let (items, sigs) = drain(child);
            assert_eq!(items, vec![5]);
            assert_eq!(sigs.len(), 2);
            let got = match &sigs[0].kind {
                SignalKind::RegionBegin { parent } => {
                    crate::coordinator::signal::parent_as::<u64>(parent).map(|v| *v)
                }
                _ => None,
            };
            assert_eq!(got, Some(42));
        }
    }

    #[test]
    fn reset_rearms_credit_and_metrics() {
        let input: Rc<Channel<u32>> = Channel::new(64, 16);
        let c1: Rc<Channel<u32>> = Channel::new(64, 16);
        input.push(1);
        input.push(2);
        input.emit_signal(SignalKind::Custom(1));
        let mut b = Broadcast::new("tee", 4, input.clone(), vec![c1.clone()]);
        b.fire().unwrap(); // ensemble of 2 + the signal
        input.push(3); // left pending
        b.reset();
        c1.reset(); // downstream node resets its own input channel
        assert!(!b.has_pending());
        assert_eq!(b.metrics().ensembles, 0);
        assert_eq!(b.metrics().signals_consumed, 0);
        // rerun: indistinguishable from a fresh node
        input.push(9);
        while b.fireable() {
            b.fire().unwrap();
        }
        let (items, sigs) = drain(&c1);
        assert_eq!(items, vec![9]);
        assert!(sigs.is_empty());
        assert_eq!(b.metrics().ensembles, 1);
    }

    #[test]
    fn blocked_child_gates_the_ensemble() {
        let input: Rc<Channel<u32>> = Channel::new(64, 16);
        for i in 0..8 {
            input.push(i);
        }
        let c1: Rc<Channel<u32>> = Channel::new(64, 16);
        let c2: Rc<Channel<u32>> = Channel::new(2, 16); // tiny child
        let mut b = Broadcast::new("tee", 4, input, vec![c1.clone(), c2.clone()]);
        b.fire().unwrap();
        assert_eq!(c1.data_len(), 2); // capped by the slow child
        assert_eq!(c2.data_len(), 2);
        assert!(!b.fireable()); // blocked until c2 drains
        let mut buf = Vec::new();
        c2.pop_data_into(2, &mut buf);
        assert!(b.fireable());
    }
}
