//! Bounded data and signal queues between pipeline stages.
//!
//! `DataQueue<T>` is a fixed-capacity ring buffer; its bulk operations
//! (`pop_into`, `push_slice`, `extend_bulk`) move whole runs with a single
//! reserve + tight copy loop instead of per-item `pop_front`/`push_back`
//! bookkeeping. This is the hot path: every firing does exactly one
//! `pop_into` and at most one bulk push, so the per-firing queue cost is
//! two bulk moves, not `2 × ensemble_width` individual queue operations.

use std::collections::VecDeque;

use super::signal::Signal;

/// Pre-reservation cap shared by the data and signal sides so both queues
/// reach their steady-state capacity at construction time (no ring growth
/// mid-run for any capacity up to the cap).
const PRE_RESERVE_CAP: usize = 1 << 20;

/// Fixed-capacity FIFO of data items.
#[derive(Debug)]
pub struct DataQueue<T> {
    buf: VecDeque<T>,
    capacity: usize,
}

impl<T> DataQueue<T> {
    /// Create a data queue with the given capacity.
    pub fn new(capacity: usize) -> DataQueue<T> {
        DataQueue {
            buf: VecDeque::with_capacity(capacity.min(PRE_RESERVE_CAP)),
            capacity,
        }
    }

    /// Queued items.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether the queue is empty.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Maximum items the queue holds.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Remaining space.
    pub fn space(&self) -> usize {
        self.capacity - self.buf.len()
    }

    /// Push one item. Panics if full — callers must check space first
    /// (the scheduler's fireable test guarantees it).
    pub fn push(&mut self, item: T) {
        assert!(self.buf.len() < self.capacity, "data queue overflow");
        self.buf.push_back(item);
    }

    /// Bulk-push a slice. Panics if the run does not fit — like [`push`],
    /// callers on the firing path have already reserved the space.
    ///
    /// [`push`]: DataQueue::push
    pub fn push_slice(&mut self, items: &[T])
    where
        T: Clone,
    {
        assert!(
            items.len() <= self.space(),
            "data queue overflow: bulk push of {} into {} free slots",
            items.len(),
            self.space()
        );
        self.buf.extend(items.iter().cloned());
    }

    /// Bulk-append from an exact-size iterator. Panics if the run does
    /// not fit — same release-mode guarantee as [`DataQueue::push`], so a
    /// mis-reported iterator length can never silently unbound the queue.
    pub fn extend_bulk<I>(&mut self, items: I)
    where
        I: ExactSizeIterator<Item = T>,
    {
        assert!(
            items.len() <= self.space(),
            "data queue overflow: bulk extend of {} into {} free slots",
            items.len(),
            self.space()
        );
        self.buf.extend(items);
        // ExactSizeIterator is a safe trait: a len() that under-reports
        // passes the pre-check, so re-verify the bound after the append
        debug_assert!(self.buf.len() <= self.capacity, "iterator len() lied");
    }

    /// Pop up to `n` items into `out` (cleared first) as one bulk move —
    /// a single `drain` of the ring's head run, no per-item `pop_front`.
    /// Returns the count.
    pub fn pop_into(&mut self, n: usize, out: &mut Vec<T>) -> usize {
        out.clear();
        let take = n.min(self.buf.len());
        out.extend(self.buf.drain(..take));
        take
    }

    /// Pop a single item.
    pub fn pop(&mut self) -> Option<T> {
        self.buf.pop_front()
    }

    /// Discard all queued items in place. The ring keeps its allocation,
    /// so a clear on the reuse path ([`Channel::reset`]) costs no
    /// allocator traffic.
    ///
    /// [`Channel::reset`]: super::channel::Channel::reset
    pub fn clear(&mut self) {
        self.buf.clear();
    }

    /// Re-target the logical capacity (per-shard source sizing for
    /// persistent pipelines). The ring's allocation never shrinks here
    /// (see [`DataQueue::shrink_to`] for the explicit release path); it
    /// grows only when `cap` exceeds every previously requested capacity
    /// — the capacity-regrowth path, amortized to zero across shards.
    pub fn set_capacity(&mut self, cap: usize) {
        debug_assert!(
            self.buf.is_empty(),
            "set_capacity on a non-empty queue would strand queued items \
             past the new bound"
        );
        self.capacity = cap;
        let target = cap.min(PRE_RESERVE_CAP);
        if self.buf.capacity() < target {
            self.buf.reserve(target - self.buf.len());
        }
    }

    /// Physical slots the ring currently holds (≥ the logical capacity
    /// after a [`DataQueue::set_capacity`] shrink) — what a shrink
    /// policy inspects to decide whether the allocation is worth
    /// releasing.
    pub fn allocated(&self) -> usize {
        self.buf.capacity()
    }

    /// Release ring memory down to `max(cap, len, capacity)` physical
    /// slots — the explicit counterpart to [`DataQueue::set_capacity`]'s
    /// keep-the-allocation default. Called off the firing path (between
    /// shards) by source-capacity shrink policies when a transient giant
    /// shard has left a ring far larger than the steady state needs;
    /// never below the logical capacity, so the next shard of typical
    /// size still runs allocation-free.
    pub fn shrink_to(&mut self, cap: usize) {
        let floor = cap.max(self.capacity).min(PRE_RESERVE_CAP);
        if self.buf.capacity() > floor {
            self.buf.shrink_to(floor);
        }
    }
}

/// Fixed-capacity FIFO of signals.
///
/// The head signal's credit is drained in place by receiver rule (2b);
/// the signal itself is consumed only once its credit reaches zero.
#[derive(Debug)]
pub struct SignalQueue {
    buf: VecDeque<Signal>,
    capacity: usize,
}

impl SignalQueue {
    /// Create a signal queue with the given capacity.
    pub fn new(capacity: usize) -> SignalQueue {
        SignalQueue {
            buf: VecDeque::with_capacity(capacity.min(PRE_RESERVE_CAP)),
            capacity,
        }
    }

    /// Queued signals.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether the queue is empty.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Maximum signals the queue holds.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Free slots remaining.
    pub fn space(&self) -> usize {
        self.capacity - self.buf.len()
    }

    /// Enqueue a signal. Panics if full — guarded by the fireable test.
    pub fn push(&mut self, sig: Signal) {
        assert!(self.buf.len() < self.capacity, "signal queue overflow");
        self.buf.push_back(sig);
    }

    /// Credit currently carried by the head signal (0 if none queued).
    pub fn head_credit(&self) -> u64 {
        self.buf.front().map(|s| s.credit).unwrap_or(0)
    }

    /// Drain the head signal's credit (receiver rule 2b). Returns the
    /// amount transferred.
    pub fn take_head_credit(&mut self) -> u64 {
        match self.buf.front_mut() {
            Some(s) => std::mem::take(&mut s.credit),
            None => 0,
        }
    }

    /// Consume the head signal. Callers must have drained its credit.
    pub fn pop(&mut self) -> Option<Signal> {
        debug_assert_eq!(self.head_credit(), 0, "consuming signal with credit");
        self.buf.pop_front()
    }

    /// Discard all queued signals in place (capacity retained — see
    /// [`DataQueue::clear`]).
    pub fn clear(&mut self) {
        self.buf.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::signal::SignalKind;

    #[test]
    fn data_queue_fifo_and_space() {
        let mut q = DataQueue::new(4);
        assert_eq!(q.space(), 4);
        q.push(1);
        q.push(2);
        q.push(3);
        assert_eq!(q.len(), 3);
        assert_eq!(q.space(), 1);
        let mut out = Vec::new();
        assert_eq!(q.pop_into(2, &mut out), 2);
        assert_eq!(out, vec![1, 2]);
        assert_eq!(q.pop(), Some(3));
        assert!(q.is_empty());
    }

    #[test]
    fn pop_into_caps_at_len() {
        let mut q = DataQueue::new(8);
        q.push(10);
        let mut out = vec![99, 98];
        assert_eq!(q.pop_into(5, &mut out), 1);
        assert_eq!(out, vec![10]); // cleared first
    }

    #[test]
    fn push_slice_keeps_fifo_order() {
        let mut q = DataQueue::new(8);
        q.push_slice(&[1, 2, 3]);
        q.push(4);
        q.push_slice(&[5]);
        let mut out = Vec::new();
        assert_eq!(q.pop_into(8, &mut out), 5);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn bulk_ops_across_wraparound() {
        // force the ring head past the physical end, then bulk-move across
        // the wrap boundary
        let mut q = DataQueue::new(6);
        q.push_slice(&[0, 1, 2, 3]);
        let mut out = Vec::new();
        q.pop_into(3, &mut out); // head now at index 3
        q.push_slice(&[4, 5, 6, 7, 8]); // wraps
        assert_eq!(q.len(), 6);
        q.pop_into(6, &mut out);
        assert_eq!(out, vec![3, 4, 5, 6, 7, 8]);
    }

    #[test]
    #[should_panic(expected = "data queue overflow")]
    fn data_overflow_panics() {
        let mut q = DataQueue::new(1);
        q.push(1);
        q.push(2);
    }

    #[test]
    #[should_panic(expected = "data queue overflow")]
    fn push_slice_overflow_panics() {
        let mut q = DataQueue::new(2);
        q.push(9);
        q.push_slice(&[1, 2]);
    }

    #[test]
    fn clear_empties_without_touching_capacity() {
        let mut q = DataQueue::new(4);
        q.push_slice(&[1, 2, 3]);
        q.clear();
        assert!(q.is_empty());
        assert_eq!(q.capacity(), 4);
        assert_eq!(q.space(), 4);
        // still usable after the clear
        q.push_slice(&[9, 8, 7, 6]);
        assert_eq!(q.len(), 4);
    }

    #[test]
    fn set_capacity_retargets_the_bound() {
        let mut q: DataQueue<u32> = DataQueue::new(2);
        q.push(1);
        q.pop();
        q.set_capacity(5);
        assert_eq!(q.capacity(), 5);
        q.push_slice(&[1, 2, 3, 4, 5]);
        assert_eq!(q.space(), 0);
        // shrinking the logical bound keeps the ring allocation
        let mut out = Vec::new();
        q.pop_into(5, &mut out);
        q.set_capacity(1);
        assert_eq!(q.capacity(), 1);
        q.push(7);
        assert_eq!(q.space(), 0);
    }

    #[test]
    fn shrink_to_releases_memory_but_never_below_the_logical_bound() {
        let mut q: DataQueue<u32> = DataQueue::new(4);
        // a giant transient shard inflates the ring
        q.set_capacity(4096);
        assert!(q.allocated() >= 4096);
        // back to steady state: logical bound drops, allocation lingers
        q.set_capacity(4);
        assert!(q.allocated() >= 4096, "set_capacity never shrinks");
        q.shrink_to(8);
        assert!(q.allocated() < 4096, "shrink_to releases the excess");
        assert!(q.allocated() >= 8);
        // still fully usable at the logical bound
        q.push_slice(&[1, 2, 3, 4]);
        assert_eq!(q.space(), 0);
        // shrinking below the logical capacity is clamped to it
        let mut out = Vec::new();
        q.pop_into(4, &mut out);
        q.shrink_to(0);
        assert!(q.allocated() >= q.capacity());
        q.push_slice(&[9, 8, 7, 6]);
        assert_eq!(q.len(), 4);
    }

    #[test]
    fn shrink_to_keeps_queued_items() {
        let mut q: DataQueue<u32> = DataQueue::new(3);
        q.set_capacity(1024);
        q.set_capacity(3);
        q.push_slice(&[1, 2, 3]);
        q.shrink_to(0);
        assert!(q.allocated() >= 3, "live items bound the shrink");
        let mut out = Vec::new();
        q.pop_into(3, &mut out);
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "data queue overflow")]
    fn shrunk_capacity_is_enforced() {
        let mut q: DataQueue<u32> = DataQueue::new(8);
        q.set_capacity(1);
        q.push(1);
        q.push(2);
    }

    #[test]
    fn signal_queue_clear_keeps_capacity() {
        let mut s = SignalQueue::new(2);
        s.push(Signal::new(SignalKind::Custom(1), 3));
        s.push(Signal::new(SignalKind::Custom(2), 0));
        s.clear();
        assert!(s.is_empty());
        assert_eq!(s.space(), 2);
        assert_eq!(s.head_credit(), 0);
    }

    #[test]
    fn signal_queue_credit_draining() {
        let mut s = SignalQueue::new(4);
        s.push(Signal::new(SignalKind::Custom(1), 3));
        s.push(Signal::new(SignalKind::Custom(2), 5));
        assert_eq!(s.head_credit(), 3);
        assert_eq!(s.take_head_credit(), 3);
        assert_eq!(s.head_credit(), 0);
        let sig = s.pop().unwrap();
        assert!(matches!(sig.kind, SignalKind::Custom(1)));
        assert_eq!(s.head_credit(), 5); // next head's credit now visible
    }

    #[test]
    fn empty_signal_queue_is_zero_credit() {
        let mut s = SignalQueue::new(2);
        assert_eq!(s.head_credit(), 0);
        assert_eq!(s.take_head_credit(), 0);
        assert!(s.pop().is_none());
    }
}
