//! Aggregation: "closing" a region by folding its elements into one result
//! per parent object (paper §4, the `aggregate` keyword).
//!
//! [`Aggregator`] is the generic fold node: `begin()` resets the
//! accumulator, `run()` folds each ensemble (typically via a SIMD-parallel
//! reduction kernel — the paper notes node `a`'s `acc += v` would really be
//! a parallel reduction), and `end()` emits the folded value. It absorbs
//! region signals (`forward_region_signals = false`): downstream nodes see
//! a plain stream of per-parent results, stripped of parent context.
//!
//! [`MapLogic`] and [`FilterMapLogic`] are the corresponding helpers for
//! ordinary pass-through stages.

use anyhow::Result;

use super::node::{Emitter, NodeLogic};
use super::signal::ParentRef;

/// Generic aggregation logic.
///
/// * `step(acc, items, parent)` folds one ensemble into the accumulator;
/// * `finish(acc, parent)` produces the per-parent output (or `None` to
///   emit nothing for that parent).
pub struct Aggregator<I, O, A, Step, Finish>
where
    A: Clone,
    Step: FnMut(&mut A, &[I], Option<&ParentRef>) -> Result<()>,
    Finish: FnMut(&mut A, &ParentRef) -> Result<Option<O>>,
{
    init: A,
    acc: A,
    step: Step,
    finish: Finish,
    _marker: std::marker::PhantomData<fn(&[I]) -> O>,
}

impl<I, O, A, Step, Finish> Aggregator<I, O, A, Step, Finish>
where
    A: Clone,
    Step: FnMut(&mut A, &[I], Option<&ParentRef>) -> Result<()>,
    Finish: FnMut(&mut A, &ParentRef) -> Result<Option<O>>,
{
    /// Create the logic from an initial state and step/finish closures.
    pub fn new(init: A, step: Step, finish: Finish) -> Self {
        Aggregator {
            acc: init.clone(),
            init,
            step,
            finish,
            _marker: std::marker::PhantomData,
        }
    }

    /// Current accumulator (for tests / inspection).
    pub fn acc(&self) -> &A {
        &self.acc
    }
}

impl<I, O, A, Step, Finish> NodeLogic for Aggregator<I, O, A, Step, Finish>
where
    I: 'static,
    O: 'static,
    A: Clone + 'static,
    Step: FnMut(&mut A, &[I], Option<&ParentRef>) -> Result<()>,
    Finish: FnMut(&mut A, &ParentRef) -> Result<Option<O>>,
{
    type In = I;
    type Out = O;

    fn run(
        &mut self,
        items: &[I],
        parent: Option<&ParentRef>,
        _out: &mut Emitter<'_, O>,
    ) -> Result<()> {
        (self.step)(&mut self.acc, items, parent)
    }

    fn begin(&mut self, _parent: &ParentRef, _out: &mut Emitter<'_, O>) -> Result<()> {
        self.acc = self.init.clone();
        Ok(())
    }

    fn end(&mut self, parent: &ParentRef, out: &mut Emitter<'_, O>) -> Result<()> {
        if let Some(o) = (self.finish)(&mut self.acc, parent)? {
            out.push(o);
        }
        Ok(())
    }

    fn max_outputs_per_input(&self) -> usize {
        0 // run() never pushes
    }

    fn max_outputs_per_signal(&self) -> usize {
        1 // end() pushes at most one aggregate
    }

    fn forward_region_signals(&self) -> bool {
        false // `aggregate` closes the enumeration scope
    }

    fn reset(&mut self) {
        // pipeline reuse: drop any residue from an aborted or completed
        // stream so the first region of the next shard folds from `init`,
        // exactly like a freshly built node (begin() also re-inits, but
        // reset keeps the guarantee independent of signal arrival)
        self.acc = self.init.clone();
    }
}

/// Stateless per-ensemble map/filter logic from a closure
/// `f(items, parent, emitter)`, declaring ≤ `max_out` outputs per input.
pub struct FilterMapLogic<I, O, F>
where
    F: FnMut(&[I], Option<&ParentRef>, &mut Emitter<'_, O>) -> Result<()>,
{
    f: F,
    max_out: usize,
    _marker: std::marker::PhantomData<fn(&[I]) -> O>,
}

impl<I, O, F> FilterMapLogic<I, O, F>
where
    F: FnMut(&[I], Option<&ParentRef>, &mut Emitter<'_, O>) -> Result<()>,
{
    /// `max_out`: a-priori bound on outputs per consumed input item.
    pub fn new(max_out: usize, f: F) -> Self {
        FilterMapLogic {
            f,
            max_out,
            _marker: std::marker::PhantomData,
        }
    }
}

impl<I, O, F> NodeLogic for FilterMapLogic<I, O, F>
where
    I: 'static,
    O: 'static,
    F: FnMut(&[I], Option<&ParentRef>, &mut Emitter<'_, O>) -> Result<()>,
{
    type In = I;
    type Out = O;

    fn run(
        &mut self,
        items: &[I],
        parent: Option<&ParentRef>,
        out: &mut Emitter<'_, O>,
    ) -> Result<()> {
        (self.f)(items, parent, out)
    }

    fn max_outputs_per_input(&self) -> usize {
        self.max_out
    }
}

/// One-to-one map logic from a per-item closure (convenience).
pub struct MapLogic<I, O, F>
where
    F: FnMut(&I) -> O,
{
    f: F,
    _marker: std::marker::PhantomData<fn(&I) -> O>,
}

impl<I, O, F> MapLogic<I, O, F>
where
    F: FnMut(&I) -> O,
{
    /// Wrap a per-item closure as node logic.
    pub fn new(f: F) -> Self {
        MapLogic {
            f,
            _marker: std::marker::PhantomData,
        }
    }
}

impl<I, O, F> NodeLogic for MapLogic<I, O, F>
where
    I: 'static,
    O: 'static,
    F: FnMut(&I) -> O,
{
    type In = I;
    type Out = O;

    fn run(
        &mut self,
        items: &[I],
        _parent: Option<&ParentRef>,
        out: &mut Emitter<'_, O>,
    ) -> Result<()> {
        for item in items {
            out.push((self.f)(item));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::channel::Channel;
    use crate::coordinator::node::{Node, NodeOps, Output};
    use crate::coordinator::signal::SignalKind;
    use std::cell::RefCell;
    use std::rc::Rc;

    fn region<T: 'static>(ch: &Channel<f32>, parent: T, items: &[f32]) {
        let p: ParentRef = Rc::new(parent);
        ch.emit_signal(SignalKind::RegionBegin { parent: p.clone() });
        for &v in items {
            ch.push(v);
        }
        ch.emit_signal(SignalKind::RegionEnd { parent: p });
    }

    #[test]
    fn aggregator_sums_per_region() {
        let ch: Rc<Channel<f32>> = Channel::new(64, 16);
        region(&ch, 1u64, &[1.0, 2.0, 3.0]);
        region(&ch, 2u64, &[10.0]);
        region(&ch, 3u64, &[]);
        let agg = Aggregator::new(
            0.0f64,
            |acc: &mut f64, items: &[f32], _p| {
                *acc += items.iter().map(|&v| v as f64).sum::<f64>();
                Ok(())
            },
            |acc: &mut f64, _p| Ok(Some(*acc)),
        );
        let sink = Rc::new(RefCell::new(Vec::new()));
        let mut node = Node::new("a", 4, ch, Output::Sink(sink.clone()), agg);
        while node.fireable() {
            node.fire().unwrap();
        }
        assert_eq!(*sink.borrow(), vec![6.0, 10.0, 0.0]);
    }

    #[test]
    fn aggregator_absorbs_region_signals() {
        let ch: Rc<Channel<f32>> = Channel::new(16, 8);
        region(&ch, 1u64, &[1.0]);
        let agg = Aggregator::new(
            0.0f64,
            |acc: &mut f64, items: &[f32], _p| {
                *acc += items.len() as f64;
                Ok(())
            },
            |acc: &mut f64, _p| Ok(Some(*acc)),
        );
        let out: Rc<Channel<f64>> = Channel::new(16, 8);
        let mut node = Node::new("a", 4, ch, Output::Chan(out.clone()), agg);
        while node.fireable() {
            node.fire().unwrap();
        }
        assert_eq!(out.data_len(), 1);
        assert_eq!(out.signal_len(), 0); // signals absorbed
    }

    #[test]
    fn finish_none_emits_nothing() {
        let ch: Rc<Channel<f32>> = Channel::new(16, 8);
        region(&ch, 1u64, &[]);
        let agg = Aggregator::new(
            0i64,
            |_acc: &mut i64, _items: &[f32], _p| Ok(()),
            |_acc: &mut i64, _p| Ok(None::<i64>),
        );
        let sink = Rc::new(RefCell::new(Vec::new()));
        let mut node = Node::new("a", 4, ch, Output::Sink(sink.clone()), agg);
        while node.fireable() {
            node.fire().unwrap();
        }
        assert!(sink.borrow().is_empty());
    }

    #[test]
    fn aggregator_reset_restores_init() {
        let mut agg = Aggregator::new(
            0.0f64,
            |acc: &mut f64, items: &[f32], _p| {
                *acc += items.len() as f64;
                Ok(())
            },
            |acc: &mut f64, _p| Ok(Some(*acc)),
        );
        let mut stage = Vec::new();
        let mut em = Emitter::new(&mut stage);
        agg.run(&[1.0, 2.0], None, &mut em).unwrap();
        assert_eq!(*agg.acc(), 2.0);
        NodeLogic::reset(&mut agg);
        assert_eq!(*agg.acc(), 0.0);
    }

    #[test]
    fn map_logic_transforms() {
        let ch: Rc<Channel<f32>> = Channel::new(16, 8);
        ch.push(1.0);
        ch.push(2.0);
        let sink = Rc::new(RefCell::new(Vec::new()));
        let mut node = Node::new(
            "m",
            4,
            ch,
            Output::Sink(sink.clone()),
            MapLogic::new(|&v: &f32| v * 10.0),
        );
        while node.fireable() {
            node.fire().unwrap();
        }
        assert_eq!(*sink.borrow(), vec![10.0, 20.0]);
    }
}
