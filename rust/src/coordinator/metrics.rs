//! Per-node and pipeline-wide execution metrics.
//!
//! SIMD occupancy is the paper's central performance quantity: the fraction
//! of lanes doing useful work per firing. Region-boundary signals cap
//! ensembles below the SIMD width, and these counters make that visible
//! (e.g. the taxi app's 91% / 9% full-ensemble split between stages).

/// Counters for one node.
#[derive(Debug, Clone)]
pub struct NodeMetrics {
    /// SIMD width the node runs at (histogram bound).
    pub width: usize,
    /// Scheduler firings (data or signal work done).
    pub firings: u64,
    /// Firings whose data phase processed ≥ 1 item (= ensembles executed).
    pub ensembles: u64,
    /// Ensembles that filled every lane.
    pub full_ensembles: u64,
    /// Total data items consumed.
    pub items: u64,
    /// Signals consumed / emitted downstream.
    pub signals_consumed: u64,
    /// Signals emitted downstream.
    pub signals_emitted: u64,
    /// Histogram of ensemble sizes: `hist[k]` = ensembles with k lanes.
    pub ensemble_hist: Vec<u64>,
}

impl NodeMetrics {
    /// Create zeroed metrics for a node of the given width.
    pub fn new(width: usize) -> NodeMetrics {
        NodeMetrics {
            width,
            firings: 0,
            ensembles: 0,
            full_ensembles: 0,
            items: 0,
            signals_consumed: 0,
            signals_emitted: 0,
            ensemble_hist: vec![0; width + 1],
        }
    }

    /// Record one executed ensemble of `size` lanes.
    pub fn record_ensemble(&mut self, size: usize) {
        debug_assert!(size >= 1 && size <= self.width);
        self.ensembles += 1;
        self.items += size as u64;
        if size == self.width {
            self.full_ensembles += 1;
        }
        self.ensemble_hist[size] += 1;
    }

    /// Mean occupancy: items / (ensembles × width).
    pub fn occupancy(&self) -> f64 {
        if self.ensembles == 0 {
            return 0.0;
        }
        self.items as f64 / (self.ensembles as f64 * self.width as f64)
    }

    /// Fraction of ensembles that were full (the paper's stage statistic).
    pub fn full_fraction(&self) -> f64 {
        if self.ensembles == 0 {
            return 0.0;
        }
        self.full_ensembles as f64 / self.ensembles as f64
    }

    /// Zero every counter in place — the histogram buffer is retained, so
    /// a reset on the pipeline-reuse path allocates nothing. After a
    /// reset the metrics are indistinguishable from `NodeMetrics::new`,
    /// which is what makes a reused pipeline's per-shard metrics fold
    /// identically to a rebuilt one's.
    pub fn reset(&mut self) {
        self.firings = 0;
        self.ensembles = 0;
        self.full_ensembles = 0;
        self.items = 0;
        self.signals_consumed = 0;
        self.signals_emitted = 0;
        self.ensemble_hist.fill(0);
    }

    /// Merge counters from another node instance (multi-worker runs).
    /// Panics on width mismatch — summing histograms of different widths
    /// would silently corrupt the occupancy statistics.
    pub fn merge(&mut self, other: &NodeMetrics) {
        assert_eq!(self.width, other.width, "metrics merge: width mismatch");
        self.firings += other.firings;
        self.ensembles += other.ensembles;
        self.full_ensembles += other.full_ensembles;
        self.items += other.items;
        self.signals_consumed += other.signals_consumed;
        self.signals_emitted += other.signals_emitted;
        for (a, b) in self.ensemble_hist.iter_mut().zip(&other.ensemble_hist) {
            *a += b;
        }
    }
}

/// Metrics for a whole pipeline run.
#[derive(Debug, Clone, Default)]
pub struct PipelineMetrics {
    /// (node name, metrics) in topology order.
    pub nodes: Vec<(String, NodeMetrics)>,
    /// Wall-clock seconds of the scheduler loop.
    pub elapsed: f64,
    /// Scheduler iterations that found nothing fireable before quiescing.
    pub idle_polls: u64,
}

impl PipelineMetrics {
    /// Mean occupancy across all consuming nodes (item-weighted).
    /// Producer nodes (e.g. enumerators) run no ensembles and are skipped.
    pub fn occupancy(&self) -> f64 {
        let (mut items, mut slots) = (0u64, 0u64);
        for (_, m) in &self.nodes {
            if m.ensembles > 0 {
                items += m.items;
                slots += m.ensembles * m.width as u64;
            }
        }
        if slots == 0 {
            0.0
        } else {
            items as f64 / slots as f64
        }
    }

    /// Total ensembles across nodes (the SIMD invocation count — the
    /// machine-model cost unit).
    pub fn total_ensembles(&self) -> u64 {
        self.nodes.iter().map(|(_, m)| m.ensembles).sum()
    }

    /// Look up one node's metrics by name.
    pub fn node(&self, name: &str) -> Option<&NodeMetrics> {
        self.nodes.iter().find(|(n, _)| n == name).map(|(_, m)| m)
    }

    /// Merge another run's metrics. The topologies must match exactly —
    /// same nodes, same order — which is what the sharded executor
    /// guarantees (every worker builds the pipeline from the same
    /// factory); a name mismatch is a bug and panics rather than folding
    /// unrelated counters together.
    pub fn merge(&mut self, other: &PipelineMetrics) {
        if self.nodes.is_empty() {
            *self = other.clone();
            return;
        }
        assert_eq!(self.nodes.len(), other.nodes.len(), "topology mismatch");
        for ((name_a, a), (name_b, b)) in self.nodes.iter_mut().zip(&other.nodes) {
            assert_eq!(
                name_a.as_str(),
                name_b.as_str(),
                "topology mismatch: node name/order"
            );
            a.merge(b);
        }
        self.elapsed = self.elapsed.max(other.elapsed);
        self.idle_polls += other.idle_polls;
    }

    /// Render a per-node occupancy table (used by `--stats`).
    pub fn table(&self) -> String {
        let mut out = String::from(
            "node                 firings  ensembles  full%   occ%    items      sig_in\n",
        );
        for (name, m) in &self.nodes {
            out.push_str(&format!(
                "{:<20} {:>7}  {:>9}  {:>5.1}  {:>5.1}  {:>9}  {:>8}\n",
                name,
                m.firings,
                m.ensembles,
                100.0 * m.full_fraction(),
                100.0 * m.occupancy(),
                m.items,
                m.signals_consumed,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_math() {
        let mut m = NodeMetrics::new(4);
        m.record_ensemble(4);
        m.record_ensemble(2);
        assert_eq!(m.ensembles, 2);
        assert_eq!(m.full_ensembles, 1);
        assert_eq!(m.items, 6);
        assert!((m.occupancy() - 0.75).abs() < 1e-12);
        assert!((m.full_fraction() - 0.5).abs() < 1e-12);
        assert_eq!(m.ensemble_hist[4], 1);
        assert_eq!(m.ensemble_hist[2], 1);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = NodeMetrics::new(8);
        assert_eq!(m.occupancy(), 0.0);
        assert_eq!(m.full_fraction(), 0.0);
    }

    #[test]
    fn reset_matches_a_fresh_instance() {
        let mut m = NodeMetrics::new(4);
        m.firings = 7;
        m.record_ensemble(4);
        m.record_ensemble(2);
        m.signals_consumed = 3;
        m.signals_emitted = 5;
        m.reset();
        let fresh = NodeMetrics::new(4);
        assert_eq!(m.firings, fresh.firings);
        assert_eq!(m.ensembles, fresh.ensembles);
        assert_eq!(m.full_ensembles, fresh.full_ensembles);
        assert_eq!(m.items, fresh.items);
        assert_eq!(m.signals_consumed, fresh.signals_consumed);
        assert_eq!(m.signals_emitted, fresh.signals_emitted);
        assert_eq!(m.ensemble_hist, fresh.ensemble_hist);
        assert_eq!(m.width, 4);
    }

    #[test]
    fn merge_adds() {
        let mut a = NodeMetrics::new(4);
        a.record_ensemble(4);
        let mut b = NodeMetrics::new(4);
        b.record_ensemble(1);
        b.firings = 3;
        a.merge(&b);
        assert_eq!(a.ensembles, 2);
        assert_eq!(a.items, 5);
        assert_eq!(a.firings, 3);
    }

    #[test]
    fn pipeline_merge_folds_matching_topologies() {
        let mk = |n: u64| {
            let mut m = NodeMetrics::new(4);
            for _ in 0..n {
                m.record_ensemble(3);
            }
            PipelineMetrics {
                nodes: vec![("enum".into(), NodeMetrics::new(4)), ("sum".into(), m)],
                elapsed: n as f64,
                idle_polls: 1,
            }
        };
        let mut a = PipelineMetrics::default();
        a.merge(&mk(2)); // empty adopts
        a.merge(&mk(3));
        assert_eq!(a.node("sum").unwrap().ensembles, 5);
        assert_eq!(a.idle_polls, 2);
        assert!((a.elapsed - 3.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "topology mismatch")]
    fn pipeline_merge_rejects_mismatched_names() {
        let pm = |name: &str| PipelineMetrics {
            nodes: vec![(name.to_string(), NodeMetrics::new(4))],
            elapsed: 0.0,
            idle_polls: 0,
        };
        let mut a = pm("sum");
        a.merge(&pm("other"));
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn node_merge_rejects_mismatched_widths() {
        let mut a = NodeMetrics::new(4);
        a.merge(&NodeMetrics::new(8));
    }

    #[test]
    fn pipeline_totals() {
        let mut pm = PipelineMetrics::default();
        let mut m1 = NodeMetrics::new(2);
        m1.record_ensemble(2);
        let mut m2 = NodeMetrics::new(2);
        m2.record_ensemble(1);
        pm.nodes.push(("a".into(), m1));
        pm.nodes.push(("b".into(), m2));
        assert_eq!(pm.total_ensembles(), 2);
        assert!((pm.occupancy() - 0.75).abs() < 1e-12);
        assert!(pm.node("b").is_some());
        assert!(pm.table().contains("a"));
    }
}
