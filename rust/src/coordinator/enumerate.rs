//! Enumeration: "opening" a stream of composite objects into a stream of
//! element indices bracketed by region signals (paper §4).
//!
//! The enumerator consumes composites and, per parent `p`:
//!
//! 1. emits `RegionBegin(p)` on the downstream signal queue,
//! 2. emits the element indices `0..p.count()` as data items,
//! 3. emits `RegionEnd(p)`.
//!
//! Credit assignment happens inside [`Channel::emit_signal`], so downstream
//! nodes receive the boundaries precisely — and therefore never mix two
//! parents' elements in one ensemble. Elements are bare `u32` indices: the
//! parent context rides on the signals, not on the items (the paper's
//! *sparse* representation; contrast with [`super::tagging`]).
//!
//! Like MERCATOR, the framework stays ignorant of composite internals: the
//! [`Composite`] trait only reports the element count (`findCount()`), and
//! node logics fetch elements from the parent themselves (Fig. 5's
//! `b->getItem(i)`).

use std::rc::Rc;

use anyhow::{bail, Result};

use super::channel::Channel;
use super::metrics::NodeMetrics;
use super::node::NodeOps;
use super::signal::{ParentRef, SignalKind};

/// A composite object whose elements can be enumerated.
pub trait Composite: 'static {
    /// Number of elements (the paper's `findCount()`).
    fn count(&self) -> usize;
}

/// The paper's running example composite (Figs 3–5): a bag of numbers.
#[derive(Debug, Clone, PartialEq)]
pub struct Blob {
    /// Region identifier, unique within a stream.
    pub id: u64,
    /// Elements of the region.
    pub elems: Vec<f32>,
}

impl Blob {
    /// Create a blob from an id and its elements.
    pub fn from_vec(id: u64, elems: Vec<f32>) -> Blob {
        Blob { id, elems }
    }

    /// Fig. 5's `b->getItem(i)`.
    pub fn get(&self, i: u32) -> f32 {
        self.elems[i as usize]
    }
}

impl Composite for Blob {
    fn count(&self) -> usize {
        self.elems.len()
    }
}

/// Progress through the current parent.
struct EnumProgress<P> {
    parent: Rc<P>,
    count: usize,
    next: usize,
    ended: bool,
}

/// Enumeration node: `Channel<P>` in, `Channel<u32>` (element indices) out.
pub struct Enumerator<P: Composite> {
    name: String,
    input: Rc<Channel<P>>,
    output: Rc<Channel<u32>>,
    state: Option<EnumProgress<P>>,
    metrics: NodeMetrics,
}

impl<P: Composite> Enumerator<P> {
    /// Create an enumerator between the given channels.
    pub fn new(
        name: impl Into<String>,
        width: usize,
        input: Rc<Channel<P>>,
        output: Rc<Channel<u32>>,
    ) -> Enumerator<P> {
        Enumerator {
            name: name.into(),
            input,
            output,
            state: None,
            metrics: NodeMetrics::new(width),
        }
    }
}

impl<P: Composite> NodeOps for Enumerator<P> {
    fn name(&self) -> &str {
        &self.name
    }

    fn has_pending(&self) -> bool {
        self.state.is_some() || self.input.has_pending()
    }

    fn fireable(&self) -> bool {
        match &self.state {
            Some(p) if p.next < p.count => self.output.data_space() > 0,
            Some(_) => self.output.signal_space() > 0, // needs to emit End
            None => {
                if self.input.data_len() > 0 {
                    // starting a parent emits Begin (and possibly End for
                    // an empty parent in the same firing)
                    self.output.signal_space() >= 1
                } else if self.input.signal_len() > 0 {
                    // forward custom signals
                    self.output.signal_space() >= 1
                } else {
                    false
                }
            }
        }
    }

    fn fire(&mut self) -> Result<bool> {
        self.metrics.firings += 1;
        let mut worked = false;

        // Forward any upstream custom signals first (precise w.r.t. the
        // composite stream; nested region signals are not supported).
        while self.state.is_none()
            && self.input.signal_len() > 0
            && self.input.head_signal_credit() == 0
            && self.output.signal_space() > 0
        {
            let sig = self.input.pop_signal().expect("len checked");
            match sig.kind {
                SignalKind::Custom(id) => {
                    self.output.emit_signal(SignalKind::Custom(id));
                    self.metrics.signals_consumed += 1;
                    self.metrics.signals_emitted += 1;
                    worked = true;
                }
                SignalKind::RegionBegin { .. } | SignalKind::RegionEnd { .. } => {
                    bail!("nested enumeration is not supported (node {})", self.name)
                }
            }
        }

        loop {
            match &mut self.state {
                None => {
                    // open the next parent
                    if self.output.signal_space() == 0 {
                        break;
                    }
                    let Some(p) = self.input.pop_data() else {
                        break;
                    };
                    let parent = Rc::new(p);
                    let count = parent.count();
                    let pref: ParentRef = parent.clone();
                    self.output
                        .emit_signal(SignalKind::RegionBegin { parent: pref });
                    self.metrics.signals_emitted += 1;
                    self.metrics.items += 1; // composites consumed
                    self.state = Some(EnumProgress {
                        parent,
                        count,
                        next: 0,
                        ended: false,
                    });
                    worked = true;
                }
                Some(prog) => {
                    // emit element indices in one batched push (single
                    // queue borrow — perf pass, EXPERIMENTS.md §Perf)
                    let burst = (prog.count - prog.next).min(self.output.data_space());
                    if burst > 0 {
                        let lo = prog.next as u32;
                        self.output.push_iter(lo..lo + burst as u32)?;
                        prog.next += burst;
                        worked = true;
                    }
                    if prog.next < prog.count {
                        break; // out of data space; resume next firing
                    }
                    if self.output.signal_space() == 0 {
                        break; // cannot emit End yet
                    }
                    let pref: ParentRef = prog.parent.clone();
                    self.output.emit_signal(SignalKind::RegionEnd { parent: pref });
                    self.metrics.signals_emitted += 1;
                    prog.ended = true;
                    self.state = None;
                    worked = true;
                }
            }
        }
        Ok(worked)
    }

    fn reset(&mut self) {
        self.input.reset();
        self.state = None;
        self.metrics.reset();
    }

    fn metrics(&self) -> &NodeMetrics {
        &self.metrics
    }

    fn ready_hint(&self) -> usize {
        // producer: how many elements could be emitted this firing
        let w = self.metrics.width;
        match &self.state {
            Some(p) => (p.count - p.next).min(self.output.data_space()).min(w),
            None if self.input.data_len() > 0 => self.output.data_space().min(w),
            None => 0,
        }
    }

    fn input_pressure(&self) -> bool {
        // composite granularity: pressured only when the source queue is
        // completely full
        self.input.data_space() == 0
    }
}

impl<P: Composite> Enumerator<P>
where
    P: 'static,
{
    /// Rc-upcast helper used when storing `Rc<P>` as a [`ParentRef`].
    #[allow(dead_code)]
    fn _assert_static(_p: &P) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::signal::Signal;

    fn drain_signals(ch: &Channel<u32>) -> Vec<Signal> {
        let mut out = Vec::new();
        while ch.signal_len() > 0 {
            // record the credit before draining it (pop requires credit 0)
            let credit = ch.take_head_signal_credit();
            let mut sig = ch.pop_signal().unwrap();
            sig.credit = credit;
            out.push(sig);
        }
        out
    }

    #[test]
    fn enumerates_indices_with_boundaries() {
        let input: Rc<Channel<Blob>> = Channel::new(8, 4);
        let output: Rc<Channel<u32>> = Channel::new(64, 16);
        input.push(Blob::from_vec(0, vec![1.0, 2.0, 3.0]));
        input.push(Blob::from_vec(1, vec![4.0]));
        let mut e = Enumerator::new("enum", 4, input, output.clone());
        while e.fireable() {
            e.fire().unwrap();
        }
        // data: 0,1,2 (blob 0), 0 (blob 1)
        let mut items = Vec::new();
        // credits: Begin(0)=0, End(0)=3, Begin(1)=0, End(1)=1
        assert_eq!(output.head_signal_credit(), 0);
        assert_eq!(output.data_len(), 4);
        output.pop_data_into(4, &mut items);
        assert_eq!(items, vec![0, 1, 2, 0]);
        let sigs = drain_signals(&output);
        assert_eq!(sigs.len(), 4);
        assert!(matches!(sigs[0].kind, SignalKind::RegionBegin { .. }));
        assert!(matches!(sigs[1].kind, SignalKind::RegionEnd { .. }));
        assert_eq!(sigs[1].credit, 3);
        assert_eq!(sigs[2].credit, 0);
        assert_eq!(sigs[3].credit, 1);
    }

    #[test]
    fn empty_parent_yields_empty_region() {
        let input: Rc<Channel<Blob>> = Channel::new(8, 4);
        let output: Rc<Channel<u32>> = Channel::new(64, 16);
        input.push(Blob::from_vec(7, vec![]));
        let mut e = Enumerator::new("enum", 4, input, output.clone());
        while e.fireable() {
            e.fire().unwrap();
        }
        assert_eq!(output.data_len(), 0);
        let sigs = drain_signals(&output);
        assert_eq!(sigs.len(), 2); // Begin + End, no elements
        assert_eq!(sigs[1].credit, 0);
    }

    #[test]
    fn resumes_when_output_fills() {
        let input: Rc<Channel<Blob>> = Channel::new(8, 4);
        let output: Rc<Channel<u32>> = Channel::new(2, 16); // tiny data queue
        input.push(Blob::from_vec(0, vec![0.0; 5]));
        let mut e = Enumerator::new("enum", 4, input, output.clone());
        assert!(e.fire().unwrap());
        assert_eq!(output.data_len(), 2); // blocked at capacity
        let mut buf = Vec::new();
        output.pop_data_into(2, &mut buf); // downstream consumes
        assert!(e.fireable());
        e.fire().unwrap();
        output.pop_data_into(2, &mut buf);
        // final firing emits the last element AND the End signal
        e.fire().unwrap();
        assert_eq!(output.data_len(), 1);
        assert_eq!(output.signal_len(), 2); // Begin + End
        assert!(!e.has_pending());
        assert!(!e.fireable());
    }

    #[test]
    fn reset_clears_mid_parent_progress() {
        let input: Rc<Channel<Blob>> = Channel::new(8, 4);
        let output: Rc<Channel<u32>> = Channel::new(2, 16); // tiny: parent stays open
        input.push(Blob::from_vec(0, vec![0.0; 5]));
        let mut e = Enumerator::new("enum", 4, input.clone(), output.clone());
        e.fire().unwrap(); // opens the blob, emits 2 indices, stalls
        assert!(e.has_pending(), "parent still open");
        e.reset();
        output.reset(); // downstream node resets its own input channel
        assert!(!e.has_pending());
        assert_eq!(e.metrics().firings, 0);
        assert_eq!(e.metrics().items, 0);
        // a fresh parent enumerates as if the node were newly built
        input.push(Blob::from_vec(1, vec![1.0, 2.0]));
        while e.fireable() {
            e.fire().unwrap();
        }
        assert_eq!(output.data_len(), 2);
        assert_eq!(output.signal_len(), 2); // Begin + End
        assert_eq!(e.metrics().items, 1); // one composite consumed
    }

    #[test]
    fn forwards_custom_signals() {
        let input: Rc<Channel<Blob>> = Channel::new(8, 4);
        let output: Rc<Channel<u32>> = Channel::new(8, 4);
        input.emit_signal(SignalKind::Custom(42));
        let mut e = Enumerator::new("enum", 4, input, output.clone());
        e.fire().unwrap();
        assert_eq!(output.signal_len(), 1);
    }

    #[test]
    fn blob_get_item() {
        let b = Blob::from_vec(3, vec![1.5, 2.5]);
        assert_eq!(b.count(), 2);
        assert_eq!(b.get(1), 2.5);
    }
}
