//! Pipeline assembly: the programmatic equivalent of the paper's topology
//! specification language (Fig. 4).
//!
//! ```no_run
//! use regatta::coordinator::topology::PipelineBuilder;
//! use regatta::coordinator::enumerate::Blob;
//! use regatta::coordinator::aggregate::{Aggregator, FilterMapLogic};
//! use regatta::coordinator::signal::parent_as;
//!
//! // Node src : Source<Blob>;
//! // Node f   : enumerate Blob -> float from Blob;
//! // Node a   : float from Blob -> aggregate double;
//! // Node snk : Sink<double>;
//! // Edges src -> f -> a -> snk;
//! let mut b = PipelineBuilder::new(128);
//! let src = b.source::<Blob>();
//! let elems = b.enumerate("enum", &src);
//! let f = b.node("f", &elems, FilterMapLogic::new(1, |idxs: &[u32], parent, out| {
//!     let blob = parent_as::<Blob>(parent.unwrap()).unwrap();
//!     for &i in idxs {
//!         let v = blob.get(i);
//!         if v > 0.0 { out.push(3.14 * v); }
//!     }
//!     Ok(())
//! }));
//! let sums = b.sink("a", &f, Aggregator::new(
//!     0.0f64,
//!     |acc, items: &[f32], _| { *acc += items.iter().map(|&v| v as f64).sum::<f64>(); Ok(()) },
//!     |acc, _| Ok(Some(*acc)),
//! ));
//! let mut pipe = b.build();
//! // feed src, then: pipe.run().unwrap();
//! ```

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::Result;

use super::channel::Channel;
use super::enumerate::{Composite, Enumerator};
use super::metrics::PipelineMetrics;
use super::node::{Node, NodeLogic, NodeOps, Output};
use super::scheduler::{Policy, Scheduler};

/// Default data-queue capacity between stages (items).
pub const DEFAULT_DATA_CAP: usize = 4096;
/// Default signal-queue capacity between stages.
pub const DEFAULT_SIGNAL_CAP: usize = 1024;

/// Type-erased channel identity (for wiring the scheduler's ready set).
fn chan_key<T>(ch: &Rc<Channel<T>>) -> usize {
    Rc::as_ptr(ch) as *const () as usize
}

/// Incrementally builds a [`Pipeline`].
pub struct PipelineBuilder {
    width: usize,
    data_cap: usize,
    signal_cap: usize,
    policy: Policy,
    nodes: Vec<Box<dyn NodeOps>>,
    /// Per node: (input channel keys, output channel keys) — the wiring
    /// the scheduler's ready set is derived from at `build()`.
    edges: Vec<(Vec<usize>, Vec<usize>)>,
}

impl PipelineBuilder {
    /// New builder at SIMD width `width`.
    pub fn new(width: usize) -> PipelineBuilder {
        PipelineBuilder {
            width,
            data_cap: DEFAULT_DATA_CAP,
            signal_cap: DEFAULT_SIGNAL_CAP,
            policy: Policy::GreedyOccupancy,
            nodes: Vec::new(),
            edges: Vec::new(),
        }
    }

    /// Override queue capacities for subsequently created channels.
    pub fn queue_caps(mut self, data_cap: usize, signal_cap: usize) -> Self {
        self.data_cap = data_cap;
        self.signal_cap = signal_cap;
        self
    }

    /// Override the scheduling policy.
    pub fn policy(mut self, policy: Policy) -> Self {
        self.policy = policy;
        self
    }

    /// Ensemble width the pipeline was built with.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Create the source channel the driver feeds (the paper's initial
    /// input stream). Sized `cap` items.
    pub fn source_with_cap<T: 'static>(&mut self, cap: usize) -> Rc<Channel<T>> {
        Channel::named("source", cap, self.signal_cap)
    }

    /// Source channel with the default capacity.
    pub fn source<T: 'static>(&mut self) -> Rc<Channel<T>> {
        self.source_with_cap(self.data_cap)
    }

    /// Append a compute node reading `input`; returns its output channel.
    pub fn node<L: NodeLogic + 'static>(
        &mut self,
        name: &str,
        input: &Rc<Channel<L::In>>,
        logic: L,
    ) -> Rc<Channel<L::Out>> {
        let out = Channel::named(format!("{name}.out"), self.data_cap, self.signal_cap);
        self.edges.push((vec![chan_key(input)], vec![chan_key(&out)]));
        self.nodes.push(Box::new(Node::new(
            name,
            self.width,
            input.clone(),
            Output::Chan(out.clone()),
            logic,
        )));
        out
    }

    /// Append a terminal node whose outputs collect into a sink buffer
    /// (unbounded, per the paper's sink semantics).
    pub fn sink<L: NodeLogic + 'static>(
        &mut self,
        name: &str,
        input: &Rc<Channel<L::In>>,
        logic: L,
    ) -> Rc<RefCell<Vec<L::Out>>> {
        self.sink_with_cap(name, input, logic, 0)
    }

    /// [`PipelineBuilder::sink`] with a pre-reserved output buffer, for
    /// long-running drivers that want to keep sink growth out of the
    /// steady state (the firing path itself is allocation-free either
    /// way — sink reallocation is amortized output-buffer growth).
    pub fn sink_with_cap<L: NodeLogic + 'static>(
        &mut self,
        name: &str,
        input: &Rc<Channel<L::In>>,
        logic: L,
        cap: usize,
    ) -> Rc<RefCell<Vec<L::Out>>> {
        let sink = Rc::new(RefCell::new(Vec::with_capacity(cap)));
        self.edges.push((vec![chan_key(input)], Vec::new()));
        self.nodes.push(Box::new(Node::new(
            name,
            self.width,
            input.clone(),
            Output::Sink(sink.clone()),
            logic,
        )));
        sink
    }

    /// Append an enumeration node (`enumerate` keyword): composites in,
    /// element indices + region signals out.
    pub fn enumerate<P: Composite>(
        &mut self,
        name: &str,
        input: &Rc<Channel<P>>,
    ) -> Rc<Channel<u32>> {
        let out = Channel::named(format!("{name}.out"), self.data_cap, self.signal_cap);
        self.edges.push((vec![chan_key(input)], vec![chan_key(&out)]));
        self.nodes.push(Box::new(Enumerator::new(
            name,
            self.width,
            input.clone(),
            out.clone(),
        )));
        out
    }

    /// Append a broadcast (fan-out) node: duplicates `input`'s data and
    /// signals, precisely interleaved, to `children` output channels —
    /// tree topologies, paper Fig. 1b.
    pub fn broadcast<T: Clone + 'static>(
        &mut self,
        name: &str,
        input: &Rc<Channel<T>>,
        children: usize,
    ) -> Vec<Rc<Channel<T>>> {
        let outs: Vec<Rc<Channel<T>>> = (0..children)
            .map(|i| Channel::named(format!("{name}.child{i}"), self.data_cap, self.signal_cap))
            .collect();
        self.edges.push((
            vec![chan_key(input)],
            outs.iter().map(chan_key).collect(),
        ));
        self.nodes.push(Box::new(super::broadcast::Broadcast::new(
            name,
            self.width,
            input.clone(),
            outs.clone(),
        )));
        outs
    }

    /// Finish assembly: derive the ready-set adjacency (which nodes to
    /// re-evaluate after each node fires) from the recorded wiring.
    pub fn build(self) -> Pipeline {
        let n = self.nodes.len();
        // every node attached to a channel, in either role — a firing
        // node can mutate both ends of every channel it touches (pop
        // data/signals and drain credits on inputs, push data/signals on
        // outputs), and any other node attached to one of those channels
        // (sibling consumer of a shared input, sibling producer into a
        // shared output, the opposite endpoint) reads that state in its
        // fireable test
        let mut attached: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (i, (ins, outs)) in self.edges.iter().enumerate() {
            for &k in ins.iter().chain(outs) {
                attached.entry(k).or_default().push(i);
            }
        }
        // node i is attached to each of its own channels, so the pass
        // below always includes i in affected[i]
        let mut affected: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, (ins, outs)) in self.edges.iter().enumerate() {
            for k in ins.iter().chain(outs) {
                if let Some(nodes) = attached.get(k) {
                    affected[i].extend(nodes.iter().copied());
                }
            }
        }
        for a in &mut affected {
            a.sort_unstable();
            a.dedup();
        }
        // the adjacency is structural wiring: hand it to the scheduler
        // once so every later `run` (and any direct `Scheduler::run`
        // caller) gets the ready-set fast path
        let mut scheduler = Scheduler::new(self.policy);
        scheduler.set_adjacency(affected);
        Pipeline {
            nodes: self.nodes,
            scheduler,
            elapsed: 0.0,
        }
    }
}

/// An assembled pipeline: nodes in topology order plus a scheduler
/// (carrying the builder-recorded ready-set adjacency).
pub struct Pipeline {
    nodes: Vec<Box<dyn NodeOps>>,
    scheduler: Scheduler,
    elapsed: f64,
}

impl Pipeline {
    /// Run to quiescence. May be called repeatedly (feed the source
    /// channel between calls); metrics accumulate.
    pub fn run(&mut self) -> Result<()> {
        let start = Instant::now();
        self.scheduler.run(&mut self.nodes)?;
        self.elapsed += start.elapsed().as_secs_f64();
        Ok(())
    }

    /// Return the pipeline to its just-built state **without releasing
    /// any capacity** — the reset-not-rebuild half of the zero-rebuild
    /// worker contract. Every node re-arms its credit/region/logic state
    /// and clears its input channel in place (rings keep their
    /// allocations), and all metrics and scheduler counters zero, so a
    /// following feed + [`Pipeline::run`] produces outputs *and metrics*
    /// bit-identical to a freshly built pipeline fed the same stream.
    /// Sink buffers are driver-owned: collect and clear them per shard.
    ///
    /// On the steady-state reuse path a reset performs no heap
    /// allocation (`rust/tests/hotpath_alloc.rs` pins this across
    /// shards).
    pub fn reset(&mut self) {
        for node in &mut self.nodes {
            node.reset();
        }
        self.scheduler.reset();
        self.elapsed = 0.0;
    }

    /// Collected metrics snapshot.
    pub fn metrics(&self) -> PipelineMetrics {
        PipelineMetrics {
            nodes: self
                .nodes
                .iter()
                .map(|n| (n.name().to_string(), n.metrics().clone()))
                .collect(),
            elapsed: self.elapsed,
            idle_polls: self.scheduler.idle_polls,
        }
    }

    /// Total scheduler firings so far.
    pub fn firings(&self) -> u64 {
        self.scheduler.firings
    }

    /// Install a trace sink on the scheduler: every firing records a
    /// [`TraceEvent::Firing`](crate::trace::TraceEvent) span. Like the
    /// ready-set adjacency the sink is structural, so it survives
    /// [`Pipeline::reset`] — a traced worker keeps tracing across every
    /// shard it runs.
    pub fn set_trace(&mut self, sink: crate::trace::TraceSink) {
        self.scheduler.set_trace(sink);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::aggregate::{Aggregator, FilterMapLogic};
    use crate::coordinator::enumerate::Blob;
    use crate::coordinator::signal::parent_as;

    /// The paper's Figs 3–5 application, end to end, on native logic.
    #[test]
    fn fig3_blob_sum_pipeline() {
        let mut b = PipelineBuilder::new(4).queue_caps(64, 32);
        let src = b.source::<Blob>();
        let elems = b.enumerate("enum", &src);
        let filtered = b.node(
            "f",
            &elems,
            FilterMapLogic::new(1, |idxs: &[u32], parent, out| {
                let blob = parent_as::<Blob>(parent.expect("in region")).unwrap();
                for &i in idxs {
                    let v = blob.get(i);
                    if v > 0.0 {
                        out.push(3.14f32 * v);
                    }
                }
                Ok(())
            }),
        );
        let sums = b.sink(
            "a",
            &filtered,
            Aggregator::new(
                0.0f64,
                |acc: &mut f64, items: &[f32], _| {
                    *acc += items.iter().map(|&v| v as f64).sum::<f64>();
                    Ok(())
                },
                |acc: &mut f64, _| Ok(Some(*acc)),
            ),
        );
        src.push(Blob::from_vec(0, vec![1.0, -2.0, 3.0]));
        src.push(Blob::from_vec(1, vec![-1.0, -1.0]));
        src.push(Blob::from_vec(2, (0..10).map(|i| i as f32).collect()));

        let mut pipe = b.build();
        pipe.run().unwrap();

        let got = sums.borrow().clone();
        assert_eq!(got.len(), 3);
        assert!((got[0] - 3.14 * 4.0).abs() < 1e-4);
        assert_eq!(got[1], 0.0);
        assert!((got[2] - 3.14 * 45.0).abs() < 1e-3);

        let m = pipe.metrics();
        // node f processed 15 elements; blob boundaries forced partials
        assert_eq!(m.node("f").unwrap().items, 15);
        assert!(m.node("f").unwrap().occupancy() < 1.0);
        assert_eq!(m.node("a").unwrap().signals_consumed, 6);
        assert_eq!(m.idle_polls, 1);
    }

    /// Reset-not-rebuild: a reused pipeline re-fed the same stream must
    /// reproduce a fresh build's outputs AND metrics exactly.
    #[test]
    fn reset_pipeline_reruns_identically() {
        let build = || {
            let mut b = PipelineBuilder::new(4).queue_caps(64, 32);
            let src = b.source_with_cap::<Blob>(8);
            let elems = b.enumerate("enum", &src);
            let sums = b.sink(
                "a",
                &elems,
                Aggregator::new(
                    0u64,
                    |acc: &mut u64, items: &[u32], _| {
                        *acc += items.iter().map(|&i| i as u64).sum::<u64>();
                        Ok(())
                    },
                    |acc: &mut u64, _| Ok(Some(*acc)),
                ),
            );
            (b.build(), src, sums)
        };
        let feed = |src: &Rc<crate::coordinator::channel::Channel<Blob>>| {
            for id in 0..5 {
                src.push(Blob::from_vec(id, vec![1.0; 3 + id as usize]));
            }
        };

        let (mut fresh, src_f, sums_f) = build();
        feed(&src_f);
        fresh.run().unwrap();
        let want = sums_f.borrow().clone();
        let want_m = fresh.metrics();

        let (mut reused, src_r, sums_r) = build();
        // first use: a different stream, then reset and replay the real one
        src_r.push(Blob::from_vec(99, vec![2.0; 17]));
        reused.run().unwrap();
        reused.reset();
        sums_r.borrow_mut().clear(); // sinks are driver-owned
        feed(&src_r);
        reused.run().unwrap();

        assert_eq!(*sums_r.borrow(), want);
        let got_m = reused.metrics();
        assert_eq!(got_m.idle_polls, want_m.idle_polls);
        for ((gn, g), (wn, w)) in got_m.nodes.iter().zip(&want_m.nodes) {
            assert_eq!(gn, wn);
            assert_eq!(g.firings, w.firings, "{gn}: firings");
            assert_eq!(g.ensembles, w.ensembles, "{gn}: ensembles");
            assert_eq!(g.items, w.items, "{gn}: items");
            assert_eq!(g.signals_consumed, w.signals_consumed, "{gn}");
            assert_eq!(g.signals_emitted, w.signals_emitted, "{gn}");
            assert_eq!(g.ensemble_hist, w.ensemble_hist, "{gn}: histogram");
        }
        assert_eq!(reused.firings(), fresh.firings());
    }

    /// Region boundaries cap ensembles: with region size == width,
    /// every ensemble is full; with width+1, occupancy craters —
    /// the Fig. 6 mechanism in miniature.
    #[test]
    fn occupancy_depends_on_region_alignment() {
        let occ = |region: usize| -> f64 {
            let mut b = PipelineBuilder::new(4).queue_caps(256, 64);
            let src = b.source::<Blob>();
            let elems = b.enumerate("enum", &src);
            let _sums = b.sink(
                "a",
                &elems,
                Aggregator::new(
                    0u64,
                    |acc: &mut u64, items: &[u32], _| {
                        *acc += items.len() as u64;
                        Ok(())
                    },
                    |acc: &mut u64, _| Ok(Some(*acc)),
                ),
            );
            for id in 0..8 {
                src.push(Blob::from_vec(id, vec![1.0; region]));
            }
            let mut pipe = b.build();
            pipe.run().unwrap();
            pipe.metrics().node("a").unwrap().occupancy()
        };
        assert!((occ(4) - 1.0).abs() < 1e-9); // aligned: all full
        assert!(occ(5) < 0.7); // misaligned: 4+1 split per region
        assert!(occ(3) < 0.8); // sub-width regions never fill
    }
}
