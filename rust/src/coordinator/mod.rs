//! Layer-3 coordinator: the paper's system contribution.
//!
//! * [`signal`] / [`queue`] / [`channel`] — out-of-band control signals,
//!   bounded queues, and the **credit protocol** that keeps the two
//!   synchronized for precise delivery under irregular dataflow (§3.1).
//! * [`node`] — two-phase firing (data ensemble + signal phase), receiver
//!   credit rules, the §3.3 SIMD rule (ensembles never span a signal).
//! * [`scheduler`] — non-preemptive firing loop with deadlock detection
//!   (Lemma 2 says detection never triggers; the tests lean on that).
//! * [`enumerate`] / [`aggregate`] — the developer-facing region-context
//!   abstraction (§4): open composites into element streams, fold them
//!   back to per-parent results.
//! * [`broadcast`] — fan-out node for tree topologies (paper Fig. 1b),
//!   duplicating data and signals precisely to every child.
//! * [`tagging`] — the dense in-band alternative used as the paper's §5
//!   comparison baseline.
//! * [`metrics`] — occupancy accounting (the paper's key performance
//!   quantity).
//! * [`topology`] — the builder API mirroring the Fig. 4 topology
//!   specification.

pub mod aggregate;
pub mod broadcast;
pub mod channel;
pub mod enumerate;
pub mod metrics;
pub mod node;
pub mod queue;
pub mod scheduler;
pub mod signal;
pub mod tagging;
pub mod topology;

pub use aggregate::{Aggregator, FilterMapLogic, MapLogic};
pub use broadcast::Broadcast;
pub use channel::Channel;
pub use enumerate::{Blob, Composite, Enumerator};
pub use metrics::{NodeMetrics, PipelineMetrics};
pub use node::{Emitter, Node, NodeLogic, NodeOps, Output};
pub use queue::{DataQueue, SignalQueue};
pub use scheduler::{Policy, Scheduler};
pub use signal::{parent_as, Credit, ParentRef, Signal, SignalKind};
pub use tagging::{densify_tags, Tagged};
pub use topology::{Pipeline, PipelineBuilder};
