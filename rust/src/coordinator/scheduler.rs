//! Non-preemptive node scheduler (paper §2.1/§3.2) with ready-set
//! selection.
//!
//! One node fires at a time; the scheduler repeatedly selects a fireable
//! node until no node has pending inputs (quiescence, guaranteed to arrive
//! by the paper's Lemma 2). If nothing is fireable while work remains the
//! scheduler reports a deadlock — Lemma 2 says this cannot happen, and the
//! property suite hammers on exactly that claim.
//!
//! ## Ready-set scheduling
//!
//! A node's fireability, ready hint and backpressure flag are pure
//! functions of its queue state plus node-internal state that only
//! changes when the node itself fires. So instead of re-probing every
//! node's queues (several `RefCell` borrows each) on every firing, the
//! scheduler caches a [`ReadyState`] per node and re-evaluates only the
//! *dirty* set after a firing: the fired node plus the producers of its
//! input channels and the consumers of its output channels (the
//! adjacency the [`super::topology::PipelineBuilder`] records while
//! wiring the graph). Selection then runs over the plain cached structs.
//! The three-rule `GreedyOccupancy` semantics are bit-identical to the
//! full rescan: cached values equal freshly computed values for every
//! non-dirty node because its queues did not change.
//!
//! The builder hands the recorded adjacency to the scheduler once, at
//! assembly time ([`Scheduler::set_adjacency`]), so plain
//! [`Scheduler::run`] gets the fast path. Callers without wiring
//! information (no adjacency set, or an explicit
//! `run_with(nodes, None)`) fall back to refreshing every node after
//! each firing — same decisions, original scan cost.

use anyhow::{bail, Result};

use crate::trace::{TraceEvent, TraceSink};

use super::node::NodeOps;

/// Node-selection policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Fire the node with the largest ready ensemble (ties: deepest).
    /// Lets queues fill so SIMD ensembles run full — MERCATOR's
    /// occupancy-maximizing heuristic, and our default.
    GreedyOccupancy,
    /// Prefer the deepest (most-downstream) fireable node. Keeps queues
    /// shallow but fires small ensembles (the ablation_lanectx bench
    /// quantifies the cost).
    DeepestFirst,
    /// Cycle through nodes in topology order.
    RoundRobin,
}

impl Policy {
    /// CLI label (round-trips through [`Policy::from_str`]).
    pub fn label(&self) -> &'static str {
        match self {
            Policy::GreedyOccupancy => "greedy",
            Policy::DeepestFirst => "deepest",
            Policy::RoundRobin => "rr",
        }
    }
}

impl std::str::FromStr for Policy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Policy> {
        match s {
            "greedy" | "greedy-occupancy" => Ok(Policy::GreedyOccupancy),
            "deepest" | "deepest-first" => Ok(Policy::DeepestFirst),
            "rr" | "round-robin" => Ok(Policy::RoundRobin),
            other => bail!("unknown policy {other:?} (use greedy|deepest|rr)"),
        }
    }
}

/// Cached fireability snapshot for one node (valid until one of its
/// adjacent queues changes).
#[derive(Debug, Clone, Copy, Default)]
struct ReadyState {
    fireable: bool,
    /// Data-ensemble size a firing would process right now.
    hint: usize,
    /// `hint >= width`: could fire a full ensemble.
    full: bool,
    /// Input queue too full for upstream to stage a full ensemble.
    pressured: bool,
}

/// Scheduler state and counters.
#[derive(Debug)]
pub struct Scheduler {
    policy: Policy,
    /// Total firings dispatched.
    pub firings: u64,
    /// Selection passes that found nothing fireable. A clean run ends
    /// with exactly one (the final quiescence scan); anything more means
    /// the scheduler spun without progress mid-run.
    pub idle_polls: u64,
    rr_cursor: usize,
    /// Ready-set cache, one entry per node (rebuilt at each `run`).
    states: Vec<ReadyState>,
    /// Builder-recorded channel adjacency (see
    /// [`Scheduler::set_adjacency`]); `None` until wired.
    adjacency: Option<Vec<Vec<usize>>>,
    /// Firing-event sink; disabled (a single branch per firing) unless
    /// [`Scheduler::set_trace`] installed an enabled one.
    trace: TraceSink,
}

impl Scheduler {
    /// Create a scheduler with the given policy.
    pub fn new(policy: Policy) -> Scheduler {
        Scheduler {
            policy,
            firings: 0,
            idle_polls: 0,
            rr_cursor: 0,
            states: Vec::new(),
            adjacency: None,
            trace: TraceSink::default(),
        }
    }

    /// Install a trace sink: every subsequent firing records one
    /// [`TraceEvent::Firing`] span with that firing's ensemble/item
    /// deltas (read from the node's own counters, so trace totals
    /// reconcile with [`NodeMetrics`](super::metrics::NodeMetrics)
    /// exactly). The sink, like the adjacency, is structural and
    /// survives [`Scheduler::reset`].
    pub fn set_trace(&mut self, sink: TraceSink) {
        self.trace = sink;
    }

    /// Record the channel adjacency derived while wiring the graph:
    /// `affected[i]` lists the node indices whose cached ready state must
    /// be refreshed after node `i` fires (always including `i`). Once
    /// set, plain [`Scheduler::run`] gets the ready-set fast path —
    /// callers no longer need to thread the adjacency through
    /// [`Scheduler::run_with`] themselves.
    pub fn set_adjacency(&mut self, affected: Vec<Vec<usize>>) {
        self.adjacency = Some(affected);
    }

    /// Zero the run counters, cursor and ready-set cache so a following
    /// `run` behaves exactly like a freshly constructed scheduler
    /// (pipeline reuse). The recorded adjacency is structural wiring, not
    /// run state, and survives the reset.
    pub fn reset(&mut self) {
        self.firings = 0;
        self.idle_polls = 0;
        self.rr_cursor = 0;
        self.states.clear();
    }

    /// Run nodes to quiescence. Uses the adjacency recorded by
    /// [`Scheduler::set_adjacency`] when available (the ready-set fast
    /// path); without it every firing refreshes every node (the
    /// pre-ready-set behaviour — same decisions, original scan cost).
    /// `nodes` must be in topology order (upstream first).
    pub fn run(&mut self, nodes: &mut [Box<dyn NodeOps>]) -> Result<()> {
        let adjacency = self.adjacency.take();
        let result = self.run_with(nodes, adjacency.as_deref());
        self.adjacency = adjacency;
        result
    }

    /// Run nodes to quiescence with an explicit adjacency override. When
    /// `affected` is given, `affected[i]` lists the node indices whose
    /// cached state must be refreshed after node `i` fires (always
    /// including `i` itself); `None` forces the refresh-all fallback
    /// regardless of any recorded adjacency. Scheduling decisions are
    /// identical either way.
    pub fn run_with(
        &mut self,
        nodes: &mut [Box<dyn NodeOps>],
        affected: Option<&[Vec<usize>]>,
    ) -> Result<()> {
        let n = nodes.len();
        if let Some(adj) = affected {
            debug_assert_eq!(adj.len(), n, "affected sets must cover every node");
        }
        // external feeding between runs invalidates everything
        self.states.clear();
        self.states.resize(n, ReadyState::default());
        for i in 0..n {
            self.refresh(nodes, i);
        }
        loop {
            let pick = match self.policy {
                Policy::GreedyOccupancy => self.select_greedy(),
                Policy::DeepestFirst => self.select_deepest(),
                Policy::RoundRobin => self.select_round_robin(),
            };
            let Some(i) = pick else {
                self.idle_polls += 1;
                // Quiescent or deadlocked?
                if let Some(stuck) = nodes.iter().find(|n| n.has_pending()) {
                    bail!(
                        "scheduler deadlock: node '{}' has pending work but nothing is fireable \
                         (queue capacities too small for declared output bounds?)",
                        stuck.name()
                    );
                }
                return Ok(());
            };
            let tracing = self.trace.enabled();
            let (t0, ens0, items0) = if tracing {
                let m = nodes[i].metrics();
                (self.trace.now_ns(), m.ensembles, m.items)
            } else {
                (0, 0, 0)
            };
            let worked = nodes[i].fire()?;
            self.firings += 1;
            if tracing {
                let t1 = self.trace.now_ns();
                let m = nodes[i].metrics();
                self.trace.record(
                    t0,
                    t1,
                    TraceEvent::Firing {
                        node: i as u32,
                        ensembles: (m.ensembles - ens0) as u32,
                        items: (m.items - items0) as u32,
                    },
                );
            }
            if matches!(self.policy, Policy::RoundRobin) {
                self.rr_cursor = (i + 1) % n;
            }
            if !worked {
                // A fireable node that makes no progress would spin the
                // scheduler forever; surface it loudly.
                bail!(
                    "node '{}' was fireable but made no progress",
                    nodes[i].name()
                );
            }
            match affected {
                Some(adj) => {
                    for &j in &adj[i] {
                        self.refresh(nodes, j);
                    }
                }
                None => {
                    for j in 0..n {
                        self.refresh(nodes, j);
                    }
                }
            }
        }
    }

    /// Re-probe node `i`'s queues and cache the result. Only the greedy
    /// policy reads hint/full/pressured, so the other policies skip those
    /// extra queue probes (the old per-policy scans only called
    /// `fireable()`).
    fn refresh(&mut self, nodes: &[Box<dyn NodeOps>], i: usize) {
        let node = &nodes[i];
        let fireable = node.fireable();
        self.states[i] = if fireable && self.policy == Policy::GreedyOccupancy {
            let hint = node.ready_hint();
            ReadyState {
                fireable,
                hint,
                full: hint >= node.metrics().width,
                pressured: node.input_pressure(),
            }
        } else {
            ReadyState {
                fireable,
                ..ReadyState::default()
            }
        };
    }

    /// Three-rule occupancy heuristic:
    ///  1. if any node could fire a FULL ensemble, fire the deepest
    ///     such node (drain at maximum occupancy);
    ///  2. else, if any node is under input BACKPRESSURE (its queue is
    ///     too full for upstream to stage another full ensemble), fire
    ///     the largest-hint such node (ties: deepest): a sub-width
    ///     firing is necessary there, and draining it un-sticks the
    ///     pipeline — otherwise a full queue locks every stage into
    ///     fragmented sub-width firings forever;
    ///  3. else fire the shallowest fireable node, giving upstream
    ///     stages the chance to fill downstream queues before anyone
    ///     runs a premature partial ensemble.
    /// Partial ensembles still happen — at region boundaries (credit
    /// caps) and at end of stream — which is exactly the occupancy
    /// cost the paper measures.
    fn select_greedy(&self) -> Option<usize> {
        let mut full: Option<usize> = None;
        let mut pressured: Option<(usize, usize)> = None; // (hint, idx)
        let mut shallowest: Option<usize> = None;
        for (i, st) in self.states.iter().enumerate() {
            if !st.fireable {
                continue;
            }
            if shallowest.is_none() {
                shallowest = Some(i);
            }
            if st.full {
                full = Some(i); // keep scanning: deepest full wins
            } else if st.pressured
                && pressured.map(|(h, j)| (st.hint, i) >= (h, j)).unwrap_or(true)
            {
                pressured = Some((st.hint, i));
            }
        }
        full.or(pressured.map(|(_, i)| i)).or(shallowest)
    }

    fn select_deepest(&self) -> Option<usize> {
        (0..self.states.len()).rev().find(|&i| self.states[i].fireable)
    }

    fn select_round_robin(&self) -> Option<usize> {
        let n = self.states.len();
        (0..n)
            .map(|k| (self.rr_cursor + k) % n.max(1))
            .find(|&i| self.states[i].fireable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::aggregate::MapLogic;
    use crate::coordinator::channel::Channel;
    use crate::coordinator::node::{Node, Output};
    use std::cell::RefCell;
    use std::rc::Rc;

    fn two_stage() -> (Vec<Box<dyn NodeOps>>, Rc<RefCell<Vec<i64>>>) {
        let ch0: Rc<Channel<i64>> = Channel::new(1024, 8);
        for i in 0..100 {
            ch0.push(i);
        }
        let ch1: Rc<Channel<i64>> = Channel::new(4, 8); // tight middle queue
        let sink = Rc::new(RefCell::new(Vec::new()));
        let n1 = Node::new(
            "double",
            4,
            ch0,
            Output::Chan(ch1.clone()),
            MapLogic::new(|&v: &i64| v * 2),
        );
        let n2 = Node::new(
            "inc",
            4,
            ch1,
            Output::Sink(sink.clone()),
            MapLogic::new(|&v: &i64| v + 1),
        );
        let nodes: Vec<Box<dyn NodeOps>> = vec![Box::new(n1), Box::new(n2)];
        (nodes, sink)
    }

    #[test]
    fn deepest_first_drains_pipeline() {
        let (mut nodes, sink) = two_stage();
        let mut s = Scheduler::new(Policy::DeepestFirst);
        s.run(&mut nodes).unwrap();
        let expect: Vec<i64> = (0..100).map(|v| v * 2 + 1).collect();
        assert_eq!(*sink.borrow(), expect);
        assert!(s.firings > 0);
        assert_eq!(s.idle_polls, 1); // only the final quiescence scan
    }

    #[test]
    fn round_robin_also_drains() {
        let (mut nodes, sink) = two_stage();
        let mut s = Scheduler::new(Policy::RoundRobin);
        s.run(&mut nodes).unwrap();
        assert_eq!(sink.borrow().len(), 100);
    }

    #[test]
    fn ready_set_with_edges_matches_full_rescan() {
        // same topology, run once with the all-dirty fallback and once
        // with explicit adjacency: firings and outputs must be identical
        let (mut a_nodes, a_sink) = two_stage();
        let mut a = Scheduler::new(Policy::GreedyOccupancy);
        a.run(&mut a_nodes).unwrap();

        let (mut b_nodes, b_sink) = two_stage();
        let mut b = Scheduler::new(Policy::GreedyOccupancy);
        // chain wiring: firing 0 affects {0,1}; firing 1 affects {0,1}
        let affected = vec![vec![0, 1], vec![0, 1]];
        b.run_with(&mut b_nodes, Some(&affected)).unwrap();

        assert_eq!(*a_sink.borrow(), *b_sink.borrow());
        assert_eq!(a.firings, b.firings);
        assert_eq!(a.idle_polls, b.idle_polls);
    }

    #[test]
    fn run_uses_recorded_adjacency_and_matches_fallback() {
        // run() with set_adjacency must make decisions identical to both
        // the refresh-all fallback and an explicit run_with override
        let (mut a_nodes, a_sink) = two_stage();
        let mut a = Scheduler::new(Policy::GreedyOccupancy);
        a.run(&mut a_nodes).unwrap(); // no adjacency: refresh-all

        let (mut b_nodes, b_sink) = two_stage();
        let mut b = Scheduler::new(Policy::GreedyOccupancy);
        b.set_adjacency(vec![vec![0, 1], vec![0, 1]]);
        b.run(&mut b_nodes).unwrap(); // recorded adjacency: fast path

        assert_eq!(*a_sink.borrow(), *b_sink.borrow());
        assert_eq!(a.firings, b.firings);
        assert_eq!(a.idle_polls, b.idle_polls);
    }

    #[test]
    fn reset_restores_fresh_counters_but_keeps_adjacency() {
        let (mut nodes, sink) = two_stage();
        let mut s = Scheduler::new(Policy::GreedyOccupancy);
        s.set_adjacency(vec![vec![0, 1], vec![0, 1]]);
        s.run(&mut nodes).unwrap();
        let (firings, idle) = (s.firings, s.idle_polls);
        assert!(firings > 0);
        s.reset();
        assert_eq!(s.firings, 0);
        assert_eq!(s.idle_polls, 0);
        // a second identical run over a fresh graph reproduces the first
        // run's counters exactly (adjacency survived the reset)
        let (mut nodes2, sink2) = two_stage();
        s.run(&mut nodes2).unwrap();
        assert_eq!(s.firings, firings);
        assert_eq!(s.idle_polls, idle);
        assert_eq!(*sink.borrow(), *sink2.borrow());
    }

    #[test]
    fn policy_parses_and_labels() {
        for (s, p) in [
            ("greedy", Policy::GreedyOccupancy),
            ("deepest", Policy::DeepestFirst),
            ("rr", Policy::RoundRobin),
            ("round-robin", Policy::RoundRobin),
        ] {
            assert_eq!(s.parse::<Policy>().unwrap(), p);
        }
        assert!("bogus".parse::<Policy>().is_err());
        assert_eq!(Policy::GreedyOccupancy.label(), "greedy");
        assert_eq!(
            Policy::DeepestFirst.label().parse::<Policy>().unwrap(),
            Policy::DeepestFirst
        );
    }

    #[test]
    fn deadlock_is_reported_not_hung() {
        // A node whose declared output bound exceeds the whole downstream
        // queue capacity can never fire: the scheduler must say so.
        struct Exploder;
        impl crate::coordinator::node::NodeLogic for Exploder {
            type In = i64;
            type Out = i64;
            fn run(
                &mut self,
                _items: &[i64],
                _p: Option<&crate::coordinator::signal::ParentRef>,
                _out: &mut crate::coordinator::node::Emitter<'_, i64>,
            ) -> anyhow::Result<()> {
                Ok(())
            }
            fn max_outputs_per_input(&self) -> usize {
                100 // bigger than the downstream queue
            }
        }
        let ch0: Rc<Channel<i64>> = Channel::new(8, 8);
        ch0.push(1);
        let ch1: Rc<Channel<i64>> = Channel::new(4, 8);
        let n1 = Node::new("exploder", 4, ch0, Output::Chan(ch1.clone()), Exploder);
        let sink = Rc::new(RefCell::new(Vec::new()));
        let n2 = Node::new(
            "sink",
            4,
            ch1,
            Output::Sink(sink),
            MapLogic::new(|&v: &i64| v),
        );
        let mut nodes: Vec<Box<dyn NodeOps>> = vec![Box::new(n1), Box::new(n2)];
        let err = Scheduler::new(Policy::DeepestFirst).run(&mut nodes).unwrap_err();
        assert!(err.to_string().contains("deadlock"), "{err}");
    }
}
