//! Non-preemptive node scheduler (paper §2.1/§3.2).
//!
//! One node fires at a time; the scheduler repeatedly selects a fireable
//! node until no node has pending inputs (quiescence, guaranteed to arrive
//! by the paper's Lemma 2). If nothing is fireable while work remains the
//! scheduler reports a deadlock — Lemma 2 says this cannot happen, and the
//! property suite hammers on exactly that claim.

use anyhow::{bail, Result};

use super::node::NodeOps;

/// Node-selection policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Fire the node with the largest ready ensemble (ties: deepest).
    /// Lets queues fill so SIMD ensembles run full — MERCATOR's
    /// occupancy-maximizing heuristic, and our default.
    GreedyOccupancy,
    /// Prefer the deepest (most-downstream) fireable node. Keeps queues
    /// shallow but fires small ensembles (the ablation_lanectx bench
    /// quantifies the cost).
    DeepestFirst,
    /// Cycle through nodes in topology order.
    RoundRobin,
}

/// Scheduler state and counters.
#[derive(Debug)]
pub struct Scheduler {
    policy: Policy,
    /// Total firings dispatched.
    pub firings: u64,
    /// Fireability scans that found no node (should stay 0 mid-run;
    /// the final quiescence scan is not counted).
    pub idle_polls: u64,
    rr_cursor: usize,
}

impl Scheduler {
    pub fn new(policy: Policy) -> Scheduler {
        Scheduler {
            policy,
            firings: 0,
            idle_polls: 0,
            rr_cursor: 0,
        }
    }

    /// Run nodes to quiescence. `nodes` must be in topology order
    /// (upstream first).
    pub fn run(&mut self, nodes: &mut [Box<dyn NodeOps>]) -> Result<()> {
        loop {
            let fired = match self.policy {
                Policy::GreedyOccupancy => self.fire_greedy(nodes)?,
                Policy::DeepestFirst => self.fire_deepest(nodes)?,
                Policy::RoundRobin => self.fire_round_robin(nodes)?,
            };
            if !fired {
                // Quiescent or deadlocked?
                if let Some(stuck) = nodes.iter().find(|n| n.has_pending()) {
                    bail!(
                        "scheduler deadlock: node '{}' has pending work but nothing is fireable \
                         (queue capacities too small for declared output bounds?)",
                        stuck.name()
                    );
                }
                return Ok(());
            }
        }
    }

    fn fire_greedy(&mut self, nodes: &mut [Box<dyn NodeOps>]) -> Result<bool> {
        // Three-rule occupancy heuristic:
        //  1. if any node could fire a FULL ensemble, fire the deepest
        //     such node (drain at maximum occupancy);
        //  2. else, if any node is under input BACKPRESSURE (its queue is
        //     too full for upstream to stage another full ensemble), fire
        //     the largest-hint such node (ties: deepest): a sub-width
        //     firing is necessary there, and draining it un-sticks the
        //     pipeline — otherwise a full queue locks every stage into
        //     fragmented sub-width firings forever;
        //  3. else fire the shallowest fireable node, giving upstream
        //     stages the chance to fill downstream queues before anyone
        //     runs a premature partial ensemble.
        // Partial ensembles still happen — at region boundaries (credit
        // caps) and at end of stream — which is exactly the occupancy
        // cost the paper measures.
        let mut full: Option<usize> = None;
        let mut pressured: Option<(usize, usize)> = None; // (hint, idx)
        let mut shallowest: Option<usize> = None;
        for i in 0..nodes.len() {
            if nodes[i].fireable() {
                if shallowest.is_none() {
                    shallowest = Some(i);
                }
                let hint = nodes[i].ready_hint();
                if hint >= nodes[i].metrics().width {
                    full = Some(i); // keep scanning: deepest full wins
                } else if nodes[i].input_pressure()
                    && pressured.map(|(h, j)| (hint, i) >= (h, j)).unwrap_or(true)
                {
                    pressured = Some((hint, i));
                }
            }
        }
        match full.or(pressured.map(|(_, i)| i)).or(shallowest) {
            Some(i) => {
                let worked = nodes[i].fire()?;
                self.firings += 1;
                if worked {
                    Ok(true)
                } else {
                    bail!(
                        "node '{}' was fireable but made no progress",
                        nodes[i].name()
                    )
                }
            }
            None => {
                self.idle_polls += 1;
                Ok(false)
            }
        }
    }

    fn fire_deepest(&mut self, nodes: &mut [Box<dyn NodeOps>]) -> Result<bool> {
        for i in (0..nodes.len()).rev() {
            if nodes[i].fireable() {
                let worked = nodes[i].fire()?;
                self.firings += 1;
                if worked {
                    return Ok(true);
                }
                // A fireable node that makes no progress would spin the
                // scheduler forever; surface it loudly.
                bail!(
                    "node '{}' was fireable but made no progress",
                    nodes[i].name()
                );
            }
        }
        self.idle_polls += 1;
        Ok(false)
    }

    fn fire_round_robin(&mut self, nodes: &mut [Box<dyn NodeOps>]) -> Result<bool> {
        let n = nodes.len();
        for k in 0..n {
            let i = (self.rr_cursor + k) % n;
            if nodes[i].fireable() {
                let worked = nodes[i].fire()?;
                self.firings += 1;
                self.rr_cursor = (i + 1) % n;
                if worked {
                    return Ok(true);
                }
                bail!(
                    "node '{}' was fireable but made no progress",
                    nodes[i].name()
                );
            }
        }
        self.idle_polls += 1;
        Ok(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::aggregate::MapLogic;
    use crate::coordinator::channel::Channel;
    use crate::coordinator::node::{Node, Output};
    use std::cell::RefCell;
    use std::rc::Rc;

    fn two_stage(policy: Policy) -> (Vec<Box<dyn NodeOps>>, Rc<RefCell<Vec<i64>>>) {
        let ch0: Rc<Channel<i64>> = Channel::new(1024, 8);
        for i in 0..100 {
            ch0.push(i);
        }
        let ch1: Rc<Channel<i64>> = Channel::new(4, 8); // tight middle queue
        let sink = Rc::new(RefCell::new(Vec::new()));
        let n1 = Node::new(
            "double",
            4,
            ch0,
            Output::Chan(ch1.clone()),
            MapLogic::new(|&v: &i64| v * 2),
        );
        let n2 = Node::new(
            "inc",
            4,
            ch1,
            Output::Sink(sink.clone()),
            MapLogic::new(|&v: &i64| v + 1),
        );
        let nodes: Vec<Box<dyn NodeOps>> = vec![Box::new(n1), Box::new(n2)];
        (nodes, sink)
    }

    #[test]
    fn deepest_first_drains_pipeline() {
        let (mut nodes, sink) = two_stage(Policy::DeepestFirst);
        let mut s = Scheduler::new(Policy::DeepestFirst);
        s.run(&mut nodes).unwrap();
        let expect: Vec<i64> = (0..100).map(|v| v * 2 + 1).collect();
        assert_eq!(*sink.borrow(), expect);
        assert!(s.firings > 0);
        assert_eq!(s.idle_polls, 1); // only the final quiescence scan
    }

    #[test]
    fn round_robin_also_drains() {
        let (mut nodes, sink) = two_stage(Policy::RoundRobin);
        let mut s = Scheduler::new(Policy::RoundRobin);
        s.run(&mut nodes).unwrap();
        assert_eq!(sink.borrow().len(), 100);
    }

    #[test]
    fn deadlock_is_reported_not_hung() {
        // A node whose declared output bound exceeds the whole downstream
        // queue capacity can never fire: the scheduler must say so.
        struct Exploder;
        impl crate::coordinator::node::NodeLogic for Exploder {
            type In = i64;
            type Out = i64;
            fn run(
                &mut self,
                _items: &[i64],
                _p: Option<&crate::coordinator::signal::ParentRef>,
                _out: &mut crate::coordinator::node::Emitter<'_, i64>,
            ) -> anyhow::Result<()> {
                Ok(())
            }
            fn max_outputs_per_input(&self) -> usize {
                100 // bigger than the downstream queue
            }
        }
        let ch0: Rc<Channel<i64>> = Channel::new(8, 8);
        ch0.push(1);
        let ch1: Rc<Channel<i64>> = Channel::new(4, 8);
        let n1 = Node::new("exploder", 4, ch0, Output::Chan(ch1.clone()), Exploder);
        let sink = Rc::new(RefCell::new(Vec::new()));
        let n2 = Node::new(
            "sink",
            4,
            ch1,
            Output::Sink(sink),
            MapLogic::new(|&v: &i64| v),
        );
        let mut nodes: Vec<Box<dyn NodeOps>> = vec![Box::new(n1), Box::new(n2)];
        let err = Scheduler::new(Policy::DeepestFirst)
            .run(&mut nodes)
            .unwrap_err();
        assert!(err.to_string().contains("deadlock"), "{err}");
    }
}
