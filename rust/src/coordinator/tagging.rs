//! In-band tagging: the *dense* representation of regional context
//! (paper §5's comparison point, after CnC-CUDA's control collections).
//!
//! Instead of bracketing regions with signals, every item carries its
//! region tag. Ensembles may then mix regions — full SIMD occupancy — at
//! the cost of per-item tag storage and per-ensemble tag bookkeeping
//! (densification + segmented reduction instead of a plain reduction).
//!
//! [`Tagged`] is the item wrapper; [`densify_tags`] remaps an ensemble's
//! global region ids onto `[0, k)` lane-local segment ids for the
//! `segmented_sum` kernel.

/// A data item carrying its region tag in-band.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Tagged<T> {
    /// Global region identifier.
    pub tag: u64,
    /// The wrapped item.
    pub item: T,
}

impl<T> Tagged<T> {
    /// Create a tagged item.
    pub fn new(tag: u64, item: T) -> Tagged<T> {
        Tagged { tag, item }
    }
}

/// Remap the global tags of one ensemble onto dense local segment ids
/// (first-occurrence order). Returns the distinct-tag count `k`; `local`
/// receives one id in `[0, k)` per input and `uniq` the global tag for
/// each local id.
///
/// Linear scan: ensembles are at most a few hundred lanes, and tags within
/// an ensemble cluster into few runs, so this beats hashing on the hot
/// path.
pub fn densify_tags(tags: &[u64], local: &mut Vec<i32>, uniq: &mut Vec<u64>) -> usize {
    local.clear();
    uniq.clear();
    for &t in tags {
        let id = match uniq.iter().rposition(|&u| u == t) {
            Some(i) => i,
            None => {
                uniq.push(t);
                uniq.len() - 1
            }
        };
        local.push(id as i32);
    }
    uniq.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn densify_basic() {
        let mut local = Vec::new();
        let mut uniq = Vec::new();
        let k = densify_tags(&[7, 7, 9, 7, 12, 9], &mut local, &mut uniq);
        assert_eq!(k, 3);
        assert_eq!(local, vec![0, 0, 1, 0, 2, 1]);
        assert_eq!(uniq, vec![7, 9, 12]);
    }

    #[test]
    fn densify_empty() {
        let mut local = Vec::new();
        let mut uniq = Vec::new();
        assert_eq!(densify_tags(&[], &mut local, &mut uniq), 0);
        assert!(local.is_empty());
    }

    #[test]
    fn densify_single_region() {
        let mut local = Vec::new();
        let mut uniq = Vec::new();
        let k = densify_tags(&[5, 5, 5, 5], &mut local, &mut uniq);
        assert_eq!(k, 1);
        assert_eq!(local, vec![0, 0, 0, 0]);
    }

    #[test]
    fn densify_reuses_buffers() {
        let mut local = vec![9; 100];
        let mut uniq = vec![42; 100];
        densify_tags(&[1, 2], &mut local, &mut uniq);
        assert_eq!(local, vec![0, 1]);
        assert_eq!(uniq, vec![1, 2]);
    }

    #[test]
    fn tagged_constructor() {
        let t = Tagged::new(3, 1.5f32);
        assert_eq!(t.tag, 3);
        assert_eq!(t.item, 1.5);
    }
}
