//! A channel = data queue + signal queue + the emitter half of the credit
//! protocol (paper §3.1).
//!
//! Emitter rules, implemented in [`Channel::emit_signal`]:
//!
//! 1. If no signal is queued on `S`, the new signal's credit is the number
//!    of data items currently queued on `Q`.
//! 2. Otherwise, its credit is the number of data items emitted since the
//!    signal at the tail of `S` was enqueued (`emitted_since_signal`,
//!    reset on every signal emission).
//!
//! The receiver half (current-credit counter, rules 1/2a/2b) lives in
//! [`super::node`], which owns the per-node counter.
//!
//! Channels carry a name (the builder derives it from the producing
//! node) so bulk-push overflow surfaces as an error naming the edge
//! instead of a bare queue panic.

use std::cell::{Cell, RefCell};
use std::rc::Rc;

use anyhow::{ensure, Result};

use super::queue::{DataQueue, SignalQueue};
use super::signal::{Signal, SignalKind};

/// A directed edge between two nodes: bounded data and signal queues.
pub struct Channel<T> {
    name: String,
    data: RefCell<DataQueue<T>>,
    signals: RefCell<SignalQueue>,
    /// Emitter-side counter for credit rule (2).
    emitted_since_signal: Cell<u64>,
}

impl<T> Channel<T> {
    /// New channel with the given queue capacities.
    pub fn new(data_cap: usize, signal_cap: usize) -> Rc<Channel<T>> {
        Channel::named("chan", data_cap, signal_cap)
    }

    /// New channel carrying `name` (used in overflow diagnostics).
    pub fn named(name: impl Into<String>, data_cap: usize, signal_cap: usize) -> Rc<Channel<T>> {
        Rc::new(Channel {
            name: name.into(),
            data: RefCell::new(DataQueue::new(data_cap)),
            signals: RefCell::new(SignalQueue::new(signal_cap)),
            emitted_since_signal: Cell::new(0),
        })
    }

    /// Channel name (used in diagnostics).
    pub fn name(&self) -> &str {
        &self.name
    }

    // ---- emitter side -----------------------------------------------

    /// Emit one data item (upstream node). Panics on overflow; the
    /// scheduler's fireable test reserves space before firing.
    pub fn push(&self, item: T) {
        self.data.borrow_mut().push(item);
        self.emitted_since_signal.set(self.emitted_since_signal.get() + 1);
    }

    /// Emit a burst of data items with a single queue borrow and one bulk
    /// append (perf: the per-item `RefCell` borrow in `push` dominates
    /// tight feed loops — see EXPERIMENTS.md §Perf). Semantically
    /// identical to pushing each item; overflow is reported as an error
    /// naming this channel instead of panicking deep in the queue.
    pub fn push_iter<I>(&self, items: I) -> Result<usize>
    where
        I: IntoIterator<Item = T>,
        I::IntoIter: ExactSizeIterator,
    {
        let it = items.into_iter();
        let n = it.len();
        let mut q = self.data.borrow_mut();
        ensure!(
            n <= q.space(),
            "data queue overflow on channel '{}': pushing {} items into {} free slots (capacity {})",
            self.name,
            n,
            q.space(),
            q.capacity()
        );
        q.extend_bulk(it);
        drop(q);
        self.emitted_since_signal
            .set(self.emitted_since_signal.get() + n as u64);
        Ok(n)
    }

    /// [`Channel::push_iter`] over a slice (bulk clone-in).
    pub fn push_slice(&self, items: &[T]) -> Result<usize>
    where
        T: Clone,
    {
        self.push_iter(items.iter().cloned())
    }

    /// Emit a signal, assigning credit per the emitter rules.
    pub fn emit_signal(&self, kind: SignalKind) {
        let mut sigs = self.signals.borrow_mut();
        let credit = if sigs.is_empty() {
            self.data.borrow().len() as u64 // rule (1)
        } else {
            self.emitted_since_signal.get() // rule (2)
        };
        sigs.push(Signal::new(kind, credit));
        self.emitted_since_signal.set(0);
    }

    // ---- reuse (persistent pipelines) ---------------------------------

    /// Return the channel to its just-built state **in place**: queued
    /// data/signals are discarded and the emitter-side credit counter is
    /// re-armed, while both rings keep their capacity — a reset on the
    /// steady-state reuse path performs no heap allocation. Called per
    /// node by [`Pipeline::reset`](crate::coordinator::topology::Pipeline::reset)
    /// (each node resets its input channel).
    pub fn reset(&self) {
        self.data.borrow_mut().clear();
        self.signals.borrow_mut().clear();
        self.emitted_since_signal.set(0);
    }

    /// Re-target the data queue's logical capacity (per-shard source
    /// sizing: a persistent pipeline's source channel is re-sized to the
    /// incoming shard's length so backpressure — and therefore scheduling
    /// — matches a freshly built pipeline bit for bit). The ring's
    /// allocation only grows, and only when `cap` exceeds every previous
    /// shard's (the capacity-regrowth path). Call on an empty channel
    /// (i.e. after [`Channel::reset`]).
    pub fn set_data_capacity(&self, cap: usize) {
        self.data.borrow_mut().set_capacity(cap);
    }

    /// Physical slots the data ring currently holds — what a shrink
    /// policy compares against recent shard sizes.
    pub fn data_allocated(&self) -> usize {
        self.data.borrow().allocated()
    }

    /// Release data-ring memory down to `cap` physical slots (clamped to
    /// the logical capacity and live items — see
    /// [`DataQueue::shrink_to`]). Off the firing path: per-app shrink
    /// policies call this between shards when a transient giant shard
    /// has left the source ring far above steady state. Scheduling
    /// depends only on the *logical* capacity, so shrinking never
    /// changes outputs.
    pub fn shrink_data_to(&self, cap: usize) {
        self.data.borrow_mut().shrink_to(cap);
    }

    // ---- capacity (for the fireable test) ----------------------------

    /// Free data-queue slots.
    pub fn data_space(&self) -> usize {
        self.data.borrow().space()
    }

    /// Free signal-queue slots.
    pub fn signal_space(&self) -> usize {
        self.signals.borrow().space()
    }

    // ---- receiver side (used by the owning node) ----------------------

    /// Queued data items.
    pub fn data_len(&self) -> usize {
        self.data.borrow().len()
    }

    /// Queued signals.
    pub fn signal_len(&self) -> usize {
        self.signals.borrow().len()
    }

    /// Any queued data or signals?
    pub fn has_pending(&self) -> bool {
        self.data_len() > 0 || self.signal_len() > 0
    }

    /// Pop up to `n` data items into the ensemble scratch buffer (one
    /// borrow, one bulk move).
    pub fn pop_data_into(&self, n: usize, out: &mut Vec<T>) -> usize {
        self.data.borrow_mut().pop_into(n, out)
    }

    /// Pop a single data item (composite-granularity consumers, e.g. the
    /// enumerator opening its next parent).
    pub fn pop_data(&self) -> Option<T> {
        self.data.borrow_mut().pop()
    }

    /// Head signal credit (0 when no signal queued).
    pub fn head_signal_credit(&self) -> u64 {
        self.signals.borrow().head_credit()
    }

    /// Drain the head signal's credit into the caller (receiver rule 2b).
    pub fn take_head_signal_credit(&self) -> u64 {
        self.signals.borrow_mut().take_head_credit()
    }

    /// Consume the head signal (its credit must already be drained).
    pub fn pop_signal(&self) -> Option<Signal> {
        self.signals.borrow_mut().pop()
    }
}

impl<T> std::fmt::Debug for Channel<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Channel")
            .field("name", &self.name)
            .field("data_len", &self.data_len())
            .field("signal_len", &self.signal_len())
            .field("emitted_since_signal", &self.emitted_since_signal.get())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule1_credit_equals_queue_len() {
        let ch = Channel::new(16, 4);
        ch.push(1);
        ch.push(2);
        ch.push(3);
        ch.emit_signal(SignalKind::Custom(0));
        assert_eq!(ch.head_signal_credit(), 3);
    }

    #[test]
    fn rule1_counts_queue_not_emissions() {
        // Items already consumed downstream must NOT count toward a new
        // signal's credit when S is empty.
        let ch = Channel::new(16, 4);
        ch.push(1);
        ch.push(2);
        let mut scratch = Vec::new();
        ch.pop_data_into(2, &mut scratch); // downstream consumed both
        ch.push(3);
        ch.emit_signal(SignalKind::Custom(0));
        assert_eq!(ch.head_signal_credit(), 1); // only item 3 queued
    }

    #[test]
    fn rule2_counts_since_last_signal() {
        let ch = Channel::new(16, 4);
        ch.push(1);
        ch.emit_signal(SignalKind::Custom(0)); // credit 1 (rule 1)
        ch.push(2);
        ch.push(3);
        ch.emit_signal(SignalKind::Custom(1)); // credit 2 (rule 2)
        ch.push(4);
        ch.emit_signal(SignalKind::Custom(2)); // credit 1 (rule 2)
        assert_eq!(ch.head_signal_credit(), 1);
        ch.take_head_signal_credit();
        ch.pop_signal();
        assert_eq!(ch.head_signal_credit(), 2);
        ch.take_head_signal_credit();
        ch.pop_signal();
        assert_eq!(ch.head_signal_credit(), 1);
    }

    #[test]
    fn push_iter_matches_per_item_pushes() {
        let a: Rc<Channel<u32>> = Channel::new(64, 8);
        let b: Rc<Channel<u32>> = Channel::new(64, 8);
        for i in 0..5 {
            a.push(i);
        }
        b.push_iter(0..5).unwrap();
        a.emit_signal(SignalKind::Custom(0));
        b.emit_signal(SignalKind::Custom(0));
        assert_eq!(a.head_signal_credit(), b.head_signal_credit());
        a.push(9);
        b.push_iter(std::iter::once(9)).unwrap();
        a.emit_signal(SignalKind::Custom(1));
        b.emit_signal(SignalKind::Custom(1));
        assert_eq!(a.data_len(), b.data_len());
    }

    #[test]
    fn push_slice_matches_push_iter() {
        let a: Rc<Channel<u32>> = Channel::new(64, 8);
        let b: Rc<Channel<u32>> = Channel::new(64, 8);
        a.push_slice(&[1, 2, 3]).unwrap();
        b.push_iter([1u32, 2, 3].into_iter()).unwrap();
        let (mut xa, mut xb) = (Vec::new(), Vec::new());
        a.pop_data_into(8, &mut xa);
        b.pop_data_into(8, &mut xb);
        assert_eq!(xa, xb);
    }

    #[test]
    fn push_iter_overflow_names_the_channel() {
        let ch: Rc<Channel<u32>> = Channel::named("f.out", 2, 2);
        ch.push(0);
        let err = ch.push_iter(1..4).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("f.out"), "{msg}");
        assert!(msg.contains("overflow"), "{msg}");
        // nothing was partially pushed
        assert_eq!(ch.data_len(), 1);
    }

    #[test]
    fn pop_data_pops_single_items_in_order() {
        let ch: Rc<Channel<u32>> = Channel::new(8, 2);
        assert_eq!(ch.pop_data(), None);
        ch.push(7);
        ch.push(8);
        assert_eq!(ch.pop_data(), Some(7));
        assert_eq!(ch.pop_data(), Some(8));
        assert_eq!(ch.pop_data(), None);
    }

    #[test]
    fn back_to_back_signals_have_zero_credit() {
        let ch: Rc<Channel<u32>> = Channel::new(8, 8);
        ch.emit_signal(SignalKind::Custom(0));
        ch.emit_signal(SignalKind::Custom(1));
        assert_eq!(ch.head_signal_credit(), 0);
        ch.pop_signal();
        assert_eq!(ch.head_signal_credit(), 0);
    }

    #[test]
    fn reset_restores_the_just_built_state() {
        let ch: Rc<Channel<u32>> = Channel::new(8, 4);
        ch.push(1);
        ch.push(2);
        ch.emit_signal(SignalKind::Custom(0));
        ch.push(3); // emitted_since_signal now 1
        ch.reset();
        assert_eq!(ch.data_len(), 0);
        assert_eq!(ch.signal_len(), 0);
        assert_eq!(ch.data_space(), 8);
        assert_eq!(ch.signal_space(), 4);
        // the emitter counter was re-armed: rule (1) applies afresh
        ch.push(9);
        ch.emit_signal(SignalKind::Custom(1));
        assert_eq!(ch.head_signal_credit(), 1);
    }

    #[test]
    fn set_data_capacity_resizes_the_source_per_shard() {
        let ch: Rc<Channel<u32>> = Channel::new(1, 4);
        ch.set_data_capacity(3);
        ch.push(1);
        ch.push(2);
        ch.push(3);
        assert_eq!(ch.data_space(), 0);
        let mut buf = Vec::new();
        ch.pop_data_into(3, &mut buf);
        ch.reset();
        ch.set_data_capacity(2);
        ch.push(4);
        assert_eq!(ch.data_space(), 1);
    }

    #[test]
    fn shrink_data_to_releases_a_transient_peak() {
        let ch: Rc<Channel<u32>> = Channel::new(4, 4);
        ch.set_data_capacity(4096);
        assert!(ch.data_allocated() >= 4096);
        ch.reset();
        ch.set_data_capacity(4);
        ch.shrink_data_to(8);
        assert!(ch.data_allocated() < 4096);
        ch.push_slice(&[1, 2, 3, 4]).unwrap();
        assert_eq!(ch.data_space(), 0);
    }

    #[test]
    fn spaces_track_queues() {
        let ch: Rc<Channel<u32>> = Channel::new(2, 1);
        assert_eq!(ch.data_space(), 2);
        ch.push(9);
        assert_eq!(ch.data_space(), 1);
        assert_eq!(ch.signal_space(), 1);
        ch.emit_signal(SignalKind::Custom(0));
        assert_eq!(ch.signal_space(), 0);
    }
}
