//! Compute nodes: two-phase firing and the receiver half of the credit
//! protocol (paper §3.1–3.3).
//!
//! A firing has a **data phase** followed by a **signal phase**:
//!
//! * Data phase — consume one SIMD *ensemble*: up to `width` items, further
//!   limited by downstream queue space and, when a signal is pending, by
//!   the node's current-credit counter (receiver rules 2a/2b). This is the
//!   §3.3 SIMD rule: an ensemble never spans a signal, so all items in an
//!   ensemble share one region context.
//! * Signal phase — entered when the credit counter is 0: consume queued
//!   signals (calling the `begin`/`end`/custom hooks and forwarding region
//!   signals downstream) until a signal recharges the counter or none
//!   remain.
//!
//! The *fireable* test (§3.2) uses each logic's a-priori output bounds to
//! guarantee a firing can never overflow downstream queues.

use std::cell::RefCell;
use std::rc::Rc;

use anyhow::Result;

use super::channel::Channel;
use super::metrics::NodeMetrics;
use super::signal::{ParentRef, Signal, SignalKind};

/// User-provided node behaviour (the paper's `run()`/`begin()`/`end()`
/// stubs, Fig. 5).
pub trait NodeLogic {
    /// Input item type.
    type In: 'static;
    /// Output item type.
    type Out: 'static;

    /// Process one ensemble. `items` has between 1 and `width` entries and
    /// never spans a region boundary; `parent` is the enclosing region's
    /// composite object (the paper's `getParent()`), uniform across the
    /// ensemble.
    fn run(
        &mut self,
        items: &[Self::In],
        parent: Option<&ParentRef>,
        out: &mut Emitter<'_, Self::Out>,
    ) -> Result<()>;

    /// Called when a region opens (before any of its items).
    fn begin(&mut self, _parent: &ParentRef, _out: &mut Emitter<'_, Self::Out>) -> Result<()> {
        Ok(())
    }

    /// Called when a region closes (after all of its items).
    fn end(&mut self, _parent: &ParentRef, _out: &mut Emitter<'_, Self::Out>) -> Result<()> {
        Ok(())
    }

    /// Called for application-defined signals.
    fn on_custom(&mut self, _id: u64, _out: &mut Emitter<'_, Self::Out>) -> Result<()> {
        Ok(())
    }

    /// A-priori bound on outputs per consumed data item (paper §3.2; the
    /// scheduler uses it to reserve downstream space).
    fn max_outputs_per_input(&self) -> usize {
        1
    }

    /// A-priori bound on data outputs per consumed *signal* (an
    /// aggregator's `end()` pushes one result; plain filters push none).
    fn max_outputs_per_signal(&self) -> usize {
        0
    }

    /// Forward region/custom signals to the downstream neighbour?
    /// `true` keeps the enumeration scope open through this node;
    /// aggregators return `false` to close it (the `aggregate` keyword).
    fn forward_region_signals(&self) -> bool {
        true
    }

    /// Clear cross-region / cross-run logic state so a reused pipeline is
    /// indistinguishable from a freshly built one
    /// ([`Pipeline::reset`](crate::coordinator::topology::Pipeline::reset)).
    /// Stateless logics — and logics whose state is fully re-initialized
    /// by `begin()` or overwritten every firing — keep the default no-op;
    /// logics with stream-scoped accumulation (e.g. the tagged sum's
    /// per-tag map) must clear it here.
    fn reset(&mut self) {}
}

/// Where a node's outputs go: a downstream channel, or a terminal sink
/// buffer (the paper's sink node, with unbounded output space).
pub enum Output<T> {
    /// Send into a downstream channel.
    Chan(Rc<Channel<T>>),
    /// Collect into a driver-owned sink buffer.
    Sink(Rc<RefCell<Vec<T>>>),
}

impl<T> Output<T> {
    fn data_space(&self) -> usize {
        match self {
            Output::Chan(c) => c.data_space(),
            Output::Sink(_) => usize::MAX,
        }
    }

    fn signal_space(&self) -> usize {
        match self {
            Output::Chan(c) => c.signal_space(),
            Output::Sink(_) => usize::MAX,
        }
    }
}

/// Push handle given to [`NodeLogic`] callbacks.
///
/// Pushes land in the node's reusable staging buffer — no queue borrow,
/// no `RefCell` traffic per item. The node flushes the whole stage with a
/// single bulk [`Channel::push_iter`] borrow after the callback returns
/// (one queue borrow per phase — see EXPERIMENTS.md §Perf). Flush points
/// are chosen so the downstream data/signal interleaving is identical to
/// immediate pushes.
pub struct Emitter<'a, T> {
    stage: &'a mut Vec<T>,
    /// Items pushed during the current callback (checked against the
    /// logic's declared bounds in debug builds).
    pub pushed: usize,
}

impl<'a, T> Emitter<'a, T> {
    pub(crate) fn new(stage: &'a mut Vec<T>) -> Emitter<'a, T> {
        // normally empty here (flush drains it), but a callback that
        // pushed and then errored leaves stale items behind; clearing
        // keeps a caller-retried fire() from flushing them downstream
        stage.clear();
        Emitter { stage, pushed: 0 }
    }

    /// Emit one output item.
    pub fn push(&mut self, item: T) {
        self.stage.push(item);
        self.pushed += 1;
    }
}

/// Flush a staging buffer downstream: one bulk move for a channel, one
/// append for a sink. The stage keeps its capacity for the next firing.
fn flush_stage<T>(stage: &mut Vec<T>, output: &Output<T>) -> Result<()> {
    if stage.is_empty() {
        return Ok(());
    }
    match output {
        Output::Chan(c) => {
            c.push_iter(stage.drain(..))?;
        }
        Output::Sink(s) => {
            s.borrow_mut().append(stage);
        }
    }
    Ok(())
}

/// Object-safe node interface driven by the scheduler.
pub trait NodeOps {
    /// Node name (for diagnostics and traces).
    fn name(&self) -> &str;
    /// Any queued data or signals?
    fn has_pending(&self) -> bool;
    /// May this node make progress if fired now? (paper §3.2 fireable test)
    fn fireable(&self) -> bool;
    /// One firing: data phase + signal phase. Returns true if progress
    /// was made.
    fn fire(&mut self) -> Result<bool>;
    /// Return the node to its just-built state **in place** (pipeline
    /// reuse): clear the input channel (each node owns resetting its own
    /// input; outputs are some downstream node's input), re-arm
    /// credit/region state, clear logic state, and zero metrics — all
    /// without releasing any buffer capacity. Sink buffers are owned by
    /// the driver, which collects-and-clears them per shard.
    fn reset(&mut self);
    /// Metrics accumulated since the last reset.
    fn metrics(&self) -> &NodeMetrics;
    /// Size of the data ensemble a firing would process right now
    /// (0 if only signal work is possible). The occupancy-greedy
    /// scheduling policy maximizes this — MERCATOR's approach to keeping
    /// SIMD ensembles full.
    fn ready_hint(&self) -> usize {
        0
    }
    /// Is this node's input queue too full for its upstream neighbour to
    /// stage another full ensemble? The scheduler uses this backpressure
    /// signal to decide when a sub-width firing is *necessary* (drain)
    /// rather than premature (it should keep accumulating).
    fn input_pressure(&self) -> bool {
        false
    }
}

/// A pipeline stage wrapping a [`NodeLogic`].
pub struct Node<L: NodeLogic> {
    name: String,
    logic: L,
    input: Rc<Channel<L::In>>,
    output: Output<L::Out>,
    /// Receiver-side current credit counter (paper §3.1).
    credit: u64,
    /// Region context, maintained from RegionBegin/RegionEnd signals.
    parent: Option<ParentRef>,
    width: usize,
    metrics: NodeMetrics,
    scratch: Vec<L::In>,
    /// Reusable output staging flushed once per phase (see [`Emitter`]).
    stage: Vec<L::Out>,
}

impl<L: NodeLogic> Node<L> {
    /// Create a node wiring `logic` between `input` and `output`.
    pub fn new(
        name: impl Into<String>,
        width: usize,
        input: Rc<Channel<L::In>>,
        output: Output<L::Out>,
        logic: L,
    ) -> Node<L> {
        Node {
            name: name.into(),
            logic,
            input,
            output,
            credit: 0,
            parent: None,
            width,
            metrics: NodeMetrics::new(width),
            scratch: Vec::with_capacity(width),
            stage: Vec::with_capacity(width),
        }
    }

    /// Invariant check (paper appendix, Claim 1): a non-zero credit counter
    /// implies pending data.
    fn check_claim1(&self) {
        debug_assert!(
            self.credit == 0 || self.input.data_len() > 0,
            "claim 1 violated at node {}: credit {} with empty data queue",
            self.name,
            self.credit
        );
    }

    /// Data-phase ensemble size limit (receiver rules 1/2a/2b + space +
    /// SIMD width). May transfer head-signal credit into the counter.
    fn data_limit(&mut self) -> usize {
        let avail = self.input.data_len();
        if avail == 0 {
            return 0;
        }
        let mut limit = avail.min(self.width);
        if self.input.signal_len() > 0 {
            if self.credit == 0 {
                // rule 2b: recharge from the head signal
                self.credit = self.input.take_head_signal_credit();
            }
            // rule 2a: never read past the next signal
            limit = limit.min(self.credit as usize);
        } else {
            debug_assert_eq!(self.credit, 0, "credit without queued signal");
        }
        let max_out = self.logic.max_outputs_per_input().max(1);
        let space = self.output.data_space() / max_out;
        limit.min(space)
    }

    fn can_consume_signal(&self) -> bool {
        // forwarding needs signal space; begin/end pushes need data space
        let sig_ok = !self.logic.forward_region_signals() || self.output.signal_space() >= 1;
        let data_ok = self.output.data_space() >= self.logic.max_outputs_per_signal();
        sig_ok && data_ok
    }

    fn handle_signal(&mut self, sig: Signal) -> Result<()> {
        match sig.kind {
            SignalKind::RegionBegin { parent } => {
                self.parent = Some(parent.clone());
                // forward FIRST: items pushed by begin() belong inside the
                // region downstream as well
                if self.logic.forward_region_signals() {
                    if let Output::Chan(c) = &self.output {
                        c.emit_signal(SignalKind::RegionBegin {
                            parent: parent.clone(),
                        });
                        self.metrics.signals_emitted += 1;
                    }
                }
                let mut em = Emitter::new(&mut self.stage);
                self.logic.begin(&parent, &mut em)?;
                let pushed = em.pushed;
                debug_assert!(pushed <= self.logic.max_outputs_per_signal());
                flush_stage(&mut self.stage, &self.output)?;
            }
            SignalKind::RegionEnd { parent } => {
                // end() pushes (e.g. an aggregate) belong BEFORE the
                // downstream region-end boundary: flush before forwarding
                let mut em = Emitter::new(&mut self.stage);
                self.logic.end(&parent, &mut em)?;
                let pushed = em.pushed;
                debug_assert!(pushed <= self.logic.max_outputs_per_signal());
                flush_stage(&mut self.stage, &self.output)?;
                self.parent = None;
                if self.logic.forward_region_signals() {
                    if let Output::Chan(c) = &self.output {
                        c.emit_signal(SignalKind::RegionEnd { parent });
                        self.metrics.signals_emitted += 1;
                    }
                }
            }
            SignalKind::Custom(id) => {
                let mut em = Emitter::new(&mut self.stage);
                self.logic.on_custom(id, &mut em)?;
                let pushed = em.pushed;
                debug_assert!(pushed <= self.logic.max_outputs_per_signal());
                flush_stage(&mut self.stage, &self.output)?;
                if self.logic.forward_region_signals() {
                    if let Output::Chan(c) = &self.output {
                        c.emit_signal(SignalKind::Custom(id));
                        self.metrics.signals_emitted += 1;
                    }
                }
            }
        }
        Ok(())
    }

    /// Access the wrapped logic (e.g. to read app state after a run).
    pub fn logic(&self) -> &L {
        &self.logic
    }
}

impl<L: NodeLogic> NodeOps for Node<L> {
    fn name(&self) -> &str {
        &self.name
    }

    fn has_pending(&self) -> bool {
        self.input.has_pending()
    }

    fn fireable(&self) -> bool {
        let data = self.input.data_len();
        let sigs = self.input.signal_len();
        if data == 0 && sigs == 0 {
            return false;
        }
        let max_out = self.logic.max_outputs_per_input().max(1);
        let room_for_data = self.output.data_space() >= max_out;
        if data > 0 && room_for_data {
            // would the credit rules admit at least one item?
            let credit_ok = if sigs > 0 {
                self.credit > 0 || self.input.head_signal_credit() > 0
            } else {
                true
            };
            if credit_ok {
                return true;
            }
        }
        // otherwise: a zero-credit signal at the head may be consumable
        if sigs > 0 && self.credit == 0 && self.input.head_signal_credit() == 0 {
            return self.can_consume_signal();
        }
        false
    }

    fn fire(&mut self) -> Result<bool> {
        self.check_claim1();
        let mut worked = false;
        self.metrics.firings += 1;

        // ---- data phase: one ensemble ----
        let limit = self.data_limit();
        if limit > 0 {
            let take = self.input.pop_data_into(limit, &mut self.scratch);
            debug_assert!(take >= 1);
            let max_pushed = take * self.logic.max_outputs_per_input().max(1);
            let mut em = Emitter::new(&mut self.stage);
            let parent = self.parent.clone();
            self.logic.run(&self.scratch[..take], parent.as_ref(), &mut em)?;
            let pushed = em.pushed;
            debug_assert!(
                pushed <= max_pushed,
                "node {} exceeded its declared output bound",
                self.name
            );
            // one bulk flush per data phase; space was reserved by
            // data_limit(), so this cannot overflow
            flush_stage(&mut self.stage, &self.output)?;
            if self.credit > 0 {
                self.credit -= take as u64;
            }
            self.metrics.record_ensemble(take);
            worked = true;
        }

        // ---- signal phase ----
        if self.credit == 0 {
            while self.input.signal_len() > 0 {
                let c = self.input.take_head_signal_credit();
                if c > 0 {
                    // counter recharged: data must be consumed first
                    self.credit = c;
                    break;
                }
                if !self.can_consume_signal() {
                    break; // blocked downstream; retry on a later firing
                }
                let sig = self.input.pop_signal().expect("len checked");
                self.handle_signal(sig)?;
                self.metrics.signals_consumed += 1;
                worked = true;
            }
        }
        self.check_claim1();
        Ok(worked)
    }

    fn reset(&mut self) {
        self.input.reset();
        self.credit = 0;
        self.parent = None;
        self.scratch.clear();
        self.stage.clear();
        self.metrics.reset();
        self.logic.reset();
    }

    fn metrics(&self) -> &NodeMetrics {
        &self.metrics
    }

    fn ready_hint(&self) -> usize {
        let avail = self.input.data_len();
        if avail == 0 {
            return 0;
        }
        let mut limit = avail.min(self.width);
        if self.input.signal_len() > 0 {
            // non-mutating mirror of data_limit(): count both the local
            // counter and the (not yet transferred) head-signal credit
            let credit = self.credit.max(self.input.head_signal_credit());
            limit = limit.min(credit as usize);
        }
        let max_out = self.logic.max_outputs_per_input().max(1);
        limit.min(self.output.data_space() / max_out)
    }

    fn input_pressure(&self) -> bool {
        self.input.data_space() < self.width
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Doubles each value; drops negatives (irregular output).
    struct DoublePos;
    impl NodeLogic for DoublePos {
        type In = f32;
        type Out = f32;
        fn run(
            &mut self,
            items: &[f32],
            _parent: Option<&ParentRef>,
            out: &mut Emitter<'_, f32>,
        ) -> Result<()> {
            for &v in items {
                if v >= 0.0 {
                    out.push(2.0 * v);
                }
            }
            Ok(())
        }
    }

    fn sink_node(
        width: usize,
        input: Rc<Channel<f32>>,
    ) -> (Node<DoublePos>, Rc<RefCell<Vec<f32>>>) {
        let sink = Rc::new(RefCell::new(Vec::new()));
        let node = Node::new("n", width, input, Output::Sink(sink.clone()), DoublePos);
        (node, sink)
    }

    #[test]
    fn fires_one_ensemble_up_to_width() {
        let ch = Channel::new(64, 8);
        for i in 0..10 {
            ch.push(i as f32);
        }
        let (mut node, sink) = sink_node(4, ch);
        assert!(node.fireable());
        assert!(node.fire().unwrap());
        assert_eq!(sink.borrow().len(), 4); // one ensemble of width 4
        assert_eq!(node.metrics().ensembles, 1);
        assert_eq!(node.metrics().full_ensembles, 1);
        // three more firings drain the rest
        while node.fireable() {
            node.fire().unwrap();
        }
        assert_eq!(sink.borrow().len(), 10);
        assert_eq!(node.metrics().ensembles, 3);
        assert_eq!(node.metrics().ensemble_hist[2], 1); // final partial
    }

    #[test]
    fn signal_caps_ensemble_at_credit() {
        let ch = Channel::new(64, 8);
        for i in 0..3 {
            ch.push(i as f32);
        }
        ch.emit_signal(SignalKind::Custom(7)); // credit 3
        for i in 3..8 {
            ch.push(i as f32);
        }
        let (mut node, sink) = sink_node(4, ch);
        // firing 1: ensemble capped at 3 (credit), then signal consumed
        assert!(node.fire().unwrap());
        assert_eq!(sink.borrow().len(), 3);
        assert_eq!(node.metrics().ensemble_hist[3], 1);
        assert_eq!(node.metrics().signals_consumed, 1);
        // firing 2: remaining 5 items → ensemble of 4, then 1
        node.fire().unwrap();
        node.fire().unwrap();
        assert_eq!(sink.borrow().len(), 8);
        assert_eq!(node.metrics().ensemble_hist[4], 1);
        assert_eq!(node.metrics().ensemble_hist[1], 1);
    }

    #[test]
    fn zero_credit_signal_consumed_before_data() {
        let ch = Channel::new(64, 8);
        ch.emit_signal(SignalKind::Custom(1)); // credit 0 (empty queue)
        ch.push(1.0);
        let (mut node, sink) = sink_node(4, ch);
        assert!(node.fire().unwrap());
        // the signal preceded the data; first firing consumed the signal
        // AND then nothing blocked the data... data phase ran first with
        // limit 0, signal phase consumed the signal.
        assert_eq!(node.metrics().signals_consumed, 1);
        assert_eq!(sink.borrow().len(), 0);
        node.fire().unwrap();
        assert_eq!(sink.borrow().len(), 1);
    }

    #[test]
    fn region_signals_update_parent_and_hooks() {
        struct ParentEcho {
            begun: u32,
            ended: u32,
        }
        impl NodeLogic for ParentEcho {
            type In = u32;
            type Out = u64;
            fn run(
                &mut self,
                items: &[u32],
                parent: Option<&ParentRef>,
                out: &mut Emitter<'_, u64>,
            ) -> Result<()> {
                let pid = parent
                    .and_then(|p| crate::coordinator::signal::parent_as::<u64>(p))
                    .map(|p| *p)
                    .unwrap_or(999);
                for &i in items {
                    out.push(pid * 1000 + i as u64);
                }
                Ok(())
            }
            fn begin(&mut self, _p: &ParentRef, _o: &mut Emitter<'_, u64>) -> Result<()> {
                self.begun += 1;
                Ok(())
            }
            fn end(&mut self, _p: &ParentRef, _o: &mut Emitter<'_, u64>) -> Result<()> {
                self.ended += 1;
                Ok(())
            }
        }

        let ch: Rc<Channel<u32>> = Channel::new(64, 8);
        let p1: ParentRef = Rc::new(5u64);
        ch.emit_signal(SignalKind::RegionBegin { parent: p1.clone() });
        ch.push(1);
        ch.push(2);
        ch.emit_signal(SignalKind::RegionEnd { parent: p1 });
        let p2: ParentRef = Rc::new(6u64);
        ch.emit_signal(SignalKind::RegionBegin { parent: p2.clone() });
        ch.push(3);
        ch.emit_signal(SignalKind::RegionEnd { parent: p2 });

        let sink = Rc::new(RefCell::new(Vec::new()));
        let mut node = Node::new(
            "echo",
            4,
            ch,
            Output::Sink(sink.clone()),
            ParentEcho { begun: 0, ended: 0 },
        );
        while node.fireable() {
            node.fire().unwrap();
        }
        assert_eq!(*sink.borrow(), vec![5001, 5002, 6003]);
        assert_eq!(node.logic().begun, 2);
        assert_eq!(node.logic().ended, 2);
        // items of different regions never shared an ensemble
        assert_eq!(node.metrics().ensemble_hist[2], 1);
        assert_eq!(node.metrics().ensemble_hist[1], 1);
    }

    #[test]
    fn reset_rearms_credit_parent_and_metrics() {
        let ch = Channel::new(64, 8);
        let p: ParentRef = Rc::new(3u64);
        ch.emit_signal(SignalKind::RegionBegin { parent: p.clone() });
        ch.push(1.0);
        ch.push(2.0);
        // open the region (firing 1 consumes the Begin), run one ensemble
        // (firing 2), then leave unconsumed data behind
        let (mut node, sink) = sink_node(4, ch.clone());
        node.fire().unwrap();
        node.fire().unwrap();
        assert_eq!(node.metrics().ensembles, 1);
        ch.push(7.0); // pending data inside the still-open region

        node.reset();
        assert!(!node.has_pending(), "input channel cleared");
        assert!(!node.fireable());
        assert_eq!(node.metrics().firings, 0);
        assert_eq!(node.metrics().ensembles, 0);

        // a rerun behaves exactly like a fresh node over a fresh channel
        sink.borrow_mut().clear();
        let q: ParentRef = Rc::new(9u64);
        ch.emit_signal(SignalKind::RegionBegin { parent: q.clone() });
        ch.push(5.0);
        ch.emit_signal(SignalKind::RegionEnd { parent: q });
        while node.fireable() {
            node.fire().unwrap();
        }
        assert_eq!(*sink.borrow(), vec![10.0]);
        assert_eq!(node.metrics().ensembles, 1);
        assert_eq!(node.metrics().signals_consumed, 2);
    }

    #[test]
    fn blocked_downstream_is_not_fireable() {
        let ch = Channel::new(64, 8);
        ch.push(1.0);
        let out: Rc<Channel<f32>> = Channel::new(0, 1); // no data space
        let mut node = Node::new("n", 4, ch, Output::Chan(out), DoublePos);
        assert!(!node.fireable());
        assert!(!node.fire().unwrap()); // firing anyway makes no progress
        assert_eq!(node.metrics().ensembles, 0);
    }

    #[test]
    fn forwards_region_signals_downstream() {
        let ch: Rc<Channel<f32>> = Channel::new(8, 4);
        let p: ParentRef = Rc::new(1u64);
        ch.emit_signal(SignalKind::RegionBegin { parent: p.clone() });
        ch.push(1.0);
        ch.emit_signal(SignalKind::RegionEnd { parent: p });
        let out: Rc<Channel<f32>> = Channel::new(8, 4);
        let mut node = Node::new("n", 4, ch, Output::Chan(out.clone()), DoublePos);
        while node.fireable() {
            node.fire().unwrap();
        }
        assert_eq!(out.data_len(), 1);
        assert_eq!(out.signal_len(), 2);
        assert_eq!(node.metrics().signals_emitted, 2);
        // forwarded Begin has credit 0 (emitted before the data), End has 1
        assert_eq!(out.head_signal_credit(), 0);
        out.pop_signal();
        assert_eq!(out.head_signal_credit(), 1);
    }
}
