//! Structured event tracing across the whole stack — zero overhead when
//! off.
//!
//! The paper's central performance quantity is SIMD occupancy *over
//! time*: how region-boundary frequency caps ensemble width per firing.
//! End-of-run aggregates ([`NodeMetrics`](crate::coordinator::metrics))
//! cannot show a straggler shard, a steal storm, or an occupancy
//! collapse mid-stream — this module can. It records typed events from
//! every layer:
//!
//! * **scheduler firings** — node id plus the ensemble/item deltas of
//!   that one firing (occupancy per firing), hooked inside
//!   [`Scheduler::run`](crate::coordinator::scheduler::Scheduler);
//! * **shard lifecycle** — claim→execute→complete as one span per shard,
//!   tagged stolen-or-local, from the worker pool;
//! * **ingest** — planner cuts (shard submission) and backpressure
//!   stalls from the streaming driver;
//! * **merge** — in-order emission from the stream merger ring;
//! * **prewarm** — each worker's eager pipeline build, as its own span
//!   outside the timed region.
//!
//! ## Design rules
//!
//! * **Zero overhead when off.** A disabled [`TraceSink`] is a single
//!   `Option` branch on the hot path; no clock reads, no stores, no
//!   allocation. The count-allocs suite pins the steady-state firing
//!   path at exactly zero allocations with tracing off *and* on.
//! * **No steady-state allocation when on.** Each lane owns one
//!   preallocated [`TraceBuffer`]; recording is a bounds check plus a
//!   32-byte store. When the buffer fills, events are dropped — counted
//!   honestly in [`TraceBuffer::dropped`] and surfaced through every
//!   export — never reallocated.
//! * **Reconciliation.** One [`TraceEvent::Firing`] is recorded per
//!   scheduler firing with deltas read from the node's own counters, so
//!   with zero drops the folded trace's firing/ensemble/item totals
//!   equal the `NodeMetrics` sums *exactly* (`tests/trace_observe.rs`).
//! * **Clock model.** All stamps are nanoseconds since one shared
//!   [`Instant`] epoch ([`TraceSpec::epoch`]) captured before workers
//!   start. `Instant` is monotonic, so per-lane event order is exact and
//!   cross-lane skew is bounded by the OS clocksource, not by wall-clock
//!   adjustments.
//!
//! Exports: [`chrome`] renders the folded [`Trace`] as Chrome
//! trace-event JSON (open in Perfetto or `chrome://tracing`); [`summary`]
//! turns that artifact back into a windowed occupancy timeline, a
//! straggler table and a steal/backpressure report (`regatta trace
//! summarize`).

pub mod chrome;
pub mod summary;

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

/// Lane id used for events recorded by the streaming driver (ingest +
/// merge), which runs on the calling thread rather than in a worker.
pub const DRIVER_LANE: usize = usize::MAX;

/// User-facing trace knobs, carried by
/// [`ExecConfig`](crate::exec::ExecConfig).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceOptions {
    /// Per-lane event capacity. Each worker (and the streaming driver)
    /// preallocates one buffer of this many records; events beyond it
    /// are dropped and counted, never grown.
    pub capacity: usize,
}

impl Default for TraceOptions {
    fn default() -> Self {
        TraceOptions { capacity: 1 << 20 }
    }
}

/// The cross-thread recipe for building per-worker sinks: the shared
/// clock epoch plus the buffer capacity. `Copy + Send` so the pool can
/// hand it to every worker thread; each worker builds its own
/// [`TraceSink`] from it, inside its own thread.
#[derive(Debug, Clone, Copy)]
pub struct TraceSpec {
    /// Shared monotonic epoch: every stamp is nanoseconds since this.
    pub epoch: Instant,
    /// Per-lane buffer capacity in records.
    pub capacity: usize,
}

impl TraceSpec {
    /// A spec whose epoch is "now".
    pub fn new(capacity: usize) -> TraceSpec {
        TraceSpec {
            epoch: Instant::now(),
            capacity,
        }
    }

    /// Spec from user-facing options.
    pub fn from_options(opts: TraceOptions) -> TraceSpec {
        TraceSpec::new(opts.capacity)
    }

    /// Build an enabled sink (one preallocated buffer) on the calling
    /// thread.
    pub fn sink(&self) -> TraceSink {
        TraceSink {
            inner: Some(Rc::new(SinkInner {
                epoch: self.epoch,
                buf: RefCell::new(TraceBuffer::new(self.capacity)),
            })),
        }
    }
}

/// One typed trace event. `Copy` and pointer-free: recording is a plain
/// store into a preallocated buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceEvent {
    /// One scheduler firing of node `node`, with the ensemble and item
    /// deltas of exactly that firing (0 ensembles = signal-only firing).
    Firing { node: u32, ensembles: u32, items: u32 },
    /// One shard executed to quiescence by this lane's worker.
    Shard { shard: u32, regions: u32, stolen: bool },
    /// Eager pipeline construction, before the timed region.
    Prewarm,
    /// Driver: shard cut by the ingest planner and pushed to the deques.
    Submit { shard: u32, regions: u32 },
    /// Driver: backpressure stall — the in-flight region budget was
    /// full, with `in_flight` regions outstanding when the stall began.
    Stall { in_flight: u32 },
    /// Driver: shard released in stream order by the merge ring.
    Emit { shard: u32, regions: u32 },
    /// A shard attempt failed (panic or error) on this lane's worker.
    /// `attempt` is 1-based; the span covers the failed execution.
    Fault { shard: u32, attempt: u32 },
    /// Recovery span: the worker rebuilt its pipeline and is about to
    /// re-run the shard as attempt `attempt` (2-based: the first retry
    /// is attempt 2).
    Retry { shard: u32, attempt: u32 },
    /// A single-region attempt failed during part-granular narrowing or
    /// part-level quarantine: `part` is the in-shard region ordinal,
    /// `attempt` is the shard-global 1-based attempt counter. The span
    /// covers the failed single-region execution.
    PartFault { shard: u32, part: u32, attempt: u32 },
    /// Part-granular recovery span: the worker rebuilt its pipeline to
    /// re-run exactly one region (`part` of `shard`) as attempt
    /// `attempt`.
    PartRetry { shard: u32, part: u32, attempt: u32 },
}

/// A stamped event: `[t0_ns, t1_ns]` nanoseconds since the shared
/// epoch. Instantaneous events carry `t0_ns == t1_ns`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceRecord {
    /// Span start, nanoseconds since the shared epoch.
    pub t0_ns: u64,
    /// Span end, nanoseconds since the shared epoch.
    pub t1_ns: u64,
    /// What happened.
    pub event: TraceEvent,
}

/// Fixed-capacity event buffer: preallocated up front, drop-and-count
/// when full, never grown. This is what keeps the traced hot path
/// allocation-free and memory bounded on arbitrarily long runs.
#[derive(Debug)]
pub struct TraceBuffer {
    records: Vec<TraceRecord>,
    capacity: usize,
    dropped: u64,
}

impl TraceBuffer {
    /// Preallocate space for `capacity` records.
    pub fn new(capacity: usize) -> TraceBuffer {
        TraceBuffer {
            records: Vec::with_capacity(capacity),
            capacity,
            dropped: 0,
        }
    }

    /// Record one event, or count it as dropped if the buffer is full.
    /// Never allocates: `records` was reserved to `capacity` in `new`.
    #[inline]
    pub fn push(&mut self, rec: TraceRecord) {
        if self.records.len() < self.capacity {
            self.records.push(rec);
        } else {
            self.dropped += 1;
        }
    }

    /// Recorded events.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// Whether nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Events dropped because the buffer was full.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// The recorded events, in push order.
    pub fn records(&self) -> &[TraceRecord] {
        &self.records
    }
}

#[derive(Debug)]
struct SinkInner {
    epoch: Instant,
    buf: RefCell<TraceBuffer>,
}

/// The recording handle threaded through scheduler, pool and driver.
/// Disabled (the default) it is a `None` and every call is a single
/// predictable branch; enabled it stamps against the shared epoch and
/// stores into the lane's preallocated buffer. `Rc`-based and
/// thread-confined, like the coordinator it instruments.
#[derive(Debug, Clone, Default)]
pub struct TraceSink {
    inner: Option<Rc<SinkInner>>,
}

impl TraceSink {
    /// The disabled sink (same as `Default`).
    pub fn disabled() -> TraceSink {
        TraceSink { inner: None }
    }

    /// Is this sink recording?
    #[inline]
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Nanoseconds since the shared epoch; 0 when disabled (callers
    /// gate on [`enabled`](TraceSink::enabled) before reading clocks).
    #[inline]
    pub fn now_ns(&self) -> u64 {
        match &self.inner {
            Some(inner) => inner.epoch.elapsed().as_nanos() as u64,
            None => 0,
        }
    }

    /// Record one stamped event; no-op when disabled.
    #[inline]
    pub fn record(&self, t0_ns: u64, t1_ns: u64, event: TraceEvent) {
        if let Some(inner) = &self.inner {
            inner.buf.borrow_mut().push(TraceRecord { t0_ns, t1_ns, event });
        }
    }

    /// Drain this lane's buffer: `(records, dropped)`. Leaves the sink
    /// enabled but empty.
    pub fn take(&self) -> (Vec<TraceRecord>, u64) {
        match &self.inner {
            Some(inner) => {
                let mut buf = inner.buf.borrow_mut();
                (std::mem::take(&mut buf.records), buf.dropped)
            }
            None => (Vec::new(), 0),
        }
    }
}

/// One lane's drained events: a worker's, or the streaming driver's
/// ([`DRIVER_LANE`]).
#[derive(Debug, Clone)]
pub struct WorkerTrace {
    /// Worker id, or [`DRIVER_LANE`] for the ingest/merge driver.
    pub worker: usize,
    /// Events in the order the lane recorded them.
    pub records: Vec<TraceRecord>,
    /// Events this lane dropped because its buffer was full.
    pub dropped: u64,
}

/// The folded post-run trace: every lane's events plus the node table
/// (name, ensemble width) that firing events index into. Attached to
/// [`ExecReport`](crate::exec::ExecReport) when tracing is on.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    /// Per-lane events, workers sorted by id, driver lane last.
    pub workers: Vec<WorkerTrace>,
    /// `(name, width)` per pipeline node, indexed by
    /// [`TraceEvent::Firing::node`].
    pub nodes: Vec<(String, usize)>,
}

impl Trace {
    /// Total recorded events across all lanes.
    pub fn events(&self) -> usize {
        self.workers.iter().map(|w| w.records.len()).sum()
    }

    /// Total dropped events across all lanes.
    pub fn dropped(&self) -> u64 {
        self.workers.iter().map(|w| w.dropped).sum()
    }

    fn fold<F: Fn(&TraceEvent) -> u64>(&self, f: F) -> u64 {
        self.workers
            .iter()
            .flat_map(|w| w.records.iter())
            .map(|r| f(&r.event))
            .sum()
    }

    /// Recorded firing events (== scheduler firings when nothing was
    /// dropped).
    pub fn firings(&self) -> u64 {
        self.fold(|e| matches!(e, TraceEvent::Firing { .. }) as u64)
    }

    /// Sum of per-firing ensemble deltas.
    pub fn ensembles(&self) -> u64 {
        self.fold(|e| match e {
            TraceEvent::Firing { ensembles, .. } => *ensembles as u64,
            _ => 0,
        })
    }

    /// Sum of per-firing item deltas.
    pub fn items(&self) -> u64 {
        self.fold(|e| match e {
            TraceEvent::Firing { items, .. } => *items as u64,
            _ => 0,
        })
    }

    /// Recorded shard-execution spans.
    pub fn shards(&self) -> u64 {
        self.fold(|e| matches!(e, TraceEvent::Shard { .. }) as u64)
    }

    /// Shard spans tagged as stolen.
    pub fn stolen_shards(&self) -> u64 {
        self.fold(|e| matches!(e, TraceEvent::Shard { stolen: true, .. }) as u64)
    }

    /// Driver submissions (streaming runs only).
    pub fn submits(&self) -> u64 {
        self.fold(|e| matches!(e, TraceEvent::Submit { .. }) as u64)
    }

    /// Driver in-order emissions (streaming runs only).
    pub fn emits(&self) -> u64 {
        self.fold(|e| matches!(e, TraceEvent::Emit { .. }) as u64)
    }

    /// Driver backpressure stalls (streaming runs only).
    pub fn stalls(&self) -> u64 {
        self.fold(|e| matches!(e, TraceEvent::Stall { .. }) as u64)
    }

    /// Failed attempts at either granularity: whole-shard
    /// ([`TraceEvent::Fault`]) plus single-region
    /// ([`TraceEvent::PartFault`]) failures caught by the pool.
    pub fn faults(&self) -> u64 {
        self.fold(|e| {
            matches!(e, TraceEvent::Fault { .. } | TraceEvent::PartFault { .. }) as u64
        })
    }

    /// Recovery spans at either granularity: pipeline rebuilds that
    /// preceded a re-run ([`TraceEvent::Retry`] and
    /// [`TraceEvent::PartRetry`]). With zero drops this equals the
    /// report's `retries` total ([`ExecReport`](crate::exec::ExecReport))
    /// on a run that recovered every fault.
    pub fn retries(&self) -> u64 {
        self.fold(|e| {
            matches!(e, TraceEvent::Retry { .. } | TraceEvent::PartRetry { .. }) as u64
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffer_drops_and_counts_past_capacity() {
        let mut buf = TraceBuffer::new(2);
        let rec = |t| TraceRecord {
            t0_ns: t,
            t1_ns: t,
            event: TraceEvent::Prewarm,
        };
        buf.push(rec(1));
        buf.push(rec(2));
        buf.push(rec(3));
        buf.push(rec(4));
        assert_eq!(buf.len(), 2);
        assert_eq!(buf.dropped(), 2);
        assert_eq!(buf.records()[1].t0_ns, 2);
    }

    #[test]
    #[cfg(feature = "count-allocs")]
    fn buffer_push_never_allocates() {
        use crate::util::alloc_count;
        let mut buf = TraceBuffer::new(1024);
        let before = alloc_count::thread_allocations();
        for t in 0..2048u64 {
            buf.push(TraceRecord {
                t0_ns: t,
                t1_ns: t + 1,
                event: TraceEvent::Firing {
                    node: 0,
                    ensembles: 1,
                    items: 8,
                },
            });
        }
        let delta = alloc_count::thread_allocations() - before;
        assert_eq!(delta, 0, "TraceBuffer::push allocated {delta} times");
        assert_eq!(buf.len(), 1024);
        assert_eq!(buf.dropped(), 1024);
    }

    #[test]
    fn disabled_sink_is_inert() {
        let sink = TraceSink::default();
        assert!(!sink.enabled());
        assert_eq!(sink.now_ns(), 0);
        sink.record(0, 1, TraceEvent::Prewarm);
        let (records, dropped) = sink.take();
        assert!(records.is_empty());
        assert_eq!(dropped, 0);
    }

    #[test]
    fn sink_records_against_shared_epoch() {
        let spec = TraceSpec::new(16);
        let sink = spec.sink();
        assert!(sink.enabled());
        let t0 = sink.now_ns();
        let t1 = sink.now_ns();
        assert!(t1 >= t0, "shared-epoch clock must be monotonic");
        sink.record(
            t0,
            t1,
            TraceEvent::Shard {
                shard: 3,
                regions: 7,
                stolen: true,
            },
        );
        let (records, dropped) = sink.take();
        assert_eq!(records.len(), 1);
        assert_eq!(dropped, 0);
        assert_eq!(
            records[0].event,
            TraceEvent::Shard {
                shard: 3,
                regions: 7,
                stolen: true
            }
        );
        // take drains but keeps recording
        sink.record(t1, t1, TraceEvent::Prewarm);
        assert_eq!(sink.take().0.len(), 1);
    }

    #[test]
    fn trace_totals_fold_all_lanes() {
        let rec = |event| TraceRecord {
            t0_ns: 0,
            t1_ns: 1,
            event,
        };
        let trace = Trace {
            workers: vec![
                WorkerTrace {
                    worker: 0,
                    records: vec![
                        rec(TraceEvent::Firing {
                            node: 0,
                            ensembles: 2,
                            items: 13,
                        }),
                        rec(TraceEvent::Firing {
                            node: 1,
                            ensembles: 0,
                            items: 0,
                        }),
                        rec(TraceEvent::Shard {
                            shard: 0,
                            regions: 4,
                            stolen: false,
                        }),
                    ],
                    dropped: 1,
                },
                WorkerTrace {
                    worker: 1,
                    records: vec![
                        rec(TraceEvent::Fault { shard: 2, attempt: 1 }),
                        rec(TraceEvent::Retry { shard: 2, attempt: 2 }),
                        rec(TraceEvent::PartFault {
                            shard: 2,
                            part: 1,
                            attempt: 2,
                        }),
                        rec(TraceEvent::PartRetry {
                            shard: 2,
                            part: 1,
                            attempt: 3,
                        }),
                        rec(TraceEvent::Shard {
                            shard: 2,
                            regions: 3,
                            stolen: true,
                        }),
                    ],
                    dropped: 0,
                },
                WorkerTrace {
                    worker: DRIVER_LANE,
                    records: vec![
                        rec(TraceEvent::Submit {
                            shard: 0,
                            regions: 4,
                        }),
                        rec(TraceEvent::Stall { in_flight: 4 }),
                        rec(TraceEvent::Emit {
                            shard: 0,
                            regions: 4,
                        }),
                    ],
                    dropped: 0,
                },
            ],
            nodes: vec![("enum".into(), 8), ("sum".into(), 8)],
        };
        assert_eq!(trace.events(), 11);
        assert_eq!(trace.dropped(), 1);
        assert_eq!(trace.firings(), 2);
        assert_eq!(trace.ensembles(), 2);
        assert_eq!(trace.items(), 13);
        assert_eq!(trace.shards(), 2);
        assert_eq!(trace.stolen_shards(), 1);
        assert_eq!(trace.submits(), 1);
        assert_eq!(trace.emits(), 1);
        assert_eq!(trace.stalls(), 1);
        assert_eq!(trace.faults(), 2, "Fault + PartFault both count");
        assert_eq!(trace.retries(), 2, "Retry + PartRetry both count");
    }
}
