//! `regatta trace summarize` — windowed occupancy timeline, straggler
//! table and steal/backpressure report from a Chrome trace artifact.
//!
//! Reads the JSON back with the vendored [`crate::util::json`] parser
//! (no external deps), so the exporter and this reader pin each other:
//! anything [`chrome::to_chrome_json`](super::chrome::to_chrome_json)
//! writes must round-trip here. The occupancy timeline buckets the run's
//! wall-clock span and reports, per node, the item-weighted SIMD
//! occupancy of the firings that *started* in each bucket — the
//! time-resolved version of
//! [`NodeMetrics::occupancy`](crate::coordinator::metrics::NodeMetrics).

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One parsed firing event.
struct FiringEv {
    node: usize,
    ts: f64,
    ensembles: f64,
    items: f64,
}

/// One parsed shard-execution span.
struct ShardEv {
    shard: usize,
    worker: usize,
    dur: f64,
    regions: usize,
    stolen: bool,
}

fn arg_f64(e: &Json, key: &str) -> f64 {
    e.get("args")
        .and_then(|a| a.get(key))
        .and_then(Json::as_f64)
        .unwrap_or(0.0)
}

fn top_f64(e: &Json, key: &str) -> f64 {
    e.get(key).and_then(Json::as_f64).unwrap_or(0.0)
}

/// Summarize a Chrome trace artifact (as produced by `--trace`) into a
/// text report: run totals, a per-node occupancy timeline over
/// `buckets` equal time windows, the longest shard executions, and the
/// steal/backpressure picture.
pub fn summarize(text: &str, buckets: usize) -> Result<String> {
    let json = Json::parse(text).context("parsing trace JSON")?;
    let events = json
        .get("traceEvents")
        .and_then(Json::as_arr)
        .context("trace JSON has no traceEvents array")?;
    if events.is_empty() {
        bail!("trace contains no events");
    }
    let meta = json.get("regatta");
    let nodes: Vec<(String, usize)> = meta
        .and_then(|m| m.get("nodes"))
        .and_then(Json::as_arr)
        .map(|arr| {
            arr.iter()
                .filter_map(|n| {
                    Some((
                        n.get("name")?.as_str()?.to_string(),
                        n.get("width")?.as_usize()?,
                    ))
                })
                .collect()
        })
        .unwrap_or_default();

    let mut firings: Vec<FiringEv> = Vec::new();
    let mut shards: Vec<ShardEv> = Vec::new();
    let mut lanes = 0usize;
    let mut stall_count = 0usize;
    let mut stall_us = 0.0f64;
    let mut prewarm_count = 0usize;
    let mut prewarm_us = 0.0f64;
    // submit/emit span timestamps by shard id, for the latency section
    let mut submit_ts: Vec<(usize, f64)> = Vec::new();
    let mut emit_ts: Vec<(usize, f64)> = Vec::new();
    let mut fault_count = 0usize;
    let mut retry_count = 0usize;
    let mut retry_us = 0.0f64;
    let mut span_lo = f64::INFINITY;
    let mut span_hi = f64::NEG_INFINITY;
    for e in events {
        let ph = e.get("ph").and_then(Json::as_str).unwrap_or("");
        if ph == "M" && e.get("name").and_then(Json::as_str) == Some("thread_name") {
            lanes += 1;
        }
        if ph != "X" {
            continue;
        }
        let ts = top_f64(e, "ts");
        let dur = top_f64(e, "dur");
        span_lo = span_lo.min(ts);
        span_hi = span_hi.max(ts + dur);
        match e.get("cat").and_then(Json::as_str).unwrap_or("") {
            "firing" => firings.push(FiringEv {
                node: arg_f64(e, "node") as usize,
                ts,
                ensembles: arg_f64(e, "ensembles"),
                items: arg_f64(e, "items"),
            }),
            "shard" => shards.push(ShardEv {
                shard: arg_f64(e, "shard") as usize,
                worker: (e.get("tid").and_then(Json::as_usize).unwrap_or(1)).saturating_sub(1),
                dur,
                regions: arg_f64(e, "regions") as usize,
                stolen: e.get("args").and_then(|a| a.get("stolen")) == Some(&Json::Bool(true)),
            }),
            "ingest" => {
                if e.get("name").and_then(Json::as_str) == Some("stall") {
                    stall_count += 1;
                    stall_us += dur;
                } else {
                    submit_ts.push((arg_f64(e, "shard") as usize, ts));
                }
            }
            "merge" => emit_ts.push((arg_f64(e, "shard") as usize, ts)),
            "fault" => {
                if e.get("name")
                    .and_then(Json::as_str)
                    .is_some_and(|n| n.starts_with("retry"))
                {
                    retry_count += 1;
                    retry_us += dur;
                } else {
                    fault_count += 1;
                }
            }
            "prewarm" => {
                prewarm_count += 1;
                prewarm_us += dur;
            }
            _ => {}
        }
    }
    if !span_hi.is_finite() {
        bail!("trace contains no spans (ph \"X\" events)");
    }
    let span_us = (span_hi - span_lo).max(1e-9);
    let dropped = meta
        .and_then(|m| m.get("dropped"))
        .and_then(Json::as_usize)
        .unwrap_or(0);

    let mut out = String::new();
    out.push_str("== trace summary ==\n");
    out.push_str(&format!(
        "events {}, lanes {}, span {:.3} ms, dropped {}\n",
        events.len(),
        lanes,
        span_us / 1000.0,
        dropped
    ));
    let total_ens: f64 = firings.iter().map(|f| f.ensembles).sum();
    let total_items: f64 = firings.iter().map(|f| f.items).sum();
    out.push_str(&format!(
        "firings {} (ensembles {}, items {}), shards {} ({} stolen), prewarm {} ({:.3} ms)\n",
        firings.len(),
        total_ens as u64,
        total_items as u64,
        shards.len(),
        shards.iter().filter(|s| s.stolen).count(),
        prewarm_count,
        prewarm_us / 1000.0
    ));

    // -- per-node occupancy over time buckets --
    let buckets = buckets.clamp(1, 120);
    out.push_str(&format!(
        "\n== occupancy% by node over {} buckets of {:.3} ms ==\n",
        buckets,
        span_us / buckets as f64 / 1000.0
    ));
    if firings.is_empty() {
        out.push_str("(no firing events in trace)\n");
    } else {
        // acc[node][bucket] = (sum items, sum ensembles)
        let nnodes = nodes
            .len()
            .max(firings.iter().map(|f| f.node + 1).max().unwrap_or(0));
        let mut acc = vec![vec![(0.0f64, 0.0f64); buckets]; nnodes];
        for f in &firings {
            let b = (((f.ts - span_lo) / span_us) * buckets as f64) as usize;
            let cell = &mut acc[f.node][b.min(buckets - 1)];
            cell.0 += f.items;
            cell.1 += f.ensembles;
        }
        for (ni, row) in acc.iter().enumerate() {
            let (name, width) = nodes
                .get(ni)
                .map(|(n, w)| (n.as_str(), *w))
                .unwrap_or(("?", 0));
            let mut line = format!("{name:<12} w{width:<4} |");
            for &(items, ens) in row {
                if ens > 0.0 && width > 0 {
                    let occ = 100.0 * items / (ens * width as f64);
                    line.push_str(&format!(" {occ:>5.1}"));
                } else {
                    line.push_str("     -");
                }
            }
            line.push('\n');
            out.push_str(&line);
        }
    }

    // -- straggler table --
    out.push_str("\n== straggler shards (longest executions) ==\n");
    if shards.is_empty() {
        out.push_str("(no shard events in trace)\n");
    } else {
        out.push_str("shard    worker   stolen   regions       ms\n");
        let mut by_dur: Vec<&ShardEv> = shards.iter().collect();
        by_dur.sort_by(|a, b| b.dur.total_cmp(&a.dur));
        for s in by_dur.iter().take(8) {
            out.push_str(&format!(
                "{:<8} {:<8} {:<8} {:>7}  {:>7.3}\n",
                s.shard,
                s.worker,
                if s.stolen { "yes" } else { "no" },
                s.regions,
                s.dur / 1000.0
            ));
        }
    }

    // -- submit → emit latency, re-derived from the driver-lane spans --
    // The same quantity the live metrics' e2e histogram measures
    // per region ([`crate::metrics::LaneMetrics::e2e`]), here recomputed
    // per shard offline from the artifact alone; the `metrics_observe`
    // suite cross-checks the two against each other on a real run.
    out.push_str("\n== latency (ingest submit -> in-order emit) ==\n");
    let emit_by_shard: std::collections::HashMap<usize, f64> =
        emit_ts.iter().copied().collect();
    let mut lat_us: Vec<f64> = submit_ts
        .iter()
        .filter_map(|&(shard, t)| emit_by_shard.get(&shard).map(|&e| (e - t).max(0.0)))
        .collect();
    if lat_us.is_empty() {
        out.push_str(
            "(no submit/emit span pairs — materialized run, or a trace \
             without the driver lane)\n",
        );
    } else {
        lat_us.sort_by(f64::total_cmp);
        let q = |f: f64| {
            let idx = (f * (lat_us.len() - 1) as f64).round() as usize;
            lat_us[idx.min(lat_us.len() - 1)]
        };
        out.push_str(&format!(
            "paired {} of {} submitted shards\n",
            lat_us.len(),
            submit_ts.len()
        ));
        out.push_str(&format!(
            "per-shard p50 {:.3} ms   p99 {:.3} ms   max {:.3} ms\n",
            q(0.5) / 1000.0,
            q(0.99) / 1000.0,
            lat_us[lat_us.len() - 1] / 1000.0
        ));
    }

    // -- steal / backpressure --
    let stolen = shards.iter().filter(|s| s.stolen).count();
    out.push_str("\n== steal / backpressure ==\n");
    out.push_str(&format!(
        "stolen shards: {} of {} ({:.1}%)\n",
        stolen,
        shards.len(),
        100.0 * stolen as f64 / shards.len().max(1) as f64
    ));
    out.push_str(&format!(
        "backpressure stalls: {} totaling {:.3} ms\n",
        stall_count,
        stall_us / 1000.0
    ));
    out.push_str(&format!(
        "ingest submits {}, merge emits {}\n",
        submit_ts.len(),
        emit_ts.len()
    ));
    if fault_count > 0 || retry_count > 0 {
        out.push_str(&format!(
            "faults: {fault_count} shard attempt(s) failed, {retry_count} retried \
             ({:.3} ms rebuilding)\n",
            retry_us / 1000.0
        ));
    }
    out.push_str(&format!("dropped events: {dropped}\n"));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::chrome::to_chrome_json;
    use crate::trace::{Trace, TraceEvent, TraceRecord, WorkerTrace, DRIVER_LANE};

    fn sample_trace() -> Trace {
        let rec = |t0: u64, t1: u64, event| TraceRecord {
            t0_ns: t0,
            t1_ns: t1,
            event,
        };
        let firing = |t0: u64, node: u32, ensembles: u32, items: u32| {
            rec(
                t0,
                t0 + 400,
                TraceEvent::Firing {
                    node,
                    ensembles,
                    items,
                },
            )
        };
        Trace {
            workers: vec![
                WorkerTrace {
                    worker: 0,
                    records: vec![
                        rec(0, 900, TraceEvent::Prewarm),
                        firing(1_000, 0, 1, 8),
                        firing(2_000, 1, 2, 9),
                        firing(9_000, 1, 1, 4),
                        rec(
                            1_000,
                            10_000,
                            TraceEvent::Shard {
                                shard: 0,
                                regions: 3,
                                stolen: false,
                            },
                        ),
                    ],
                    dropped: 0,
                },
                WorkerTrace {
                    worker: 1,
                    records: vec![
                        firing(3_000, 0, 1, 2),
                        rec(
                            3_000,
                            5_000,
                            TraceEvent::Shard {
                                shard: 1,
                                regions: 1,
                                stolen: true,
                            },
                        ),
                    ],
                    dropped: 0,
                },
                WorkerTrace {
                    worker: DRIVER_LANE,
                    records: vec![
                        rec(
                            500,
                            500,
                            TraceEvent::Submit {
                                shard: 0,
                                regions: 3,
                            },
                        ),
                        rec(600, 800, TraceEvent::Stall { in_flight: 3 }),
                        rec(
                            10_100,
                            10_100,
                            TraceEvent::Emit {
                                shard: 0,
                                regions: 3,
                            },
                        ),
                    ],
                    dropped: 0,
                },
            ],
            nodes: vec![("enum".into(), 8), ("sum".into(), 8)],
        }
    }

    #[test]
    fn summarize_roundtrips_the_chrome_artifact() {
        let text = to_chrome_json(&sample_trace());
        let report = summarize(&text, 4).unwrap();
        assert!(report.contains("firings 4"), "{report}");
        assert!(report.contains("shards 2 (1 stolen)"), "{report}");
        assert!(report.contains("enum"), "{report}");
        assert!(report.contains("sum"), "{report}");
        assert!(report.contains("straggler"), "{report}");
        assert!(report.contains("backpressure stalls: 1"), "{report}");
        assert!(report.contains("ingest submits 1, merge emits 1"), "{report}");
        assert!(report.contains("dropped events: 0"), "{report}");
    }

    #[test]
    fn straggler_table_ranks_by_duration() {
        let text = to_chrome_json(&sample_trace());
        let report = summarize(&text, 2).unwrap();
        let straggler_at = report.find("straggler").unwrap();
        let shard0_at = report[straggler_at..].find("\n0 ").map(|i| i + straggler_at);
        let shard1_at = report[straggler_at..].find("\n1 ").map(|i| i + straggler_at);
        let (s0, s1) = (shard0_at.unwrap(), shard1_at.unwrap());
        assert!(s0 < s1, "longest shard (0, 9ms) must rank above shard 1 (2ms)");
    }

    #[test]
    fn fault_line_appears_only_when_faults_happened() {
        // fault-free: no recovery line
        let clean = summarize(&to_chrome_json(&sample_trace()), 2).unwrap();
        assert!(!clean.contains("faults:"), "{clean}");

        let mut trace = sample_trace();
        trace.workers[1].records.push(TraceRecord {
            t0_ns: 6_000,
            t1_ns: 6_100,
            event: TraceEvent::Fault { shard: 1, attempt: 1 },
        });
        trace.workers[1].records.push(TraceRecord {
            t0_ns: 6_100,
            t1_ns: 7_100,
            event: TraceEvent::Retry { shard: 1, attempt: 2 },
        });
        let report = summarize(&to_chrome_json(&trace), 2).unwrap();
        assert!(
            report.contains("faults: 1 shard attempt(s) failed, 1 retried"),
            "{report}"
        );
        assert!(report.contains("(0.001 ms rebuilding)"), "{report}");
    }

    #[test]
    fn latency_section_pairs_submit_and_emit_spans() {
        // sample trace: submit shard 0 @ 500 ns, emit shard 0 @ 10_100 ns
        // → one pair of 9.6 µs ≈ 0.010 ms at the report's precision
        let report = summarize(&to_chrome_json(&sample_trace()), 2).unwrap();
        assert!(report.contains("paired 1 of 1 submitted shards"), "{report}");
        assert!(
            report.contains("per-shard p50 0.010 ms   p99 0.010 ms   max 0.010 ms"),
            "{report}"
        );
    }

    #[test]
    fn latency_section_degrades_without_driver_spans() {
        let mut trace = sample_trace();
        trace.workers.retain(|w| w.worker != DRIVER_LANE);
        let report = summarize(&to_chrome_json(&trace), 2).unwrap();
        assert!(report.contains("no submit/emit span pairs"), "{report}");
    }

    #[test]
    fn rejects_non_trace_json() {
        assert!(summarize("{\"not\": \"a trace\"}", 4).is_err());
        assert!(summarize("{\"traceEvents\": []}", 4).is_err());
        assert!(summarize("not json", 4).is_err());
    }

    #[test]
    fn bucket_count_is_clamped() {
        let text = to_chrome_json(&sample_trace());
        assert!(summarize(&text, 0).is_ok());
        assert!(summarize(&text, 10_000).is_ok());
    }
}
