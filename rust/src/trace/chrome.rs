//! Chrome trace-event JSON export.
//!
//! Renders a folded [`Trace`] in the Trace Event Format understood by
//! Perfetto (<https://ui.perfetto.dev>) and `chrome://tracing`: one
//! track per lane (`tid 0` = streaming driver, `tid n+1` = worker `n`),
//! complete events (`"ph": "X"`, microsecond `ts`/`dur`) for firings,
//! shard executions, prewarm and stalls, plus counter tracks
//! (`"ph": "C"`) for per-worker occupancy and the driver's in-flight
//! region budget.
//!
//! Alongside the standard `traceEvents` array the artifact carries a
//! `"regatta"` object with the folded totals (firings, ensembles,
//! items, shards, drops) and the node table — that object is what CI
//! and `trace summarize` reconcile against `NodeMetrics`, and what the
//! tests parse back with the vendored [`crate::util::json`] reader (the
//! writer therefore emits pure ASCII).

use super::{Trace, TraceEvent, DRIVER_LANE};

/// Escape a string for a JSON literal, staying ASCII-only so the
/// vendored byte-wise parser round-trips it exactly.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) > 0xFFFF => out.push_str("\\ufffd"),
            c if (c as u32) < 0x20 || !c.is_ascii() => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Chrome thread id for a lane: driver first, then workers in order.
fn lane_tid(worker: usize) -> usize {
    if worker == DRIVER_LANE {
        0
    } else {
        worker + 1
    }
}

/// Human name for a lane's track.
fn lane_name(worker: usize) -> String {
    if worker == DRIVER_LANE {
        "driver (ingest+merge)".to_string()
    } else {
        format!("worker {worker}")
    }
}

/// Render the folded trace as a Chrome trace-event JSON document.
pub fn to_chrome_json(trace: &Trace) -> String {
    let mut ev: Vec<String> = Vec::new();
    ev.push(
        "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\"tid\":0,\
         \"args\":{\"name\":\"regatta\"}}"
            .to_string(),
    );
    for lane in &trace.workers {
        let tid = lane_tid(lane.worker);
        let name = esc(&lane_name(lane.worker));
        ev.push(format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\
             \"args\":{{\"name\":\"{name}\"}}}}"
        ));
    }

    for lane in &trace.workers {
        let tid = lane_tid(lane.worker);
        // running in-flight region count, driven by this lane's
        // Submit/Emit events (only the driver lane records those)
        let mut in_flight: i64 = 0;
        for rec in &lane.records {
            let ts = rec.t0_ns as f64 / 1000.0;
            let dur = rec.t1_ns.saturating_sub(rec.t0_ns) as f64 / 1000.0;
            match rec.event {
                TraceEvent::Firing {
                    node,
                    ensembles,
                    items,
                } => {
                    let (name, width) = trace
                        .nodes
                        .get(node as usize)
                        .map(|(n, w)| (n.as_str(), *w))
                        .unwrap_or(("node", 0));
                    let name = esc(name);
                    ev.push(format!(
                        "{{\"name\":\"fire {name}\",\"cat\":\"firing\",\"ph\":\"X\",\
                         \"pid\":1,\"tid\":{tid},\"ts\":{ts:.3},\"dur\":{dur:.3},\
                         \"args\":{{\"node\":{node},\"ensembles\":{ensembles},\
                         \"items\":{items}}}}}"
                    ));
                    if ensembles > 0 && width > 0 {
                        let occ = 100.0 * items as f64 / (ensembles as f64 * width as f64);
                        let w = lane.worker;
                        ev.push(format!(
                            "{{\"name\":\"occupancy w{w}\",\"ph\":\"C\",\"pid\":1,\
                             \"tid\":{tid},\"ts\":{ts:.3},\"args\":{{\"occ\":{occ:.2}}}}}"
                        ));
                    }
                }
                TraceEvent::Shard {
                    shard,
                    regions,
                    stolen,
                } => {
                    ev.push(format!(
                        "{{\"name\":\"shard {shard}\",\"cat\":\"shard\",\"ph\":\"X\",\
                         \"pid\":1,\"tid\":{tid},\"ts\":{ts:.3},\"dur\":{dur:.3},\
                         \"args\":{{\"shard\":{shard},\"regions\":{regions},\
                         \"stolen\":{stolen}}}}}"
                    ));
                }
                TraceEvent::Prewarm => {
                    ev.push(format!(
                        "{{\"name\":\"prewarm\",\"cat\":\"prewarm\",\"ph\":\"X\",\
                         \"pid\":1,\"tid\":{tid},\"ts\":{ts:.3},\"dur\":{dur:.3},\
                         \"args\":{{}}}}"
                    ));
                }
                TraceEvent::Submit { shard, regions } => {
                    in_flight += regions as i64;
                    ev.push(format!(
                        "{{\"name\":\"submit {shard}\",\"cat\":\"ingest\",\"ph\":\"X\",\
                         \"pid\":1,\"tid\":{tid},\"ts\":{ts:.3},\"dur\":{dur:.3},\
                         \"args\":{{\"shard\":{shard},\"regions\":{regions}}}}}"
                    ));
                    ev.push(format!(
                        "{{\"name\":\"in-flight regions\",\"ph\":\"C\",\"pid\":1,\
                         \"tid\":{tid},\"ts\":{ts:.3},\"args\":{{\"regions\":{in_flight}}}}}"
                    ));
                }
                TraceEvent::Stall { in_flight: held } => {
                    ev.push(format!(
                        "{{\"name\":\"stall\",\"cat\":\"ingest\",\"ph\":\"X\",\
                         \"pid\":1,\"tid\":{tid},\"ts\":{ts:.3},\"dur\":{dur:.3},\
                         \"args\":{{\"in_flight\":{held}}}}}"
                    ));
                }
                TraceEvent::Emit { shard, regions } => {
                    in_flight -= regions as i64;
                    ev.push(format!(
                        "{{\"name\":\"emit {shard}\",\"cat\":\"merge\",\"ph\":\"X\",\
                         \"pid\":1,\"tid\":{tid},\"ts\":{ts:.3},\"dur\":{dur:.3},\
                         \"args\":{{\"shard\":{shard},\"regions\":{regions}}}}}"
                    ));
                    ev.push(format!(
                        "{{\"name\":\"in-flight regions\",\"ph\":\"C\",\"pid\":1,\
                         \"tid\":{tid},\"ts\":{ts:.3},\"args\":{{\"regions\":{in_flight}}}}}"
                    ));
                }
                TraceEvent::Fault { shard, attempt } => {
                    ev.push(format!(
                        "{{\"name\":\"fault {shard}\",\"cat\":\"fault\",\"ph\":\"X\",\
                         \"pid\":1,\"tid\":{tid},\"ts\":{ts:.3},\"dur\":{dur:.3},\
                         \"args\":{{\"shard\":{shard},\"attempt\":{attempt}}}}}"
                    ));
                }
                TraceEvent::Retry { shard, attempt } => {
                    ev.push(format!(
                        "{{\"name\":\"retry {shard}\",\"cat\":\"fault\",\"ph\":\"X\",\
                         \"pid\":1,\"tid\":{tid},\"ts\":{ts:.3},\"dur\":{dur:.3},\
                         \"args\":{{\"shard\":{shard},\"attempt\":{attempt}}}}}"
                    ));
                }
                TraceEvent::PartFault {
                    shard,
                    part,
                    attempt,
                } => {
                    ev.push(format!(
                        "{{\"name\":\"fault {shard}.{part}\",\"cat\":\"fault\",\"ph\":\"X\",\
                         \"pid\":1,\"tid\":{tid},\"ts\":{ts:.3},\"dur\":{dur:.3},\
                         \"args\":{{\"shard\":{shard},\"part\":{part},\
                         \"attempt\":{attempt}}}}}"
                    ));
                }
                TraceEvent::PartRetry {
                    shard,
                    part,
                    attempt,
                } => {
                    ev.push(format!(
                        "{{\"name\":\"retry {shard}.{part}\",\"cat\":\"fault\",\"ph\":\"X\",\
                         \"pid\":1,\"tid\":{tid},\"ts\":{ts:.3},\"dur\":{dur:.3},\
                         \"args\":{{\"shard\":{shard},\"part\":{part},\
                         \"attempt\":{attempt}}}}}"
                    ));
                }
            }
        }
    }

    let nodes = trace
        .nodes
        .iter()
        .map(|(name, width)| format!("{{\"name\":\"{}\",\"width\":{width}}}", esc(name)))
        .collect::<Vec<_>>()
        .join(",");
    let mut out = String::new();
    out.push_str("{\"traceEvents\":[\n");
    out.push_str(&ev.join(",\n"));
    out.push_str("\n],\n\"regatta\":{");
    out.push_str(&format!(
        "\"firings\":{},\"ensembles\":{},\"items\":{},\"shards\":{},\
         \"stolen\":{},\"submits\":{},\"emits\":{},\"stalls\":{},\
         \"faults\":{},\"retries\":{},\
         \"events\":{},\"dropped\":{},\"lanes\":{},\"nodes\":[{}]",
        trace.firings(),
        trace.ensembles(),
        trace.items(),
        trace.shards(),
        trace.stolen_shards(),
        trace.submits(),
        trace.emits(),
        trace.stalls(),
        trace.faults(),
        trace.retries(),
        trace.events(),
        trace.dropped(),
        trace.workers.len(),
        nodes
    ));
    out.push_str("}}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{TraceRecord, WorkerTrace};
    use crate::util::json::Json;

    fn sample_trace() -> Trace {
        let rec = |t0: u64, t1: u64, event| TraceRecord {
            t0_ns: t0,
            t1_ns: t1,
            event,
        };
        Trace {
            workers: vec![
                WorkerTrace {
                    worker: 0,
                    records: vec![
                        rec(0, 500, TraceEvent::Prewarm),
                        rec(
                            1_000,
                            2_000,
                            TraceEvent::Firing {
                                node: 1,
                                ensembles: 2,
                                items: 12,
                            },
                        ),
                        rec(
                            1_000,
                            3_000,
                            TraceEvent::Shard {
                                shard: 0,
                                regions: 4,
                                stolen: true,
                            },
                        ),
                        rec(3_100, 3_200, TraceEvent::Fault { shard: 1, attempt: 1 }),
                        rec(3_200, 3_300, TraceEvent::Retry { shard: 1, attempt: 2 }),
                        rec(
                            3_300,
                            3_350,
                            TraceEvent::PartFault {
                                shard: 1,
                                part: 0,
                                attempt: 2,
                            },
                        ),
                        rec(
                            3_350,
                            3_400,
                            TraceEvent::PartRetry {
                                shard: 1,
                                part: 0,
                                attempt: 3,
                            },
                        ),
                    ],
                    dropped: 0,
                },
                WorkerTrace {
                    worker: DRIVER_LANE,
                    records: vec![
                        rec(
                            900,
                            900,
                            TraceEvent::Submit {
                                shard: 0,
                                regions: 4,
                            },
                        ),
                        rec(950, 980, TraceEvent::Stall { in_flight: 4 }),
                        rec(
                            3_100,
                            3_100,
                            TraceEvent::Emit {
                                shard: 0,
                                regions: 4,
                            },
                        ),
                    ],
                    dropped: 2,
                },
            ],
            nodes: vec![("enum".into(), 8), ("sum".into(), 8)],
        }
    }

    #[test]
    fn emitted_json_parses_and_reconciles() {
        let trace = sample_trace();
        let text = to_chrome_json(&trace);
        let json = Json::parse(&text).expect("chrome JSON parses with the vendored reader");
        let events = json.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!events.is_empty());
        // every event is an object with the required phase field
        for e in events {
            assert!(e.get("ph").and_then(Json::as_str).is_some());
        }
        let meta = json.get("regatta").expect("totals object present");
        assert_eq!(meta.get("firings").unwrap().as_usize(), Some(1));
        assert_eq!(meta.get("ensembles").unwrap().as_usize(), Some(2));
        assert_eq!(meta.get("items").unwrap().as_usize(), Some(12));
        assert_eq!(meta.get("shards").unwrap().as_usize(), Some(1));
        assert_eq!(meta.get("stolen").unwrap().as_usize(), Some(1));
        assert_eq!(meta.get("faults").unwrap().as_usize(), Some(2), "Fault + PartFault");
        assert_eq!(meta.get("retries").unwrap().as_usize(), Some(2), "Retry + PartRetry");
        assert_eq!(meta.get("dropped").unwrap().as_usize(), Some(2));
        let nodes = meta.get("nodes").unwrap().as_arr().unwrap();
        assert_eq!(nodes.len(), 2);
        assert_eq!(nodes[1].get("name").unwrap().as_str(), Some("sum"));
        assert_eq!(nodes[1].get("width").unwrap().as_usize(), Some(8));
    }

    #[test]
    fn tracks_and_counters_are_present() {
        let text = to_chrome_json(&sample_trace());
        let json = Json::parse(&text).unwrap();
        let events = json.get("traceEvents").unwrap().as_arr().unwrap();
        let named = |name: &str| {
            events
                .iter()
                .filter(|e| e.get("name").and_then(Json::as_str) == Some(name))
                .count()
        };
        assert_eq!(named("thread_name"), 2);
        assert_eq!(named("occupancy w0"), 1);
        assert_eq!(named("in-flight regions"), 2, "one per submit/emit");
        assert_eq!(named("fire sum"), 1);
        assert_eq!(named("fault 1"), 1);
        assert_eq!(named("retry 1"), 1);
        assert_eq!(named("fault 1.0"), 1, "part fault names shard.part");
        assert_eq!(named("retry 1.0"), 1, "part retry names shard.part");
        // fault spans land on the failing worker's own track
        let fault = events
            .iter()
            .find(|e| e.get("cat").and_then(Json::as_str) == Some("fault"))
            .unwrap();
        assert_eq!(fault.get("tid").unwrap().as_usize(), Some(1));
        // the shard span is on worker 0's track (tid 1), stolen tagged
        let shard = events
            .iter()
            .find(|e| e.get("cat").and_then(Json::as_str) == Some("shard"))
            .unwrap();
        assert_eq!(shard.get("tid").unwrap().as_usize(), Some(1));
        assert_eq!(shard.get("args").unwrap().get("stolen"), Some(&Json::Bool(true)));
        // driver events land on tid 0
        let submit = events
            .iter()
            .find(|e| e.get("cat").and_then(Json::as_str) == Some("ingest"))
            .unwrap();
        assert_eq!(submit.get("tid").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn escapes_stay_ascii() {
        assert_eq!(esc("plain"), "plain");
        assert_eq!(esc("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(esc("tab\there"), "tab\\u0009here");
        assert_eq!(esc("π"), "\\u03c0");
    }
}
