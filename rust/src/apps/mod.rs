//! Benchmark applications from the paper's evaluation (§5).
//!
//! * [`sum`] — the region-sum microbenchmark behind Figs 6/7: enumerate
//!   each region of an integer stream, filter+scale+sum its elements, one
//!   sum per region. Variants: enumerated (sparse signals), tagged
//!   (dense in-band), fused vs two-stage pipeline shapes.
//! * [`taxi`] — the DIBS `tstcsv->csv` application behind Fig. 8, in the
//!   paper's three implementations: pure enumeration, hybrid
//!   (enumerate stage 1 / tag stage 2), and pure tagging.
//!
//! Every app runs on either kernel backend (native Rust mirror or the
//! AOT-compiled XLA artifacts via PJRT) at any compiled ensemble width.

pub mod sum;
pub mod taxi;

/// Fill `mask` with `take` ones followed by `width - take` zeros — the
/// standard compact-ensemble occupancy mask (public: the bench harness
/// uses it for its raw-loop baselines).
pub fn prefix_mask(mask: &mut Vec<i32>, take: usize, width: usize) {
    mask.clear();
    mask.resize(width, 0);
    for m in mask.iter_mut().take(take) {
        *m = 1;
    }
}

/// Consecutive sub-half-peak shards a source ring must see before its
/// transient peak allocation is released (see [`SourceShrink`]).
pub const SHRINK_WINDOW: usize = 32;

/// Source-capacity shrink heuristic for persistent per-worker pipelines.
///
/// A persistent pipeline re-targets its source channel's *logical*
/// capacity to each shard's length ([`Channel::set_data_capacity`]), but
/// the ring *allocation* only ever grows — one transient giant shard
/// (e.g. an oversized region admitted alone under the streaming budget)
/// leaves every later shard paying its high-water memory. This policy
/// watches the shard-size sequence and, after [`SHRINK_WINDOW`]
/// consecutive shards at most half the observed peak, asks the owner to
/// [`Channel::shrink_data_to`] twice the recent maximum (headroom for
/// jitter) and re-arms against that new, lower peak.
///
/// Purely observational: it reads shard lengths and returns a target —
/// it never touches scheduling, and since backpressure depends only on
/// the logical capacity, applying a shrink keeps outputs bit-identical
/// (`apps::sum` pins this down in `reuse_stays_bit_identical_across_a_shrink`).
///
/// [`Channel::set_data_capacity`]: crate::coordinator::channel::Channel::set_data_capacity
/// [`Channel::shrink_data_to`]: crate::coordinator::channel::Channel::shrink_data_to
#[derive(Debug, Clone, Default)]
pub struct SourceShrink {
    peak: usize,
    window_max: usize,
    below: usize,
    shrinks: u64,
}

impl SourceShrink {
    /// A fresh policy with no history.
    pub fn new() -> SourceShrink {
        SourceShrink::default()
    }

    /// Observe one shard of `shard_regions` regions. Returns
    /// `Some(target)` — physical slots to shrink the source ring to —
    /// once [`SHRINK_WINDOW`] consecutive shards stayed at or below half
    /// the running peak; `None` otherwise.
    pub fn observe(&mut self, shard_regions: usize) -> Option<usize> {
        if self.peak > 0 && shard_regions <= self.peak / 2 {
            self.below += 1;
            self.window_max = self.window_max.max(shard_regions);
            if self.below >= SHRINK_WINDOW {
                // Twice the recent maximum: headroom so normal jitter
                // doesn't force an immediate regrow, floor of one slot.
                let target = (self.window_max * 2).max(1);
                self.peak = self.window_max;
                self.window_max = 0;
                self.below = 0;
                self.shrinks += 1;
                return Some(target);
            }
        } else {
            self.peak = self.peak.max(shard_regions);
            self.below = 0;
            self.window_max = 0;
        }
        None
    }

    /// Shrinks recommended so far.
    pub fn shrinks(&self) -> u64 {
        self.shrinks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_mask_shapes() {
        let mut m = Vec::new();
        prefix_mask(&mut m, 3, 8);
        assert_eq!(m, vec![1, 1, 1, 0, 0, 0, 0, 0]);
        prefix_mask(&mut m, 0, 4);
        assert_eq!(m, vec![0, 0, 0, 0]);
        prefix_mask(&mut m, 4, 4);
        assert_eq!(m, vec![1, 1, 1, 1]);
    }

    #[test]
    fn source_shrink_fires_after_a_sustained_drop() {
        let mut p = SourceShrink::new();
        assert_eq!(p.observe(1000), None, "first shard sets the peak");
        // a long run of small shards: fires exactly at the window edge
        for i in 0..SHRINK_WINDOW - 1 {
            assert_eq!(p.observe(10), None, "shard {i} below the window");
        }
        assert_eq!(p.observe(12), Some(24), "2x the recent max, at the window");
        assert_eq!(p.shrinks(), 1);
        // re-armed against the new peak (12): small shards count afresh
        for _ in 0..SHRINK_WINDOW - 1 {
            assert_eq!(p.observe(3), None);
        }
        assert_eq!(p.observe(3), Some(6), "second shrink against the lower peak");
    }

    #[test]
    fn source_shrink_resets_on_a_big_shard() {
        let mut p = SourceShrink::new();
        p.observe(1000);
        for _ in 0..SHRINK_WINDOW - 1 {
            assert_eq!(p.observe(10), None);
        }
        // one near-peak shard breaks the streak: no shrink, streak restarts
        assert_eq!(p.observe(900), None);
        for _ in 0..SHRINK_WINDOW - 1 {
            assert_eq!(p.observe(10), None);
        }
        assert_eq!(p.observe(10), Some(20), "full window needed again");
    }

    #[test]
    fn source_shrink_never_fires_on_steady_streams() {
        let mut p = SourceShrink::new();
        for _ in 0..10 * SHRINK_WINDOW {
            assert_eq!(p.observe(64), None, "uniform shards never shrink");
        }
        assert_eq!(p.shrinks(), 0);
        // half-the-peak boundary is inclusive: 32 counts against peak 64
        let mut p = SourceShrink::new();
        p.observe(64);
        for _ in 0..SHRINK_WINDOW - 1 {
            assert_eq!(p.observe(32), None);
        }
        assert_eq!(p.observe(32), Some(64));
    }
}
