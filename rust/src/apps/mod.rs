//! Benchmark applications from the paper's evaluation (§5).
//!
//! * [`sum`] — the region-sum microbenchmark behind Figs 6/7: enumerate
//!   each region of an integer stream, filter+scale+sum its elements, one
//!   sum per region. Variants: enumerated (sparse signals), tagged
//!   (dense in-band), fused vs two-stage pipeline shapes.
//! * [`taxi`] — the DIBS `tstcsv->csv` application behind Fig. 8, in the
//!   paper's three implementations: pure enumeration, hybrid
//!   (enumerate stage 1 / tag stage 2), and pure tagging.
//!
//! Every app runs on either kernel backend (native Rust mirror or the
//! AOT-compiled XLA artifacts via PJRT) at any compiled ensemble width.

pub mod sum;
pub mod taxi;

/// Fill `mask` with `take` ones followed by `width - take` zeros — the
/// standard compact-ensemble occupancy mask (public: the bench harness
/// uses it for its raw-loop baselines).
pub fn prefix_mask(mask: &mut Vec<i32>, take: usize, width: usize) {
    mask.clear();
    mask.resize(width, 0);
    for m in mask.iter_mut().take(take) {
        *m = 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_mask_shapes() {
        let mut m = Vec::new();
        prefix_mask(&mut m, 3, 8);
        assert_eq!(m, vec![1, 1, 1, 0, 0, 0, 0, 0]);
        prefix_mask(&mut m, 0, 4);
        assert_eq!(m, vec![0, 0, 0, 0]);
        prefix_mask(&mut m, 4, 4);
        assert_eq!(m, vec![1, 1, 1, 1]);
    }
}
