//! The region-sum benchmark app (paper §5, Figs 6/7).
//!
//! Computation: the stream is divided into regions; each region is
//! enumerated, its elements filtered (`v > threshold`), scaled and summed;
//! the app emits one sum per region.
//!
//! Implementations:
//!
//! * [`SumMode::Enumerated`] — the paper's design: sparse region context
//!   via enumeration + precise signals. Region boundaries cap ensembles,
//!   so occupancy (and hence time) depends on region size vs SIMD width —
//!   the Fig. 6 effect.
//! * [`SumMode::Tagged`] — the dense baseline: every element carries its
//!   region tag; ensembles stay full but each firing pays for tag
//!   densification and a segmented (one-hot matmul) reduction.
//!
//! Pipeline shapes for the enumerated mode:
//!
//! * [`SumShape::Fused`] — one aggregation node running the fused
//!   `sum_region` kernel per ensemble (the optimized hot path; used by the
//!   figure benches).
//! * [`SumShape::TwoStage`] — the paper's Fig. 3 topology: filter node `f`
//!   (kernel `filter_scale`) then accumulator `a` (kernel `masked_sum`).

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::coordinator::aggregate::{Aggregator, FilterMapLogic};
use crate::coordinator::enumerate::Blob;
use crate::coordinator::metrics::PipelineMetrics;
use crate::exec::{
    ContainerPool, ExecConfig, KernelSpawn, PipelineFactory, ShardOutput, ShardWorker,
    ShardedRunner, Splittability, WorkerKernels,
};
use crate::coordinator::channel::Channel;
use crate::coordinator::node::{Emitter, NodeLogic};
use crate::coordinator::signal::{parent_as, ParentRef, SignalKind};
use crate::coordinator::scheduler::Policy;
use crate::coordinator::tagging::{densify_tags, Tagged};
use crate::coordinator::topology::{Pipeline, PipelineBuilder};
use crate::runtime::kernels::KernelSet;
use crate::runtime::native::SCALE;

use super::{prefix_mask, SourceShrink};

/// Region-context representation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SumMode {
    /// Per-region enumeration with precise `RegionBegin`/`RegionEnd` signals.
    Enumerated,
    /// Dense tagged baseline: items carry region tags, no boundary signals.
    Tagged,
}

/// Pipeline shape for the enumerated mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SumShape {
    /// Single kernel fusing filter, scale, and sum per ensemble.
    Fused,
    /// Separate filter/compact and sum stages with an intermediate channel.
    TwoStage,
}

/// App configuration.
#[derive(Debug, Clone, Copy)]
pub struct SumConfig {
    /// SIMD ensemble width (lanes per firing).
    pub width: usize,
    /// Filter cutoff handed to the filter/scale kernel.
    pub threshold: f32,
    /// Region-context representation to run with.
    pub mode: SumMode,
    /// Pipeline shape (enumerated mode only).
    pub shape: SumShape,
    /// Data-queue capacity for every channel.
    pub data_cap: usize,
    /// Signal-queue capacity for every channel.
    pub signal_cap: usize,
    /// Node-selection policy for the scheduler.
    pub policy: Policy,
}

impl Default for SumConfig {
    fn default() -> Self {
        SumConfig {
            width: 128,
            threshold: 0.0,
            mode: SumMode::Enumerated,
            shape: SumShape::Fused,
            data_cap: 4096,
            signal_cap: 1024,
            policy: Policy::GreedyOccupancy,
        }
    }
}

/// Run report: per-region sums plus execution metrics.
#[derive(Debug, Clone)]
pub struct SumReport {
    /// `(region id, sum)` in stream order (tagged mode: tag order).
    pub outputs: Vec<(u64, f64)>,
    /// Merged pipeline metrics for the run.
    pub metrics: PipelineMetrics,
    /// Wall-clock seconds of the pipeline run(s).
    pub elapsed: f64,
    /// Kernel invocations (the SIMD cost unit).
    pub invocations: u64,
}

/// The app: a configured pipeline factory over a kernel set.
pub struct SumApp {
    cfg: SumConfig,
    kernels: Rc<KernelSet>,
}

/// Flush marker for the tagged mode's end-of-stream signal.
const FLUSH: u64 = u64::MAX;

impl SumApp {
    /// Create the app from a config and a shared kernel set.
    pub fn new(cfg: SumConfig, kernels: Rc<KernelSet>) -> SumApp {
        assert_eq!(cfg.width, kernels.width(), "config/kernel width mismatch");
        SumApp { cfg, kernels }
    }

    /// The configuration this app runs with.
    pub fn config(&self) -> &SumConfig {
        &self.cfg
    }

    /// Process a stream of region composites; returns per-region sums.
    ///
    /// Builds a one-shot [`SumPipeline`] and runs the stream as a single
    /// shard. Long-lived callers — the sharded executor's workers —
    /// build the pipeline once and call [`SumPipeline::run_shard`]
    /// repeatedly instead (reset, not rebuild).
    pub fn run(&self, blobs: &[Blob]) -> Result<SumReport> {
        let inv0 = self.kernels.invocations();
        let mut pipeline = SumPipeline::build(self.cfg, self.kernels.clone());
        let (outputs, metrics) = pipeline.run_shard(blobs)?;
        Ok(SumReport {
            outputs,
            elapsed: metrics.elapsed,
            invocations: self.kernels.invocations() - inv0,
            metrics,
        })
    }

    /// Process the stream sharded across `workers` OS threads (L3.5).
    ///
    /// The stream is partitioned at region boundaries, each worker runs a
    /// fresh pipeline on this app's configuration and backend, and outputs
    /// come back in stream order. For the enumerated modes the result is
    /// bit-identical to [`SumApp::run`] at any worker count. The tagged
    /// mode matches the single run's tag-sorted, coalesced output (partial
    /// sums of a tag that spans shards are folded here), but values may
    /// differ in float rounding — sharding changes how lanes pack into
    /// ensembles. See [`crate::exec`].
    pub fn run_sharded(&self, blobs: &[Blob], workers: usize) -> Result<SumReport> {
        self.run_sharded_with(blobs, &ExecConfig::new(workers))
    }

    /// [`SumApp::run_sharded`] with full executor configuration.
    pub fn run_sharded_with(&self, blobs: &[Blob], exec: &ExecConfig) -> Result<SumReport> {
        exec.validate()?;
        if exec.workers <= 1
            && exec.shard.shards_per_worker <= 1
            && exec.trace.is_none()
            && !exec.metrics
            && exec.progress.is_none()
            && exec.max_region_items == 0
            && matches!(exec.fault, crate::exec::FaultPolicy::FailFast)
        {
            // One worker, one shard, untraced, unmetered, unsplit,
            // fail-fast, inline: identical to a plain run, so reuse this
            // app's kernel set instead of spawning a fresh engine (on the
            // XLA backend that is a full PJRT spin-up). Traced or metered
            // runs and non-default fault policies always go through the
            // executor, which owns the trace lanes, the metrics hubs and
            // the recovery machinery.
            return self.run(blobs);
        }
        let factory = SumFactory::new(self.cfg, KernelSpawn::from_backend(self.kernels.backend()));
        let report = ShardedRunner::new(exec.clone()).run(&factory, blobs)?;
        Ok(SumReport {
            outputs: finish_sharded_outputs(self.cfg.mode, report.outputs),
            metrics: report.metrics,
            elapsed: report.elapsed,
            invocations: report.invocations,
        })
    }

    /// Streaming execution (L3.5 v2): pull regions from `source`
    /// incrementally, shard them on the fly under `exec.ingest`'s
    /// in-flight budget, and execute with work stealing. For the
    /// enumerated modes the outputs are bit-identical to [`SumApp::run`]
    /// over the materialized stream at any worker count; the tagged mode
    /// gets the same post-merge fold as [`SumApp::run_sharded_with`].
    /// Input memory is bounded by the budget, never by stream length —
    /// pair with [`GenBlobSource`](crate::workload::regions::GenBlobSource)
    /// (or any out-of-core reader) for streams that don't fit in memory.
    pub fn run_streaming<S>(&self, source: S, exec: &ExecConfig) -> Result<SumReport>
    where
        S: crate::workload::source::RegionSource<Region = Blob>,
    {
        exec.validate()?;
        let factory = SumFactory::new(self.cfg, KernelSpawn::from_backend(self.kernels.backend()));
        let report = ShardedRunner::new(exec.clone()).run_stream(&factory, source)?;
        Ok(SumReport {
            outputs: finish_sharded_outputs(self.cfg.mode, report.outputs),
            metrics: report.metrics,
            elapsed: report.elapsed,
            invocations: report.invocations,
        })
    }

    /// [`SumApp::run_streaming`] with results landed in a
    /// [`ResultSink`](crate::io::ResultSink) instead of collected:
    /// each shard's `(region id, sum)` rows are written as soon as
    /// their stream-order prefix completes, so a file-backed source
    /// plus a file sink keeps the whole run's memory bounded by the
    /// ingest budget. The returned report's `outputs` is empty; the
    /// caller still owns the sink and calls
    /// [`finish`](crate::io::ResultSink::finish) once to flush and
    /// collect [`SinkStats`](crate::io::SinkStats).
    ///
    /// Enumerated modes only: the tagged baseline's outputs need a
    /// global sort+fold after the run
    /// ([`finish_sharded_outputs`]), which contradicts incremental
    /// emission — asking for it is a named error, not silent
    /// misordered output.
    pub fn run_streaming_into<S, K>(
        &self,
        source: S,
        exec: &ExecConfig,
        sink: &mut K,
    ) -> Result<SumReport>
    where
        S: crate::workload::source::RegionSource<Region = Blob>,
        K: crate::io::ResultSink<(u64, f64)> + ?Sized,
    {
        exec.validate()?;
        ensure!(
            self.cfg.mode == SumMode::Enumerated,
            "streaming sinks need stream-order outputs: SumMode::Tagged emits \
             per-shard partials that require a global fold after the run \
             (use run_streaming + finish_sharded_outputs instead)"
        );
        let factory = SumFactory::new(self.cfg, KernelSpawn::from_backend(self.kernels.backend()));
        let report = ShardedRunner::new(exec.clone()).run_stream_into(&factory, source, sink)?;
        Ok(SumReport {
            outputs: Vec::new(),
            metrics: report.metrics,
            elapsed: report.elapsed,
            invocations: report.invocations,
        })
    }
}

/// A persistent, reusable sum pipeline — the worker-side half of the
/// zero-rebuild contract. The node graph, queues, channels, scheduler
/// adjacency and kernel staging buffers are built **once**; every shard
/// then runs `reset → feed → drain` against the same graph
/// ([`Pipeline::reset`]). Per-shard outputs *and metrics* are
/// bit-identical to building a fresh pipeline per shard, at none of the
/// rebuild cost — `bench hotpath`'s reuse sweep quantifies the win on
/// many-small-shard streams (EXPERIMENTS.md §Reuse).
pub struct SumPipeline {
    kind: SumPipelineKind,
    /// Source-ring shrink policy: releases the transient high-water
    /// allocation a giant shard leaves behind (see [`SourceShrink`]).
    shrink: SourceShrink,
}

enum SumPipelineKind {
    /// Both enumerated shapes: `Blob` source → … → `(id, sum)` sink.
    Enumerated {
        pipe: Pipeline,
        src: Rc<Channel<Blob>>,
        sums: Rc<RefCell<Vec<(u64, f64)>>>,
    },
    /// The dense tagged baseline: `Tagged<f32>` source → `tagsum` sink.
    Tagged {
        pipe: Pipeline,
        src: Rc<Channel<Tagged<f32>>>,
        sums: Rc<RefCell<Vec<(u64, f64)>>>,
    },
}

impl SumPipeline {
    /// Assemble the graph for `cfg` over `kernels` (widths must match).
    pub fn build(cfg: SumConfig, kernels: Rc<KernelSet>) -> SumPipeline {
        assert_eq!(cfg.width, kernels.width(), "config/kernel width mismatch");
        let kind = match cfg.mode {
            SumMode::Enumerated => match cfg.shape {
                SumShape::Fused => SumPipeline::build_fused(cfg, kernels),
                SumShape::TwoStage => SumPipeline::build_two_stage(cfg, kernels),
            },
            SumMode::Tagged => SumPipeline::build_tagged(cfg, kernels),
        };
        SumPipeline {
            kind,
            shrink: SourceShrink::new(),
        }
    }

    /// Run one shard to quiescence on the persistent graph. Counters are
    /// zero at entry (the reset), so the returned [`PipelineMetrics`]
    /// cover exactly this shard — identical to a fresh build's.
    pub fn run_shard(&mut self, blobs: &[Blob]) -> Result<(Vec<(u64, f64)>, PipelineMetrics)> {
        match &mut self.kind {
            SumPipelineKind::Enumerated { pipe, src, sums } => {
                pipe.reset();
                // a failed previous shard may have left partial rows in
                // the driver-owned sink; a fresh build starts empty
                sums.borrow_mut().clear();
                // Source sized exactly like a fresh build's (capacity ==
                // shard length), so backpressure — and hence scheduling,
                // ensemble packing and float grouping — matches the
                // rebuild-per-shard behaviour bit for bit. The ring only
                // grows when a shard outsizes every previous one.
                src.set_data_capacity(blobs.len().max(1));
                for blob in blobs {
                    src.push(blob.clone());
                }
                pipe.run()?;
                // Off the firing path, after the shard drained: release
                // the ring's physical allocation once shard sizes have
                // durably dropped below a transient peak. Backpressure
                // depends only on the *logical* capacity set above, so
                // this cannot perturb scheduling or outputs
                // (`reuse_stays_bit_identical_across_a_shrink`).
                if let Some(target) = self.shrink.observe(blobs.len()) {
                    src.shrink_data_to(target);
                }
                Ok((take_outputs(sums), pipe.metrics()))
            }
            SumPipelineKind::Tagged { pipe, src, sums } => {
                pipe.reset();
                sums.borrow_mut().clear(); // see the enumerated branch
                let items = crate::workload::regions::flatten_tagged(blobs);
                // Feed in capacity-sized batches, draining between
                // refills (the stream is larger than any queue).
                let mut fed = 0usize;
                while fed < items.len() {
                    let n = src.data_space().min(items.len() - fed);
                    src.push_slice(&items[fed..fed + n])?;
                    fed += n;
                    pipe.run()?;
                }
                src.emit_signal(SignalKind::Custom(FLUSH));
                pipe.run()?;
                Ok((take_outputs(sums), pipe.metrics()))
            }
        }
    }

    /// Source-ring shrinks applied over this pipeline's lifetime
    /// (see [`SourceShrink`]).
    pub fn shrinks(&self) -> u64 {
        self.shrink.shrinks()
    }

    /// Physical slots currently allocated in the source ring — the
    /// quantity the shrink policy manages (tests assert it is released
    /// after a transient peak).
    pub fn source_allocated(&self) -> usize {
        match &self.kind {
            SumPipelineKind::Enumerated { src, .. } => src.data_allocated(),
            SumPipelineKind::Tagged { src, .. } => src.data_allocated(),
        }
    }

    /// Install a trace sink on the underlying pipeline's scheduler so
    /// every firing is recorded (see [`crate::trace`]). The sink
    /// survives per-shard resets, so one install covers the worker's
    /// whole lifetime.
    pub fn set_trace(&mut self, sink: crate::trace::TraceSink) {
        match &mut self.kind {
            SumPipelineKind::Enumerated { pipe, .. } | SumPipelineKind::Tagged { pipe, .. } => {
                pipe.set_trace(sink)
            }
        }
    }

    fn build_fused(cfg: SumConfig, ks: Rc<KernelSet>) -> SumPipelineKind {
        let mut b = PipelineBuilder::new(cfg.width)
            .queue_caps(cfg.data_cap, cfg.signal_cap)
            .policy(cfg.policy);
        // capacity is re-targeted per shard in run_shard
        let src = b.source_with_cap::<Blob>(1);
        let elems = b.enumerate("enum", &src);

        let vals = RefCell::new(vec![0.0f32; cfg.width]);
        let mask = RefCell::new(Vec::with_capacity(cfg.width));
        let sums = b.sink(
            "sum",
            &elems,
            Aggregator::new(
                (0u64, 0.0f64), // (region id, accumulator)
                move |acc: &mut (u64, f64), idxs: &[u32], parent: Option<&ParentRef>| {
                    let blob = parent_as::<Blob>(parent.expect("enumerated")).expect("Blob");
                    acc.0 = blob.id;
                    let mut vals = vals.borrow_mut();
                    let mut mask = mask.borrow_mut();
                    for (slot, &i) in vals.iter_mut().zip(idxs) {
                        *slot = blob.get(i);
                    }
                    for slot in vals.iter_mut().skip(idxs.len()) {
                        *slot = 0.0;
                    }
                    prefix_mask(&mut mask, idxs.len(), cfg.width);
                    let (partial, _kept) = ks.sum_region(&vals, &mask, cfg.threshold)?;
                    acc.1 += partial as f64;
                    Ok(())
                },
                |acc: &mut (u64, f64), parent: &ParentRef| {
                    let blob = parent_as::<Blob>(parent).expect("Blob");
                    Ok(Some((blob.id, if acc.0 == blob.id { acc.1 } else { 0.0 })))
                },
            ),
        );
        SumPipelineKind::Enumerated {
            pipe: b.build(),
            src,
            sums,
        }
    }

    fn build_two_stage(cfg: SumConfig, ks: Rc<KernelSet>) -> SumPipelineKind {
        let ks_f = ks.clone();
        let ks_a = ks;
        let mut b = PipelineBuilder::new(cfg.width)
            .queue_caps(cfg.data_cap, cfg.signal_cap)
            .policy(cfg.policy);
        let src = b.source_with_cap::<Blob>(1);
        let elems = b.enumerate("enum", &src);

        // Node f (paper Fig. 5): gather elements, filter+scale via the
        // in-place kernel into firing-persistent output buffers.
        let f_vals = RefCell::new(vec![0.0f32; cfg.width]);
        let f_mask = RefCell::new(Vec::with_capacity(cfg.width));
        let f_ov = RefCell::new(vec![0.0f32; cfg.width]);
        let f_om = RefCell::new(vec![0i32; cfg.width]);
        let filtered = b.node(
            "f",
            &elems,
            FilterMapLogic::new(1, move |idxs: &[u32], parent, out: &mut Emitter<'_, f32>| {
                let blob = parent_as::<Blob>(parent.expect("enumerated")).expect("Blob");
                let mut vals = f_vals.borrow_mut();
                let mut mask = f_mask.borrow_mut();
                let mut ov = f_ov.borrow_mut();
                let mut om = f_om.borrow_mut();
                for (slot, &i) in vals.iter_mut().zip(idxs) {
                    *slot = blob.get(i);
                }
                for slot in vals.iter_mut().skip(idxs.len()) {
                    *slot = 0.0;
                }
                prefix_mask(&mut mask, idxs.len(), cfg.width);
                ks_f.filter_scale_into(&vals, &mask, cfg.threshold, &mut ov, &mut om)?;
                for i in 0..idxs.len() {
                    if om[i] != 0 {
                        out.push(ov[i]);
                    }
                }
                Ok(())
            }),
        );

        // Node a: SIMD-parallel reduction per ensemble.
        let a_vals = RefCell::new(vec![0.0f32; cfg.width]);
        let a_mask = RefCell::new(Vec::with_capacity(cfg.width));
        let sums = b.sink(
            "a",
            &filtered,
            Aggregator::new(
                0.0f64,
                move |acc: &mut f64, items: &[f32], _parent: Option<&ParentRef>| {
                    let mut vals = a_vals.borrow_mut();
                    let mut mask = a_mask.borrow_mut();
                    vals[..items.len()].copy_from_slice(items);
                    for slot in vals.iter_mut().skip(items.len()) {
                        *slot = 0.0;
                    }
                    prefix_mask(&mut mask, items.len(), cfg.width);
                    let (partial, _n) = ks_a.masked_sum(&vals, &mask)?;
                    *acc += partial as f64;
                    Ok(())
                },
                |acc: &mut f64, parent: &ParentRef| {
                    let blob = parent_as::<Blob>(parent).expect("Blob");
                    Ok(Some((blob.id, *acc)))
                },
            ),
        );
        SumPipelineKind::Enumerated {
            pipe: b.build(),
            src,
            sums,
        }
    }

    fn build_tagged(cfg: SumConfig, ks: Rc<KernelSet>) -> SumPipelineKind {
        let mut b = PipelineBuilder::new(cfg.width)
            .queue_caps(cfg.data_cap, cfg.signal_cap)
            .policy(cfg.policy);
        let src = b.source_with_cap::<Tagged<f32>>(cfg.data_cap.max(cfg.width));
        let sums = b.sink("tagsum", &src, TaggedSumLogic::new(ks, cfg));
        SumPipelineKind::Tagged {
            pipe: b.build(),
            src,
            sums,
        }
    }
}

/// Collect a sink's outputs for this shard and clear it for the next.
/// The sink keeps its capacity; the per-shard cost is one exact-size
/// clone — the result vector that crosses back to the caller anyway.
/// Shared with the taxi app's persistent pipeline.
pub(crate) fn take_outputs<T: Clone>(sink: &Rc<RefCell<Vec<T>>>) -> Vec<T> {
    let mut s = sink.borrow_mut();
    let out = s.clone();
    s.clear();
    out
}

/// Tagged-mode accumulator node: full ensembles, per-lane tags, segmented
/// reduction, flush-on-signal.
struct TaggedSumLogic {
    kernels: Rc<KernelSet>,
    threshold: f32,
    width: usize,
    vals: Vec<f32>,
    seg: Vec<i32>,
    mask: Vec<i32>,
    local: Vec<i32>,
    uniq: Vec<u64>,
    tags_scratch: Vec<u64>,
    /// Kernel output staging, reused across firings (zero-alloc path).
    sums: Vec<f32>,
    counts: Vec<i32>,
    acc: std::collections::BTreeMap<u64, f64>,
}

impl TaggedSumLogic {
    fn new(kernels: Rc<KernelSet>, cfg: SumConfig) -> TaggedSumLogic {
        TaggedSumLogic {
            kernels,
            threshold: cfg.threshold,
            width: cfg.width,
            vals: vec![0.0; cfg.width],
            seg: vec![0; cfg.width],
            mask: Vec::with_capacity(cfg.width),
            local: Vec::with_capacity(cfg.width),
            uniq: Vec::with_capacity(cfg.width),
            tags_scratch: Vec::with_capacity(cfg.width),
            sums: vec![0.0; cfg.width],
            counts: vec![0; cfg.width],
            acc: std::collections::BTreeMap::new(),
        }
    }
}

impl NodeLogic for TaggedSumLogic {
    type In = Tagged<f32>;
    type Out = (u64, f64);

    fn run(
        &mut self,
        items: &[Tagged<f32>],
        _parent: Option<&ParentRef>,
        _out: &mut Emitter<'_, (u64, f64)>,
    ) -> Result<()> {
        // The dense representation's per-item work: unpack tags, apply the
        // filter on the CPU-visible side... no — filtering stays in the
        // kernel; here we only stage values and densify tags.
        self.tags_scratch.clear();
        for (i, t) in items.iter().enumerate() {
            self.vals[i] = t.item;
            self.tags_scratch.push(t.tag);
        }
        for slot in self.vals[items.len()..].iter_mut() {
            *slot = 0.0;
        }
        let k = densify_tags(&self.tags_scratch, &mut self.local, &mut self.uniq);
        self.seg[..items.len()].copy_from_slice(&self.local);
        for slot in self.seg[items.len()..].iter_mut() {
            *slot = 0;
        }
        prefix_mask(&mut self.mask, items.len(), self.width);
        // fused filter+scale+segmented reduce — ONE invocation per
        // ensemble (perf pass; was filter_scale + segmented_sum), written
        // into the logic-owned staging buffers (no per-firing allocation)
        self.kernels.tagged_sum_region_into(
            &self.vals,
            &self.seg,
            &self.mask,
            self.threshold,
            &mut self.sums,
            &mut self.counts,
        )?;
        for s in 0..k {
            *self.acc.entry(self.uniq[s]).or_insert(0.0) += self.sums[s] as f64;
        }
        Ok(())
    }

    fn on_custom(&mut self, id: u64, out: &mut Emitter<'_, (u64, f64)>) -> Result<()> {
        if id == FLUSH {
            for (&tag, &sum) in &self.acc {
                out.push((tag, sum));
            }
            self.acc.clear();
        }
        Ok(())
    }

    fn max_outputs_per_input(&self) -> usize {
        0
    }

    fn max_outputs_per_signal(&self) -> usize {
        usize::MAX // flush emits one output per region; sink space is unbounded
    }

    fn reset(&mut self) {
        // cross-shard reuse: the per-tag accumulation is stream-scoped
        // state — FLUSH drains it on a clean run, but reset guarantees a
        // reused pipeline starts the next shard with provably no carryover
        self.acc.clear();
    }
}

/// [`PipelineFactory`] for the sum app: one persistent [`SumPipeline`]
/// per worker thread (built in `make_worker`, reset between shards),
/// shards balanced by region element count.
pub struct SumFactory {
    cfg: SumConfig,
    spawn: KernelSpawn,
    elem_pool: Option<Arc<ContainerPool<f32>>>,
}

impl SumFactory {
    /// Create a factory that builds per-worker sum pipelines on `spawn` kernels.
    pub fn new(cfg: SumConfig, spawn: KernelSpawn) -> SumFactory {
        SumFactory {
            cfg,
            spawn,
            elem_pool: None,
        }
    }

    /// Share an element-container pool with the region source: workers
    /// return each completed region's `Vec<f32>` here instead of
    /// dropping it, and a pooled source
    /// ([`GenBlobSource::with_pool`](crate::workload::regions::GenBlobSource::with_pool),
    /// [`BlobFileSource::with_pool`](crate::io::BlobFileSource::with_pool))
    /// takes them back on the ingest driver — closing the loop that
    /// makes steady-state streaming allocation-free end to end.
    pub fn with_elem_pool(mut self, pool: Arc<ContainerPool<f32>>) -> SumFactory {
        self.elem_pool = Some(pool);
        self
    }
}

/// A worker-private persistent sum pipeline: the kernel engine **and**
/// the built node graph live as long as the worker; every shard runs
/// `reset → feed → drain` on the same [`SumPipeline`] (zero rebuild).
pub struct SumShardWorker {
    pipeline: SumPipeline,
    kernels: WorkerKernels,
    /// Node graphs built over this worker's lifetime — the reuse proof:
    /// stays at 1 however many shards the worker runs.
    builds: u64,
}

impl PipelineFactory for SumFactory {
    type In = Blob;
    type Out = (u64, f64);
    type Worker = SumShardWorker;

    fn make_worker(&self, _worker_id: usize) -> Result<SumShardWorker> {
        let kernels = self.spawn.spawn(self.cfg.width)?;
        let pipeline = SumPipeline::build(self.cfg, kernels.kernels.clone());
        Ok(SumShardWorker {
            pipeline,
            kernels,
            builds: 1,
        })
    }

    fn weight(&self, blob: &Blob) -> usize {
        // Empty regions still cost a firing; weigh them 1 so the planner
        // never builds a zero-weight shard.
        blob.elems.len().max(1)
    }

    fn recycle_region(&self, blob: Blob) {
        if let Some(pool) = &self.elem_pool {
            pool.put(blob.elems);
        }
    }

    /// Which sum variants may legally split a region:
    ///
    /// * fused enumerated — **RegionFold**: the aggregator folds one f32
    ///   partial per ensemble into an f64 accumulator, strictly in
    ///   ensemble order, and [`SumFactory::split_region`] cuts at
    ///   ensemble boundaries — so re-folding part rows left-to-right
    ///   replays the identical f64 addition sequence (bit-identity, not
    ///   approximation).
    /// * two-stage enumerated — **refuses**: the filter node compacts
    ///   survivors across ensemble boundaries *within* a region before
    ///   the accumulator sees them, so any cut changes how lanes group
    ///   into `masked_sum` invocations (float rounding).
    /// * tagged — **GlobalFold**: per-shard `(tag, partial)` rows are
    ///   globally re-sorted and folded after every sharded run anyway
    ///   ([`finish_sharded_outputs`]), and the tagged baseline already
    ///   trades bit-identity for lane packing — split partials ride the
    ///   same contract.
    fn splittability(&self) -> Splittability {
        match (self.cfg.mode, self.cfg.shape) {
            (SumMode::Enumerated, SumShape::Fused) => Splittability::RegionFold,
            (SumMode::Enumerated, SumShape::TwoStage) => Splittability::Opaque {
                reason: "the two-stage enumerated sum compacts filter survivors across \
                         ensemble boundaries within a region, so cutting the region \
                         changes float grouping",
            },
            (SumMode::Tagged, _) => Splittability::GlobalFold,
        }
    }

    /// Cut at **ensemble boundaries**: each part is exactly one ensemble
    /// (`width` elements, the last one shorter), keeping the same `id`.
    /// A part's own pipeline run computes `0.0 + partial` — exactly the
    /// f64 addition the unsplit run performs for that ensemble — so the
    /// left-linear [`SumFactory::combine`] chain is bit-identical.
    /// Multi-ensemble parts would *not* be (their pre-summed partials
    /// reassociate the addition chain), which is why the cut ignores any
    /// slack `max_items` leaves above `width`.
    fn split_region(&self, blob: &Blob, max_items: usize) -> Result<Vec<Blob>> {
        if blob.elems.len().max(1) <= max_items {
            return Ok(vec![blob.clone()]);
        }
        ensure!(
            max_items >= self.cfg.width,
            "max_region_items = {max_items} is below the SIMD width {} — parts must \
             stay ensemble-aligned to preserve bit-identity, so the threshold cannot \
             cut inside one ensemble",
            self.cfg.width
        );
        Ok(blob
            .elems
            .chunks(self.cfg.width)
            .map(|c| Blob::from_vec(blob.id, c.to_vec()))
            .collect())
    }

    /// Left-linear partial fold: part 0's row seeds the accumulator and
    /// each later part adds its (single-ensemble) partial — the same
    /// `acc += partial as f64` the fused aggregator runs unsplit.
    fn combine(&self, acc: &mut (u64, f64), part: (u64, f64)) -> Result<()> {
        ensure!(
            acc.0 == part.0,
            "combine folded rows of different regions ({} vs {}) — split ledger \
             misaligned (executor bug)",
            acc.0,
            part.0
        );
        acc.1 += part.1;
        Ok(())
    }
}

impl ShardWorker for SumShardWorker {
    type In = Blob;
    type Out = (u64, f64);

    fn run_shard(&mut self, shard: &[Blob]) -> Result<ShardOutput<(u64, f64)>> {
        let inv0 = self.kernels.kernels.invocations();
        let (outputs, metrics) = self.pipeline.run_shard(shard)?;
        Ok(ShardOutput {
            outputs,
            metrics,
            invocations: self.kernels.kernels.invocations() - inv0,
        })
    }

    fn pipelines_built(&self) -> u64 {
        self.builds
    }

    fn set_trace(&mut self, sink: crate::trace::TraceSink) {
        self.pipeline.set_trace(sink);
    }
}

/// The mode-appropriate post-merge fold for sharded outputs. Enumerated
/// outputs are already one-per-region in stream order; the single tagged
/// run emits one globally tag-sorted entry per tag, so per-shard tagged
/// entries must be re-sorted and folded. Public (and used by
/// [`SumApp::run_sharded_with`]) so callers driving
/// [`crate::exec::ShardedRunner`] directly — the CLI, benches — apply the
/// identical fold.
pub fn finish_sharded_outputs(mode: SumMode, outputs: Vec<(u64, f64)>) -> Vec<(u64, f64)> {
    match mode {
        SumMode::Enumerated => outputs,
        SumMode::Tagged => coalesce_tag_sums(outputs),
    }
}

/// Fold per-shard tagged outputs into the single-run shape: globally
/// tag-sorted, one entry per tag (stable sort keeps equal-tag partials in
/// shard order before they fold).
fn coalesce_tag_sums(mut outputs: Vec<(u64, f64)>) -> Vec<(u64, f64)> {
    outputs.sort_by_key(|&(tag, _)| tag);
    let mut folded: Vec<(u64, f64)> = Vec::with_capacity(outputs.len());
    for (tag, sum) in outputs {
        match folded.last_mut() {
            Some((t, s)) if *t == tag => *s += sum,
            _ => folded.push((tag, sum)),
        }
    }
    folded
}

/// f64 reference sums (independent of ensemble grouping) for validation.
pub fn reference_sums(blobs: &[Blob], threshold: f32) -> Vec<(u64, f64)> {
    blobs
        .iter()
        .map(|b| {
            let s: f64 = b
                .elems
                .iter()
                .filter(|&&v| v > threshold)
                .map(|&v| (SCALE * v) as f64)
                .sum();
            (b.id, s)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::regions::{gen_blobs, RegionSpec};

    fn native_app(mode: SumMode, shape: SumShape, width: usize) -> SumApp {
        SumApp::new(
            SumConfig {
                width,
                mode,
                shape,
                data_cap: 256,
                signal_cap: 64,
                ..Default::default()
            },
            Rc::new(KernelSet::native(width)),
        )
    }

    fn check_close(got: &[(u64, f64)], want: &[(u64, f64)]) {
        assert_eq!(got.len(), want.len());
        for ((gi, gv), (wi, wv)) in got.iter().zip(want) {
            assert_eq!(gi, wi);
            assert!(
                (gv - wv).abs() <= 1e-3 * (1.0 + wv.abs()),
                "region {gi}: got {gv}, want {wv}"
            );
        }
    }

    #[test]
    fn fused_matches_reference() {
        let blobs = gen_blobs(2000, RegionSpec::Fixed { size: 96 }, 1);
        let app = native_app(SumMode::Enumerated, SumShape::Fused, 8);
        let report = app.run(&blobs).unwrap();
        check_close(&report.outputs, &reference_sums(&blobs, 0.0));
        assert!(report.invocations > 0);
    }

    #[test]
    fn two_stage_matches_reference() {
        let blobs = gen_blobs(500, RegionSpec::Uniform { max: 40 }, 2);
        let app = native_app(SumMode::Enumerated, SumShape::TwoStage, 8);
        let report = app.run(&blobs).unwrap();
        check_close(&report.outputs, &reference_sums(&blobs, 0.0));
    }

    #[test]
    fn tagged_matches_reference() {
        let blobs = gen_blobs(1000, RegionSpec::Fixed { size: 37 }, 3);
        let app = native_app(SumMode::Tagged, SumShape::Fused, 8);
        let report = app.run(&blobs).unwrap();
        // tagged emits in tag order == id order here
        check_close(&report.outputs, &reference_sums(&blobs, 0.0));
    }

    #[test]
    fn tagged_occupancy_beats_enumerated_on_small_regions() {
        let blobs = gen_blobs(800, RegionSpec::Fixed { size: 3 }, 4);
        let enumerated = native_app(SumMode::Enumerated, SumShape::Fused, 8).run(&blobs).unwrap();
        let tagged = native_app(SumMode::Tagged, SumShape::Fused, 8).run(&blobs).unwrap();
        let occ_enum = enumerated.metrics.node("sum").unwrap().occupancy();
        let occ_tag = tagged.metrics.node("tagsum").unwrap().occupancy();
        assert!(occ_enum < 0.5, "enumerated occupancy {occ_enum}");
        assert!(occ_tag > 0.9, "tagged occupancy {occ_tag}");
        // and the invocation count (SIMD cost) reflects it
        assert!(tagged.metrics.node("tagsum").unwrap().ensembles
            < enumerated.metrics.node("sum").unwrap().ensembles);
    }

    #[test]
    fn sharded_tagged_coalesces_nonmonotonic_region_ids() {
        // Two regions share id 7 and ids arrive out of order: the single
        // tagged run folds them into one tag-sorted entry; the sharded run
        // must match (shape exactly, values within rounding).
        let blobs = vec![
            Blob::from_vec(7, vec![1.0, 2.0, 3.0]),
            Blob::from_vec(3, vec![4.0; 10]),
            Blob::from_vec(7, vec![5.0; 6]),
        ];
        let app = native_app(SumMode::Tagged, SumShape::Fused, 4);
        let single = app.run(&blobs).unwrap();
        assert_eq!(single.outputs.len(), 2); // tags 3 and 7
        for workers in 1..=3 {
            let sharded = app.run_sharded(&blobs, workers).unwrap();
            assert_eq!(sharded.outputs.len(), 2, "workers {workers}");
            for ((gi, gv), (wi, wv)) in sharded.outputs.iter().zip(&single.outputs) {
                assert_eq!(gi, wi, "workers {workers}");
                assert!(
                    (gv - wv).abs() <= 1e-3 * (1.0 + wv.abs()),
                    "workers {workers}: tag {gi}: {gv} vs {wv}"
                );
            }
        }
    }

    #[test]
    fn sharded_run_is_bitwise_identical() {
        let blobs = gen_blobs(1200, RegionSpec::Uniform { max: 24 }, 6);
        let app = native_app(SumMode::Enumerated, SumShape::Fused, 8);
        let single = app.run(&blobs).unwrap();
        let sharded = app.run_sharded(&blobs, 4).unwrap();
        assert_eq!(sharded.outputs.len(), single.outputs.len());
        for ((gi, gv), (wi, wv)) in sharded.outputs.iter().zip(&single.outputs) {
            assert_eq!(gi, wi);
            assert_eq!(gv.to_bits(), wv.to_bits());
        }
        assert_eq!(sharded.invocations, single.invocations);
    }

    #[test]
    fn streamed_run_is_bitwise_identical() {
        let blobs = gen_blobs(1500, RegionSpec::Uniform { max: 24 }, 8);
        let app = native_app(SumMode::Enumerated, SumShape::Fused, 8);
        let single = app.run(&blobs).unwrap();
        let exec = ExecConfig::new(3).streaming(64);
        let streamed = app
            .run_streaming(crate::workload::source::SliceSource::new(&blobs), &exec)
            .unwrap();
        assert_eq!(streamed.outputs.len(), single.outputs.len());
        for ((gi, gv), (wi, wv)) in streamed.outputs.iter().zip(&single.outputs) {
            assert_eq!(gi, wi);
            assert_eq!(gv.to_bits(), wv.to_bits());
        }
        assert_eq!(streamed.invocations, single.invocations);
    }

    #[test]
    fn persistent_pipeline_reuse_matches_fresh_runs() {
        let blobs = gen_blobs(600, RegionSpec::Uniform { max: 20 }, 9);
        let app = native_app(SumMode::Enumerated, SumShape::Fused, 8);
        let mut pipeline = SumPipeline::build(*app.config(), Rc::new(KernelSet::native(8)));
        for shard in blobs.chunks(37) {
            let fresh = app.run(shard).unwrap(); // builds per call: the oracle
            let (outputs, metrics) = pipeline.run_shard(shard).unwrap();
            assert_eq!(outputs.len(), fresh.outputs.len());
            for ((gi, gv), (wi, wv)) in outputs.iter().zip(&fresh.outputs) {
                assert_eq!(gi, wi);
                assert_eq!(gv.to_bits(), wv.to_bits());
            }
            let (g, w) = (
                metrics.node("sum").unwrap(),
                fresh.metrics.node("sum").unwrap(),
            );
            assert_eq!(g.firings, w.firings);
            assert_eq!(g.ensemble_hist, w.ensemble_hist);
        }
    }

    #[test]
    fn reuse_stays_bit_identical_across_a_shrink() {
        use crate::apps::SHRINK_WINDOW;
        let app = native_app(SumMode::Enumerated, SumShape::Fused, 8);
        let mut pipeline = SumPipeline::build(*app.config(), Rc::new(KernelSet::native(8)));
        // one transient giant shard leaves a high-water ring allocation
        let giant = gen_blobs(4096, RegionSpec::Fixed { size: 4 }, 11);
        let fresh = app.run(&giant).unwrap();
        let (outputs, _) = pipeline.run_shard(&giant).unwrap();
        assert_eq!(outputs.len(), fresh.outputs.len());
        let peak = pipeline.source_allocated();
        assert!(peak >= 4096, "giant shard grew the ring to {peak}");
        // a long tail of small shards: the shrink policy fires, the ring
        // is released, and every shard still matches a fresh build bit
        // for bit — the policy only touches physical allocation, never
        // the logical capacity backpressure sees
        let small = gen_blobs(8 * (SHRINK_WINDOW + 8), RegionSpec::Uniform { max: 20 }, 12);
        for shard in small.chunks(8) {
            let fresh = app.run(shard).unwrap();
            let (outputs, metrics) = pipeline.run_shard(shard).unwrap();
            assert_eq!(outputs.len(), fresh.outputs.len());
            for ((gi, gv), (wi, wv)) in outputs.iter().zip(&fresh.outputs) {
                assert_eq!(gi, wi);
                assert_eq!(gv.to_bits(), wv.to_bits());
            }
            assert_eq!(
                metrics.node("sum").unwrap().ensemble_hist,
                fresh.metrics.node("sum").unwrap().ensemble_hist
            );
        }
        assert!(pipeline.shrinks() >= 1, "sustained small shards trigger a shrink");
        let now = pipeline.source_allocated();
        assert!(now < peak, "ring released: {now} slots vs peak {peak}");
    }

    #[test]
    fn zero_workers_errors_instead_of_clamping() {
        let blobs = gen_blobs(100, RegionSpec::Fixed { size: 10 }, 1);
        let app = native_app(SumMode::Enumerated, SumShape::Fused, 8);
        let err = app.run_sharded(&blobs, 0).unwrap_err();
        assert!(err.to_string().contains("workers = 0"), "{err}");
        let err = app
            .run_streaming(
                crate::workload::source::SliceSource::new(&blobs),
                &ExecConfig::new(0),
            )
            .unwrap_err();
        assert!(err.to_string().contains("workers = 0"), "{err}");
    }

    #[test]
    fn empty_regions_emit_zero_sums() {
        let blobs = vec![
            Blob::from_vec(0, vec![]),
            Blob::from_vec(1, vec![1.0]),
            Blob::from_vec(2, vec![]),
        ];
        let app = native_app(SumMode::Enumerated, SumShape::Fused, 4);
        let report = app.run(&blobs).unwrap();
        assert_eq!(report.outputs.len(), 3);
        assert_eq!(report.outputs[0].1, 0.0);
        assert_eq!(report.outputs[2].1, 0.0);
    }

    #[test]
    fn region_alignment_changes_invocations() {
        // Fig. 6's mechanism: regions of width+1 need 2 ensembles each;
        // regions of exactly width need 1.
        let aligned = gen_blobs(64 * 8, RegionSpec::Fixed { size: 8 }, 5);
        let misaligned = gen_blobs(72 * 8, RegionSpec::Fixed { size: 9 }, 5);
        let app = native_app(SumMode::Enumerated, SumShape::Fused, 8);
        let ra = app.run(&aligned).unwrap();
        let rm = app.run(&misaligned).unwrap();
        let ens_per_region_aligned =
            ra.metrics.node("sum").unwrap().ensembles as f64 / aligned.len() as f64;
        let ens_per_region_misaligned =
            rm.metrics.node("sum").unwrap().ensembles as f64 / misaligned.len() as f64;
        assert!((ens_per_region_aligned - 1.0).abs() < 1e-9);
        assert!((ens_per_region_misaligned - 2.0).abs() < 1e-9);
    }
}
