//! The "taxi" application (paper §5, Fig. 8): DIBS `tstcsv->csv`.
//!
//! Parse every `{lat,lon}` pair out of a stream of tagged text lines,
//! swap the pair, and emit it with its line's tag. Two stages:
//!
//! 1. **classify** — scan the line's characters for candidate `'{'`s;
//! 2. **parse** — verify each candidate and parse the pair.
//!
//! The paper's three implementations, reproduced here as [`TaxiVariant`]:
//!
//! * `Enumerated` — both stages consume enumerated streams inside the
//!   line's region. Stage 1 sees ~1397 chars/line (mostly full ensembles,
//!   paper: 91 %); stage 2 sees ~45 candidates/line (mostly partial,
//!   paper: 9 % full).
//! * `Hybrid` — stage 1 enumerated, but it *closes* the region and tags
//!   each candidate explicitly; stage 2 packs candidates from many lines
//!   into full ensembles. The paper's winner.
//! * `Tagged` — no enumeration anywhere: every character is tagged
//!   (dense context), both stages run full but stage 1 pays the per-char
//!   tag overhead — ~30 % slower than hybrid at scale.

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::aggregate::MapLogic;
use crate::coordinator::metrics::PipelineMetrics;
use crate::exec::{
    ExecConfig, KernelSpawn, PipelineFactory, ShardOutput, ShardWorker, ShardedRunner,
    WorkerKernels,
};
use crate::coordinator::channel::Channel;
use crate::coordinator::node::{Emitter, NodeLogic};
use crate::coordinator::scheduler::Policy;
use crate::coordinator::signal::{parent_as, ParentRef};
use crate::coordinator::topology::{Pipeline, PipelineBuilder};
use crate::runtime::kernels::KernelSet;
use crate::workload::taxi::{TaxiLine, TaxiWorkload};

use super::{prefix_mask, SourceShrink};

/// Implementation strategy (the three series of Fig. 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaxiVariant {
    /// Pure enumeration: per-line regions with precise boundary signals.
    Enumerated,
    /// Enumerated first stage feeding a tagged second stage.
    Hybrid,
    /// Pure tagging: items carry line tags, no boundary signals.
    Tagged,
}

impl TaxiVariant {
    /// Every variant, in presentation order.
    pub fn all() -> [TaxiVariant; 3] {
        [
            TaxiVariant::Enumerated,
            TaxiVariant::Hybrid,
            TaxiVariant::Tagged,
        ]
    }

    /// Short name used in tables and JSON output.
    pub fn label(&self) -> &'static str {
        match self {
            TaxiVariant::Enumerated => "pure-enumeration",
            TaxiVariant::Hybrid => "hybrid",
            TaxiVariant::Tagged => "pure-tagging",
        }
    }
}

/// One parsed, swapped coordinate pair, marked with its line's tag.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TaxiPair {
    /// Tag of the line the pair was parsed from.
    pub tag: u32,
    /// Parsed x coordinate.
    pub x: f32,
    /// Parsed y coordinate.
    pub y: f32,
}

/// A candidate position flowing between stages: absolute text offset plus
/// (for the tagged representations) the line tag and line end.
#[derive(Debug, Clone, Copy)]
pub struct Candidate {
    /// Absolute text offset of the candidate.
    pub abs: u32,
    /// Absolute text offset of the owning line's end.
    pub line_end: u32,
    /// Tag of the owning line.
    pub tag: u32,
}

/// App configuration.
#[derive(Debug, Clone, Copy)]
pub struct TaxiConfig {
    /// SIMD ensemble width (lanes per firing).
    pub width: usize,
    /// Pipeline variant to build.
    pub variant: TaxiVariant,
    /// Data-queue capacity for every channel.
    pub data_cap: usize,
    /// Signal-queue capacity for every channel.
    pub signal_cap: usize,
    /// Node-selection policy for the scheduler.
    pub policy: Policy,
}

impl Default for TaxiConfig {
    fn default() -> Self {
        TaxiConfig {
            width: 128,
            variant: TaxiVariant::Hybrid,
            data_cap: 8192,
            signal_cap: 2048,
            policy: Policy::GreedyOccupancy,
        }
    }
}

/// Run report.
#[derive(Debug, Clone)]
pub struct TaxiReport {
    /// Parsed coordinate pairs, in stream order.
    pub pairs: Vec<TaxiPair>,
    /// Merged pipeline metrics for the run.
    pub metrics: PipelineMetrics,
    /// Wall-clock seconds of the run.
    pub elapsed: f64,
    /// Kernel invocations spent (the SIMD cost unit).
    pub invocations: u64,
}

/// Parse the line tag from its head (`T<digits>,`): the paper parses each
/// line's tag once, when the line is first enumerated.
pub fn parse_tag(line: &TaxiLine) -> u32 {
    let bytes = line.bytes();
    let mut v: u32 = 0;
    for &b in bytes.iter().skip(1) {
        if b.is_ascii_digit() {
            v = v * 10 + (b - b'0') as u32;
        } else {
            break;
        }
    }
    v
}

/// The taxi application.
pub struct TaxiApp {
    cfg: TaxiConfig,
    kernels: Rc<KernelSet>,
}

impl TaxiApp {
    /// Create the app from a config and a shared kernel set.
    pub fn new(cfg: TaxiConfig, kernels: Rc<KernelSet>) -> TaxiApp {
        assert_eq!(cfg.width, kernels.width(), "config/kernel width mismatch");
        TaxiApp { cfg, kernels }
    }

    /// The configuration this app runs with.
    pub fn config(&self) -> &TaxiConfig {
        &self.cfg
    }

    /// Process a workload; returns the parsed pairs and metrics.
    ///
    /// Builds a one-shot [`TaxiPipeline`] over the workload's text and
    /// runs the line stream as a single shard. Long-lived callers — the
    /// sharded executor's workers — build the pipeline once and call
    /// [`TaxiPipeline::run_shard`] repeatedly instead (reset, not
    /// rebuild).
    pub fn run(&self, w: &TaxiWorkload) -> Result<TaxiReport> {
        let inv0 = self.kernels.invocations();
        let mut pipeline = TaxiPipeline::build(self.cfg, self.kernels.clone(), w.text.clone());
        let (pairs, metrics) = pipeline.run_shard(&w.lines)?;
        Ok(TaxiReport {
            pairs,
            elapsed: metrics.elapsed,
            invocations: self.kernels.invocations() - inv0,
            metrics,
        })
    }

    /// Process the workload sharded across `workers` OS threads (L3.5).
    ///
    /// Lines are the regions here: shards cut between lines (balanced by
    /// character count), each worker parses its shard with a fresh
    /// pipeline against the shared text buffer, and pairs come back in
    /// stream order — bit-identical to [`TaxiApp::run`] at any worker
    /// count (each candidate's window parse is independent of ensemble
    /// packing). See [`crate::exec`].
    pub fn run_sharded(&self, w: &TaxiWorkload, workers: usize) -> Result<TaxiReport> {
        self.run_sharded_with(w, &ExecConfig::new(workers))
    }

    /// [`TaxiApp::run_sharded`] with full executor configuration.
    pub fn run_sharded_with(&self, w: &TaxiWorkload, exec: &ExecConfig) -> Result<TaxiReport> {
        exec.validate()?;
        if exec.workers <= 1
            && exec.shard.shards_per_worker <= 1
            && exec.trace.is_none()
            && !exec.metrics
            && exec.progress.is_none()
            && exec.max_region_items == 0
            && matches!(exec.fault, crate::exec::FaultPolicy::FailFast)
        {
            // One worker, one shard, untraced, unmetered, unsplit,
            // fail-fast, inline: identical to a plain run, so reuse this
            // app's kernel set instead of spawning a fresh engine (on the
            // XLA backend that is a full PJRT spin-up). Traced or metered
            // runs and non-default fault policies always go through the
            // executor, which owns the trace lanes, the metrics hubs and
            // the recovery machinery.
            return self.run(w);
        }
        let factory = TaxiFactory::new(
            self.cfg,
            KernelSpawn::from_backend(self.kernels.backend()),
            w.text.clone(),
        );
        let report = ShardedRunner::new(exec.clone()).run(&factory, &w.lines)?;
        Ok(TaxiReport {
            pairs: report.outputs,
            metrics: report.metrics,
            elapsed: report.elapsed,
            invocations: report.invocations,
        })
    }

    /// Streaming execution (L3.5 v2): lines arrive incrementally from
    /// `source` (all viewing the shared `text` buffer), are sharded on
    /// the fly under `exec.ingest`'s in-flight budget, and execute with
    /// work stealing — pairs come back in stream order, bit-identical to
    /// [`TaxiApp::run`] over the materialized line list at any worker
    /// count. Line-index memory is bounded by the budget, not by how
    /// many lines the stream carries.
    pub fn run_streaming<S>(
        &self,
        text: Arc<Vec<u8>>,
        source: S,
        exec: &ExecConfig,
    ) -> Result<TaxiReport>
    where
        S: crate::workload::source::RegionSource<Region = TaxiLine>,
    {
        exec.validate()?;
        let factory = TaxiFactory::new(
            self.cfg,
            KernelSpawn::from_backend(self.kernels.backend()),
            text,
        );
        let report = ShardedRunner::new(exec.clone()).run_stream(&factory, source)?;
        Ok(TaxiReport {
            pairs: report.outputs,
            metrics: report.metrics,
            elapsed: report.elapsed,
            invocations: report.invocations,
        })
    }

    /// [`TaxiApp::run_streaming`] with pairs landed in a
    /// [`ResultSink`](crate::io::ResultSink) instead of collected: each
    /// shard's pairs are written as soon as their stream-order prefix
    /// completes (all three variants emit in stream order, so no
    /// post-run fold is needed). Pair with
    /// [`TextSource`](crate::io::TextSource) for the file-backed path;
    /// the returned report's `pairs` is empty and the caller calls
    /// [`finish`](crate::io::ResultSink::finish) once to flush and
    /// collect [`SinkStats`](crate::io::SinkStats).
    pub fn run_streaming_into<S, K>(
        &self,
        text: Arc<Vec<u8>>,
        source: S,
        exec: &ExecConfig,
        sink: &mut K,
    ) -> Result<TaxiReport>
    where
        S: crate::workload::source::RegionSource<Region = TaxiLine>,
        K: crate::io::ResultSink<TaxiPair> + ?Sized,
    {
        exec.validate()?;
        let factory = TaxiFactory::new(
            self.cfg,
            KernelSpawn::from_backend(self.kernels.backend()),
            text,
        );
        let report = ShardedRunner::new(exec.clone()).run_stream_into(&factory, source, sink)?;
        Ok(TaxiReport {
            pairs: Vec::new(),
            metrics: report.metrics,
            elapsed: report.elapsed,
            invocations: report.invocations,
        })
    }
}

/// A persistent, reusable taxi pipeline over one shared text buffer —
/// the worker-side half of the zero-rebuild contract (see
/// [`SumPipeline`](crate::apps::sum::SumPipeline) for the sum twin).
/// Built once per worker; every shard of lines runs `reset → feed →
/// drain` on the same graph with per-shard outputs and metrics
/// bit-identical to a fresh build's.
pub struct TaxiPipeline {
    kind: TaxiPipelineKind,
    /// Source-ring shrink policy: releases the transient high-water
    /// allocation a giant shard leaves behind (see [`SourceShrink`]).
    shrink: SourceShrink,
}

enum TaxiPipelineKind {
    /// Enumerated and hybrid variants: `TaxiLine` source → … → pair sink.
    Lines {
        pipe: Pipeline,
        src: Rc<Channel<TaxiLine>>,
        sink: Rc<RefCell<Vec<TaxiPair>>>,
    },
    /// Pure tagging: every character fed as a tagged `Candidate`.
    Tagged {
        pipe: Pipeline,
        src: Rc<Channel<Candidate>>,
        sink: Rc<RefCell<Vec<TaxiPair>>>,
    },
}

impl TaxiPipeline {
    /// Assemble the graph for `cfg` over `kernels`, parsing against the
    /// shared `text` buffer (widths must match).
    pub fn build(cfg: TaxiConfig, kernels: Rc<KernelSet>, text: Arc<Vec<u8>>) -> TaxiPipeline {
        assert_eq!(cfg.width, kernels.width(), "config/kernel width mismatch");
        let kind = match cfg.variant {
            TaxiVariant::Enumerated | TaxiVariant::Hybrid => {
                TaxiPipeline::build_lines(cfg, kernels, text)
            }
            TaxiVariant::Tagged => TaxiPipeline::build_tagged(cfg, kernels, text),
        };
        TaxiPipeline {
            kind,
            shrink: SourceShrink::new(),
        }
    }

    /// Run one shard of lines to quiescence on the persistent graph.
    /// Counters are zero at entry (the reset), so the returned
    /// [`PipelineMetrics`] cover exactly this shard.
    pub fn run_shard(&mut self, lines: &[TaxiLine]) -> Result<(Vec<TaxiPair>, PipelineMetrics)> {
        match &mut self.kind {
            TaxiPipelineKind::Lines { pipe, src, sink } => {
                pipe.reset();
                // a failed previous shard may have left partial pairs in
                // the driver-owned sink; a fresh build starts empty
                sink.borrow_mut().clear();
                // same per-shard source sizing as a fresh build (see
                // SumPipeline::run_shard): backpressure, and therefore
                // scheduling, matches the rebuild behaviour exactly
                src.set_data_capacity(lines.len().max(1));
                for line in lines {
                    src.push(line.clone());
                }
                pipe.run()?;
                // release a transient peak allocation once shard sizes
                // durably drop (physical only — logical capacity, and so
                // scheduling, is untouched; see SumPipeline::run_shard)
                if let Some(target) = self.shrink.observe(lines.len()) {
                    src.shrink_data_to(target);
                }
                Ok((super::sum::take_outputs(sink), pipe.metrics()))
            }
            TaxiPipelineKind::Tagged { pipe, src, sink } => {
                pipe.reset();
                sink.borrow_mut().clear(); // see the Lines branch
                // Dense representation: EVERY character becomes a tagged
                // item. Feed in queue-capacity batches, draining between
                // refills.
                for line in lines {
                    let tag = parse_tag(line);
                    let end = (line.start + line.len) as u32;
                    let mut off = 0usize;
                    while off < line.len {
                        let n = src.data_space().min(line.len - off);
                        let base = (line.start + off) as u32;
                        src.push_iter((0..n as u32).map(|k| Candidate {
                            abs: base + k,
                            line_end: end,
                            tag,
                        }))?;
                        off += n;
                        if off < line.len {
                            pipe.run()?;
                        }
                    }
                }
                pipe.run()?;
                Ok((super::sum::take_outputs(sink), pipe.metrics()))
            }
        }
    }

    /// Install a trace sink on the underlying pipeline's scheduler so
    /// every firing is recorded (see [`crate::trace`]). The sink
    /// survives per-shard resets, so one install covers the worker's
    /// whole lifetime.
    pub fn set_trace(&mut self, sink: crate::trace::TraceSink) {
        match &mut self.kind {
            TaxiPipelineKind::Lines { pipe, .. } | TaxiPipelineKind::Tagged { pipe, .. } => {
                pipe.set_trace(sink)
            }
        }
    }

    fn build_lines(cfg: TaxiConfig, ks: Rc<KernelSet>, text: Arc<Vec<u8>>) -> TaxiPipelineKind {
        let mut b = PipelineBuilder::new(cfg.width)
            .queue_caps(cfg.data_cap, cfg.signal_cap)
            .policy(cfg.policy);
        // capacity is re-targeted per shard in run_shard
        let src = b.source_with_cap::<TaxiLine>(1);
        let chars = b.enumerate("enum_chars", &src);
        // pure enumeration keeps candidates in the line's region; hybrid
        // closes the region and tags each candidate explicitly
        let stage1_out = match cfg.variant {
            TaxiVariant::Enumerated => StageOneOut::InRegion,
            _ => StageOneOut::TaggedCandidates,
        };
        let cands = b.node(
            "classify",
            &chars,
            ClassifyLogic::new(ks.clone(), cfg.width, stage1_out),
        );
        let parsed = match cfg.variant {
            TaxiVariant::Enumerated => b.node(
                "parse",
                &cands,
                ParseEnumLogic::new(ks.clone(), cfg.width),
            ),
            // hybrid: stage 1 closed the region; stage 2 parses tagged
            // candidates against the shared text
            _ => b.node(
                "parse",
                &cands,
                ParsePlainLogic::new(ks.clone(), cfg.width, text),
            ),
        };
        let sink = b.sink("out", &parsed, MapLogic::new(|p: &TaxiPair| *p));
        TaxiPipelineKind::Lines {
            pipe: b.build(),
            src,
            sink,
        }
    }

    fn build_tagged(cfg: TaxiConfig, ks: Rc<KernelSet>, text: Arc<Vec<u8>>) -> TaxiPipelineKind {
        let mut b = PipelineBuilder::new(cfg.width)
            .queue_caps(cfg.data_cap, cfg.signal_cap)
            .policy(cfg.policy);
        let src = b.source_with_cap::<Candidate>(cfg.data_cap);
        let cands = b.node(
            "classify",
            &src,
            TaggedClassifyLogic::new(ks.clone(), cfg.width, text.clone()),
        );
        let parsed = b.node("parse", &cands, ParsePlainLogic::new(ks, cfg.width, text));
        let sink = b.sink("out", &parsed, MapLogic::new(|p: &TaxiPair| *p));
        TaxiPipelineKind::Tagged {
            pipe: b.build(),
            src,
            sink,
        }
    }
}

/// [`PipelineFactory`] for the taxi app: one persistent [`TaxiPipeline`]
/// per worker thread over the shared text buffer (built in
/// `make_worker`, reset between shards), shards balanced by line length.
pub struct TaxiFactory {
    cfg: TaxiConfig,
    spawn: KernelSpawn,
    text: Arc<Vec<u8>>,
}

impl TaxiFactory {
    /// Create a factory that builds per-worker taxi pipelines over the shared text.
    pub fn new(cfg: TaxiConfig, spawn: KernelSpawn, text: Arc<Vec<u8>>) -> TaxiFactory {
        TaxiFactory { cfg, spawn, text }
    }
}

/// A worker-private persistent taxi pipeline: the kernel engine **and**
/// the built node graph (over the shared text) live as long as the
/// worker; every shard runs `reset → feed → drain` on the same
/// [`TaxiPipeline`] (zero rebuild).
pub struct TaxiShardWorker {
    pipeline: TaxiPipeline,
    kernels: WorkerKernels,
    /// Node graphs built over this worker's lifetime — the reuse proof:
    /// stays at 1 however many shards the worker runs.
    builds: u64,
}

impl PipelineFactory for TaxiFactory {
    type In = TaxiLine;
    type Out = TaxiPair;
    type Worker = TaxiShardWorker;

    fn make_worker(&self, _worker_id: usize) -> Result<TaxiShardWorker> {
        let kernels = self.spawn.spawn(self.cfg.width)?;
        let pipeline = TaxiPipeline::build(self.cfg, kernels.kernels.clone(), self.text.clone());
        Ok(TaxiShardWorker {
            pipeline,
            kernels,
            builds: 1,
        })
    }

    fn weight(&self, line: &TaxiLine) -> usize {
        line.len.max(1)
    }

    /// Taxi refuses intra-region splitting by name: a line's candidate
    /// windows parse against the **line context** captured at
    /// `RegionBegin` (offset, length, the shared text view), and every
    /// window's validity depends on its position within that whole line
    /// — order-dependent context state, not an associative accumulator.
    /// Cutting a line would parse windows against the wrong context.
    /// (A reorder-tolerant context variant is the named follow-on in the
    /// ROADMAP.)
    fn splittability(&self) -> crate::exec::Splittability {
        crate::exec::Splittability::Opaque {
            reason: "taxi's per-line parse context is order-dependent (candidate \
                     windows are validated against the whole line captured at \
                     RegionBegin), so a line cannot be cut into sub-shards",
        }
    }
}

impl ShardWorker for TaxiShardWorker {
    type In = TaxiLine;
    type Out = TaxiPair;

    fn run_shard(&mut self, shard: &[TaxiLine]) -> Result<ShardOutput<TaxiPair>> {
        let inv0 = self.kernels.kernels.invocations();
        let (outputs, metrics) = self.pipeline.run_shard(shard)?;
        Ok(ShardOutput {
            outputs,
            metrics,
            invocations: self.kernels.kernels.invocations() - inv0,
        })
    }

    fn pipelines_built(&self) -> u64 {
        self.builds
    }

    fn set_trace(&mut self, sink: crate::trace::TraceSink) {
        self.pipeline.set_trace(sink);
    }
}

/// What stage 1 emits.
enum StageOneOut {
    /// Line-relative offsets, staying inside the enumeration region.
    InRegion,
    /// Explicitly tagged absolute candidates; the region is closed here.
    TaggedCandidates,
}

/// Stage 1 over enumerated characters: gather + `char_classify` kernel.
struct ClassifyLogic {
    kernels: Rc<KernelSet>,
    width: usize,
    out_kind: StageOneOut,
    chars: Vec<i32>,
    mask: Vec<i32>,
    /// Kernel output staging, reused across firings (zero-alloc path).
    flags: Vec<i32>,
    bits: Vec<i32>,
    line: Option<Rc<TaxiLine>>,
    tag: u32,
}

impl ClassifyLogic {
    fn new(kernels: Rc<KernelSet>, width: usize, out_kind: StageOneOut) -> ClassifyLogic {
        ClassifyLogic {
            kernels,
            width,
            out_kind,
            chars: vec![0; width],
            mask: Vec::with_capacity(width),
            flags: vec![0; width],
            bits: vec![0; width],
            line: None,
            tag: 0,
        }
    }
}

/// Stage-1 output item: either a line-relative offset (enumerated) or a
/// tagged absolute candidate (hybrid). One type keeps the channel simple.
#[derive(Debug, Clone, Copy)]
pub enum Stage1Item {
    /// Line-relative element offset (enumerated stage 1).
    Offset(u32),
    /// Tagged absolute candidate (hybrid stage 1).
    Cand(Candidate),
}

impl NodeLogic for ClassifyLogic {
    type In = u32;
    type Out = Stage1Item;

    fn begin(&mut self, parent: &ParentRef, _out: &mut Emitter<'_, Stage1Item>) -> Result<()> {
        let line = parent_as::<TaxiLine>(parent).expect("TaxiLine parent");
        // tag parsed once per line, on first enumeration (paper §5)
        self.tag = parse_tag(&line);
        self.line = Some(line);
        Ok(())
    }

    fn run(
        &mut self,
        items: &[u32],
        parent: Option<&ParentRef>,
        out: &mut Emitter<'_, Stage1Item>,
    ) -> Result<()> {
        let line = match &self.line {
            Some(l) => l.clone(),
            None => parent_as::<TaxiLine>(parent.expect("enumerated")).expect("TaxiLine"),
        };
        let bytes = line.bytes();
        for (slot, &off) in self.chars.iter_mut().zip(items) {
            *slot = bytes[off as usize] as i32;
        }
        for slot in self.chars[items.len()..].iter_mut() {
            *slot = 0;
        }
        prefix_mask(&mut self.mask, items.len(), self.width);
        self.kernels
            .char_classify_into(&self.chars, &self.mask, &mut self.flags, &mut self.bits)?;
        for i in 0..items.len() {
            if self.flags[i] != 0 {
                match self.out_kind {
                    StageOneOut::InRegion => out.push(Stage1Item::Offset(items[i])),
                    StageOneOut::TaggedCandidates => out.push(Stage1Item::Cand(Candidate {
                        abs: line.abs(items[i]) as u32,
                        line_end: (line.start + line.len) as u32,
                        tag: self.tag,
                    })),
                }
            }
        }
        Ok(())
    }

    fn end(&mut self, _parent: &ParentRef, _out: &mut Emitter<'_, Stage1Item>) -> Result<()> {
        self.line = None;
        Ok(())
    }

    fn max_outputs_per_input(&self) -> usize {
        1
    }

    fn forward_region_signals(&self) -> bool {
        matches!(self.out_kind, StageOneOut::InRegion)
    }

    fn reset(&mut self) {
        // cross-shard reuse: a clean run closes the line at end(), but
        // reset guarantees no region context leaks into the next shard
        self.line = None;
        self.tag = 0;
    }
}

/// Stage 2 inside the enumeration region (pure-enumeration variant):
/// candidates are line-relative; the parent supplies text and tag.
struct ParseEnumLogic {
    kernels: Rc<KernelSet>,
    width: usize,
    windows: Vec<i32>,
    mask: Vec<i32>,
    /// Kernel output staging, reused across firings (zero-alloc path).
    xs: Vec<f32>,
    ys: Vec<f32>,
    oks: Vec<i32>,
    line: Option<Rc<TaxiLine>>,
    tag: u32,
}

impl ParseEnumLogic {
    fn new(kernels: Rc<KernelSet>, width: usize) -> ParseEnumLogic {
        let wl = kernels.window_len();
        ParseEnumLogic {
            kernels,
            width,
            windows: vec![0; width * wl],
            mask: Vec::with_capacity(width),
            xs: vec![0.0; width],
            ys: vec![0.0; width],
            oks: vec![0; width],
            line: None,
            tag: 0,
        }
    }
}

fn fill_window(dst: &mut [i32], text: &[u8], start: usize, end: usize) {
    let avail = end.saturating_sub(start).min(dst.len());
    for (k, slot) in dst.iter_mut().enumerate() {
        *slot = if k < avail { text[start + k] as i32 } else { 0 };
    }
}

impl NodeLogic for ParseEnumLogic {
    type In = Stage1Item;
    type Out = TaxiPair;

    fn begin(&mut self, parent: &ParentRef, _out: &mut Emitter<'_, TaxiPair>) -> Result<()> {
        let line = parent_as::<TaxiLine>(parent).expect("TaxiLine parent");
        self.tag = parse_tag(&line);
        self.line = Some(line);
        Ok(())
    }

    fn end(&mut self, _parent: &ParentRef, _out: &mut Emitter<'_, TaxiPair>) -> Result<()> {
        self.line = None;
        Ok(())
    }

    fn run(
        &mut self,
        items: &[Stage1Item],
        parent: Option<&ParentRef>,
        out: &mut Emitter<'_, TaxiPair>,
    ) -> Result<()> {
        let line = match &self.line {
            Some(l) => l.clone(),
            None => parent_as::<TaxiLine>(parent.expect("enumerated")).expect("TaxiLine"),
        };
        let wl = self.kernels.window_len();
        let text: &[u8] = &line.text;
        let line_end = line.start + line.len;
        for (i, item) in items.iter().enumerate() {
            let off = match item {
                Stage1Item::Offset(o) => *o,
                Stage1Item::Cand(_) => unreachable!("enum variant emits offsets"),
            };
            let abs = line.abs(off);
            fill_window(&mut self.windows[i * wl..(i + 1) * wl], text, abs, line_end);
        }
        for i in items.len()..self.width {
            self.windows[i * wl..(i + 1) * wl].fill(0);
        }
        prefix_mask(&mut self.mask, items.len(), self.width);
        self.kernels.coord_parse_into(
            &self.windows,
            &self.mask,
            &mut self.xs,
            &mut self.ys,
            &mut self.oks,
        )?;
        for i in 0..items.len() {
            if self.oks[i] != 0 {
                out.push(TaxiPair {
                    tag: self.tag,
                    x: self.xs[i],
                    y: self.ys[i],
                });
            }
        }
        Ok(())
    }

    fn max_outputs_per_input(&self) -> usize {
        1
    }

    fn reset(&mut self) {
        self.line = None;
        self.tag = 0;
    }
}

/// Stage 2 outside any region (hybrid + tagged variants): candidates carry
/// their own tag and window bounds; ensembles mix lines freely.
struct ParsePlainLogic {
    kernels: Rc<KernelSet>,
    width: usize,
    text: Arc<Vec<u8>>,
    windows: Vec<i32>,
    mask: Vec<i32>,
    /// Kernel output staging, reused across firings (zero-alloc path).
    xs: Vec<f32>,
    ys: Vec<f32>,
    oks: Vec<i32>,
}

impl ParsePlainLogic {
    fn new(kernels: Rc<KernelSet>, width: usize, text: Arc<Vec<u8>>) -> ParsePlainLogic {
        let wl = kernels.window_len();
        ParsePlainLogic {
            kernels,
            width,
            text,
            windows: vec![0; width * wl],
            mask: Vec::with_capacity(width),
            xs: vec![0.0; width],
            ys: vec![0.0; width],
            oks: vec![0; width],
        }
    }
}

impl NodeLogic for ParsePlainLogic {
    type In = Stage1Item;
    type Out = TaxiPair;

    fn run(
        &mut self,
        items: &[Stage1Item],
        _parent: Option<&ParentRef>,
        out: &mut Emitter<'_, TaxiPair>,
    ) -> Result<()> {
        let wl = self.kernels.window_len();
        for (i, item) in items.iter().enumerate() {
            let c = match item {
                Stage1Item::Cand(c) => *c,
                Stage1Item::Offset(_) => unreachable!("plain parse needs tagged candidates"),
            };
            fill_window(
                &mut self.windows[i * wl..(i + 1) * wl],
                &self.text,
                c.abs as usize,
                c.line_end as usize,
            );
        }
        for i in items.len()..self.width {
            self.windows[i * wl..(i + 1) * wl].fill(0);
        }
        prefix_mask(&mut self.mask, items.len(), self.width);
        self.kernels.coord_parse_into(
            &self.windows,
            &self.mask,
            &mut self.xs,
            &mut self.ys,
            &mut self.oks,
        )?;
        for (i, item) in items.iter().enumerate() {
            if self.oks[i] != 0 {
                let tag = match item {
                    Stage1Item::Cand(c) => c.tag,
                    Stage1Item::Offset(_) => unreachable!(),
                };
                out.push(TaxiPair {
                    tag,
                    x: self.xs[i],
                    y: self.ys[i],
                });
            }
        }
        Ok(())
    }

    fn max_outputs_per_input(&self) -> usize {
        1
    }
}

/// Stage 1 of the pure-tagging variant: every char arrives as a tagged
/// item; classification runs the fused kernel that also does the per-tag
/// bookkeeping (the dense representation's overhead).
struct TaggedClassifyLogic {
    kernels: Rc<KernelSet>,
    width: usize,
    text: Arc<Vec<u8>>,
    chars: Vec<i32>,
    tags_dense: Vec<i32>,
    mask: Vec<i32>,
    local: Vec<i32>,
    uniq: Vec<u64>,
    tag_scratch: Vec<u64>,
    /// Kernel output staging, reused across firings (zero-alloc path).
    flags: Vec<i32>,
    bits: Vec<i32>,
    counts: Vec<i32>,
}

impl TaggedClassifyLogic {
    fn new(kernels: Rc<KernelSet>, width: usize, text: Arc<Vec<u8>>) -> TaggedClassifyLogic {
        TaggedClassifyLogic {
            kernels,
            width,
            text,
            chars: vec![0; width],
            tags_dense: vec![0; width],
            mask: Vec::with_capacity(width),
            local: Vec::with_capacity(width),
            uniq: Vec::with_capacity(width),
            tag_scratch: Vec::with_capacity(width),
            flags: vec![0; width],
            bits: vec![0; width],
            counts: vec![0; width],
        }
    }
}

impl NodeLogic for TaggedClassifyLogic {
    type In = Candidate;
    type Out = Stage1Item;

    fn run(
        &mut self,
        items: &[Candidate],
        _parent: Option<&ParentRef>,
        out: &mut Emitter<'_, Stage1Item>,
    ) -> Result<()> {
        self.tag_scratch.clear();
        for (i, c) in items.iter().enumerate() {
            self.chars[i] = self.text[c.abs as usize] as i32;
            self.tag_scratch.push(c.tag as u64);
        }
        for slot in self.chars[items.len()..].iter_mut() {
            *slot = 0;
        }
        crate::coordinator::tagging::densify_tags(
            &self.tag_scratch,
            &mut self.local,
            &mut self.uniq,
        );
        self.tags_dense[..items.len()].copy_from_slice(&self.local);
        for slot in self.tags_dense[items.len()..].iter_mut() {
            *slot = 0;
        }
        prefix_mask(&mut self.mask, items.len(), self.width);
        self.kernels.tagged_char_stage_into(
            &self.chars,
            &self.tags_dense,
            &self.mask,
            &mut self.flags,
            &mut self.bits,
            &mut self.counts,
        )?;
        for (i, c) in items.iter().enumerate() {
            if self.flags[i] != 0 {
                out.push(Stage1Item::Cand(*c));
            }
        }
        Ok(())
    }

    fn max_outputs_per_input(&self) -> usize {
        1
    }
}

/// Independent ground truth for validation: parse the text with plain Rust
/// string handling (no kernels, no pipeline).
pub fn reference_pairs(w: &TaxiWorkload) -> Vec<TaxiPair> {
    let mut out = Vec::new();
    for line in &w.lines {
        let tag = parse_tag(line);
        let bytes = line.bytes();
        let mut i = 0;
        while i < bytes.len() {
            if bytes[i] == b'{' {
                let end = line.start + line.len;
                let mut win = vec![0i32; crate::runtime::native::WINDOW_LEN];
                fill_window(&mut win, &line.text, line.start + i, end);
                let (a, b, ok) = crate::runtime::native::parse_window(&win);
                if ok {
                    out.push(TaxiPair { tag, x: b, y: a });
                }
            }
            i += 1;
        }
    }
    out
}

/// Sort pairs for order-insensitive comparison across variants.
pub fn sort_pairs(pairs: &mut [TaxiPair]) {
    pairs.sort_by(|p, q| {
        (p.tag, p.x.to_bits(), p.y.to_bits()).cmp(&(q.tag, q.x.to_bits(), q.y.to_bits()))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::taxi::{generate, TaxiGenConfig};

    fn small_workload() -> TaxiWorkload {
        generate(
            12,
            TaxiGenConfig {
                avg_pairs: 6,
                avg_line_len: 160,
            },
            42,
        )
    }

    fn run_variant(v: TaxiVariant, w: &TaxiWorkload, width: usize) -> TaxiReport {
        let app = TaxiApp::new(
            TaxiConfig {
                width,
                variant: v,
                data_cap: 512,
                signal_cap: 128,
                policy: Policy::GreedyOccupancy,
            },
            Rc::new(KernelSet::native(width)),
        );
        app.run(w).unwrap()
    }

    #[test]
    fn all_variants_agree_with_reference() {
        let w = small_workload();
        let mut want = reference_pairs(&w);
        assert_eq!(want.len(), w.total_pairs);
        sort_pairs(&mut want);
        for v in TaxiVariant::all() {
            let mut got = run_variant(v, &w, 8).pairs;
            sort_pairs(&mut got);
            assert_eq!(got.len(), want.len(), "variant {v:?} pair count");
            for (g, e) in got.iter().zip(&want) {
                assert_eq!(g.tag, e.tag, "variant {v:?}");
                assert_eq!(g.x.to_bits(), e.x.to_bits(), "variant {v:?}");
                assert_eq!(g.y.to_bits(), e.y.to_bits(), "variant {v:?}");
            }
        }
    }

    #[test]
    fn enumerated_preserves_stream_order() {
        let w = small_workload();
        let got = run_variant(TaxiVariant::Enumerated, &w, 8).pairs;
        let want = reference_pairs(&w);
        assert_eq!(got.len(), want.len());
        for (g, e) in got.iter().zip(&want) {
            assert_eq!((g.tag, g.x.to_bits()), (e.tag, e.x.to_bits()));
        }
    }

    #[test]
    fn occupancy_split_matches_paper_shape() {
        // stage 1 (chars/line >> width) mostly full; stage 2 in the
        // enumerated variant (pairs/line < width) mostly partial; hybrid's
        // stage 2 mostly full.
        let w = generate(
            30,
            TaxiGenConfig {
                avg_pairs: 5,
                avg_line_len: 300,
            },
            7,
        );
        let e = run_variant(TaxiVariant::Enumerated, &w, 16);
        let h = run_variant(TaxiVariant::Hybrid, &w, 16);
        let e_s1 = e.metrics.node("classify").unwrap().full_fraction();
        let e_s2 = e.metrics.node("parse").unwrap().full_fraction();
        let h_s2 = h.metrics.node("parse").unwrap().full_fraction();
        assert!(e_s1 > 0.7, "enum stage1 full fraction {e_s1}");
        assert!(e_s2 < 0.3, "enum stage2 full fraction {e_s2}");
        assert!(h_s2 > 0.7, "hybrid stage2 full fraction {h_s2}");
    }

    #[test]
    fn tagged_variant_runs_full_ensembles_everywhere() {
        let w = small_workload();
        let t = run_variant(TaxiVariant::Tagged, &w, 8);
        let s1 = t.metrics.node("classify").unwrap();
        assert!(
            s1.occupancy() > 0.95,
            "tagged stage1 occupancy {}",
            s1.occupancy()
        );
    }

    #[test]
    fn sharded_run_is_bitwise_identical() {
        let w = small_workload();
        let app = TaxiApp::new(
            TaxiConfig {
                width: 8,
                variant: TaxiVariant::Hybrid,
                data_cap: 512,
                signal_cap: 128,
                policy: Policy::GreedyOccupancy,
            },
            Rc::new(KernelSet::native(8)),
        );
        let single = app.run(&w).unwrap();
        let sharded = app.run_sharded(&w, 3).unwrap();
        assert_eq!(sharded.pairs.len(), single.pairs.len());
        for (a, b) in sharded.pairs.iter().zip(&single.pairs) {
            assert_eq!(a.tag, b.tag);
            assert_eq!(a.x.to_bits(), b.x.to_bits());
            assert_eq!(a.y.to_bits(), b.y.to_bits());
        }
    }

    #[test]
    fn streamed_run_is_bitwise_identical() {
        let w = small_workload();
        let app = TaxiApp::new(
            TaxiConfig {
                width: 8,
                variant: TaxiVariant::Hybrid,
                data_cap: 512,
                signal_cap: 128,
                policy: Policy::GreedyOccupancy,
            },
            Rc::new(KernelSet::native(8)),
        );
        let single = app.run(&w).unwrap();
        let exec = crate::exec::ExecConfig::new(3).streaming(8);
        let streamed = app
            .run_streaming(
                w.text.clone(),
                crate::workload::source::SliceSource::new(&w.lines),
                &exec,
            )
            .unwrap();
        assert_eq!(streamed.pairs.len(), single.pairs.len());
        for (a, b) in streamed.pairs.iter().zip(&single.pairs) {
            assert_eq!(a.tag, b.tag);
            assert_eq!(a.x.to_bits(), b.x.to_bits());
            assert_eq!(a.y.to_bits(), b.y.to_bits());
        }
    }

    #[test]
    fn persistent_pipeline_reuse_matches_fresh_runs() {
        let w = generate(
            30,
            TaxiGenConfig {
                avg_pairs: 5,
                avg_line_len: 200,
            },
            13,
        );
        for variant in TaxiVariant::all() {
            let app = TaxiApp::new(
                TaxiConfig {
                    width: 8,
                    variant,
                    data_cap: 512,
                    signal_cap: 128,
                    policy: Policy::GreedyOccupancy,
                },
                Rc::new(KernelSet::native(8)),
            );
            let mut pipeline =
                TaxiPipeline::build(*app.config(), Rc::new(KernelSet::native(8)), w.text.clone());
            for shard in w.lines.chunks(7) {
                let shard_w = TaxiWorkload {
                    text: w.text.clone(),
                    lines: shard.to_vec(),
                    total_pairs: 0,
                };
                let fresh = app.run(&shard_w).unwrap(); // builds per call: the oracle
                let (pairs, metrics) = pipeline.run_shard(shard).unwrap();
                assert_eq!(pairs.len(), fresh.pairs.len(), "{variant:?}");
                for (g, e) in pairs.iter().zip(&fresh.pairs) {
                    assert_eq!(g.tag, e.tag, "{variant:?}");
                    assert_eq!(g.x.to_bits(), e.x.to_bits(), "{variant:?}");
                    assert_eq!(g.y.to_bits(), e.y.to_bits(), "{variant:?}");
                }
                let (g, e) = (
                    metrics.node("parse").unwrap(),
                    fresh.metrics.node("parse").unwrap(),
                );
                assert_eq!(g.firings, e.firings, "{variant:?}");
                assert_eq!(g.ensemble_hist, e.ensemble_hist, "{variant:?}");
            }
        }
    }

    #[test]
    fn parse_tag_reads_line_head() {
        let w = small_workload();
        for line in &w.lines {
            assert_eq!(parse_tag(line), line.tag);
        }
    }
}
