//! Typed ensemble-kernel wrappers over the AOT artifacts.
//!
//! [`KernelSet`] bundles every L1 kernel at one ensemble width behind a
//! typed API, with two interchangeable backends:
//!
//! * **Xla** — the measured configuration: each call is one PJRT
//!   invocation of the AOT-compiled fixed-width module (the "SIMD
//!   processor executes one ensemble" cost unit of the paper's model).
//! * **Native** — the pure-Rust mirror from [`super::native`], used by
//!   coordinator unit tests and as an oracle for the XLA backend.
//!
//! All slices must be exactly `width` lanes; the coordinator owns padding
//! and masking (occupancy is its concern, not the kernels').

use std::cell::{Cell, RefCell};
use std::rc::Rc;

use anyhow::Result;

use super::{lit_f32, lit_i32, lit_i32_2d, native, Engine, KernelName, LoadedKernel};

/// Reusable staging buffers owned by a [`KernelSet`], so wrapper-internal
/// intermediates (e.g. the native `tagged_char_stage` flag→f32 cast and
/// segmented-sum outputs) are allocated once and reused across firings —
/// part of the zero-allocation steady-state contract (EXPERIMENTS.md
/// §Perf).
#[derive(Default)]
struct KernelScratch {
    f32_a: Vec<f32>,
    f32_b: Vec<f32>,
    i32_a: Vec<i32>,
}

/// Which backend a [`KernelSet`] runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust mirror of the kernels (tests / no-artifacts fallback).
    Native,
    /// AOT artifacts through PJRT (the measured hot path).
    Xla,
}

enum SetImpl {
    Native,
    Xla {
        filter_scale: Rc<LoadedKernel>,
        masked_sum: Rc<LoadedKernel>,
        sum_region: Rc<LoadedKernel>,
        segmented_sum: Rc<LoadedKernel>,
        tagged_sum_region: Rc<LoadedKernel>,
        char_classify: Rc<LoadedKernel>,
        coord_parse: Rc<LoadedKernel>,
        tagged_char_stage: Rc<LoadedKernel>,
    },
}

/// All ensemble kernels at one width.
pub struct KernelSet {
    width: usize,
    window_len: usize,
    imp: SetImpl,
    native_invocations: Cell<u64>,
    scratch: RefCell<KernelScratch>,
}

impl KernelSet {
    /// Pure-Rust backend.
    pub fn native(width: usize) -> KernelSet {
        KernelSet {
            width,
            window_len: native::WINDOW_LEN,
            imp: SetImpl::Native,
            native_invocations: Cell::new(0),
            scratch: RefCell::new(KernelScratch::default()),
        }
    }

    /// XLA backend: compiles (memoized in `engine`) every kernel at `width`.
    pub fn xla(engine: &Engine, width: usize) -> Result<KernelSet> {
        Ok(KernelSet {
            width,
            window_len: engine.store().manifest().window_len,
            imp: SetImpl::Xla {
                filter_scale: engine.kernel(KernelName::FilterScale, width)?,
                masked_sum: engine.kernel(KernelName::MaskedSum, width)?,
                sum_region: engine.kernel(KernelName::SumRegion, width)?,
                segmented_sum: engine.kernel(KernelName::SegmentedSum, width)?,
                tagged_sum_region: engine.kernel(KernelName::TaggedSumRegion, width)?,
                char_classify: engine.kernel(KernelName::CharClassify, width)?,
                coord_parse: engine.kernel(KernelName::CoordParse, width)?,
                tagged_char_stage: engine.kernel(KernelName::TaggedCharStage, width)?,
            },
            native_invocations: Cell::new(0),
            scratch: RefCell::new(KernelScratch::default()),
        })
    }

    /// Which backend this set runs on.
    pub fn backend(&self) -> Backend {
        match self.imp {
            SetImpl::Native => Backend::Native,
            SetImpl::Xla { .. } => Backend::Xla,
        }
    }

    /// Ensemble width `w` (SIMD lanes per firing).
    pub fn width(&self) -> usize {
        self.width
    }

    /// `coord_parse` window length.
    pub fn window_len(&self) -> usize {
        self.window_len
    }

    /// Number of kernel invocations so far (both backends).
    pub fn invocations(&self) -> u64 {
        match &self.imp {
            SetImpl::Native => self.native_invocations.get(),
            SetImpl::Xla {
                filter_scale,
                masked_sum,
                sum_region,
                segmented_sum,
                tagged_sum_region,
                char_classify,
                coord_parse,
                tagged_char_stage,
            } => [
                filter_scale,
                masked_sum,
                sum_region,
                segmented_sum,
                tagged_sum_region,
                char_classify,
                coord_parse,
                tagged_char_stage,
            ]
            .iter()
            .map(|k| k.invocations.get())
            .sum(),
        }
    }

    fn tick(&self) {
        self.native_invocations.set(self.native_invocations.get() + 1);
    }

    fn check_w(&self, n: usize) {
        debug_assert_eq!(n, self.width, "ensemble buffer must be exactly width");
    }

    /// Masked filter + scale (paper Fig. 5 node `f`).
    pub fn filter_scale(
        &self,
        vals: &[f32],
        mask: &[i32],
        threshold: f32,
    ) -> Result<(Vec<f32>, Vec<i32>)> {
        self.check_w(vals.len());
        match &self.imp {
            SetImpl::Native => {
                self.tick();
                Ok(native::filter_scale(vals, mask, threshold))
            }
            SetImpl::Xla { filter_scale, .. } => {
                let out =
                    filter_scale.call(&[lit_f32(vals), lit_i32(mask), lit_f32(&[threshold])])?;
                Ok((out[0].to_vec::<f32>()?, out[1].to_vec::<i32>()?))
            }
        }
    }

    /// Sum + count of active lanes (aggregation accumulate).
    pub fn masked_sum(&self, vals: &[f32], mask: &[i32]) -> Result<(f32, i32)> {
        self.check_w(vals.len());
        match &self.imp {
            SetImpl::Native => {
                self.tick();
                Ok(native::masked_sum(vals, mask))
            }
            SetImpl::Xla { masked_sum, .. } => {
                let out = masked_sum.call(&[lit_f32(vals), lit_i32(mask)])?;
                Ok((
                    out[0].to_vec::<f32>()?[0],
                    out[1].to_vec::<i32>()?[0],
                ))
            }
        }
    }

    /// Fused filter+scale+partial-sum (sum-app hot path).
    pub fn sum_region(&self, vals: &[f32], mask: &[i32], threshold: f32) -> Result<(f32, i32)> {
        self.check_w(vals.len());
        match &self.imp {
            SetImpl::Native => {
                self.tick();
                Ok(native::sum_region(vals, mask, threshold))
            }
            SetImpl::Xla { sum_region, .. } => {
                let out = sum_region.call(&[lit_f32(vals), lit_i32(mask), lit_f32(&[threshold])])?;
                Ok((
                    out[0].to_vec::<f32>()?[0],
                    out[1].to_vec::<i32>()?[0],
                ))
            }
        }
    }

    /// Per-segment sums within an ensemble (tagging baseline).
    pub fn segmented_sum(
        &self,
        vals: &[f32],
        seg: &[i32],
        mask: &[i32],
    ) -> Result<(Vec<f32>, Vec<i32>)> {
        self.check_w(vals.len());
        match &self.imp {
            SetImpl::Native => {
                self.tick();
                Ok(native::segmented_sum(vals, seg, mask))
            }
            SetImpl::Xla { segmented_sum, .. } => {
                let out = segmented_sum.call(&[lit_f32(vals), lit_i32(seg), lit_i32(mask)])?;
                Ok((out[0].to_vec::<f32>()?, out[1].to_vec::<i32>()?))
            }
        }
    }

    /// Fused filter+scale+per-segment-sum (perf-pass kernel: one
    /// invocation per tagged ensemble instead of filter_scale +
    /// segmented_sum — see EXPERIMENTS.md §Perf).
    pub fn tagged_sum_region(
        &self,
        vals: &[f32],
        seg: &[i32],
        mask: &[i32],
        threshold: f32,
    ) -> Result<(Vec<f32>, Vec<i32>)> {
        self.check_w(vals.len());
        match &self.imp {
            SetImpl::Native => {
                self.tick();
                Ok(native::tagged_sum_region(vals, seg, mask, threshold))
            }
            SetImpl::Xla {
                tagged_sum_region, ..
            } => {
                let out = tagged_sum_region.call(&[
                    lit_f32(vals),
                    lit_i32(seg),
                    lit_i32(mask),
                    lit_f32(&[threshold]),
                ])?;
                Ok((out[0].to_vec::<f32>()?, out[1].to_vec::<i32>()?))
            }
        }
    }

    /// Candidate detection over a char ensemble (taxi stage 1).
    pub fn char_classify(&self, chars: &[i32], mask: &[i32]) -> Result<(Vec<i32>, Vec<i32>)> {
        self.check_w(chars.len());
        match &self.imp {
            SetImpl::Native => {
                self.tick();
                Ok(native::char_classify(chars, mask))
            }
            SetImpl::Xla { char_classify, .. } => {
                let out = char_classify.call(&[lit_i32(chars), lit_i32(mask)])?;
                Ok((out[0].to_vec::<i32>()?, out[1].to_vec::<i32>()?))
            }
        }
    }

    /// Verify + parse candidate windows (taxi stage 2). `windows` is
    /// row-major `[width, window_len]`.
    pub fn coord_parse(
        &self,
        windows: &[i32],
        mask: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<i32>)> {
        self.check_w(mask.len());
        debug_assert_eq!(windows.len(), self.width * self.window_len);
        match &self.imp {
            SetImpl::Native => {
                self.tick();
                Ok(native::coord_parse(windows, self.window_len, mask))
            }
            SetImpl::Xla { coord_parse, .. } => {
                let out = coord_parse.call(&[
                    lit_i32_2d(windows, self.width, self.window_len)?,
                    lit_i32(mask),
                ])?;
                Ok((
                    out[0].to_vec::<f32>()?,
                    out[1].to_vec::<f32>()?,
                    out[2].to_vec::<i32>()?,
                ))
            }
        }
    }

    /// Fused classify + per-tag candidate counts (pure-tagging taxi).
    pub fn tagged_char_stage(
        &self,
        chars: &[i32],
        tags: &[i32],
        mask: &[i32],
    ) -> Result<(Vec<i32>, Vec<i32>, Vec<i32>)> {
        let w = chars.len();
        let mut flags = vec![0i32; w];
        let mut bits = vec![0i32; w];
        let mut counts = vec![0i32; w];
        self.tagged_char_stage_into(chars, tags, mask, &mut flags, &mut bits, &mut counts)?;
        Ok((flags, bits, counts))
    }

    // ---- in-place variants (the allocation-free firing hot path) ------
    //
    // Each writes into caller-provided slices sized exactly `width`; the
    // node logics own those buffers and reuse them across firings, so a
    // steady-state firing performs zero heap allocations on the native
    // backend. (The XLA backend still allocates inside the PJRT literal
    // round-trip; buffer donation there is a ROADMAP item.)

    /// [`KernelSet::filter_scale`] into caller slices.
    pub fn filter_scale_into(
        &self,
        vals: &[f32],
        mask: &[i32],
        threshold: f32,
        out_vals: &mut [f32],
        out_mask: &mut [i32],
    ) -> Result<()> {
        self.check_w(vals.len());
        match &self.imp {
            SetImpl::Native => {
                self.tick();
                native::filter_scale_into(vals, mask, threshold, out_vals, out_mask);
                Ok(())
            }
            SetImpl::Xla { filter_scale, .. } => {
                let out =
                    filter_scale.call(&[lit_f32(vals), lit_i32(mask), lit_f32(&[threshold])])?;
                out_vals.copy_from_slice(&out[0].to_vec::<f32>()?);
                out_mask.copy_from_slice(&out[1].to_vec::<i32>()?);
                Ok(())
            }
        }
    }

    /// [`KernelSet::segmented_sum`] into caller slices.
    pub fn segmented_sum_into(
        &self,
        vals: &[f32],
        seg: &[i32],
        mask: &[i32],
        out_sums: &mut [f32],
        out_counts: &mut [i32],
    ) -> Result<()> {
        self.check_w(vals.len());
        match &self.imp {
            SetImpl::Native => {
                self.tick();
                native::segmented_sum_into(vals, seg, mask, out_sums, out_counts);
                Ok(())
            }
            SetImpl::Xla { segmented_sum, .. } => {
                let out = segmented_sum.call(&[lit_f32(vals), lit_i32(seg), lit_i32(mask)])?;
                out_sums.copy_from_slice(&out[0].to_vec::<f32>()?);
                out_counts.copy_from_slice(&out[1].to_vec::<i32>()?);
                Ok(())
            }
        }
    }

    /// [`KernelSet::tagged_sum_region`] into caller slices.
    pub fn tagged_sum_region_into(
        &self,
        vals: &[f32],
        seg: &[i32],
        mask: &[i32],
        threshold: f32,
        out_sums: &mut [f32],
        out_counts: &mut [i32],
    ) -> Result<()> {
        self.check_w(vals.len());
        match &self.imp {
            SetImpl::Native => {
                self.tick();
                native::tagged_sum_region_into(vals, seg, mask, threshold, out_sums, out_counts);
                Ok(())
            }
            SetImpl::Xla {
                tagged_sum_region, ..
            } => {
                let out = tagged_sum_region.call(&[
                    lit_f32(vals),
                    lit_i32(seg),
                    lit_i32(mask),
                    lit_f32(&[threshold]),
                ])?;
                out_sums.copy_from_slice(&out[0].to_vec::<f32>()?);
                out_counts.copy_from_slice(&out[1].to_vec::<i32>()?);
                Ok(())
            }
        }
    }

    /// [`KernelSet::char_classify`] into caller slices.
    pub fn char_classify_into(
        &self,
        chars: &[i32],
        mask: &[i32],
        out_flags: &mut [i32],
        out_bits: &mut [i32],
    ) -> Result<()> {
        self.check_w(chars.len());
        match &self.imp {
            SetImpl::Native => {
                self.tick();
                native::char_classify_into(chars, mask, out_flags, out_bits);
                Ok(())
            }
            SetImpl::Xla { char_classify, .. } => {
                let out = char_classify.call(&[lit_i32(chars), lit_i32(mask)])?;
                out_flags.copy_from_slice(&out[0].to_vec::<i32>()?);
                out_bits.copy_from_slice(&out[1].to_vec::<i32>()?);
                Ok(())
            }
        }
    }

    /// [`KernelSet::coord_parse`] into caller slices.
    pub fn coord_parse_into(
        &self,
        windows: &[i32],
        mask: &[i32],
        out_x: &mut [f32],
        out_y: &mut [f32],
        out_ok: &mut [i32],
    ) -> Result<()> {
        self.check_w(mask.len());
        debug_assert_eq!(windows.len(), self.width * self.window_len);
        match &self.imp {
            SetImpl::Native => {
                self.tick();
                native::coord_parse_into(windows, self.window_len, mask, out_x, out_y, out_ok);
                Ok(())
            }
            SetImpl::Xla { coord_parse, .. } => {
                let out = coord_parse.call(&[
                    lit_i32_2d(windows, self.width, self.window_len)?,
                    lit_i32(mask),
                ])?;
                out_x.copy_from_slice(&out[0].to_vec::<f32>()?);
                out_y.copy_from_slice(&out[1].to_vec::<f32>()?);
                out_ok.copy_from_slice(&out[2].to_vec::<i32>()?);
                Ok(())
            }
        }
    }

    /// [`KernelSet::tagged_char_stage`] into caller slices. The native
    /// backend stages its flag→f32 cast and segmented-sum intermediates
    /// in the set-owned scratch pool — no per-call allocation.
    pub fn tagged_char_stage_into(
        &self,
        chars: &[i32],
        tags: &[i32],
        mask: &[i32],
        out_flags: &mut [i32],
        out_bits: &mut [i32],
        out_counts: &mut [i32],
    ) -> Result<()> {
        self.check_w(chars.len());
        match &self.imp {
            SetImpl::Native => {
                self.tick();
                native::char_classify_into(chars, mask, out_flags, out_bits);
                let mut scratch = self.scratch.borrow_mut();
                let KernelScratch { f32_a, f32_b, i32_a } = &mut *scratch;
                f32_a.clear();
                f32_a.extend(out_flags.iter().map(|&f| f as f32));
                f32_b.resize(self.width, 0.0);
                i32_a.resize(self.width, 0);
                native::segmented_sum_into(f32_a, tags, mask, f32_b, i32_a);
                for (c, s) in out_counts.iter_mut().zip(f32_b.iter()) {
                    *c = *s as i32;
                }
                Ok(())
            }
            SetImpl::Xla {
                tagged_char_stage, ..
            } => {
                let out =
                    tagged_char_stage.call(&[lit_i32(chars), lit_i32(tags), lit_i32(mask)])?;
                out_flags.copy_from_slice(&out[0].to_vec::<i32>()?);
                out_bits.copy_from_slice(&out[1].to_vec::<i32>()?);
                out_counts.copy_from_slice(&out[2].to_vec::<i32>()?);
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_set_matches_native_module() {
        let ks = KernelSet::native(8);
        assert_eq!(ks.backend(), Backend::Native);
        let vals = [1.0, -2.0, 3.0, 4.0, -5.0, 6.0, 7.0, 8.0];
        let mask = [1, 1, 1, 1, 1, 1, 0, 0];
        let (s, k) = ks.sum_region(&vals, &mask, 0.0).unwrap();
        let (es, ek) = native::sum_region(&vals, &mask, 0.0);
        assert_eq!((s, k), (es, ek));
        assert_eq!(ks.invocations(), 1);
    }

    #[test]
    fn native_tagged_stage_counts_braces() {
        let ks = KernelSet::native(4);
        let chars: Vec<i32> = "{x{y".bytes().map(|b| b as i32).collect();
        let tags = [0, 0, 1, 1];
        let mask = [1, 1, 1, 1];
        let (flags, _, counts) = ks.tagged_char_stage(&chars, &tags, &mask).unwrap();
        assert_eq!(flags, vec![1, 0, 1, 0]);
        assert_eq!(counts[0], 1);
        assert_eq!(counts[1], 1);
    }

    #[test]
    fn into_variants_match_vec_apis() {
        let ks = KernelSet::native(8);
        let vals = [1.0f32, -2.0, 3.0, 4.0, -5.0, 6.0, 7.0, 8.0];
        let mask = [1, 1, 1, 1, 1, 1, 0, 0];
        let seg = [0, 0, 1, 1, 2, 2, 3, 3];

        let (ov, om) = ks.filter_scale(&vals, &mask, 0.0).unwrap();
        let mut iv = vec![9.0f32; 8];
        let mut im = vec![9i32; 8];
        ks.filter_scale_into(&vals, &mask, 0.0, &mut iv, &mut im)
            .unwrap();
        assert_eq!(om, im);
        for (a, b) in ov.iter().zip(&iv) {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        let (s, c) = ks.tagged_sum_region(&vals, &seg, &mask, 0.0).unwrap();
        let mut is = vec![9.0f32; 8];
        let mut ic = vec![9i32; 8];
        ks.tagged_sum_region_into(&vals, &seg, &mask, 0.0, &mut is, &mut ic)
            .unwrap();
        assert_eq!(c, ic);
        for (a, b) in s.iter().zip(&is) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn tagged_stage_into_reuses_scratch() {
        let ks = KernelSet::native(4);
        let chars: Vec<i32> = "{x{y".bytes().map(|b| b as i32).collect();
        let tags = [0, 0, 1, 1];
        let mask = [1, 1, 1, 1];
        let (mut f, mut b, mut c) = (vec![9; 4], vec![9; 4], vec![9; 4]);
        for _ in 0..3 {
            ks.tagged_char_stage_into(&chars, &tags, &mask, &mut f, &mut b, &mut c)
                .unwrap();
            assert_eq!(f, vec![1, 0, 1, 0]);
            assert_eq!(&c[..2], &[1, 1]);
        }
    }

    #[test]
    #[should_panic(expected = "ensemble buffer")]
    #[cfg(debug_assertions)]
    fn wrong_width_panics_in_debug() {
        let ks = KernelSet::native(8);
        let _ = ks.masked_sum(&[1.0; 4], &[1; 4]);
    }
}
