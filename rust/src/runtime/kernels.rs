//! Typed ensemble-kernel wrappers over the AOT artifacts.
//!
//! [`KernelSet`] bundles every L1 kernel at one ensemble width behind a
//! typed API, with two interchangeable backends:
//!
//! * **Xla** — the measured configuration: each call is one PJRT
//!   invocation of the AOT-compiled fixed-width module (the "SIMD
//!   processor executes one ensemble" cost unit of the paper's model).
//! * **Native** — the pure-Rust mirror from [`super::native`], used by
//!   coordinator unit tests and as an oracle for the XLA backend.
//!
//! All slices must be exactly `width` lanes; the coordinator owns padding
//! and masking (occupancy is its concern, not the kernels').

use std::cell::Cell;
use std::rc::Rc;

use anyhow::Result;

use super::{lit_f32, lit_i32, lit_i32_2d, native, Engine, KernelName, LoadedKernel};

/// Which backend a [`KernelSet`] runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust mirror of the kernels (tests / no-artifacts fallback).
    Native,
    /// AOT artifacts through PJRT (the measured hot path).
    Xla,
}

enum SetImpl {
    Native,
    Xla {
        filter_scale: Rc<LoadedKernel>,
        masked_sum: Rc<LoadedKernel>,
        sum_region: Rc<LoadedKernel>,
        segmented_sum: Rc<LoadedKernel>,
        tagged_sum_region: Rc<LoadedKernel>,
        char_classify: Rc<LoadedKernel>,
        coord_parse: Rc<LoadedKernel>,
        tagged_char_stage: Rc<LoadedKernel>,
    },
}

/// All ensemble kernels at one width.
pub struct KernelSet {
    width: usize,
    window_len: usize,
    imp: SetImpl,
    native_invocations: Cell<u64>,
}

impl KernelSet {
    /// Pure-Rust backend.
    pub fn native(width: usize) -> KernelSet {
        KernelSet {
            width,
            window_len: native::WINDOW_LEN,
            imp: SetImpl::Native,
            native_invocations: Cell::new(0),
        }
    }

    /// XLA backend: compiles (memoized in `engine`) every kernel at `width`.
    pub fn xla(engine: &Engine, width: usize) -> Result<KernelSet> {
        Ok(KernelSet {
            width,
            window_len: engine.store().manifest().window_len,
            imp: SetImpl::Xla {
                filter_scale: engine.kernel(KernelName::FilterScale, width)?,
                masked_sum: engine.kernel(KernelName::MaskedSum, width)?,
                sum_region: engine.kernel(KernelName::SumRegion, width)?,
                segmented_sum: engine.kernel(KernelName::SegmentedSum, width)?,
                tagged_sum_region: engine.kernel(KernelName::TaggedSumRegion, width)?,
                char_classify: engine.kernel(KernelName::CharClassify, width)?,
                coord_parse: engine.kernel(KernelName::CoordParse, width)?,
                tagged_char_stage: engine.kernel(KernelName::TaggedCharStage, width)?,
            },
            native_invocations: Cell::new(0),
        })
    }

    pub fn backend(&self) -> Backend {
        match self.imp {
            SetImpl::Native => Backend::Native,
            SetImpl::Xla { .. } => Backend::Xla,
        }
    }

    /// Ensemble width `w` (SIMD lanes per firing).
    pub fn width(&self) -> usize {
        self.width
    }

    /// `coord_parse` window length.
    pub fn window_len(&self) -> usize {
        self.window_len
    }

    /// Number of kernel invocations so far (both backends).
    pub fn invocations(&self) -> u64 {
        match &self.imp {
            SetImpl::Native => self.native_invocations.get(),
            SetImpl::Xla {
                filter_scale,
                masked_sum,
                sum_region,
                segmented_sum,
                tagged_sum_region,
                char_classify,
                coord_parse,
                tagged_char_stage,
            } => [
                filter_scale,
                masked_sum,
                sum_region,
                segmented_sum,
                tagged_sum_region,
                char_classify,
                coord_parse,
                tagged_char_stage,
            ]
            .iter()
            .map(|k| k.invocations.get())
            .sum(),
        }
    }

    fn tick(&self) {
        self.native_invocations
            .set(self.native_invocations.get() + 1);
    }

    fn check_w(&self, n: usize) {
        debug_assert_eq!(n, self.width, "ensemble buffer must be exactly width");
    }

    /// Masked filter + scale (paper Fig. 5 node `f`).
    pub fn filter_scale(
        &self,
        vals: &[f32],
        mask: &[i32],
        threshold: f32,
    ) -> Result<(Vec<f32>, Vec<i32>)> {
        self.check_w(vals.len());
        match &self.imp {
            SetImpl::Native => {
                self.tick();
                Ok(native::filter_scale(vals, mask, threshold))
            }
            SetImpl::Xla { filter_scale, .. } => {
                let out = filter_scale.call(&[lit_f32(vals), lit_i32(mask), lit_f32(&[threshold])])?;
                Ok((out[0].to_vec::<f32>()?, out[1].to_vec::<i32>()?))
            }
        }
    }

    /// Sum + count of active lanes (aggregation accumulate).
    pub fn masked_sum(&self, vals: &[f32], mask: &[i32]) -> Result<(f32, i32)> {
        self.check_w(vals.len());
        match &self.imp {
            SetImpl::Native => {
                self.tick();
                Ok(native::masked_sum(vals, mask))
            }
            SetImpl::Xla { masked_sum, .. } => {
                let out = masked_sum.call(&[lit_f32(vals), lit_i32(mask)])?;
                Ok((
                    out[0].to_vec::<f32>()?[0],
                    out[1].to_vec::<i32>()?[0],
                ))
            }
        }
    }

    /// Fused filter+scale+partial-sum (sum-app hot path).
    pub fn sum_region(&self, vals: &[f32], mask: &[i32], threshold: f32) -> Result<(f32, i32)> {
        self.check_w(vals.len());
        match &self.imp {
            SetImpl::Native => {
                self.tick();
                Ok(native::sum_region(vals, mask, threshold))
            }
            SetImpl::Xla { sum_region, .. } => {
                let out = sum_region.call(&[lit_f32(vals), lit_i32(mask), lit_f32(&[threshold])])?;
                Ok((
                    out[0].to_vec::<f32>()?[0],
                    out[1].to_vec::<i32>()?[0],
                ))
            }
        }
    }

    /// Per-segment sums within an ensemble (tagging baseline).
    pub fn segmented_sum(
        &self,
        vals: &[f32],
        seg: &[i32],
        mask: &[i32],
    ) -> Result<(Vec<f32>, Vec<i32>)> {
        self.check_w(vals.len());
        match &self.imp {
            SetImpl::Native => {
                self.tick();
                Ok(native::segmented_sum(vals, seg, mask))
            }
            SetImpl::Xla { segmented_sum, .. } => {
                let out = segmented_sum.call(&[lit_f32(vals), lit_i32(seg), lit_i32(mask)])?;
                Ok((out[0].to_vec::<f32>()?, out[1].to_vec::<i32>()?))
            }
        }
    }

    /// Fused filter+scale+per-segment-sum (perf-pass kernel: one
    /// invocation per tagged ensemble instead of filter_scale +
    /// segmented_sum — see EXPERIMENTS.md §Perf).
    pub fn tagged_sum_region(
        &self,
        vals: &[f32],
        seg: &[i32],
        mask: &[i32],
        threshold: f32,
    ) -> Result<(Vec<f32>, Vec<i32>)> {
        self.check_w(vals.len());
        match &self.imp {
            SetImpl::Native => {
                self.tick();
                Ok(native::tagged_sum_region(vals, seg, mask, threshold))
            }
            SetImpl::Xla {
                tagged_sum_region, ..
            } => {
                let out = tagged_sum_region.call(&[
                    lit_f32(vals),
                    lit_i32(seg),
                    lit_i32(mask),
                    lit_f32(&[threshold]),
                ])?;
                Ok((out[0].to_vec::<f32>()?, out[1].to_vec::<i32>()?))
            }
        }
    }

    /// Candidate detection over a char ensemble (taxi stage 1).
    pub fn char_classify(&self, chars: &[i32], mask: &[i32]) -> Result<(Vec<i32>, Vec<i32>)> {
        self.check_w(chars.len());
        match &self.imp {
            SetImpl::Native => {
                self.tick();
                Ok(native::char_classify(chars, mask))
            }
            SetImpl::Xla { char_classify, .. } => {
                let out = char_classify.call(&[lit_i32(chars), lit_i32(mask)])?;
                Ok((out[0].to_vec::<i32>()?, out[1].to_vec::<i32>()?))
            }
        }
    }

    /// Verify + parse candidate windows (taxi stage 2). `windows` is
    /// row-major `[width, window_len]`.
    pub fn coord_parse(
        &self,
        windows: &[i32],
        mask: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<i32>)> {
        self.check_w(mask.len());
        debug_assert_eq!(windows.len(), self.width * self.window_len);
        match &self.imp {
            SetImpl::Native => {
                self.tick();
                Ok(native::coord_parse(windows, self.window_len, mask))
            }
            SetImpl::Xla { coord_parse, .. } => {
                let out = coord_parse.call(&[
                    lit_i32_2d(windows, self.width, self.window_len)?,
                    lit_i32(mask),
                ])?;
                Ok((
                    out[0].to_vec::<f32>()?,
                    out[1].to_vec::<f32>()?,
                    out[2].to_vec::<i32>()?,
                ))
            }
        }
    }

    /// Fused classify + per-tag candidate counts (pure-tagging taxi).
    pub fn tagged_char_stage(
        &self,
        chars: &[i32],
        tags: &[i32],
        mask: &[i32],
    ) -> Result<(Vec<i32>, Vec<i32>, Vec<i32>)> {
        self.check_w(chars.len());
        match &self.imp {
            SetImpl::Native => {
                self.tick();
                let (flags, bits) = native::char_classify(chars, mask);
                let fvals: Vec<f32> = flags.iter().map(|&f| f as f32).collect();
                let (sums, _) = native::segmented_sum(&fvals, tags, mask);
                let counts: Vec<i32> = sums.iter().map(|&s| s as i32).collect();
                Ok((flags, bits, counts))
            }
            SetImpl::Xla {
                tagged_char_stage, ..
            } => {
                let out =
                    tagged_char_stage.call(&[lit_i32(chars), lit_i32(tags), lit_i32(mask)])?;
                Ok((
                    out[0].to_vec::<i32>()?,
                    out[1].to_vec::<i32>()?,
                    out[2].to_vec::<i32>()?,
                ))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_set_matches_native_module() {
        let ks = KernelSet::native(8);
        assert_eq!(ks.backend(), Backend::Native);
        let vals = [1.0, -2.0, 3.0, 4.0, -5.0, 6.0, 7.0, 8.0];
        let mask = [1, 1, 1, 1, 1, 1, 0, 0];
        let (s, k) = ks.sum_region(&vals, &mask, 0.0).unwrap();
        let (es, ek) = native::sum_region(&vals, &mask, 0.0);
        assert_eq!((s, k), (es, ek));
        assert_eq!(ks.invocations(), 1);
    }

    #[test]
    fn native_tagged_stage_counts_braces() {
        let ks = KernelSet::native(4);
        let chars: Vec<i32> = "{x{y".bytes().map(|b| b as i32).collect();
        let tags = [0, 0, 1, 1];
        let mask = [1, 1, 1, 1];
        let (flags, _, counts) = ks.tagged_char_stage(&chars, &tags, &mask).unwrap();
        assert_eq!(flags, vec![1, 0, 1, 0]);
        assert_eq!(counts[0], 1);
        assert_eq!(counts[1], 1);
    }

    #[test]
    #[should_panic(expected = "ensemble buffer")]
    #[cfg(debug_assertions)]
    fn wrong_width_panics_in_debug() {
        let ks = KernelSet::native(8);
        let _ = ks.masked_sum(&[1.0; 4], &[1; 4]);
    }
}
