//! Artifact store: the AOT output directory plus its manifest.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

use super::KernelName;

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Ensemble widths that were compiled.
    pub widths: Vec<usize>,
    /// `coord_parse` window length (chars per candidate window).
    pub window_len: usize,
    /// The paper's Fig. 5 scale constant baked into filter kernels.
    pub scale: f64,
    /// Entry names present in the artifact set.
    pub entries: Vec<String>,
}

impl Manifest {
    /// Parse manifest text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let widths = j
            .get("widths")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: missing widths"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("manifest: bad width")))
            .collect::<Result<Vec<_>>>()?;
        let window_len = j
            .get("window_len")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest: missing window_len"))?;
        let scale = j
            .get("scale")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("manifest: missing scale"))?;
        let entries = j
            .get("entries")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest: missing entries"))?
            .keys()
            .cloned()
            .collect();
        Ok(Manifest {
            widths,
            window_len,
            scale,
            entries,
        })
    }
}

/// The artifact directory (`artifacts/` by default).
#[derive(Debug, Clone)]
pub struct ArtifactStore {
    dir: PathBuf,
    manifest: Manifest,
}

impl ArtifactStore {
    /// Open a store, reading and validating its manifest.
    pub fn open(dir: impl Into<PathBuf>) -> Result<ArtifactStore> {
        let dir = dir.into();
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                mpath.display()
            )
        })?;
        let manifest = Manifest::parse(&text)?;
        Ok(ArtifactStore { dir, manifest })
    }

    /// Locate the artifact directory relative to the repo root, walking up
    /// from the current directory (tests and benches run from subdirs).
    pub fn discover() -> Result<ArtifactStore> {
        let mut dir = std::env::current_dir()?;
        loop {
            let cand = dir.join("artifacts");
            if cand.join("manifest.json").is_file() {
                return ArtifactStore::open(cand);
            }
            if !dir.pop() {
                bail!("no artifacts/manifest.json found — run `make artifacts`");
            }
        }
    }

    /// The parsed manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Directory the artifacts live in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Path of the HLO text for (kernel, width), validated against the
    /// manifest.
    pub fn path_for(&self, name: KernelName, width: usize) -> Result<PathBuf> {
        if !self.manifest.widths.contains(&width) {
            bail!(
                "width {width} not in artifact set {:?} — re-run `make artifacts` with --widths",
                self.manifest.widths
            );
        }
        if !self.manifest.entries.iter().any(|e| e == name.stem()) {
            bail!("kernel {} not in manifest", name.stem());
        }
        let p = self.dir.join(format!("w{width}/{}.hlo.txt", name.stem()));
        if !p.is_file() {
            bail!("artifact missing: {}", p.display());
        }
        Ok(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text", "widths": [32, 128], "window_len": 32,
      "scale": 3.14, "path_format": "w{width}/{entry}.hlo.txt",
      "entries": {"sum_region": {"inputs": []}, "coord_parse": {"inputs": []}}
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.widths, vec![32, 128]);
        assert_eq!(m.window_len, 32);
        assert!((m.scale - 3.14).abs() < 1e-12);
        assert_eq!(m.entries.len(), 2);
    }

    #[test]
    fn rejects_incomplete_manifest() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"widths": [1]}"#).is_err());
    }
}
