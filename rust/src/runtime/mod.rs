//! PJRT runtime: load AOT artifacts and execute them from the hot path.
//!
//! The coordinator never touches Python. `make artifacts` lowers every L2
//! entry point to HLO **text** under `artifacts/w<width>/<name>.hlo.txt`
//! (text, not serialized proto — xla_extension 0.5.1 rejects jax ≥ 0.5's
//! 64-bit instruction ids; the text parser reassigns them). This module:
//!
//! * [`ArtifactStore`] — reads `manifest.json`, resolves artifact paths.
//! * [`Engine`] — a PJRT CPU client plus a compiled-executable cache, keyed
//!   by (kernel, width). `Engine` is deliberately `!Send`: PJRT client
//!   handles are thread-confined, so each worker thread of the SIMD
//!   machine owns its own `Engine` (mirroring one CUDA context per SM in
//!   the paper's mapping — see `simd/`).
//! * [`kernels`] — typed wrappers, one per L1 kernel, each with a pure-Rust
//!   *native* backend (bit-compatible oracle, used by unit tests and as a
//!   no-artifacts fallback) and the *XLA* backend used for measurements.

pub mod artifacts;
pub mod kernels;
pub mod native;

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{Context, Result};

pub use artifacts::{ArtifactStore, Manifest};

/// Names of the AOT-compiled L2 entry points.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelName {
    /// Fused filter/scale stage kernel.
    FilterScale,
    /// Masked lane-sum kernel.
    MaskedSum,
    /// Whole-region sum kernel.
    SumRegion,
    /// Segmented (per-region) sum kernel.
    SegmentedSum,
    /// Tagged per-region sum kernel.
    TaggedSumRegion,
    /// Taxi character-classification kernel.
    CharClassify,
    /// Taxi coordinate-parse kernel.
    CoordParse,
    /// Fused tagged character-stage kernel.
    TaggedCharStage,
}

impl KernelName {
    /// Artifact file stem (matches `python/compile/model.py::ENTRIES`).
    pub fn stem(self) -> &'static str {
        match self {
            KernelName::FilterScale => "filter_scale",
            KernelName::MaskedSum => "masked_sum",
            KernelName::SumRegion => "sum_region",
            KernelName::SegmentedSum => "segmented_sum",
            KernelName::TaggedSumRegion => "tagged_sum_region",
            KernelName::CharClassify => "char_classify",
            KernelName::CoordParse => "coord_parse",
            KernelName::TaggedCharStage => "tagged_char_stage",
        }
    }

    /// All kernel names (for preloading / smoke tests).
    pub fn all() -> [KernelName; 8] {
        [
            KernelName::FilterScale,
            KernelName::MaskedSum,
            KernelName::SumRegion,
            KernelName::SegmentedSum,
            KernelName::TaggedSumRegion,
            KernelName::CharClassify,
            KernelName::CoordParse,
            KernelName::TaggedCharStage,
        ]
    }
}

/// A compiled executable for one (kernel, width).
pub struct LoadedKernel {
    /// Kernel name.
    pub name: KernelName,
    /// Ensemble width it was compiled for.
    pub width: usize,
    exe: xla::PjRtLoadedExecutable,
    /// Cumulative number of invocations (the SIMD cost unit).
    pub invocations: std::cell::Cell<u64>,
}

impl LoadedKernel {
    /// Raw executable handle (perf probes / advanced callers).
    pub fn exe_ref(&self) -> &xla::PjRtLoadedExecutable {
        &self.exe
    }

    /// Execute with literal inputs; returns the flattened output tuple.
    pub fn call(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.invocations.set(self.invocations.get() + 1);
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}@w{}", self.name.stem(), self.width))?;
        let lit = result[0][0].to_literal_sync().context("fetching result literal")?;
        // L2 entries are lowered with return_tuple=True.
        Ok(lit.to_tuple()?)
    }
}

/// PJRT CPU client + executable cache. One per worker thread.
pub struct Engine {
    client: xla::PjRtClient,
    store: ArtifactStore,
    cache: RefCell<HashMap<(KernelName, usize), Rc<LoadedKernel>>>,
}

impl Engine {
    /// Create an engine over an artifact store.
    pub fn new(store: ArtifactStore) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            store,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Convenience: engine over the default `artifacts/` directory.
    pub fn from_dir(dir: impl Into<std::path::PathBuf>) -> Result<Engine> {
        Engine::new(ArtifactStore::open(dir)?)
    }

    /// The artifact store backing this engine.
    pub fn store(&self) -> &ArtifactStore {
        &self.store
    }

    /// PJRT platform name (e.g. `"cpu"`).
    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile) a kernel at a width, memoized.
    pub fn kernel(&self, name: KernelName, width: usize) -> Result<Rc<LoadedKernel>> {
        if let Some(k) = self.cache.borrow().get(&(name, width)) {
            return Ok(k.clone());
        }
        let path = self.store.path_for(name, width)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}@w{width}", name.stem()))?;
        let k = Rc::new(LoadedKernel {
            name,
            width,
            exe,
            invocations: std::cell::Cell::new(0),
        });
        self.cache.borrow_mut().insert((name, width), k.clone());
        Ok(k)
    }

    /// Preload every kernel at a width (so benches don't measure compiles).
    pub fn preload(&self, width: usize) -> Result<()> {
        for name in KernelName::all() {
            self.kernel(name, width)?;
        }
        Ok(())
    }

    /// Total executable invocations across all cached kernels.
    pub fn total_invocations(&self) -> u64 {
        self.cache.borrow().values().map(|k| k.invocations.get()).sum()
    }
}

/// Build an `f32[n]` literal from a slice.
pub fn lit_f32(xs: &[f32]) -> xla::Literal {
    xla::Literal::vec1(xs)
}

/// Build an `i32[n]` literal from a slice.
pub fn lit_i32(xs: &[i32]) -> xla::Literal {
    xla::Literal::vec1(xs)
}

/// Build an `i32[rows, cols]` literal from a flattened row-major slice.
pub fn lit_i32_2d(xs: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
    debug_assert_eq!(xs.len(), rows * cols);
    Ok(xla::Literal::vec1(xs).reshape(&[rows as i64, cols as i64])?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_names_roundtrip_stems() {
        for name in KernelName::all() {
            assert!(!name.stem().is_empty());
        }
        assert_eq!(KernelName::SumRegion.stem(), "sum_region");
    }

    #[test]
    fn literal_builders() {
        let l = lit_f32(&[1.0, 2.0, 3.0]);
        assert_eq!(l.element_count(), 3);
        let l2 = lit_i32_2d(&[1, 2, 3, 4, 5, 6], 2, 3).unwrap();
        assert_eq!(l2.element_count(), 6);
    }
}
