//! Pure-Rust implementations of every L1 kernel.
//!
//! Two tiers:
//!
//! * **Vectorized in-place kernels** (`*_into` plus the scalar-returning
//!   reductions) — the firing hot path. They write into caller-provided
//!   `&mut [_]` slices, so a steady-state firing performs **zero heap
//!   allocations**, and their inner loops are branch-free mask-selects
//!   over `chunks_exact` blocks that LLVM autovectorizes (compare + blend
//!   per lane instead of a per-lane branch). Reductions use
//!   select-on-accumulator (`acc = if keep { acc + x } else { acc }`)
//!   rather than adding a masked `0.0`, which keeps the f32 accumulation
//!   bit-identical to the scalar references (adding `0.0` would flip a
//!   `-0.0` accumulator).
//! * **[`scalar`]** — the retained per-lane `if` reference
//!   implementations, mirroring `python/compile/kernels/ref.py`
//!   operation-for-operation. They are the oracle: the property suite
//!   (`tests/hotpath_properties.rs`) proves the vectorized kernels
//!   bit-identical across widths 1..=256, odd tails and all-masked lanes.
//!
//! Thin `Vec`-returning shims over the in-place kernels remain for tests
//! and the XLA-oracle comparisons; they are not the measured hot path.

/// The paper's Fig. 5 scale constant (must match `kernels/filter_scale.py`).
pub const SCALE: f32 = 3.14;

/// Window length for `coord_parse` (must match `kernels/coord_parse.py`).
pub const WINDOW_LEN: usize = 32;

/// ASCII of the taxi candidate marker.
pub const OPEN_BRACE: i32 = 0x7B;

/// Block size for the `chunks_exact` inner loops (a SIMD register's worth
/// of f32 lanes on the narrowest targets we care about).
const LANES: usize = 8;

pub mod scalar {
    //! Retained scalar reference implementations (per-lane `if`s, fresh
    //! output `Vec`s) — the oracle the vectorized in-place kernels are
    //! property-tested bit-identical against, and the "pre-PR" baseline
    //! the `bench hotpath` firing-path comparison measures.

    use super::{parse_window, OPEN_BRACE, SCALE};

    /// `filter_scale`: masked filter (`v > threshold`) + scale.
    pub fn filter_scale(vals: &[f32], mask: &[i32], threshold: f32) -> (Vec<f32>, Vec<i32>) {
        let mut ov = vec![0.0f32; vals.len()];
        let mut om = vec![0i32; vals.len()];
        for i in 0..vals.len() {
            if mask[i] != 0 && vals[i] > threshold {
                ov[i] = SCALE * vals[i];
                om[i] = 1;
            }
        }
        (ov, om)
    }

    /// `masked_sum`: sum + count of active lanes.
    pub fn masked_sum(vals: &[f32], mask: &[i32]) -> (f32, i32) {
        let mut s = 0.0f32;
        let mut c = 0i32;
        for i in 0..vals.len() {
            if mask[i] != 0 {
                s += vals[i];
                c += 1;
            }
        }
        (s, c)
    }

    /// `sum_region`: fused filter+scale+sum.
    pub fn sum_region(vals: &[f32], mask: &[i32], threshold: f32) -> (f32, i32) {
        let mut s = 0.0f32;
        let mut k = 0i32;
        for i in 0..vals.len() {
            if mask[i] != 0 && vals[i] > threshold {
                s += SCALE * vals[i];
                k += 1;
            }
        }
        (s, k)
    }

    /// `segmented_sum`: per-segment sums/counts (segment ids in `[0, w)`).
    pub fn segmented_sum(vals: &[f32], seg: &[i32], mask: &[i32]) -> (Vec<f32>, Vec<i32>) {
        let w = vals.len();
        let mut sums = vec![0.0f32; w];
        let mut counts = vec![0i32; w];
        for i in 0..w {
            if mask[i] != 0 {
                let s = seg[i] as usize;
                sums[s] += vals[i];
                counts[s] += 1;
            }
        }
        (sums, counts)
    }

    /// `tagged_sum_region`: fused filter+scale+segmented-sum.
    pub fn tagged_sum_region(
        vals: &[f32],
        seg: &[i32],
        mask: &[i32],
        threshold: f32,
    ) -> (Vec<f32>, Vec<i32>) {
        let w = vals.len();
        let mut sums = vec![0.0f32; w];
        let mut counts = vec![0i32; w];
        for i in 0..w {
            if mask[i] != 0 && vals[i] > threshold {
                let s = seg[i] as usize;
                sums[s] += SCALE * vals[i];
                counts[s] += 1;
            }
        }
        (sums, counts)
    }

    /// `char_classify`: candidate flag + class bitmap.
    pub fn char_classify(chars: &[i32], mask: &[i32]) -> (Vec<i32>, Vec<i32>) {
        let w = chars.len();
        let mut flags = vec![0i32; w];
        let mut bits = vec![0i32; w];
        for i in 0..w {
            if mask[i] == 0 {
                continue;
            }
            let c = chars[i];
            if c == OPEN_BRACE {
                flags[i] = 1;
            }
            let mut k = 0;
            if (0x30..=0x39).contains(&c) {
                k += 1;
            }
            if c == 0x2E {
                k += 2;
            }
            if c == 0x2C {
                k += 4;
            }
            if c == 0x2D {
                k += 8;
            }
            if c == 0x7D {
                k += 16;
            }
            bits[i] = k;
        }
        (flags, bits)
    }

    /// `coord_parse`: per-lane window parse with swapped output.
    pub fn coord_parse(
        windows: &[i32],
        window_len: usize,
        mask: &[i32],
    ) -> (Vec<f32>, Vec<f32>, Vec<i32>) {
        let w = mask.len();
        debug_assert_eq!(windows.len(), w * window_len);
        let mut x = vec![0.0f32; w];
        let mut y = vec![0.0f32; w];
        let mut ok = vec![0i32; w];
        for i in 0..w {
            if mask[i] == 0 {
                continue;
            }
            let (a, b, good) = parse_window(&windows[i * window_len..(i + 1) * window_len]);
            if good {
                x[i] = b;
                y[i] = a;
                ok[i] = 1;
            }
        }
        (x, y, ok)
    }
}

// ---- vectorized in-place kernels (the firing hot path) -----------------

/// `filter_scale` into caller slices: per-lane `keep = mask & (v > t)`
/// select, no branches, no allocation. Bit-identical to
/// [`scalar::filter_scale`] (a rejected lane writes exactly `0.0`, not a
/// mask-multiplied `-0.0`).
pub fn filter_scale_into(
    vals: &[f32],
    mask: &[i32],
    threshold: f32,
    out_vals: &mut [f32],
    out_mask: &mut [i32],
) {
    let n = vals.len();
    debug_assert_eq!(mask.len(), n);
    debug_assert_eq!(out_vals.len(), n);
    debug_assert_eq!(out_mask.len(), n);
    let mut vc = vals.chunks_exact(LANES);
    let mut mc = mask.chunks_exact(LANES);
    let mut ovc = out_vals.chunks_exact_mut(LANES);
    let mut omc = out_mask.chunks_exact_mut(LANES);
    for (((v, m), ov), om) in (&mut vc).zip(&mut mc).zip(&mut ovc).zip(&mut omc) {
        for k in 0..LANES {
            let keep = ((m[k] != 0) & (v[k] > threshold)) as i32;
            om[k] = keep;
            ov[k] = if keep != 0 { SCALE * v[k] } else { 0.0 };
        }
    }
    for (((v, m), ov), om) in vc
        .remainder()
        .iter()
        .zip(mc.remainder())
        .zip(ovc.into_remainder())
        .zip(omc.into_remainder())
    {
        let keep = ((*m != 0) & (*v > threshold)) as i32;
        *om = keep;
        *ov = if keep != 0 { SCALE * *v } else { 0.0 };
    }
}

/// `masked_sum`: sum + count of active lanes. Select-on-accumulator keeps
/// the accumulation order (and bits) identical to [`scalar::masked_sum`].
pub fn masked_sum(vals: &[f32], mask: &[i32]) -> (f32, i32) {
    debug_assert_eq!(mask.len(), vals.len());
    let mut s = 0.0f32;
    let mut c = 0i32;
    for (v, m) in vals.iter().zip(mask) {
        let keep = *m != 0;
        s = if keep { s + *v } else { s };
        c += keep as i32;
    }
    (s, c)
}

/// `sum_region`: fused filter+scale+sum, branch-free select per lane.
pub fn sum_region(vals: &[f32], mask: &[i32], threshold: f32) -> (f32, i32) {
    debug_assert_eq!(mask.len(), vals.len());
    let mut s = 0.0f32;
    let mut k = 0i32;
    for (v, m) in vals.iter().zip(mask) {
        let keep = (*m != 0) & (*v > threshold);
        s = if keep { s + SCALE * *v } else { s };
        k += keep as i32;
    }
    (s, k)
}

/// `segmented_sum` into caller slices (`out_*` are fully overwritten).
/// The per-lane scatter keeps its guard — a masked lane's segment id may
/// be garbage and must not be touched.
pub fn segmented_sum_into(
    vals: &[f32],
    seg: &[i32],
    mask: &[i32],
    out_sums: &mut [f32],
    out_counts: &mut [i32],
) {
    let w = vals.len();
    debug_assert_eq!(seg.len(), w);
    debug_assert_eq!(mask.len(), w);
    debug_assert_eq!(out_sums.len(), w);
    debug_assert_eq!(out_counts.len(), w);
    out_sums.fill(0.0);
    out_counts.fill(0);
    for i in 0..w {
        if mask[i] != 0 {
            let s = seg[i] as usize;
            out_sums[s] += vals[i];
            out_counts[s] += 1;
        }
    }
}

/// `tagged_sum_region` into caller slices: fused filter+scale+segmented
/// sum, zero allocation (perf-pass kernel; one invocation per tagged
/// ensemble instead of two).
pub fn tagged_sum_region_into(
    vals: &[f32],
    seg: &[i32],
    mask: &[i32],
    threshold: f32,
    out_sums: &mut [f32],
    out_counts: &mut [i32],
) {
    let w = vals.len();
    debug_assert_eq!(seg.len(), w);
    debug_assert_eq!(mask.len(), w);
    debug_assert_eq!(out_sums.len(), w);
    debug_assert_eq!(out_counts.len(), w);
    out_sums.fill(0.0);
    out_counts.fill(0);
    for i in 0..w {
        if mask[i] != 0 && vals[i] > threshold {
            let s = seg[i] as usize;
            out_sums[s] += SCALE * vals[i];
            out_counts[s] += 1;
        }
    }
}

/// `char_classify` into caller slices: fully branch-free integer lanes
/// (`flag = act · (c=='{')`, `bits = act · Σ 2^j·(c==marker_j)`).
pub fn char_classify_into(
    chars: &[i32],
    mask: &[i32],
    out_flags: &mut [i32],
    out_bits: &mut [i32],
) {
    let n = chars.len();
    debug_assert_eq!(mask.len(), n);
    debug_assert_eq!(out_flags.len(), n);
    debug_assert_eq!(out_bits.len(), n);
    let classify = |c: i32, m: i32| -> (i32, i32) {
        let act = (m != 0) as i32;
        let flag = act * (c == OPEN_BRACE) as i32;
        let bits = ((0x30..=0x39).contains(&c) as i32)
            + 2 * (c == 0x2E) as i32
            + 4 * (c == 0x2C) as i32
            + 8 * (c == 0x2D) as i32
            + 16 * (c == 0x7D) as i32;
        (flag, act * bits)
    };
    let mut cc = chars.chunks_exact(LANES);
    let mut mc = mask.chunks_exact(LANES);
    let mut fc = out_flags.chunks_exact_mut(LANES);
    let mut bc = out_bits.chunks_exact_mut(LANES);
    for (((c, m), f), b) in (&mut cc).zip(&mut mc).zip(&mut fc).zip(&mut bc) {
        for k in 0..LANES {
            let (flag, bits) = classify(c[k], m[k]);
            f[k] = flag;
            b[k] = bits;
        }
    }
    for (((c, m), f), b) in cc
        .remainder()
        .iter()
        .zip(mc.remainder())
        .zip(fc.into_remainder())
        .zip(bc.into_remainder())
    {
        let (flag, bits) = classify(*c, *m);
        *f = flag;
        *b = bits;
    }
}

/// `coord_parse` into caller slices (`out_*` fully overwritten). The
/// per-lane window parse is inherently branchy; the win here is the
/// allocation-free output path.
pub fn coord_parse_into(
    windows: &[i32],
    window_len: usize,
    mask: &[i32],
    out_x: &mut [f32],
    out_y: &mut [f32],
    out_ok: &mut [i32],
) {
    let w = mask.len();
    debug_assert_eq!(windows.len(), w * window_len);
    debug_assert_eq!(out_x.len(), w);
    debug_assert_eq!(out_y.len(), w);
    debug_assert_eq!(out_ok.len(), w);
    for i in 0..w {
        out_x[i] = 0.0;
        out_y[i] = 0.0;
        out_ok[i] = 0;
        if mask[i] == 0 {
            continue;
        }
        let (a, b, good) = parse_window(&windows[i * window_len..(i + 1) * window_len]);
        if good {
            out_x[i] = b;
            out_y[i] = a;
            out_ok[i] = 1;
        }
    }
}

// ---- Vec-returning shims (tests / XLA-oracle comparisons) --------------

/// `filter_scale` shim over [`filter_scale_into`].
pub fn filter_scale(vals: &[f32], mask: &[i32], threshold: f32) -> (Vec<f32>, Vec<i32>) {
    let mut ov = vec![0.0f32; vals.len()];
    let mut om = vec![0i32; vals.len()];
    filter_scale_into(vals, mask, threshold, &mut ov, &mut om);
    (ov, om)
}

/// `segmented_sum` shim over [`segmented_sum_into`].
pub fn segmented_sum(vals: &[f32], seg: &[i32], mask: &[i32]) -> (Vec<f32>, Vec<i32>) {
    let mut sums = vec![0.0f32; vals.len()];
    let mut counts = vec![0i32; vals.len()];
    segmented_sum_into(vals, seg, mask, &mut sums, &mut counts);
    (sums, counts)
}

/// `tagged_sum_region` shim over [`tagged_sum_region_into`].
pub fn tagged_sum_region(
    vals: &[f32],
    seg: &[i32],
    mask: &[i32],
    threshold: f32,
) -> (Vec<f32>, Vec<i32>) {
    let mut sums = vec![0.0f32; vals.len()];
    let mut counts = vec![0i32; vals.len()];
    tagged_sum_region_into(vals, seg, mask, threshold, &mut sums, &mut counts);
    (sums, counts)
}

/// `char_classify` shim over [`char_classify_into`].
pub fn char_classify(chars: &[i32], mask: &[i32]) -> (Vec<i32>, Vec<i32>) {
    let mut flags = vec![0i32; chars.len()];
    let mut bits = vec![0i32; chars.len()];
    char_classify_into(chars, mask, &mut flags, &mut bits);
    (flags, bits)
}

/// `coord_parse` shim over [`coord_parse_into`].
pub fn coord_parse(
    windows: &[i32],
    window_len: usize,
    mask: &[i32],
) -> (Vec<f32>, Vec<f32>, Vec<i32>) {
    let w = mask.len();
    let mut x = vec![0.0f32; w];
    let mut y = vec![0.0f32; w];
    let mut ok = vec![0i32; w];
    coord_parse_into(windows, window_len, mask, &mut x, &mut y, &mut ok);
    (x, y, ok)
}

/// Parse one `{a,b}` window. Returns `(a, b, ok)`; arithmetic is f32
/// step-by-step to match the kernel's accumulation exactly.
pub fn parse_window(window: &[i32]) -> (f32, f32, bool) {
    if window.is_empty() || window[0] != OPEN_BRACE {
        return (0.0, 0.0, false);
    }
    let mut field = 0;
    let (mut acc_i, mut acc_f, mut fdiv, mut sign) = (0.0f32, 0.0f32, 1.0f32, 1.0f32);
    let (mut seen_dot, mut seen_digit) = (false, false);
    let mut a = 0.0f32;
    for &c in &window[1..] {
        match c {
            0x30..=0x39 => {
                let d = (c - 0x30) as f32;
                if seen_dot {
                    acc_f = acc_f * 10.0 + d;
                    fdiv *= 10.0;
                } else {
                    acc_i = acc_i * 10.0 + d;
                }
                seen_digit = true;
            }
            0x2E => {
                // '.'
                if seen_dot || !seen_digit {
                    return (0.0, 0.0, false);
                }
                seen_dot = true;
            }
            0x2D => {
                // '-'
                if seen_digit || seen_dot || sign < 0.0 {
                    return (0.0, 0.0, false);
                }
                sign = -1.0;
            }
            0x2C => {
                // ','
                if field != 0 || !seen_digit {
                    return (0.0, 0.0, false);
                }
                a = sign * (acc_i + acc_f / fdiv);
                field = 1;
                acc_i = 0.0;
                acc_f = 0.0;
                fdiv = 1.0;
                sign = 1.0;
                seen_dot = false;
                seen_digit = false;
            }
            0x7D => {
                // '}'
                if field != 1 || !seen_digit {
                    return (0.0, 0.0, false);
                }
                let b = sign * (acc_i + acc_f / fdiv);
                return (a, b, true);
            }
            _ => return (0.0, 0.0, false),
        }
    }
    (0.0, 0.0, false) // ran out of window without '}'
}

#[cfg(test)]
mod tests {
    use super::*;

    fn win(s: &str) -> Vec<i32> {
        let mut v = vec![0i32; WINDOW_LEN];
        for (i, b) in s.bytes().take(WINDOW_LEN).enumerate() {
            v[i] = b as i32;
        }
        v
    }

    #[test]
    fn filter_scale_basics() {
        let (ov, om) = filter_scale(&[1.0, -1.0, 2.0], &[1, 1, 0], 0.0);
        assert_eq!(om, vec![1, 0, 0]);
        assert!((ov[0] - SCALE).abs() < 1e-6);
        assert_eq!(ov[1], 0.0);
    }

    #[test]
    fn into_kernels_overwrite_stale_outputs() {
        // caller slices start with garbage; every lane must be rewritten
        let vals = [1.0f32, -2.0, 3.0, 4.0, 5.0, -6.0, 7.0, 8.0, 9.0];
        let mask = [1, 1, 0, 1, 1, 1, 0, 1, 1];
        let mut ov = vec![99.0f32; 9];
        let mut om = vec![-7i32; 9];
        filter_scale_into(&vals, &mask, 0.0, &mut ov, &mut om);
        let (sv, sm) = scalar::filter_scale(&vals, &mask, 0.0);
        assert_eq!(om, sm);
        for (a, b) in ov.iter().zip(&sv) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn branchless_sums_match_scalar_bitwise() {
        let vals = [1.5f32, -2.25, 3.0, 0.5, -0.75, 8.25, 1.125];
        let mask = [1, 0, 1, 1, 1, 0, 1];
        let (s, c) = masked_sum(&vals, &mask);
        let (ss, sc) = scalar::masked_sum(&vals, &mask);
        assert_eq!((s.to_bits(), c), (ss.to_bits(), sc));
        let (r, k) = sum_region(&vals, &mask, 0.4);
        let (sr, sk) = scalar::sum_region(&vals, &mask, 0.4);
        assert_eq!((r.to_bits(), k), (sr.to_bits(), sk));
    }

    #[test]
    fn masked_and_region_sums() {
        let vals = [1.0, 2.0, 3.0, 4.0];
        let mask = [1, 0, 1, 1];
        assert_eq!(masked_sum(&vals, &mask), (8.0, 3));
        let (s, k) = sum_region(&vals, &mask, 2.5);
        assert_eq!(k, 2);
        assert!((s - SCALE * 7.0).abs() < 1e-4);
    }

    #[test]
    fn segmented_sum_routes_by_tag() {
        let (s, c) = segmented_sum(&[1.0, 2.0, 3.0, 4.0], &[0, 1, 0, 1], &[1, 1, 1, 0]);
        assert_eq!(s[0], 4.0);
        assert_eq!(s[1], 2.0);
        assert_eq!(c[0], 2);
        assert_eq!(c[1], 1);
    }

    #[test]
    fn tagged_sum_region_fuses_filter_and_segments() {
        let (s, c) = tagged_sum_region(
            &[1.0, -2.0, 3.0, 4.0],
            &[0, 0, 1, 1],
            &[1, 1, 1, 0],
            0.0,
        );
        assert!((s[0] - SCALE).abs() < 1e-6); // -2.0 filtered out
        assert!((s[1] - SCALE * 3.0).abs() < 1e-5); // 4.0 masked off
        assert_eq!(c, vec![1, 1, 0, 0]);
    }

    #[test]
    fn classify_finds_braces() {
        let chars: Vec<i32> = "a{1,}".bytes().map(|b| b as i32).collect();
        let (f, bits) = char_classify(&chars, &[1; 5]);
        assert_eq!(f, vec![0, 1, 0, 0, 0]);
        assert_eq!(bits, vec![0, 0, 1, 4, 16]);
    }

    #[test]
    fn parse_accepts_valid() {
        let (a, b, ok) = parse_window(&win("{12.5,-3.25}"));
        assert!(ok);
        assert_eq!(a, 12.5);
        assert_eq!(b, -3.25);
    }

    #[test]
    fn parse_rejects_invalid() {
        for bad in [
            "{bad}", "{1.2,}", "{1,2", "{--1,2}", "{1.2.3,4}", "{.5,1}", "{1,2,3}", "x1,2}",
            "{-,1}", "{,1}", "{}",
        ] {
            assert!(!parse_window(&win(bad)).2, "accepted {bad:?}");
        }
    }

    #[test]
    fn coord_parse_swaps() {
        let mut ws = win("{11.5,-42.25}");
        ws.extend(win("{1,2}"));
        let (x, y, ok) = coord_parse(&ws, WINDOW_LEN, &[1, 1]);
        assert_eq!(ok, vec![1, 1]);
        assert_eq!(x[0], -42.25);
        assert_eq!(y[0], 11.5);
        assert_eq!((x[1], y[1]), (2.0, 1.0));
    }

    #[test]
    fn coord_parse_respects_mask() {
        let ws = [win("{1,2}"), win("{3,4}")].concat();
        let (_, _, ok) = coord_parse(&ws, WINDOW_LEN, &[0, 1]);
        assert_eq!(ok, vec![0, 1]);
    }
}
