//! Pure-Rust implementations of every L1 kernel.
//!
//! These mirror `python/compile/kernels/ref.py` operation-for-operation in
//! f32, so they serve as (a) an in-process oracle for the XLA backend in
//! integration tests and (b) a no-artifacts backend for fast unit tests of
//! the coordinator. They are NOT the measured hot path — benches run the
//! XLA backend.

/// The paper's Fig. 5 scale constant (must match `kernels/filter_scale.py`).
pub const SCALE: f32 = 3.14;

/// Window length for `coord_parse` (must match `kernels/coord_parse.py`).
pub const WINDOW_LEN: usize = 32;

/// ASCII of the taxi candidate marker.
pub const OPEN_BRACE: i32 = 0x7B;

/// `filter_scale`: masked filter (`v > threshold`) + scale.
pub fn filter_scale(vals: &[f32], mask: &[i32], threshold: f32) -> (Vec<f32>, Vec<i32>) {
    let mut ov = vec![0.0f32; vals.len()];
    let mut om = vec![0i32; vals.len()];
    for i in 0..vals.len() {
        if mask[i] != 0 && vals[i] > threshold {
            ov[i] = SCALE * vals[i];
            om[i] = 1;
        }
    }
    (ov, om)
}

/// `masked_sum`: sum + count of active lanes.
pub fn masked_sum(vals: &[f32], mask: &[i32]) -> (f32, i32) {
    let mut s = 0.0f32;
    let mut c = 0i32;
    for i in 0..vals.len() {
        if mask[i] != 0 {
            s += vals[i];
            c += 1;
        }
    }
    (s, c)
}

/// `sum_region`: fused filter+scale+sum.
pub fn sum_region(vals: &[f32], mask: &[i32], threshold: f32) -> (f32, i32) {
    let mut s = 0.0f32;
    let mut k = 0i32;
    for i in 0..vals.len() {
        if mask[i] != 0 && vals[i] > threshold {
            s += SCALE * vals[i];
            k += 1;
        }
    }
    (s, k)
}

/// `segmented_sum`: per-segment sums/counts (segment ids in `[0, w)`).
pub fn segmented_sum(vals: &[f32], seg: &[i32], mask: &[i32]) -> (Vec<f32>, Vec<i32>) {
    let w = vals.len();
    let mut sums = vec![0.0f32; w];
    let mut counts = vec![0i32; w];
    for i in 0..w {
        if mask[i] != 0 {
            let s = seg[i] as usize;
            sums[s] += vals[i];
            counts[s] += 1;
        }
    }
    (sums, counts)
}

/// `tagged_sum_region`: fused filter+scale+segmented-sum (perf-pass
/// kernel; one invocation per tagged ensemble instead of two).
pub fn tagged_sum_region(
    vals: &[f32],
    seg: &[i32],
    mask: &[i32],
    threshold: f32,
) -> (Vec<f32>, Vec<i32>) {
    let w = vals.len();
    let mut sums = vec![0.0f32; w];
    let mut counts = vec![0i32; w];
    for i in 0..w {
        if mask[i] != 0 && vals[i] > threshold {
            let s = seg[i] as usize;
            sums[s] += SCALE * vals[i];
            counts[s] += 1;
        }
    }
    (sums, counts)
}

/// `char_classify`: candidate flag + class bitmap.
pub fn char_classify(chars: &[i32], mask: &[i32]) -> (Vec<i32>, Vec<i32>) {
    let w = chars.len();
    let mut flags = vec![0i32; w];
    let mut bits = vec![0i32; w];
    for i in 0..w {
        if mask[i] == 0 {
            continue;
        }
        let c = chars[i];
        if c == OPEN_BRACE {
            flags[i] = 1;
        }
        let mut k = 0;
        if (0x30..=0x39).contains(&c) {
            k += 1;
        }
        if c == 0x2E {
            k += 2;
        }
        if c == 0x2C {
            k += 4;
        }
        if c == 0x2D {
            k += 8;
        }
        if c == 0x7D {
            k += 16;
        }
        bits[i] = k;
    }
    (flags, bits)
}

/// Parse one `{a,b}` window. Returns `(a, b, ok)`; arithmetic is f32
/// step-by-step to match the kernel's accumulation exactly.
pub fn parse_window(window: &[i32]) -> (f32, f32, bool) {
    if window.is_empty() || window[0] != OPEN_BRACE {
        return (0.0, 0.0, false);
    }
    let mut field = 0;
    let (mut acc_i, mut acc_f, mut fdiv, mut sign) = (0.0f32, 0.0f32, 1.0f32, 1.0f32);
    let (mut seen_dot, mut seen_digit) = (false, false);
    let mut a = 0.0f32;
    for &c in &window[1..] {
        match c {
            0x30..=0x39 => {
                let d = (c - 0x30) as f32;
                if seen_dot {
                    acc_f = acc_f * 10.0 + d;
                    fdiv *= 10.0;
                } else {
                    acc_i = acc_i * 10.0 + d;
                }
                seen_digit = true;
            }
            0x2E => {
                // '.'
                if seen_dot || !seen_digit {
                    return (0.0, 0.0, false);
                }
                seen_dot = true;
            }
            0x2D => {
                // '-'
                if seen_digit || seen_dot || sign < 0.0 {
                    return (0.0, 0.0, false);
                }
                sign = -1.0;
            }
            0x2C => {
                // ','
                if field != 0 || !seen_digit {
                    return (0.0, 0.0, false);
                }
                a = sign * (acc_i + acc_f / fdiv);
                field = 1;
                acc_i = 0.0;
                acc_f = 0.0;
                fdiv = 1.0;
                sign = 1.0;
                seen_dot = false;
                seen_digit = false;
            }
            0x7D => {
                // '}'
                if field != 1 || !seen_digit {
                    return (0.0, 0.0, false);
                }
                let b = sign * (acc_i + acc_f / fdiv);
                return (a, b, true);
            }
            _ => return (0.0, 0.0, false),
        }
    }
    (0.0, 0.0, false) // ran out of window without '}'
}

/// `coord_parse`: per-lane window parse with swapped output
/// (`x` = second field, `y` = first field).
pub fn coord_parse(
    windows: &[i32],
    window_len: usize,
    mask: &[i32],
) -> (Vec<f32>, Vec<f32>, Vec<i32>) {
    let w = mask.len();
    debug_assert_eq!(windows.len(), w * window_len);
    let mut x = vec![0.0f32; w];
    let mut y = vec![0.0f32; w];
    let mut ok = vec![0i32; w];
    for i in 0..w {
        if mask[i] == 0 {
            continue;
        }
        let (a, b, good) = parse_window(&windows[i * window_len..(i + 1) * window_len]);
        if good {
            x[i] = b;
            y[i] = a;
            ok[i] = 1;
        }
    }
    (x, y, ok)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn win(s: &str) -> Vec<i32> {
        let mut v = vec![0i32; WINDOW_LEN];
        for (i, b) in s.bytes().take(WINDOW_LEN).enumerate() {
            v[i] = b as i32;
        }
        v
    }

    #[test]
    fn filter_scale_basics() {
        let (ov, om) = filter_scale(&[1.0, -1.0, 2.0], &[1, 1, 0], 0.0);
        assert_eq!(om, vec![1, 0, 0]);
        assert!((ov[0] - SCALE).abs() < 1e-6);
        assert_eq!(ov[1], 0.0);
    }

    #[test]
    fn masked_and_region_sums() {
        let vals = [1.0, 2.0, 3.0, 4.0];
        let mask = [1, 0, 1, 1];
        assert_eq!(masked_sum(&vals, &mask), (8.0, 3));
        let (s, k) = sum_region(&vals, &mask, 2.5);
        assert_eq!(k, 2);
        assert!((s - SCALE * 7.0).abs() < 1e-4);
    }

    #[test]
    fn segmented_sum_routes_by_tag() {
        let (s, c) = segmented_sum(&[1.0, 2.0, 3.0, 4.0], &[0, 1, 0, 1], &[1, 1, 1, 0]);
        assert_eq!(s[0], 4.0);
        assert_eq!(s[1], 2.0);
        assert_eq!(c[0], 2);
        assert_eq!(c[1], 1);
    }

    #[test]
    fn tagged_sum_region_fuses_filter_and_segments() {
        let (s, c) = tagged_sum_region(
            &[1.0, -2.0, 3.0, 4.0],
            &[0, 0, 1, 1],
            &[1, 1, 1, 0],
            0.0,
        );
        assert!((s[0] - SCALE).abs() < 1e-6); // -2.0 filtered out
        assert!((s[1] - SCALE * 3.0).abs() < 1e-5); // 4.0 masked off
        assert_eq!(c, vec![1, 1, 0, 0]);
    }

    #[test]
    fn classify_finds_braces() {
        let chars: Vec<i32> = "a{1,}".bytes().map(|b| b as i32).collect();
        let (f, bits) = char_classify(&chars, &[1; 5]);
        assert_eq!(f, vec![0, 1, 0, 0, 0]);
        assert_eq!(bits, vec![0, 0, 1, 4, 16]);
    }

    #[test]
    fn parse_accepts_valid() {
        let (a, b, ok) = parse_window(&win("{12.5,-3.25}"));
        assert!(ok);
        assert_eq!(a, 12.5);
        assert_eq!(b, -3.25);
    }

    #[test]
    fn parse_rejects_invalid() {
        for bad in [
            "{bad}", "{1.2,}", "{1,2", "{--1,2}", "{1.2.3,4}", "{.5,1}", "{1,2,3}", "x1,2}",
            "{-,1}", "{,1}", "{}",
        ] {
            assert!(!parse_window(&win(bad)).2, "accepted {bad:?}");
        }
    }

    #[test]
    fn coord_parse_swaps() {
        let mut ws = win("{11.5,-42.25}");
        ws.extend(win("{1,2}"));
        let (x, y, ok) = coord_parse(&ws, WINDOW_LEN, &[1, 1]);
        assert_eq!(ok, vec![1, 1]);
        assert_eq!(x[0], -42.25);
        assert_eq!(y[0], 11.5);
        assert_eq!((x[1], y[1]), (2.0, 1.0));
    }

    #[test]
    fn coord_parse_respects_mask() {
        let ws = [win("{1,2}"), win("{3,4}")].concat();
        let (_, _, ok) = coord_parse(&ws, WINDOW_LEN, &[0, 1]);
        assert_eq!(ok, vec![0, 1]);
    }
}
