//! Live telemetry: per-worker counters, gauges and log2-bucketed latency
//! histograms — always-cheap observability for *every* run.
//!
//! The trace layer ([`crate::trace`]) records events exhaustively for one
//! run; this module summarizes continuously. Each worker (and the
//! streaming driver) owns a thread-confined [`MetricsHub`] — the same
//! Copy-spec + lane pattern as [`TraceSink`](crate::trace::TraceSink) —
//! holding fixed-size [`LatencyHist`]s and plain counters in one inline
//! [`LaneMetrics`] block. Recording is a `RefCell` borrow plus integer
//! stores: no locks, no clock reads when disabled, and **zero heap
//! allocations on the record path** (pinned by the counting allocator in
//! this module's tests and `tests/metrics_observe.rs`).
//!
//! ## What is measured
//!
//! * **Per-region end-to-end latency** — ingest submit → in-order merge
//!   emit, stamped against the shared trace epoch
//!   ([`MetricsSpec::epoch`]). Streaming runs only: materialized runs
//!   have no submit stamp, so their `e2e` histogram stays empty.
//! * **Shard queue-wait vs service time** — submit → claim, and the
//!   `run_shard` span itself.
//! * **Rates** — steals, backpressure stalls (count + blocked time),
//!   faults and retries, derived from the exact same quantities the
//!   [`ExecReport`](crate::exec::ExecReport) folds, so the totals
//!   reconcile number for number.
//! * **Live occupancy** — the peak in-flight region count (a max-fold
//!   gauge) and per-worker busy/idle nanoseconds.
//!
//! ## Bucket scheme
//!
//! [`LatencyHist`] has 64 preallocated buckets: bucket 0 holds samples
//! of 0–1 ns, bucket *i* (*i* ≥ 1) holds `[2^i, 2^(i+1))` ns. The merge
//! is element-wise integer addition plus a max-fold — **exact and
//! associative**, so folding per-lane histograms in any order yields the
//! same [`MetricsReport`], and quantiles are bucket-bounded rather than
//! sampled (a reported p99 names the bucket the true p99 falls in).
//!
//! ## Invariants
//!
//! * Metrics-on runs are **bit-identical** to metrics-off runs: hubs
//!   only read clocks and bump counters, never influence scheduling.
//! * Disabled hubs cost one `Option` branch per site — no clock reads.
//! * The record path never allocates, with metrics on or off.

use std::cell::RefCell;
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Number of log2 buckets in a [`LatencyHist`] — enough for every
/// nanosecond magnitude a `u64` can hold.
pub const HIST_BUCKETS: usize = 64;

/// The cross-thread recipe for building per-worker hubs: just the shared
/// clock epoch. `Copy + Send`, mirroring
/// [`TraceSpec`](crate::trace::TraceSpec); when a run is both traced and
/// metered the runner hands both specs the *same* epoch, so trace stamps
/// and metric latencies are directly comparable.
#[derive(Debug, Clone, Copy)]
pub struct MetricsSpec {
    /// Shared monotonic epoch: every stamp is nanoseconds since this.
    pub epoch: Instant,
}

impl MetricsSpec {
    /// A spec whose epoch is "now".
    pub fn new() -> MetricsSpec {
        MetricsSpec {
            epoch: Instant::now(),
        }
    }

    /// A spec stamping against an existing epoch (shared with a
    /// [`TraceSpec`](crate::trace::TraceSpec) when both are on).
    pub fn with_epoch(epoch: Instant) -> MetricsSpec {
        MetricsSpec { epoch }
    }

    /// Build an enabled hub (one inline lane block) on the calling
    /// thread.
    pub fn hub(&self) -> MetricsHub {
        MetricsHub {
            inner: Some(Rc::new(HubInner {
                epoch: self.epoch,
                state: RefCell::new(LaneMetrics::default()),
            })),
        }
    }
}

impl Default for MetricsSpec {
    fn default() -> Self {
        MetricsSpec::new()
    }
}

/// Fixed-size log2-bucketed latency histogram: preallocated, never
/// grows, merges exactly. Bucket 0 covers 0–1 ns; bucket *i* covers
/// `[2^i, 2^(i+1))` ns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LatencyHist {
    /// Per-bucket sample counts.
    pub buckets: [u64; HIST_BUCKETS],
    /// Total samples recorded.
    pub count: u64,
    /// Exact sum of all recorded nanoseconds.
    pub sum_ns: u64,
    /// Largest recorded sample.
    pub max_ns: u64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        LatencyHist {
            buckets: [0; HIST_BUCKETS],
            count: 0,
            sum_ns: 0,
            max_ns: 0,
        }
    }
}

impl LatencyHist {
    /// The bucket index a sample lands in.
    #[inline]
    pub fn bucket_index(ns: u64) -> usize {
        if ns == 0 {
            0
        } else {
            63 - ns.leading_zeros() as usize
        }
    }

    /// `(lower, upper)` nanosecond bounds of bucket `i`, inclusive.
    pub fn bucket_bounds(i: usize) -> (u64, u64) {
        let lo = if i == 0 { 0 } else { 1u64 << i };
        let hi = if i >= 63 { u64::MAX } else { (1u64 << (i + 1)) - 1 };
        (lo, hi)
    }

    /// Record one sample. Never allocates.
    #[inline]
    pub fn record(&mut self, ns: u64) {
        self.record_n(ns, 1);
    }

    /// Record `n` samples of the same value (used for per-region
    /// latencies derived from one shard-level stamp). Never allocates.
    #[inline]
    pub fn record_n(&mut self, ns: u64, n: u64) {
        if n == 0 {
            return;
        }
        self.buckets[Self::bucket_index(ns)] += n;
        self.count += n;
        self.sum_ns += ns * n;
        self.max_ns = self.max_ns.max(ns);
    }

    /// Exact merge: element-wise addition plus a max-fold. Associative
    /// and commutative, so lane fold order never changes the result.
    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// The `(lower, upper)` bounds of the bucket holding the `q`th
    /// quantile sample (rank `ceil(q × count)`), or `None` when empty.
    /// The true quantile provably lies within these bounds — the
    /// cross-check tests hold trace-derived exact quantiles against them.
    pub fn quantile_bounds(&self, q: f64) -> Option<(u64, u64)> {
        if self.count == 0 {
            return None;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Some(Self::bucket_bounds(i));
            }
        }
        None
    }

    /// Midpoint of the `q`th quantile's bucket (0 when empty) — the
    /// headline estimator used by the heartbeat and `bench latency`.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        match self.quantile_bounds(q) {
            Some((lo, hi)) => lo + (hi - lo) / 2,
            None => 0,
        }
    }

    /// Mean recorded nanoseconds (0 when empty).
    pub fn mean_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.sum_ns / self.count
        }
    }
}

/// One lane's complete metric state: three histograms plus counters and
/// gauges, all inline (`~1.6 KB`, no heap). Worker lanes fill the
/// shard-side fields, the streaming driver's lane fills the
/// submit/emit/stall side; unused fields stay zero, and the exact merge
/// ([`LaneMetrics::merge`]) folds any mix of lanes.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LaneMetrics {
    /// Per-region end-to-end latency: ingest submit → in-order emit
    /// (streaming driver lane; empty on materialized runs).
    pub e2e: LatencyHist,
    /// Per-shard queue wait: submit → claim (worker lanes, streaming).
    pub queue_wait: LatencyHist,
    /// Per-shard service time: the `run_shard` span (worker lanes).
    pub service: LatencyHist,
    /// Shards executed.
    pub shards: u64,
    /// Regions executed.
    pub regions: u64,
    /// Shards claimed from another worker's deque.
    pub stolen: u64,
    /// Failed shard attempts (each retry or quarantine attempt).
    pub faults: u64,
    /// Rebuild-and-rerun recovery cycles.
    pub retries: u64,
    /// Nanoseconds spent executing shards.
    pub busy_ns: u64,
    /// Nanoseconds spent blocked waiting for work to claim.
    pub idle_ns: u64,
    /// Backpressure stalls (driver lane).
    pub stalls: u64,
    /// Nanoseconds the driver spent blocked on backpressure.
    pub stall_ns: u64,
    /// Shards submitted by the ingest driver.
    pub submitted_shards: u64,
    /// Regions submitted by the ingest driver.
    pub submitted_regions: u64,
    /// Shards emitted in stream order.
    pub emitted_shards: u64,
    /// Regions emitted in stream order.
    pub emitted_regions: u64,
    /// Peak regions in flight (submitted − emitted): a max-fold gauge.
    pub peak_in_flight: u64,
    /// Regions that lost at least one part to a part-granular
    /// quarantine and were emitted only through the salvage ledger
    /// ([`PartialRegion`](crate::exec::PartialRegion)).
    pub partial_regions: u64,
    /// Workers retired mid-run after losing their pipeline beyond
    /// recovery (their remaining work was re-dealt to survivors).
    pub dead_workers: u64,
    /// Transient ingest-source failures absorbed by the `Retry`
    /// policy's bounded backoff at the `RegionSource` boundary.
    pub source_retries: u64,
}

impl LaneMetrics {
    /// Exact fold of another lane into this one: counters add,
    /// histograms merge element-wise, gauges max-fold. Associative, so
    /// the per-worker fold order never changes the report.
    pub fn merge(&mut self, other: &LaneMetrics) {
        self.e2e.merge(&other.e2e);
        self.queue_wait.merge(&other.queue_wait);
        self.service.merge(&other.service);
        self.shards += other.shards;
        self.regions += other.regions;
        self.stolen += other.stolen;
        self.faults += other.faults;
        self.retries += other.retries;
        self.busy_ns += other.busy_ns;
        self.idle_ns += other.idle_ns;
        self.stalls += other.stalls;
        self.stall_ns += other.stall_ns;
        self.submitted_shards += other.submitted_shards;
        self.submitted_regions += other.submitted_regions;
        self.emitted_shards += other.emitted_shards;
        self.emitted_regions += other.emitted_regions;
        self.peak_in_flight = self.peak_in_flight.max(other.peak_in_flight);
        self.partial_regions += other.partial_regions;
        self.dead_workers += other.dead_workers;
        self.source_retries += other.source_retries;
    }
}

#[derive(Debug)]
struct HubInner {
    epoch: Instant,
    state: RefCell<LaneMetrics>,
}

/// The recording handle threaded through pool, driver and merger.
/// Disabled (the default) it is a `None` and every call is a single
/// predictable branch with **no clock read**; enabled it stamps against
/// the shared epoch and mutates the lane's inline [`LaneMetrics`] in
/// place. `Rc`-based and thread-confined, exactly like
/// [`TraceSink`](crate::trace::TraceSink).
#[derive(Debug, Clone, Default)]
pub struct MetricsHub {
    inner: Option<Rc<HubInner>>,
}

impl MetricsHub {
    /// The disabled hub (same as `Default`).
    pub fn disabled() -> MetricsHub {
        MetricsHub { inner: None }
    }

    /// Is this hub recording?
    #[inline]
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Nanoseconds since the shared epoch; 0 when disabled (callers
    /// gate on [`enabled`](MetricsHub::enabled) before differencing
    /// stamps).
    #[inline]
    pub fn now_ns(&self) -> u64 {
        match &self.inner {
            Some(inner) => inner.epoch.elapsed().as_nanos() as u64,
            None => 0,
        }
    }

    #[inline]
    fn with<F: FnOnce(&mut LaneMetrics)>(&self, f: F) {
        if let Some(inner) = &self.inner {
            f(&mut inner.state.borrow_mut());
        }
    }

    /// Read the lane's current state (`None` when disabled) — used by
    /// the heartbeat for so-far quantiles.
    pub fn peek<R, F: FnOnce(&LaneMetrics) -> R>(&self, f: F) -> Option<R> {
        self.inner.as_ref().map(|inner| f(&inner.state.borrow()))
    }

    /// Worker lane: one shard executed to completion.
    #[inline]
    pub fn record_shard(&self, regions: u64, stolen: bool, queue_wait_ns: u64, service_ns: u64) {
        self.with(|m| {
            m.shards += 1;
            m.regions += regions;
            m.stolen += stolen as u64;
            m.busy_ns += service_ns;
            m.queue_wait.record(queue_wait_ns);
            m.service.record(service_ns);
        });
    }

    /// Worker lane: time spent blocked waiting to claim work.
    #[inline]
    pub fn record_idle(&self, ns: u64) {
        self.with(|m| m.idle_ns += ns);
    }

    /// Worker lane: failed attempts and recovery cycles for one shard.
    #[inline]
    pub fn record_faults(&self, faults: u64, retries: u64) {
        if faults == 0 && retries == 0 {
            return;
        }
        self.with(|m| {
            m.faults += faults;
            m.retries += retries;
        });
    }

    /// Driver lane: one transient ingest-source failure absorbed by the
    /// `Retry` policy's bounded backoff.
    #[inline]
    pub fn record_source_retry(&self) {
        self.with(|m| m.source_retries += 1);
    }

    /// Driver lane: one shard submitted to the deques.
    #[inline]
    pub fn record_submit(&self, regions: u64) {
        self.with(|m| {
            m.submitted_shards += 1;
            m.submitted_regions += regions;
        });
    }

    /// Driver lane: one backpressure stall of `ns` nanoseconds.
    #[inline]
    pub fn record_stall(&self, ns: u64) {
        self.with(|m| {
            m.stalls += 1;
            m.stall_ns += ns;
        });
    }

    /// Driver lane: one shard of `regions` regions emitted in stream
    /// order, each region's end-to-end latency being `e2e_ns`.
    #[inline]
    pub fn record_emit(&self, regions: u64, e2e_ns: u64) {
        self.with(|m| {
            m.emitted_shards += 1;
            m.emitted_regions += regions;
            m.e2e.record_n(e2e_ns, regions);
        });
    }

    /// Driver lane: max-fold the live in-flight region gauge.
    #[inline]
    pub fn note_in_flight(&self, regions: u64) {
        self.with(|m| m.peak_in_flight = m.peak_in_flight.max(regions));
    }

    /// Drain this lane's state, leaving the hub enabled but zeroed.
    /// Allocation-free: [`LaneMetrics`] is inline.
    pub fn take(&self) -> LaneMetrics {
        match &self.inner {
            Some(inner) => std::mem::take(&mut inner.state.borrow_mut()),
            None => LaneMetrics::default(),
        }
    }
}

/// The folded post-run telemetry: every lane's [`LaneMetrics`] merged
/// exactly, plus run shape. Attached to
/// [`ExecReport`](crate::exec::ExecReport) when metrics are on, exported
/// as JSON (`--metrics out.json`) or Prometheus text
/// (`--metrics-format prom`), and re-loadable via
/// [`MetricsReport::from_json`] for `regatta metrics summarize`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsReport {
    /// Worker threads the run was configured with.
    pub workers: usize,
    /// Wall-clock seconds of the measured phase.
    pub elapsed: f64,
    /// All lanes folded (exact merge).
    pub totals: LaneMetrics,
}

/// JSON schema tag written by [`MetricsReport::to_json`].
pub const METRICS_SCHEMA: &str = "regatta-metrics-v1";

fn hist_json(name: &str, h: &LatencyHist, out: &mut String) {
    out.push_str(&format!(
        "    \"{name}\": {{\"count\": {}, \"sum_ns\": {}, \"max_ns\": {}, \"buckets\": [",
        h.count, h.sum_ns, h.max_ns
    ));
    for (i, b) in h.buckets.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&b.to_string());
    }
    out.push_str("]}");
}

fn hist_from_json(j: &Json, name: &str) -> Result<LatencyHist> {
    let h = j.get(name).with_context(|| format!("metrics JSON: missing histogram {name:?}"))?;
    let int = |key: &str| -> Result<u64> {
        Ok(h.get(key)
            .and_then(Json::as_f64)
            .with_context(|| format!("metrics JSON: histogram {name:?} missing {key:?}"))?
            as u64)
    };
    let raw = h
        .get("buckets")
        .and_then(Json::as_arr)
        .with_context(|| format!("metrics JSON: histogram {name:?} missing buckets"))?;
    if raw.len() != HIST_BUCKETS {
        bail!(
            "metrics JSON: histogram {name:?} has {} buckets, expected {HIST_BUCKETS}",
            raw.len()
        );
    }
    let mut buckets = [0u64; HIST_BUCKETS];
    for (slot, v) in buckets.iter_mut().zip(raw.iter()) {
        *slot = v.as_f64().context("metrics JSON: non-numeric bucket")? as u64;
    }
    Ok(LatencyHist {
        buckets,
        count: int("count")?,
        sum_ns: int("sum_ns")?,
        max_ns: int("max_ns")?,
    })
}

/// `(name, value)` pairs of every scalar counter/gauge in a lane, in a
/// fixed order — shared by the JSON exporter, the parser and the
/// Prometheus renderer so the three can never drift apart.
fn counters(t: &LaneMetrics) -> [(&'static str, u64); 17] {
    [
        ("shards", t.shards),
        ("regions", t.regions),
        ("stolen", t.stolen),
        ("faults", t.faults),
        ("retries", t.retries),
        ("busy_ns", t.busy_ns),
        ("idle_ns", t.idle_ns),
        ("stalls", t.stalls),
        ("stall_ns", t.stall_ns),
        ("submitted_shards", t.submitted_shards),
        ("submitted_regions", t.submitted_regions),
        ("emitted_shards", t.emitted_shards),
        ("emitted_regions", t.emitted_regions),
        ("peak_in_flight", t.peak_in_flight),
        ("partial_regions", t.partial_regions),
        ("dead_workers", t.dead_workers),
        ("source_retries", t.source_retries),
    ]
}

impl MetricsReport {
    /// In-order emit rate over the measured phase, regions per second.
    pub fn emit_rate(&self) -> f64 {
        if self.elapsed > 0.0 {
            self.totals.emitted_regions as f64 / self.elapsed
        } else {
            0.0
        }
    }

    /// Render the JSON artifact (`--metrics out.json`). Round-trips
    /// through [`MetricsReport::from_json`] via [`crate::util::json`].
    pub fn to_json(&self) -> String {
        let t = &self.totals;
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"schema\": \"{METRICS_SCHEMA}\",\n"));
        out.push_str(&format!("  \"workers\": {},\n", self.workers));
        out.push_str(&format!("  \"elapsed_secs\": {},\n", self.elapsed));
        out.push_str("  \"counters\": {");
        for (i, (name, v)) in counters(t).iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!("\"{name}\": {v}"));
        }
        out.push_str("},\n");
        out.push_str("  \"histograms\": {\n");
        hist_json("e2e_ns", &t.e2e, &mut out);
        out.push_str(",\n");
        hist_json("queue_wait_ns", &t.queue_wait, &mut out);
        out.push_str(",\n");
        hist_json("service_ns", &t.service, &mut out);
        out.push_str("\n  }\n}\n");
        out
    }

    /// Parse a [`MetricsReport::to_json`] artifact back (the
    /// `regatta metrics summarize` loader).
    pub fn from_json(text: &str) -> Result<MetricsReport> {
        let j = Json::parse(text).context("parsing metrics JSON")?;
        let schema = j.get("schema").and_then(Json::as_str).unwrap_or("");
        if schema != METRICS_SCHEMA {
            bail!("metrics JSON: schema {schema:?} is not {METRICS_SCHEMA:?}");
        }
        let c = j.get("counters").context("metrics JSON: missing counters")?;
        let int = |key: &str| -> Result<u64> {
            Ok(c.get(key)
                .and_then(Json::as_f64)
                .with_context(|| format!("metrics JSON: missing counter {key:?}"))?
                as u64)
        };
        let h = j.get("histograms").context("metrics JSON: missing histograms")?;
        let totals = LaneMetrics {
            e2e: hist_from_json(h, "e2e_ns")?,
            queue_wait: hist_from_json(h, "queue_wait_ns")?,
            service: hist_from_json(h, "service_ns")?,
            shards: int("shards")?,
            regions: int("regions")?,
            stolen: int("stolen")?,
            faults: int("faults")?,
            retries: int("retries")?,
            busy_ns: int("busy_ns")?,
            idle_ns: int("idle_ns")?,
            stalls: int("stalls")?,
            stall_ns: int("stall_ns")?,
            submitted_shards: int("submitted_shards")?,
            submitted_regions: int("submitted_regions")?,
            emitted_shards: int("emitted_shards")?,
            emitted_regions: int("emitted_regions")?,
            peak_in_flight: int("peak_in_flight")?,
            partial_regions: int("partial_regions")?,
            dead_workers: int("dead_workers")?,
            source_retries: int("source_retries")?,
        };
        Ok(MetricsReport {
            workers: j.get("workers").and_then(Json::as_usize).unwrap_or(0),
            elapsed: j.get("elapsed_secs").and_then(Json::as_f64).unwrap_or(0.0),
            totals,
        })
    }

    /// Render Prometheus text exposition (`--metrics-format prom`).
    /// Counters are `regatta_*_total`, durations are converted to
    /// seconds, histograms use cumulative `le` buckets at the power-of-2
    /// nanosecond boundaries.
    pub fn to_prometheus(&self) -> String {
        let t = &self.totals;
        let mut out = String::new();
        let mut counter = |name: &str, help: &str, v: f64| {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"));
        };
        counter("regatta_shards_total", "Shards executed.", t.shards as f64);
        counter("regatta_regions_total", "Regions executed.", t.regions as f64);
        counter(
            "regatta_steals_total",
            "Shards claimed from another worker's deque.",
            t.stolen as f64,
        );
        counter("regatta_faults_total", "Failed shard attempts.", t.faults as f64);
        counter("regatta_retries_total", "Shard recovery cycles.", t.retries as f64);
        counter(
            "regatta_stalls_total",
            "Ingest backpressure stalls.",
            t.stalls as f64,
        );
        counter(
            "regatta_stall_seconds_total",
            "Seconds the ingest driver spent blocked on backpressure.",
            t.stall_ns as f64 / 1e9,
        );
        counter(
            "regatta_busy_seconds_total",
            "Seconds workers spent executing shards.",
            t.busy_ns as f64 / 1e9,
        );
        counter(
            "regatta_idle_seconds_total",
            "Seconds workers spent blocked waiting for work.",
            t.idle_ns as f64 / 1e9,
        );
        counter(
            "regatta_submitted_regions_total",
            "Regions submitted by the ingest driver.",
            t.submitted_regions as f64,
        );
        counter(
            "regatta_emitted_regions_total",
            "Regions emitted in stream order.",
            t.emitted_regions as f64,
        );
        counter(
            "regatta_partial_regions_total",
            "Regions salvaged partially after part-granular quarantine.",
            t.partial_regions as f64,
        );
        counter(
            "regatta_dead_workers_total",
            "Workers retired mid-run after unrecoverable pipeline loss.",
            t.dead_workers as f64,
        );
        counter(
            "regatta_source_retries_total",
            "Transient ingest-source failures absorbed by retry backoff.",
            t.source_retries as f64,
        );
        out.push_str(
            "# HELP regatta_in_flight_regions_peak Peak regions in flight.\n\
             # TYPE regatta_in_flight_regions_peak gauge\n",
        );
        out.push_str(&format!("regatta_in_flight_regions_peak {}\n", t.peak_in_flight));
        for (name, help, h) in [
            (
                "regatta_e2e_latency_seconds",
                "Per-region end-to-end latency (submit to in-order emit).",
                &t.e2e,
            ),
            (
                "regatta_queue_wait_seconds",
                "Per-shard queue wait (submit to claim).",
                &t.queue_wait,
            ),
            (
                "regatta_service_seconds",
                "Per-shard service time (the run_shard span).",
                &t.service,
            ),
        ] {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
            let top = h
                .buckets
                .iter()
                .rposition(|&c| c > 0)
                .map(|i| i + 1)
                .unwrap_or(0);
            let mut cum = 0u64;
            for (i, &c) in h.buckets.iter().take(top).enumerate() {
                cum += c;
                let (_, hi) = LatencyHist::bucket_bounds(i);
                out.push_str(&format!(
                    "{name}_bucket{{le=\"{}\"}} {cum}\n",
                    (hi as f64 + 1.0) / 1e9
                ));
            }
            out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
            out.push_str(&format!("{name}_sum {}\n", h.sum_ns as f64 / 1e9));
            out.push_str(&format!("{name}_count {}\n", h.count));
        }
        out
    }

    /// Human-readable summary (the `regatta metrics summarize` body and
    /// the `--stats` footer).
    pub fn summary_table(&self) -> String {
        let t = &self.totals;
        let ms = |ns: u64| ns as f64 / 1e6;
        let mut out = String::new();
        out.push_str(&format!(
            "run: {} worker(s), {:.3}s, {} shard(s) / {} region(s), {} stolen, \
             {} fault(s), {} retrie(s)\n",
            self.workers, self.elapsed, t.shards, t.regions, t.stolen, t.faults, t.retries
        ));
        out.push_str(&format!(
            "flow: {} submitted / {} emitted region(s), peak in-flight {}, \
             {} stall(s) ({:.3} ms blocked), emit rate {:.1}/s\n",
            t.submitted_regions,
            t.emitted_regions,
            t.peak_in_flight,
            t.stalls,
            ms(t.stall_ns),
            self.emit_rate(),
        ));
        if t.partial_regions > 0 || t.dead_workers > 0 || t.source_retries > 0 {
            out.push_str(&format!(
                "salvage: {} partial region(s), {} retired worker(s), \
                 {} ingest retrie(s)\n",
                t.partial_regions, t.dead_workers, t.source_retries
            ));
        }
        out.push_str("latency_ms         count      p50      p99      max     mean\n");
        for (name, h) in [
            ("e2e", &t.e2e),
            ("queue_wait", &t.queue_wait),
            ("service", &t.service),
        ] {
            out.push_str(&format!(
                "{:<16} {:>9}  {:>7.3}  {:>7.3}  {:>7.3}  {:>7.3}\n",
                name,
                h.count,
                ms(h.quantile_ns(0.50)),
                ms(h.quantile_ns(0.99)),
                ms(h.max_ns),
                ms(h.mean_ns()),
            ));
        }
        out
    }
}

/// Progress-heartbeat tick state: decides *when* a line is due against
/// the shared epoch clock, with no thread of its own — the streaming
/// driver polls it from the same loop that beats the watchdog
/// [`Pulse`](crate::exec::Pulse).
#[derive(Debug)]
pub struct Heartbeat {
    every_ns: u64,
    next_ns: u64,
    ticks: u64,
}

impl Heartbeat {
    /// A heartbeat firing every `every` (first tick one interval in).
    pub fn new(every: Duration) -> Heartbeat {
        let every_ns = (every.as_nanos() as u64).max(1);
        Heartbeat {
            every_ns,
            next_ns: every_ns,
            ticks: 0,
        }
    }

    /// Is a tick due at `now_ns` (nanoseconds since the epoch)? Advances
    /// the schedule past `now_ns` when it fires, so a late poll emits
    /// one line, not a burst.
    pub fn due(&mut self, now_ns: u64) -> bool {
        if now_ns < self.next_ns {
            return false;
        }
        self.ticks += 1;
        while self.next_ns <= now_ns {
            self.next_ns += self.every_ns;
        }
        true
    }

    /// Lines emitted so far.
    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// Render one machine-parseable heartbeat line (no trailing
    /// newline): space-separated `key=value` tokens after the fixed
    /// `progress` prefix. `rate` is emitted regions per second; `done=1`
    /// marks the forced end-of-stream tick.
    pub fn render(s: &ProgressSnapshot) -> String {
        let rate = if s.elapsed_secs > 0.0 {
            s.emitted_regions as f64 / s.elapsed_secs
        } else {
            0.0
        };
        format!(
            "progress t={:.1} regions={}/{} rate={:.1} in_flight={} p50_ms={:.3} \
             p99_ms={:.3} steals={} faults={} done={}",
            s.elapsed_secs,
            s.emitted_regions,
            s.submitted_regions,
            rate,
            s.in_flight_regions,
            s.p50_ns as f64 / 1e6,
            s.p99_ns as f64 / 1e6,
            s.stolen,
            s.faults,
            s.done as u8,
        )
    }
}

/// One heartbeat tick's inputs, gathered by the streaming driver.
#[derive(Debug, Clone, Copy, Default)]
pub struct ProgressSnapshot {
    /// Seconds since the run's epoch.
    pub elapsed_secs: f64,
    /// Regions submitted so far.
    pub submitted_regions: u64,
    /// Regions emitted in stream order so far.
    pub emitted_regions: u64,
    /// Regions currently in flight.
    pub in_flight_regions: u64,
    /// Shards observed stolen so far.
    pub stolen: u64,
    /// Failed shard attempts observed so far.
    pub faults: u64,
    /// So-far p50 end-to-end latency (bucket midpoint), nanoseconds.
    pub p50_ns: u64,
    /// So-far p99 end-to-end latency (bucket midpoint), nanoseconds.
    pub p99_ns: u64,
    /// True on the forced end-of-stream tick.
    pub done: bool,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_indexing_is_log2() {
        assert_eq!(LatencyHist::bucket_index(0), 0);
        assert_eq!(LatencyHist::bucket_index(1), 0);
        assert_eq!(LatencyHist::bucket_index(2), 1);
        assert_eq!(LatencyHist::bucket_index(3), 1);
        assert_eq!(LatencyHist::bucket_index(4), 2);
        assert_eq!(LatencyHist::bucket_index(1023), 9);
        assert_eq!(LatencyHist::bucket_index(1024), 10);
        assert_eq!(LatencyHist::bucket_index(u64::MAX), 63);
        for i in 0..HIST_BUCKETS {
            let (lo, hi) = LatencyHist::bucket_bounds(i);
            assert_eq!(LatencyHist::bucket_index(lo.max(1).min(hi)), i.max(0));
            assert_eq!(LatencyHist::bucket_index(hi), i);
            assert!(lo <= hi);
        }
    }

    #[test]
    fn hist_records_and_quantiles() {
        let mut h = LatencyHist::default();
        for ns in [100u64, 200, 300, 4000, 50_000] {
            h.record(ns);
        }
        assert_eq!(h.count, 5);
        assert_eq!(h.sum_ns, 54_600);
        assert_eq!(h.max_ns, 50_000);
        assert_eq!(h.mean_ns(), 10_920);
        // p50 = rank 3 = 300 ns → bucket 8 = [256, 511]
        let (lo, hi) = h.quantile_bounds(0.5).unwrap();
        assert!(lo <= 300 && 300 <= hi, "[{lo}, {hi}]");
        assert_eq!((lo, hi), (256, 511));
        // p99 = rank 5 = 50_000 ns
        let (lo, hi) = h.quantile_bounds(0.99).unwrap();
        assert!(lo <= 50_000 && 50_000 <= hi, "[{lo}, {hi}]");
        assert_eq!(LatencyHist::default().quantile_bounds(0.5), None);
        assert_eq!(LatencyHist::default().quantile_ns(0.5), 0);
        let mid = h.quantile_ns(0.5);
        assert!((256..=511).contains(&mid));
    }

    #[test]
    fn hist_merge_is_exact_and_associative() {
        let fill = |vals: &[u64]| {
            let mut h = LatencyHist::default();
            for &v in vals {
                h.record(v);
            }
            h
        };
        let (a, b, c) = (
            fill(&[1, 17, 300]),
            fill(&[2, 2, 900_000]),
            fill(&[0, u64::MAX / 2]),
        );
        // (a ⊕ b) ⊕ c
        let mut left = a;
        left.merge(&b);
        left.merge(&c);
        // a ⊕ (b ⊕ c)
        let mut bc = b;
        bc.merge(&c);
        let mut right = a;
        right.merge(&bc);
        assert_eq!(left, right, "merge is associative");
        // and equals recording everything into one histogram
        let all = fill(&[1, 17, 300, 2, 2, 900_000, 0, u64::MAX / 2]);
        assert_eq!(left, all, "merge is exact");
    }

    #[test]
    fn record_n_matches_n_records() {
        let mut a = LatencyHist::default();
        a.record_n(777, 5);
        let mut b = LatencyHist::default();
        for _ in 0..5 {
            b.record(777);
        }
        assert_eq!(a, b);
        a.record_n(1, 0);
        assert_eq!(a, b, "n = 0 records nothing");
    }

    #[test]
    fn disabled_hub_is_inert() {
        let hub = MetricsHub::default();
        assert!(!hub.enabled());
        assert_eq!(hub.now_ns(), 0);
        hub.record_shard(4, true, 10, 20);
        hub.record_emit(4, 30);
        hub.record_faults(1, 1);
        assert!(hub.peek(|m| m.shards).is_none());
        assert_eq!(hub.take(), LaneMetrics::default());
    }

    #[test]
    fn hub_records_against_shared_epoch_and_drains() {
        let spec = MetricsSpec::new();
        let hub = spec.hub();
        assert!(hub.enabled());
        let t0 = hub.now_ns();
        let t1 = hub.now_ns();
        assert!(t1 >= t0, "shared-epoch clock must be monotonic");
        hub.record_shard(7, true, 100, 900);
        hub.record_submit(7);
        hub.record_stall(50);
        hub.record_emit(7, 1000);
        hub.note_in_flight(7);
        hub.note_in_flight(3);
        hub.record_idle(11);
        hub.record_faults(2, 1);
        hub.record_source_retry();
        let lane = hub.take();
        assert_eq!(lane.shards, 1);
        assert_eq!(lane.regions, 7);
        assert_eq!(lane.stolen, 1);
        assert_eq!(lane.queue_wait.count, 1);
        assert_eq!(lane.service.sum_ns, 900);
        assert_eq!(lane.busy_ns, 900);
        assert_eq!(lane.idle_ns, 11);
        assert_eq!(lane.submitted_regions, 7);
        assert_eq!(lane.stalls, 1);
        assert_eq!(lane.stall_ns, 50);
        assert_eq!(lane.emitted_regions, 7);
        assert_eq!(lane.e2e.count, 7, "one e2e sample per region");
        assert_eq!(lane.peak_in_flight, 7, "gauge max-folds");
        assert_eq!(lane.faults, 2);
        assert_eq!(lane.retries, 1);
        assert_eq!(lane.source_retries, 1);
        // take drains but keeps recording
        hub.record_shard(1, false, 0, 1);
        assert_eq!(hub.take().shards, 1);
    }

    #[test]
    #[cfg(feature = "count-allocs")]
    fn record_path_never_allocates() {
        use crate::util::alloc_count;
        let hub = MetricsSpec::new().hub();
        // warm the Rc + RefCell before counting
        hub.record_shard(1, false, 1, 1);
        let before = alloc_count::thread_allocations();
        for i in 0..4096u64 {
            hub.record_shard(3, i % 7 == 0, i, i * 2);
            hub.record_submit(3);
            hub.record_emit(3, i * 3);
            hub.record_stall(i);
            hub.note_in_flight(i % 64);
            hub.record_idle(i);
            hub.record_faults(i % 2, i % 2);
        }
        let lane = hub.take();
        let delta = alloc_count::thread_allocations() - before;
        assert_eq!(delta, 0, "metrics record path allocated {delta} times");
        assert_eq!(lane.shards, 4096);
    }

    #[test]
    fn lane_merge_folds_every_field() {
        let mut a = LaneMetrics {
            shards: 2,
            regions: 9,
            stolen: 1,
            peak_in_flight: 5,
            ..Default::default()
        };
        a.service.record(100);
        let mut b = LaneMetrics {
            shards: 3,
            regions: 4,
            faults: 2,
            retries: 1,
            stalls: 1,
            stall_ns: 70,
            submitted_shards: 5,
            submitted_regions: 13,
            emitted_shards: 5,
            emitted_regions: 13,
            peak_in_flight: 3,
            busy_ns: 40,
            idle_ns: 8,
            partial_regions: 2,
            dead_workers: 1,
            source_retries: 3,
            ..Default::default()
        };
        b.e2e.record_n(500, 13);
        a.merge(&b);
        assert_eq!(a.shards, 5);
        assert_eq!(a.regions, 13);
        assert_eq!(a.stolen, 1);
        assert_eq!(a.faults, 2);
        assert_eq!(a.retries, 1);
        assert_eq!(a.stalls, 1);
        assert_eq!(a.stall_ns, 70);
        assert_eq!(a.submitted_regions, 13);
        assert_eq!(a.emitted_regions, 13);
        assert_eq!(a.busy_ns, 40);
        assert_eq!(a.idle_ns, 8);
        assert_eq!(a.peak_in_flight, 5, "gauge max-folds, not adds");
        assert_eq!(a.partial_regions, 2);
        assert_eq!(a.dead_workers, 1);
        assert_eq!(a.source_retries, 3);
        assert_eq!(a.e2e.count, 13);
        assert_eq!(a.service.count, 1);
    }

    #[test]
    fn json_round_trips() {
        let mut totals = LaneMetrics {
            shards: 4,
            regions: 100,
            stolen: 2,
            submitted_shards: 4,
            submitted_regions: 100,
            emitted_shards: 4,
            emitted_regions: 100,
            peak_in_flight: 32,
            busy_ns: 123_456,
            partial_regions: 3,
            dead_workers: 1,
            source_retries: 2,
            ..Default::default()
        };
        totals.e2e.record_n(10_000, 100);
        totals.queue_wait.record_n(700, 4);
        totals.service.record_n(30_000, 4);
        let report = MetricsReport {
            workers: 4,
            elapsed: 0.25,
            totals,
        };
        let text = report.to_json();
        let back = MetricsReport::from_json(&text).unwrap();
        assert_eq!(back, report, "JSON round-trip is lossless");
        // and the artifact is well-formed for the offline parser
        assert!(Json::parse(&text).is_ok());
        assert!(MetricsReport::from_json("{\"schema\": \"nope\"}").is_err());
        assert!(MetricsReport::from_json("not json").is_err());
    }

    #[test]
    fn prometheus_export_is_cumulative_and_named() {
        let mut totals = LaneMetrics {
            shards: 2,
            regions: 10,
            emitted_regions: 10,
            ..Default::default()
        };
        totals.e2e.record(100); // bucket 6 [64, 127]
        totals.e2e.record(100_000); // bucket 16
        let report = MetricsReport {
            workers: 2,
            elapsed: 1.0,
            totals,
        };
        let prom = report.to_prometheus();
        assert!(prom.contains("# TYPE regatta_shards_total counter"), "{prom}");
        assert!(prom.contains("regatta_shards_total 2\n"), "{prom}");
        assert!(prom.contains("# TYPE regatta_e2e_latency_seconds histogram"), "{prom}");
        assert!(prom.contains("regatta_e2e_latency_seconds_bucket{le=\"+Inf\"} 2"), "{prom}");
        assert!(prom.contains("regatta_e2e_latency_seconds_count 2"), "{prom}");
        assert!(prom.contains("regatta_in_flight_regions_peak 0"), "{prom}");
        // cumulative: the last finite bucket already holds both samples
        let lines: Vec<&str> = prom
            .lines()
            .filter(|l| l.starts_with("regatta_e2e_latency_seconds_bucket"))
            .collect();
        assert!(lines.len() >= 2);
        let last_finite = lines[lines.len() - 2];
        assert!(last_finite.ends_with(" 2"), "{last_finite}");
    }

    #[test]
    fn summary_table_reports_quantiles() {
        let mut totals = LaneMetrics {
            shards: 1,
            regions: 8,
            emitted_regions: 8,
            submitted_regions: 8,
            ..Default::default()
        };
        totals.e2e.record_n(1_000_000, 8); // 1 ms
        let report = MetricsReport {
            workers: 1,
            elapsed: 2.0,
            totals,
        };
        let table = report.summary_table();
        assert!(table.contains("e2e"), "{table}");
        assert!(table.contains("p50"), "{table}");
        assert!(table.contains("queue_wait"), "{table}");
        assert!((report.emit_rate() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn heartbeat_ticks_on_schedule_without_bursting() {
        let mut hb = Heartbeat::new(Duration::from_millis(10));
        assert!(!hb.due(5_000_000));
        assert!(hb.due(10_000_000));
        assert!(!hb.due(11_000_000));
        // a long gap yields ONE tick, schedule advanced past now
        assert!(hb.due(95_000_000));
        assert!(!hb.due(99_000_000));
        assert!(hb.due(100_000_000));
        assert_eq!(hb.ticks(), 3);
    }

    #[test]
    fn heartbeat_line_is_single_and_parseable() {
        let line = Heartbeat::render(&ProgressSnapshot {
            elapsed_secs: 2.5,
            submitted_regions: 100,
            emitted_regions: 80,
            in_flight_regions: 20,
            stolen: 3,
            faults: 1,
            p50_ns: 1_500_000,
            p99_ns: 9_000_000,
            done: false,
        });
        assert!(!line.contains('\n'), "one line, no embedded newlines: {line:?}");
        assert!(line.starts_with("progress "), "{line}");
        let mut tokens = line.split_whitespace();
        assert_eq!(tokens.next(), Some("progress"));
        for tok in tokens {
            let (key, value) = tok.split_once('=').expect("every token is key=value");
            assert!(!key.is_empty() && !value.is_empty(), "{tok}");
        }
        assert!(line.contains("regions=80/100"), "{line}");
        assert!(line.contains("rate=32.0"), "{line}");
        assert!(line.contains("done=0"), "{line}");
        let done = Heartbeat::render(&ProgressSnapshot {
            done: true,
            ..Default::default()
        });
        assert!(done.contains("done=1"), "{done}");
    }
}
