//! `regatta` — launcher CLI for the REGATTA streaming framework.
//!
//! ```text
//! regatta run sum   [--items N] [--region-size N | --region-max N | --region-skew N]
//!                   [--mode enum|tagged] [--shape fused|two-stage]
//!                   [--width W] [--backend xla|native] [--threshold T]
//!                   [--workers K] [--stream] [--ingest-buffer R] [--stats]
//! regatta run taxi  [--lines N] [--replicate K] [--variant enum|hybrid|tagged]
//!                   [--width W] [--backend xla|native]
//!                   [--workers K] [--stream] [--ingest-buffer R] [--stats]
//! regatta bench <fig6|fig7|fig8|scale|hotpath|ingest|penalty|width|lanectx>
//! regatta info      # artifact manifest + platform
//! regatta --config <file.toml>   # load a [run] config (see configs/)
//! ```

use anyhow::{bail, Context, Result};

use regatta::apps::sum::{reference_sums, SumApp, SumConfig, SumFactory, SumMode, SumShape};
use regatta::apps::taxi::{TaxiApp, TaxiConfig, TaxiFactory, TaxiVariant};
use regatta::bench::figures::{self, BackendSel, SweepConfig};
use regatta::exec::{ExecConfig, KernelSpawn, ShardedRunner};
use regatta::runtime::{ArtifactStore, Engine};
use regatta::util::cli::Args;
use regatta::util::config::Config;
use regatta::util::stats::{fmt_count, fmt_duration};
use regatta::workload::regions::{gen_blobs, GenBlobSource, RegionSpec};
use regatta::workload::source::SliceSource;
use regatta::workload::taxi::{generate, replicate, TaxiGenConfig};

const USAGE: &str = "\
regatta — region-based state for streaming computations on SIMD architectures

USAGE:
  regatta run sum   [--items N] [--region-size N | --region-max N | --region-skew N]
                    [--mode enum|tagged] [--shape fused|two-stage]
                    [--width W] [--backend xla|native] [--threshold T]
                    [--policy greedy|deepest|rr]
                    [--workers K] [--shards-per-worker S]
                    [--stream] [--ingest-buffer R] [--stats] [--verify]
  regatta run taxi  [--lines N] [--replicate K] [--variant enum|hybrid|tagged]
                    [--width W] [--backend xla|native]
                    [--policy greedy|deepest|rr]
                    [--workers K] [--shards-per-worker S]
                    [--stream] [--ingest-buffer R] [--stats]
  regatta bench <fig6|fig7|fig8|scale|penalty|width|lanectx>
                    [--items N] [--width W] [--backend xla|native]
                    [--workers K1,K2,...] [--json FILE]
  regatta bench hotpath [--smoke] [--items N] [--widths W1,W2,...]
                    [--policy greedy|deepest|rr] [--json FILE] [--check BASELINE]
  regatta bench ingest  [--smoke] [--items N] [--width W] [--workers K1,K2,...]
                    [--ingest-buffer R] [--json FILE]
  regatta info
  regatta --config <file.toml>

  --stream runs the app through the v2 streaming executor: regions are
  ingested incrementally (at most R in flight, backpressure beyond) and
  executed by work-stealing workers; outputs stay in stream order.
";

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        eprintln!("\n{USAGE}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let mut args = Args::from_env()?;
    if let Some(path) = args.opt("config").map(str::to_string) {
        args = config_to_args(&path)?;
    }
    match args.subcommand() {
        Some("run") => match args.positional.get(1).map(String::as_str) {
            Some("sum") => run_sum(&args),
            Some("taxi") => run_taxi(&args),
            other => bail!("unknown app {other:?} (use sum|taxi)"),
        },
        Some("bench") => run_bench(&args),
        Some("info") => info(),
        Some(other) => bail!("unknown subcommand {other:?}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

/// Convert a `[run]` config file into the equivalent CLI arguments.
fn config_to_args(path: &str) -> Result<Args> {
    let cfg = Config::load(path)?;
    let mut argv: Vec<String> = Vec::new();
    let cmd = cfg.str_or("run", "command", "")?;
    if cmd.is_empty() {
        bail!("config {path}: [run] command = \"sum run ...\" is required");
    }
    argv.extend(cmd.split_whitespace().map(str::to_string));
    for key in [
        "items", "region-size", "region-max", "region-skew", "mode", "shape", "width",
        "backend", "threshold", "workers", "shards-per-worker", "ingest-buffer", "lines",
        "replicate", "variant", "policy",
    ] {
        if let Some(v) = cfg.get("run", &key.replace('-', "_")) {
            let vs = match v {
                regatta::util::config::Value::Str(s) => s.clone(),
                regatta::util::config::Value::Int(i) => i.to_string(),
                regatta::util::config::Value::Float(f) => f.to_string(),
                regatta::util::config::Value::Bool(b) => b.to_string(),
                other => bail!("config {path}: bad value {other:?} for {key}"),
            };
            argv.push(format!("--{key}"));
            argv.push(vs);
        }
    }
    for flag in ["stats", "stream", "verify"] {
        if cfg.bool_or("run", flag, false)? {
            argv.push(format!("--{flag}"));
        }
    }
    Args::parse(argv)
}

fn backend(args: &Args) -> Result<BackendSel> {
    args.str_or("backend", "xla").parse()
}

fn policy(args: &Args) -> Result<regatta::prelude::Policy> {
    args.str_or("policy", "greedy").parse()
}

fn exec_config(args: &Args, workers: usize) -> Result<ExecConfig> {
    Ok(ExecConfig::new(workers)
        .with_shards_per_worker(args.get_or("shards-per-worker", 1)?)
        .streaming(args.get_or("ingest-buffer", 1024)?))
}

fn print_exec_stats<T>(report: &regatta::exec::ExecReport<T>) {
    println!(
        "{} shard(s), utilization {:.0}%",
        report.shards,
        100.0 * report.utilization()
    );
    print!("{}", report.worker_table());
}

fn run_sum(args: &Args) -> Result<()> {
    let width: usize = args.get_or("width", 128)?;
    let items: usize = args.get_or("items", 1 << 20)?;
    let threshold: f32 = args.get_or("threshold", 0.0)?;
    let workers: usize = args.get_or("workers", 1)?;
    anyhow::ensure!(workers >= 1, "--workers must be >= 1 (got {workers})");
    let mode = match args.str_or("mode", "enum").as_str() {
        "enum" => SumMode::Enumerated,
        "tagged" => SumMode::Tagged,
        other => bail!("unknown mode {other:?}"),
    };
    let shape = match args.str_or("shape", "fused").as_str() {
        "fused" => SumShape::Fused,
        "two-stage" => SumShape::TwoStage,
        other => bail!("unknown shape {other:?}"),
    };
    let spec = if let Some(max) = args.get::<usize>("region-max")? {
        RegionSpec::Uniform { max }
    } else if let Some(max) = args.get::<usize>("region-skew")? {
        RegionSpec::Skewed { max }
    } else {
        RegionSpec::Fixed {
            size: args.get_or("region-size", 128)?,
        }
    };
    let sel = backend(args)?;
    let pol = policy(args)?;
    let seed = args.get_or("seed", 0xF16u64)?;
    let streaming = args.flag("stream");
    // the streaming path never materializes the blob stream — that is
    // its point; --verify regenerates it separately below
    let blobs = if streaming {
        Vec::new()
    } else {
        gen_blobs(items, spec, seed)
    };
    let cfg = SumConfig {
        width,
        threshold,
        mode,
        shape,
        policy: pol,
        ..Default::default()
    };

    let regions_label = if streaming {
        "streamed regions".to_string()
    } else {
        format!("{} regions", blobs.len())
    };
    println!(
        "sum app: {items} items, {regions_label} ({spec:?}), width {width}, \
         {mode:?}/{shape:?}, backend {sel:?}, policy {}, {workers} worker(s){}",
        pol.label(),
        if streaming { ", streaming ingest" } else { "" }
    );

    let (outputs, metrics, elapsed) = if streaming {
        // L3.5 v2: regions are generated lazily on the ingest thread,
        // sharded on the fly under the --ingest-buffer budget, and run
        // by work-stealing workers; outputs stay in stream order
        let factory = SumFactory::new(cfg, KernelSpawn::from(sel));
        let runner = ShardedRunner::new(exec_config(args, workers)?);
        let report = runner.run_stream(&factory, GenBlobSource::new(items, spec, seed))?;
        if args.flag("stats") {
            print_exec_stats(&report);
        }
        let outputs = regatta::apps::sum::finish_sharded_outputs(mode, report.outputs);
        (outputs, report.metrics, report.elapsed)
    } else if workers <= 1 {
        let p = figures::provider(sel, width)?;
        let app = SumApp::new(cfg, p.kernels);
        let report = app.run(&blobs)?;
        (report.outputs, report.metrics, report.elapsed)
    } else {
        // L3.5: shard at region boundaries, one pipeline replica per
        // worker thread, deterministic merge back into stream order
        let factory = SumFactory::new(cfg, KernelSpawn::from(sel));
        let runner = ShardedRunner::new(exec_config(args, workers)?);
        let report = runner.run(&factory, &blobs)?;
        if args.flag("stats") {
            print_exec_stats(&report);
        }
        let outputs = regatta::apps::sum::finish_sharded_outputs(mode, report.outputs);
        (outputs, report.metrics, report.elapsed)
    };

    println!(
        "-> {} region sums in {} ({} items/s)",
        outputs.len(),
        fmt_duration(elapsed),
        fmt_count(items as f64 / elapsed)
    );
    if args.flag("verify") {
        let blobs = if streaming {
            gen_blobs(items, spec, seed)
        } else {
            blobs
        };
        let want = reference_sums(&blobs, threshold);
        anyhow::ensure!(outputs.len() == want.len(), "sum count mismatch");
        for ((gi, gv), (wi, wv)) in outputs.iter().zip(&want) {
            anyhow::ensure!(gi == wi, "region order mismatch");
            anyhow::ensure!(
                (gv - wv).abs() <= 1e-3 * (1.0 + wv.abs()),
                "region {gi}: {gv} vs reference {wv}"
            );
        }
        println!("verify: OK (matches f64 reference)");
    }
    if args.flag("stats") {
        print!("{}", metrics.table());
        println!("mean occupancy: {:.1}%", 100.0 * metrics.occupancy());
    }
    Ok(())
}

fn run_taxi(args: &Args) -> Result<()> {
    let width: usize = args.get_or("width", 128)?;
    let lines: usize = args.get_or("lines", 64)?;
    let reps: usize = args.get_or("replicate", 1)?;
    let variant = match args.str_or("variant", "hybrid").as_str() {
        "enum" => TaxiVariant::Enumerated,
        "hybrid" => TaxiVariant::Hybrid,
        "tagged" => TaxiVariant::Tagged,
        other => bail!("unknown variant {other:?}"),
    };
    let sel = backend(args)?;
    let pol = policy(args)?;
    let workers: usize = args.get_or("workers", 1)?;
    anyhow::ensure!(workers >= 1, "--workers must be >= 1 (got {workers})");
    let streaming = args.flag("stream");
    let base = generate(lines, TaxiGenConfig::default(), args.get_or("seed", 0xF16u64)?);
    let w = if reps > 1 { replicate(&base, reps) } else { base };
    let chars: usize = w.lines.iter().map(|l| l.len).sum();
    println!(
        "taxi app: {} lines ({} chars, {} pairs), width {width}, {} variant, \
         backend {sel:?}, policy {}, {workers} worker(s){}",
        w.lines.len(),
        fmt_count(chars as f64),
        w.total_pairs,
        variant.label(),
        pol.label(),
        if streaming { ", streaming ingest" } else { "" }
    );
    let cfg = TaxiConfig {
        width,
        variant,
        policy: pol,
        ..Default::default()
    };
    let (pairs, metrics, elapsed) = if streaming {
        // L3.5 v2: lines flow through the bounded ingest buffer and are
        // parsed by work-stealing workers over the shared text
        let factory = TaxiFactory::new(cfg, KernelSpawn::from(sel), w.text.clone());
        let runner = ShardedRunner::new(exec_config(args, workers)?);
        let report = runner.run_stream(&factory, SliceSource::new(&w.lines))?;
        if args.flag("stats") {
            print_exec_stats(&report);
        }
        (report.outputs, report.metrics, report.elapsed)
    } else if workers <= 1 {
        let p = figures::provider(sel, width)?;
        let report = TaxiApp::new(cfg, p.kernels).run(&w)?;
        (report.pairs, report.metrics, report.elapsed)
    } else {
        // L3.5: lines are the regions — shard between lines, balanced by
        // character count, pairs merged back in stream order
        let factory = TaxiFactory::new(cfg, KernelSpawn::from(sel), w.text.clone());
        let runner = ShardedRunner::new(exec_config(args, workers)?);
        let report = runner.run(&factory, &w.lines)?;
        if args.flag("stats") {
            print_exec_stats(&report);
        }
        (report.outputs, report.metrics, report.elapsed)
    };
    anyhow::ensure!(
        pairs.len() == w.total_pairs,
        "parsed {} of {} pairs",
        pairs.len(),
        w.total_pairs
    );
    println!(
        "-> {} pairs parsed in {} ({} chars/s)",
        pairs.len(),
        fmt_duration(elapsed),
        fmt_count(chars as f64 / elapsed)
    );
    if args.flag("stats") {
        print!("{}", metrics.table());
    }
    Ok(())
}

fn run_bench(args: &Args) -> Result<()> {
    let which = args.positional.get(1).context(
        "bench target required: fig6|fig7|fig8|scale|hotpath|ingest|penalty|width|lanectx",
    )?;
    if which == "hotpath" {
        return run_bench_hotpath(args);
    }
    if which == "ingest" {
        return run_bench_ingest(args);
    }
    let mut cfg = SweepConfig {
        backend: backend(args)?,
        ..Default::default()
    };
    cfg.width = args.get_or("width", cfg.width)?;
    cfg.items = args.get_or("items", 1 << 18)?;
    match which.as_str() {
        "fig6" => {
            figures::fig6(&cfg)?;
        }
        "fig7" => {
            figures::fig7(&cfg)?;
        }
        "fig8" => {
            figures::fig8(&cfg, args.get_or("lines", 32)?, &[1, 2, 4])?;
        }
        "scale" => {
            let workers = args.list_or("workers", &[1usize, 2, 4, 8])?;
            let w = cfg.width;
            let regions = [(w / 8).max(1), w, 8 * w];
            let rows = figures::scaling_shards(&cfg, &workers, &regions)?;
            if let Some(path) = args.opt("json") {
                std::fs::write(path, figures::scaling_to_json(&rows))
                    .with_context(|| format!("writing {path}"))?;
                println!("wrote {path}");
            }
        }
        "penalty" => {
            figures::abstraction_penalty(&cfg)?;
        }
        "width" => {
            figures::ablation_width(&cfg, &[32, 64, 128, 256])?;
        }
        "lanectx" => {
            figures::ablation_lanectx(&cfg)?;
            figures::ablation_policy(&cfg, args.get_or("lines", 32)?)?;
        }
        other => bail!("unknown bench {other:?}"),
    }
    Ok(())
}

/// `bench hotpath`: firing-path + app sweep, JSON artifact, optional
/// baseline regression gate (see `rust/src/bench/hotpath.rs`).
fn run_bench_hotpath(args: &Args) -> Result<()> {
    use regatta::bench::hotpath;
    let mut cfg = if args.flag("smoke") {
        hotpath::HotpathConfig::smoke()
    } else {
        hotpath::HotpathConfig::default()
    };
    cfg.widths = args.list_or("widths", &cfg.widths)?;
    cfg.items = args.get_or("items", cfg.items)?;
    cfg.seed = args.get_or("seed", cfg.seed)?;
    if args.opt("policy").is_some() {
        cfg.policies = vec![policy(args)?];
    }
    let report = hotpath::run(&cfg)?;
    let path = args.str_or("json", "BENCH_hotpath.json");
    std::fs::write(&path, hotpath::to_json(&report)).with_context(|| format!("writing {path}"))?;
    println!("wrote {path}");
    if let Some(baseline) = args.opt("check") {
        hotpath::check_against(&report, baseline)?;
    }
    Ok(())
}

/// `bench ingest`: streaming ingest + work stealing vs the legacy cursor
/// across region-size distributions, with a JSON artifact (see
/// `rust/src/bench/ingest.rs`).
fn run_bench_ingest(args: &Args) -> Result<()> {
    use regatta::bench::ingest;
    let mut cfg = if args.flag("smoke") {
        ingest::IngestConfig::smoke()
    } else {
        ingest::IngestConfig::default()
    };
    cfg.width = args.get_or("width", cfg.width)?;
    cfg.items = args.get_or("items", cfg.items)?;
    cfg.workers = args.list_or("workers", &cfg.workers)?;
    cfg.buffer_regions = args.get_or("ingest-buffer", cfg.buffer_regions)?;
    cfg.seed = args.get_or("seed", cfg.seed)?;
    let report = ingest::run(&cfg)?;
    let path = args.str_or("json", "BENCH_ingest.json");
    std::fs::write(&path, ingest::to_json(&report)).with_context(|| format!("writing {path}"))?;
    println!("wrote {path}");
    if let Some(speedup) = ingest::skew_speedup(&report) {
        println!("skewed stream, stealing vs cursor at max workers: {speedup:.2}x");
    }
    Ok(())
}

fn info() -> Result<()> {
    let store = ArtifactStore::discover()?;
    let m = store.manifest();
    println!("artifact dir : {}", store.dir().display());
    println!("widths       : {:?}", m.widths);
    println!("kernels      : {}", m.entries.join(", "));
    println!("window_len   : {}", m.window_len);
    let engine = Engine::new(store.clone())?;
    println!("PJRT platform: {}", engine.platform_name());
    engine.preload(128)?;
    println!("preload      : all kernels compiled at w=128 OK");
    Ok(())
}
