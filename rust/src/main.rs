//! `regatta` — launcher CLI for the REGATTA streaming framework.
//!
//! ```text
//! regatta run sum   [--items N] [--region-size N | --region-max N | --region-skew N]
//!                   [--mode enum|tagged] [--shape fused|two-stage]
//!                   [--width W] [--backend xla|native] [--threshold T]
//!                   [--workers K] [--stream] [--ingest-buffer R] [--stats]
//!                   [--input data.rgn] [--output results.jsonl|.bin]
//! regatta run taxi  [--lines N] [--replicate K] [--variant enum|hybrid|tagged]
//!                   [--width W] [--backend xla|native]
//!                   [--workers K] [--stream] [--ingest-buffer R] [--stats]
//!                   [--input trips.txt] [--output pairs.jsonl|.bin]
//! regatta gen sum   --out data.rgn  [--items N] [--region-*] [--seed S]
//! regatta gen taxi  --out trips.txt [--lines N] [--replicate K] [--seed S]
//! regatta rgn verify <data.rgn> [--json]   # per-frame checksum + footer audit
//! regatta bench <fig6|fig7|fig8|scale|hotpath|ingest|io|faults|latency|penalty|width|lanectx>
//! regatta trace summarize --input out.trace.json [--buckets N]
//! regatta metrics summarize --input out.metrics.json
//! regatta info      # artifact manifest + platform
//! regatta --config <file.toml>   # load a [run] config (see configs/)
//! ```
//!
//! `run` also takes `--metrics out.json [--metrics-format json|prom]`
//! and `--progress-secs N` for live telemetry (see the USAGE text).

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use regatta::apps::sum::{reference_sums, SumApp, SumConfig, SumFactory, SumMode, SumShape};
use regatta::apps::taxi::{TaxiApp, TaxiConfig, TaxiFactory, TaxiPair, TaxiVariant};
use regatta::bench::figures::{self, BackendSel, SweepConfig};
use regatta::coordinator::enumerate::Blob;
use regatta::exec::{ContainerPool, ExecConfig, FaultPolicy, KernelSpawn, ShardedRunner};
use regatta::io::{
    peek_rgn_footer, read_rgn_file, verify_rgn_file, write_rgn_file, write_taxi_file, BinRecord,
    BinarySink, BlobFileSource, JsonRecord, JsonlSink, ResultSink, TextSource,
};
use regatta::runtime::{ArtifactStore, Engine};
use regatta::util::cli::Args;
use regatta::util::config::Config;
use regatta::util::stats::{fmt_count, fmt_duration};
use regatta::workload::regions::{gen_blobs, GenBlobSource, RegionSpec};
use regatta::workload::source::{RegionSource, SliceSource};
use regatta::workload::taxi::{generate, replicate, TaxiGenConfig};

const USAGE: &str = "\
regatta — region-based state for streaming computations on SIMD architectures

USAGE:
  regatta run sum   [--items N] [--region-size N | --region-max N | --region-skew N]
                    [--mode enum|tagged] [--shape fused|two-stage]
                    [--width W] [--backend xla|native] [--threshold T]
                    [--policy greedy|deepest|rr]
                    [--workers K] [--shards-per-worker S]
                    [--stream] [--ingest-buffer R] [--stats] [--verify]
                    [--fault-policy fail-fast|retry|quarantine] [--fault-retries N]
                    [--fault-backoff-ms N] [--watchdog-secs S] [--max-region-items N]
                    [--input data.rgn] [--output results.jsonl|.bin]
                    [--trace out.trace.json]
                    [--metrics out.json [--metrics-format json|prom]]
                    [--progress-secs N]
  regatta run taxi  [--lines N] [--replicate K] [--variant enum|hybrid|tagged]
                    [--width W] [--backend xla|native]
                    [--policy greedy|deepest|rr]
                    [--workers K] [--shards-per-worker S]
                    [--stream] [--ingest-buffer R] [--stats]
                    [--fault-policy fail-fast|retry|quarantine] [--fault-retries N]
                    [--fault-backoff-ms N] [--watchdog-secs S] [--max-region-items N]
                    [--input trips.txt] [--output pairs.jsonl|.bin]
                    [--trace out.trace.json]
                    [--metrics out.json [--metrics-format json|prom]]
                    [--progress-secs N]
  regatta gen sum   --out data.rgn  [--items N] [--region-size N | --region-max N |
                    --region-skew N] [--seed S]
  regatta gen taxi  --out trips.txt [--lines N] [--replicate K] [--seed S]
  regatta rgn verify <data.rgn> [--json]
  regatta bench <fig6|fig7|fig8|scale|penalty|width|lanectx>
                    [--items N] [--width W] [--backend xla|native]
                    [--workers K1,K2,...] [--json FILE]
  regatta bench hotpath [--smoke] [--items N] [--widths W1,W2,...]
                    [--policy greedy|deepest|rr] [--reuse-granules G1,G2,...]
                    [--json FILE] [--check BASELINE]
  regatta bench ingest  [--smoke] [--items N] [--width W] [--workers K1,K2,...]
                    [--ingest-buffer R] [--json FILE]
  regatta bench io      [--smoke] [--items N] [--width W] [--workers K]
                    [--buffers R1,R2,...] [--json FILE]
  regatta bench faults  [--smoke] [--items N] [--width W] [--workers K]
                    [--fault-rate P] [--json FILE]
  regatta bench latency [--smoke] [--items N] [--width W] [--workers K1,K2,...]
                    [--ingest-buffer R] [--json FILE]
  regatta trace summarize --input out.trace.json [--buckets N]
  regatta metrics summarize --input out.metrics.json
  regatta info
  regatta --config <file.toml>

  --trace FILE records every scheduler firing, shard execution, ingest
  cut and merge emission into per-worker ring buffers and writes one
  Chrome-trace JSON artifact (load in Perfetto or chrome://tracing, or
  run `regatta trace summarize` for an occupancy timeline, straggler
  table and steal/backpressure report). Tracing never changes outputs;
  without the flag the hot path runs exactly as untraced.

  --stream runs the app through the v2 streaming executor: regions are
  ingested incrementally (at most R in flight, backpressure beyond) and
  executed by work-stealing workers; outputs stay in stream order.

  --input streams regions out of a file written by `regatta gen` (sum:
  .rgn containers, taxi: line-delimited text) and --output lands results
  incrementally in stream order (.bin = fixed-record binary, anything
  else JSONL); either flag implies --stream. For sum, input + output
  memory is bounded by --ingest-buffer, not by file size; for taxi the
  raw text stays resident (it models the shared device buffer) but the
  line index and results are budget-bound. Output files are written to
  <path>.tmp and renamed into place only when complete.

  --fault-policy picks what a worker does when a shard panics or errors:
  fail-fast (default) aborts the run naming worker and shard; retry
  rebuilds the worker's pipeline and re-runs the shard up to
  --fault-retries times, narrowing to single-region re-runs after the
  first whole-shard failure so only the poisoned part repeats (outputs
  stay bit-identical to a fault-free run); quarantine drops only the
  poisoned parts, salvaging each region's surviving partial aggregates
  into the report's partial-region ledger (--stats prints it), and
  retires a worker whose rebuilt pipeline fails again, re-dealing its
  work to survivors. --fault-backoff-ms N waits N ms between attempts
  (also applied to transient ingest-source failures) without tripping
  the watchdog. --watchdog-secs bounds how long the pool waits without
  any progress before failing with a stall diagnosis instead of
  hanging.

  --max-region-items N splits regions heavier than N items into
  sub-shards that different workers run concurrently, re-folding the
  partial aggregates deterministically — output stays bit-identical for
  the fused enumerated sum; stages with order-dependent region state
  (taxi, two-stage sum) refuse with a named error. 0 (default) never
  splits.

  rgn verify audits a .rgn container and exits 0 when it verifies
  clean, 2 when the container was read but failed verification
  (corrupt frames or footer mismatch), and 1 when the file could not
  be audited at all (missing, unreadable, bad usage). --json prints
  one machine-readable report object instead of the human summary.

  --metrics FILE meters the run with per-worker counters and
  log2-bucketed latency histograms — per-region submit->emit e2e
  latency, shard queue-wait and service time, steal / fault /
  backpressure rates — and writes one artifact on completion
  (--metrics-format json|prom; json round-trips through `regatta
  metrics summarize`). Metering reads clocks and bumps thread-local
  counters only, so outputs are bit-identical to an unmetered run.
  --progress-secs N prints one machine-parseable heartbeat line
  (`progress t=... regions=emitted/submitted rate=... p50_ms=...`)
  every N seconds of a streamed run, from the ingest driver's own
  loop — no extra thread. It implies metering; combine with --metrics
  to also keep the artifact.
";

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        eprintln!("\n{USAGE}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let mut args = Args::from_env()?;
    if let Some(path) = args.opt("config").map(str::to_string) {
        args = config_to_args(&path)?;
    }
    match args.subcommand() {
        Some("run") => match args.positional.get(1).map(String::as_str) {
            Some("sum") => run_sum(&args),
            Some("taxi") => run_taxi(&args),
            other => bail!("unknown app {other:?} (use sum|taxi)"),
        },
        Some("gen") => run_gen(&args),
        Some("rgn") => run_rgn(&args),
        Some("bench") => run_bench(&args),
        Some("trace") => run_trace(&args),
        Some("metrics") => run_metrics(&args),
        Some("info") => info(),
        Some(other) => bail!("unknown subcommand {other:?}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

/// Convert a `[run]` config file into the equivalent CLI arguments.
fn config_to_args(path: &str) -> Result<Args> {
    let cfg = Config::load(path)?;
    let mut argv: Vec<String> = Vec::new();
    let cmd = cfg.str_or("run", "command", "")?;
    if cmd.is_empty() {
        bail!("config {path}: [run] command = \"sum run ...\" is required");
    }
    argv.extend(cmd.split_whitespace().map(str::to_string));
    for key in [
        "items", "region-size", "region-max", "region-skew", "mode", "shape", "width",
        "backend", "threshold", "workers", "shards-per-worker", "ingest-buffer", "lines",
        "replicate", "variant", "policy", "input", "output", "trace", "fault-policy",
        "fault-retries", "fault-backoff-ms", "watchdog-secs", "max-region-items", "metrics",
        "metrics-format", "progress-secs",
    ] {
        if let Some(v) = cfg.get("run", &key.replace('-', "_")) {
            let vs = match v {
                regatta::util::config::Value::Str(s) => s.clone(),
                regatta::util::config::Value::Int(i) => i.to_string(),
                regatta::util::config::Value::Float(f) => f.to_string(),
                regatta::util::config::Value::Bool(b) => b.to_string(),
                other => bail!("config {path}: bad value {other:?} for {key}"),
            };
            argv.push(format!("--{key}"));
            argv.push(vs);
        }
    }
    for flag in ["stats", "stream", "verify"] {
        if cfg.bool_or("run", flag, false)? {
            argv.push(format!("--{flag}"));
        }
    }
    Args::parse(argv)
}

fn backend(args: &Args) -> Result<BackendSel> {
    args.str_or("backend", "xla").parse()
}

fn policy(args: &Args) -> Result<regatta::prelude::Policy> {
    args.str_or("policy", "greedy").parse()
}

/// `--fault-policy` / `--fault-retries` / `--fault-backoff-ms` into a
/// [`FaultPolicy`].
fn fault_policy(args: &Args) -> Result<FaultPolicy> {
    Ok(match args.str_or("fault-policy", "fail-fast").as_str() {
        "fail-fast" => FaultPolicy::FailFast,
        "retry" => FaultPolicy::Retry {
            max_attempts: args.get_or("fault-retries", 3)?,
            backoff: Duration::from_millis(args.get_or("fault-backoff-ms", 0)?),
        },
        "quarantine" => FaultPolicy::Quarantine,
        other => bail!("unknown fault policy {other:?} (use fail-fast|retry|quarantine)"),
    })
}

fn exec_config(args: &Args, workers: usize) -> Result<ExecConfig> {
    let cfg = ExecConfig::new(workers)
        .with_shards_per_worker(args.get_or("shards-per-worker", 1)?)
        .streaming(args.get_or("ingest-buffer", 1024)?)
        .with_fault(fault_policy(args)?)
        .with_watchdog(Duration::from_secs(args.get_or("watchdog-secs", 60)?))
        .with_max_region_items(args.get_or("max-region-items", 0)?)
        .with_trace(
            args.opt("trace")
                .map(|_| regatta::trace::TraceOptions::default()),
        )
        .with_metrics(args.opt("metrics").is_some())
        .with_progress(
            args.get::<u64>("progress-secs")?
                .map(Duration::from_secs),
        );
    // names zero and absurd (unit-mistake) budgets and a zero heartbeat
    // period, mentioning the flag
    cfg.validate()?;
    Ok(cfg)
}

/// `--metrics FILE [--metrics-format json|prom]`: write the run's
/// metrics artifact.
fn write_metrics_artifact<T>(
    report: &regatta::exec::ExecReport<T>,
    path: &str,
    format: &str,
) -> Result<()> {
    let m = report.metrics_report.as_ref().context(
        "run was launched with --metrics but carries no metrics report (internal error)",
    )?;
    let body = match format {
        "json" => m.to_json(),
        "prom" => m.to_prometheus(),
        other => bail!("unknown metrics format {other:?} (use json|prom)"),
    };
    std::fs::write(path, body).with_context(|| format!("writing {path}"))?;
    println!(
        "metrics: {} worker(s), {} region(s), e2e p99 {:.3} ms -> {path}",
        m.workers,
        m.totals.regions,
        m.totals.e2e.quantile_ns(0.99) as f64 / 1e6
    );
    if format == "json" {
        println!("metrics: inspect with `regatta metrics summarize --input {path}`");
    }
    Ok(())
}

/// `regatta metrics summarize`: run/flow/latency tables from a
/// `--metrics` JSON artifact.
fn run_metrics(args: &Args) -> Result<()> {
    match args.positional.get(1).map(String::as_str) {
        Some("summarize") => {
            let path = args
                .opt("input")
                .context("metrics summarize needs --input FILE (a --metrics artifact)")?;
            let text =
                std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            let report = regatta::metrics::MetricsReport::from_json(&text)?;
            print!("{}", report.summary_table());
            Ok(())
        }
        other => bail!("unknown metrics action {other:?} (use summarize)"),
    }
}

/// `--trace FILE`: write the run's Chrome-trace artifact.
fn write_trace_artifact<T>(report: &regatta::exec::ExecReport<T>, path: &str) -> Result<()> {
    let trace = report
        .trace
        .as_ref()
        .context("run was launched with tracing but carries no trace (internal error)")?;
    std::fs::write(path, regatta::trace::chrome::to_chrome_json(trace))
        .with_context(|| format!("writing {path}"))?;
    println!(
        "trace: {} event(s) across {} lane(s), {} dropped -> {path}\n\
         trace: load in Perfetto / chrome://tracing, or run \
         `regatta trace summarize --input {path}`",
        trace.events(),
        trace.workers.len(),
        trace.dropped()
    );
    Ok(())
}

/// `regatta trace summarize`: occupancy timeline, straggler table and
/// steal/backpressure report from a `--trace` artifact.
fn run_trace(args: &Args) -> Result<()> {
    match args.positional.get(1).map(String::as_str) {
        Some("summarize") => {
            let path = args
                .opt("input")
                .context("trace summarize needs --input FILE (a --trace artifact)")?;
            let text =
                std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            let buckets: usize = args.get_or("buckets", 24)?;
            print!("{}", regatta::trace::summary::summarize(&text, buckets)?);
            Ok(())
        }
        other => bail!("unknown trace action {other:?} (use summarize)"),
    }
}

/// The region-size spec shared by `run sum`, `gen sum` and the benches.
fn region_spec(args: &Args) -> Result<RegionSpec> {
    Ok(if let Some(max) = args.get::<usize>("region-max")? {
        RegionSpec::Uniform { max }
    } else if let Some(max) = args.get::<usize>("region-skew")? {
        RegionSpec::Skewed { max }
    } else {
        RegionSpec::Fixed {
            size: args.get_or("region-size", 128)?,
        }
    })
}

/// Pick the result encoding from the output path: `.bin` gets the
/// fixed-record binary sink, everything else JSONL.
fn file_sink<T>(path: &str) -> Result<Box<dyn ResultSink<T>>>
where
    T: JsonRecord + BinRecord + 'static,
{
    Ok(if path.ends_with(".bin") {
        Box::new(BinarySink::create(path)?)
    } else {
        Box::new(JsonlSink::create(path)?)
    })
}

/// Refuse `--output` aliasing `--input`: the sink streams into the
/// output's `.tmp` sibling and renames over the output on finish, so
/// both the final path and its `.tmp` staging path must stay clear of
/// the input.
fn ensure_distinct_io(input: &str, output: &str) -> Result<()> {
    let resolve = |p: &std::path::Path| {
        std::fs::canonicalize(p).unwrap_or_else(|_| p.to_path_buf())
    };
    let input_path = resolve(std::path::Path::new(input));
    let output_path = std::path::Path::new(output);
    anyhow::ensure!(
        input_path != resolve(output_path),
        "--output {output} is the same file as --input {input}: refusing to \
         overwrite the input while reading it"
    );
    let tmp = regatta::io::tmp_path(output_path);
    anyhow::ensure!(
        input_path != resolve(&tmp),
        "--output {output} stages through {}, which is the same file as \
         --input {input}: refusing to truncate the input while reading it",
        tmp.display()
    );
    Ok(())
}

fn print_exec_stats<T>(report: &regatta::exec::ExecReport<T>) {
    println!(
        "{} shard(s), utilization {:.0}%",
        report.shards,
        100.0 * report.utilization()
    );
    if report.split_regions > 0 {
        println!(
            "{} region(s) split into sub-shards (--max-region-items)",
            report.split_regions
        );
    }
    if report.rerun_regions > 0 {
        println!(
            "{} single-region re-run(s) during part-granular retry narrowing",
            report.rerun_regions
        );
    }
    print!("{}", report.worker_table());
    let retired = report.per_worker.iter().filter(|w| w.dead).count();
    if retired > 0 {
        println!("{retired} worker(s) retired mid-run; their work was re-dealt to survivors");
    }
    let faults = report.fault_table();
    if !faults.is_empty() {
        print!("quarantined work:\n{faults}");
    }
    let partials = report.partial_table();
    if !partials.is_empty() {
        print!("partially salvaged regions (no output row emitted):\n{partials}");
    }
}

fn run_sum(args: &Args) -> Result<()> {
    let width: usize = args.get_or("width", 128)?;
    let threshold: f32 = args.get_or("threshold", 0.0)?;
    let workers: usize = args.get_or("workers", 1)?;
    anyhow::ensure!(workers >= 1, "--workers must be >= 1 (got {workers})");
    let mode = match args.str_or("mode", "enum").as_str() {
        "enum" => SumMode::Enumerated,
        "tagged" => SumMode::Tagged,
        other => bail!("unknown mode {other:?}"),
    };
    let shape = match args.str_or("shape", "fused").as_str() {
        "fused" => SumShape::Fused,
        "two-stage" => SumShape::TwoStage,
        other => bail!("unknown shape {other:?}"),
    };
    let spec = region_spec(args)?;
    let sel = backend(args)?;
    let pol = policy(args)?;
    let seed = args.get_or("seed", 0xF16u64)?;
    let input = args.opt("input").map(str::to_string);
    let output = args.opt("output").map(str::to_string);
    let trace_path = args.opt("trace").map(str::to_string);
    let metrics_path = args.opt("metrics").map(str::to_string);
    let metrics_format = args.str_or("metrics-format", "json");
    // file I/O always runs through the streaming executor — bounded
    // memory is its point
    let streaming = args.flag("stream") || input.is_some() || output.is_some();
    anyhow::ensure!(
        !(args.flag("verify") && output.is_some()),
        "--verify compares collected outputs and cannot be combined with --output"
    );
    let items: usize = match &input {
        // totals come from the file's validated footer, not from flags
        Some(path) => peek_rgn_footer(path)?.items as usize,
        None => args.get_or("items", 1 << 20)?,
    };
    // the streaming path never materializes the blob stream — that is
    // its point; --verify regenerates it separately below
    let blobs = if streaming {
        Vec::new()
    } else {
        gen_blobs(items, spec, seed)
    };
    let cfg = SumConfig {
        width,
        threshold,
        mode,
        shape,
        policy: pol,
        ..Default::default()
    };

    let source_label = match &input {
        Some(path) => format!("file {path}"),
        None if streaming => format!("streamed regions ({spec:?})"),
        None => format!("{} regions ({spec:?})", blobs.len()),
    };
    println!(
        "sum app: {items} items, {source_label}, width {width}, \
         {mode:?}/{shape:?}, backend {sel:?}, policy {}, {workers} worker(s){}",
        pol.label(),
        if streaming { ", streaming ingest" } else { "" }
    );

    let (outputs, metrics, elapsed) = if streaming {
        // L3.5 v2: regions arrive incrementally — generated lazily or
        // read from a .rgn container — sharded on the fly under the
        // --ingest-buffer budget and run by work-stealing workers;
        // element containers circulate through a shared pool (source
        // takes, workers return), so steady-state driver allocations
        // are governed by the budget, not stream length
        let pool = Arc::new(ContainerPool::new());
        let factory = SumFactory::new(cfg, KernelSpawn::from(sel)).with_elem_pool(pool.clone());
        let runner = ShardedRunner::new(exec_config(args, workers)?);
        let source: Box<dyn RegionSource<Region = Blob>> = match &input {
            Some(path) => Box::new(BlobFileSource::open(path)?.with_pool(pool.clone())),
            None => Box::new(GenBlobSource::new(items, spec, seed).with_pool(pool)),
        };
        if let Some(out_path) = &output {
            anyhow::ensure!(
                mode == SumMode::Enumerated,
                "--output needs stream-order results; tagged-mode outputs are \
                 folded only after the whole run (drop --output or use --mode enum)"
            );
            if let Some(in_path) = &input {
                ensure_distinct_io(in_path, out_path)?;
            }
            let mut sink = file_sink::<(u64, f64)>(out_path)?;
            let report = runner.run_stream_into(&factory, source, &mut *sink)?;
            let stats = sink.finish()?;
            if let Some(tp) = &trace_path {
                write_trace_artifact(&report, tp)?;
            }
            if let Some(mp) = &metrics_path {
                write_metrics_artifact(&report, mp, &metrics_format)?;
            }
            if args.flag("stats") {
                print_exec_stats(&report);
                print!("{}", report.metrics.table());
            }
            println!(
                "-> {} region sums streamed to {out_path} ({} bytes) in {} ({} items/s)",
                stats.records,
                stats.bytes,
                fmt_duration(report.elapsed),
                fmt_count(items as f64 / report.elapsed)
            );
            return Ok(());
        }
        let report = runner.run_stream(&factory, source)?;
        if let Some(tp) = &trace_path {
            write_trace_artifact(&report, tp)?;
        }
        if let Some(mp) = &metrics_path {
            write_metrics_artifact(&report, mp, &metrics_format)?;
        }
        if args.flag("stats") {
            print_exec_stats(&report);
        }
        let outputs = regatta::apps::sum::finish_sharded_outputs(mode, report.outputs);
        (outputs, report.metrics, report.elapsed)
    } else if workers <= 1
        && trace_path.is_none()
        && metrics_path.is_none()
        && args.get::<u64>("progress-secs")?.is_none()
        && args.get_or("max-region-items", 0)? == 0usize
        && matches!(fault_policy(args)?, FaultPolicy::FailFast)
    {
        let p = figures::provider(sel, width)?;
        let app = SumApp::new(cfg, p.kernels);
        let report = app.run(&blobs)?;
        (report.outputs, report.metrics, report.elapsed)
    } else {
        // L3.5: shard at region boundaries, one pipeline replica per
        // worker thread, deterministic merge back into stream order (a
        // traced run takes this path even at one worker — the executor
        // owns the trace lanes)
        let factory = SumFactory::new(cfg, KernelSpawn::from(sel));
        let runner = ShardedRunner::new(exec_config(args, workers)?);
        let report = runner.run(&factory, &blobs)?;
        if let Some(tp) = &trace_path {
            write_trace_artifact(&report, tp)?;
        }
        if let Some(mp) = &metrics_path {
            write_metrics_artifact(&report, mp, &metrics_format)?;
        }
        if args.flag("stats") {
            print_exec_stats(&report);
        }
        let outputs = regatta::apps::sum::finish_sharded_outputs(mode, report.outputs);
        (outputs, report.metrics, report.elapsed)
    };

    println!(
        "-> {} region sums in {} ({} items/s)",
        outputs.len(),
        fmt_duration(elapsed),
        fmt_count(items as f64 / elapsed)
    );
    if args.flag("verify") {
        let blobs = if let Some(path) = &input {
            read_rgn_file(path)? // small-input materialization for the oracle
        } else if streaming {
            gen_blobs(items, spec, seed)
        } else {
            blobs
        };
        let want = reference_sums(&blobs, threshold);
        anyhow::ensure!(outputs.len() == want.len(), "sum count mismatch");
        for ((gi, gv), (wi, wv)) in outputs.iter().zip(&want) {
            anyhow::ensure!(gi == wi, "region order mismatch");
            anyhow::ensure!(
                (gv - wv).abs() <= 1e-3 * (1.0 + wv.abs()),
                "region {gi}: {gv} vs reference {wv}"
            );
        }
        println!("verify: OK (matches f64 reference)");
    }
    if args.flag("stats") {
        print!("{}", metrics.table());
        println!("mean occupancy: {:.1}%", 100.0 * metrics.occupancy());
    }
    Ok(())
}

fn run_taxi(args: &Args) -> Result<()> {
    let width: usize = args.get_or("width", 128)?;
    let lines: usize = args.get_or("lines", 64)?;
    let reps: usize = args.get_or("replicate", 1)?;
    let variant = match args.str_or("variant", "hybrid").as_str() {
        "enum" => TaxiVariant::Enumerated,
        "hybrid" => TaxiVariant::Hybrid,
        "tagged" => TaxiVariant::Tagged,
        other => bail!("unknown variant {other:?}"),
    };
    let sel = backend(args)?;
    let pol = policy(args)?;
    let workers: usize = args.get_or("workers", 1)?;
    anyhow::ensure!(workers >= 1, "--workers must be >= 1 (got {workers})");
    let output = args.opt("output").map(str::to_string);
    let trace_path = args.opt("trace").map(str::to_string);
    let metrics_path = args.opt("metrics").map(str::to_string);
    let metrics_format = args.str_or("metrics-format", "json");
    if let Some(path) = args.opt("input").map(str::to_string) {
        return run_taxi_file(args, &path, output.as_deref(), variant, width, pol, workers);
    }
    let streaming = args.flag("stream") || output.is_some();
    let base = generate(lines, TaxiGenConfig::default(), args.get_or("seed", 0xF16u64)?);
    let w = if reps > 1 { replicate(&base, reps) } else { base };
    let chars: usize = w.lines.iter().map(|l| l.len).sum();
    println!(
        "taxi app: {} lines ({} chars, {} pairs), width {width}, {} variant, \
         backend {sel:?}, policy {}, {workers} worker(s){}",
        w.lines.len(),
        fmt_count(chars as f64),
        w.total_pairs,
        variant.label(),
        pol.label(),
        if streaming { ", streaming ingest" } else { "" }
    );
    let cfg = TaxiConfig {
        width,
        variant,
        policy: pol,
        ..Default::default()
    };
    let (pairs, metrics, elapsed) = if streaming {
        // L3.5 v2: lines flow through the bounded ingest buffer and are
        // parsed by work-stealing workers over the shared text
        let factory = TaxiFactory::new(cfg, KernelSpawn::from(sel), w.text.clone());
        let runner = ShardedRunner::new(exec_config(args, workers)?);
        if let Some(out_path) = &output {
            let mut sink = file_sink::<TaxiPair>(out_path)?;
            let report =
                runner.run_stream_into(&factory, SliceSource::new(&w.lines), &mut *sink)?;
            let stats = sink.finish()?;
            if let Some(tp) = &trace_path {
                write_trace_artifact(&report, tp)?;
            }
            if let Some(mp) = &metrics_path {
                write_metrics_artifact(&report, mp, &metrics_format)?;
            }
            if args.flag("stats") {
                print_exec_stats(&report);
                print!("{}", report.metrics.table());
            }
            anyhow::ensure!(
                stats.records as usize == w.total_pairs,
                "streamed {} of {} pairs",
                stats.records,
                w.total_pairs
            );
            println!(
                "-> {} pairs streamed to {out_path} ({} bytes) in {}",
                stats.records,
                stats.bytes,
                fmt_duration(report.elapsed)
            );
            return Ok(());
        }
        let report = runner.run_stream(&factory, SliceSource::new(&w.lines))?;
        if let Some(tp) = &trace_path {
            write_trace_artifact(&report, tp)?;
        }
        if let Some(mp) = &metrics_path {
            write_metrics_artifact(&report, mp, &metrics_format)?;
        }
        if args.flag("stats") {
            print_exec_stats(&report);
        }
        (report.outputs, report.metrics, report.elapsed)
    } else if workers <= 1
        && trace_path.is_none()
        && metrics_path.is_none()
        && args.get::<u64>("progress-secs")?.is_none()
        && args.get_or("max-region-items", 0)? == 0usize
        && matches!(fault_policy(args)?, FaultPolicy::FailFast)
    {
        let p = figures::provider(sel, width)?;
        let report = TaxiApp::new(cfg, p.kernels).run(&w)?;
        (report.pairs, report.metrics, report.elapsed)
    } else {
        // L3.5: lines are the regions — shard between lines, balanced by
        // character count, pairs merged back in stream order (a traced
        // run takes this path even at one worker)
        let factory = TaxiFactory::new(cfg, KernelSpawn::from(sel), w.text.clone());
        let runner = ShardedRunner::new(exec_config(args, workers)?);
        let report = runner.run(&factory, &w.lines)?;
        if let Some(tp) = &trace_path {
            write_trace_artifact(&report, tp)?;
        }
        if let Some(mp) = &metrics_path {
            write_metrics_artifact(&report, mp, &metrics_format)?;
        }
        if args.flag("stats") {
            print_exec_stats(&report);
        }
        (report.outputs, report.metrics, report.elapsed)
    };
    anyhow::ensure!(
        pairs.len() == w.total_pairs,
        "parsed {} of {} pairs",
        pairs.len(),
        w.total_pairs
    );
    println!(
        "-> {} pairs parsed in {} ({} chars/s)",
        pairs.len(),
        fmt_duration(elapsed),
        fmt_count(chars as f64 / elapsed)
    );
    if args.flag("stats") {
        print!("{}", metrics.table());
    }
    Ok(())
}

/// `run taxi --input`: stream records out of a line-delimited taxi file
/// (no generated ground truth — the text is whatever the file holds).
fn run_taxi_file(
    args: &Args,
    path: &str,
    output: Option<&str>,
    variant: TaxiVariant,
    width: usize,
    pol: regatta::prelude::Policy,
    workers: usize,
) -> Result<()> {
    let sel = backend(args)?;
    let source = TextSource::open(path)?;
    let text = source.text();
    println!(
        "taxi app: input {path} ({} chars), width {width}, {} variant, \
         backend {sel:?}, policy {}, {workers} worker(s), streaming ingest",
        fmt_count(text.len() as f64),
        variant.label(),
        pol.label()
    );
    let cfg = TaxiConfig {
        width,
        variant,
        policy: pol,
        ..Default::default()
    };
    let factory = TaxiFactory::new(cfg, KernelSpawn::from(sel), text.clone());
    let runner = ShardedRunner::new(exec_config(args, workers)?);
    let trace_path = args.opt("trace").map(str::to_string);
    let metrics_path = args.opt("metrics").map(str::to_string);
    let metrics_format = args.str_or("metrics-format", "json");
    if let Some(out_path) = output {
        ensure_distinct_io(path, out_path)?;
        let mut sink = file_sink::<TaxiPair>(out_path)?;
        let report = runner.run_stream_into(&factory, source, &mut *sink)?;
        let stats = sink.finish()?;
        if let Some(tp) = &trace_path {
            write_trace_artifact(&report, tp)?;
        }
        if let Some(mp) = &metrics_path {
            write_metrics_artifact(&report, mp, &metrics_format)?;
        }
        if args.flag("stats") {
            print_exec_stats(&report);
            print!("{}", report.metrics.table());
        }
        println!(
            "-> {} pairs streamed to {out_path} ({} bytes) in {}",
            stats.records,
            stats.bytes,
            fmt_duration(report.elapsed)
        );
    } else {
        let report = runner.run_stream(&factory, source)?;
        if let Some(tp) = &trace_path {
            write_trace_artifact(&report, tp)?;
        }
        if let Some(mp) = &metrics_path {
            write_metrics_artifact(&report, mp, &metrics_format)?;
        }
        if args.flag("stats") {
            print_exec_stats(&report);
            print!("{}", report.metrics.table());
        }
        println!(
            "-> {} pairs parsed in {} ({} chars/s)",
            report.outputs.len(),
            fmt_duration(report.elapsed),
            fmt_count(text.len() as f64 / report.elapsed)
        );
    }
    Ok(())
}

/// `regatta gen`: materialize a synthetic stream to disk so later runs
/// (and other tools) can go file-backed.
fn run_gen(args: &Args) -> Result<()> {
    let out = args
        .opt("out")
        .or_else(|| args.opt("output"))
        .map(str::to_string)
        .context("gen needs --out FILE")?;
    let seed = args.get_or("seed", 0xF16u64)?;
    match args.positional.get(1).map(String::as_str) {
        Some("sum") => {
            let items: usize = args.get_or("items", 1 << 20)?;
            let spec = region_spec(args)?;
            let stats = write_rgn_file(&out, GenBlobSource::new(items, spec, seed))?;
            println!(
                "wrote {out}: {} region(s), {} item(s), {} bytes ({spec:?}, seed {seed:#x})",
                stats.regions, stats.items, stats.bytes
            );
        }
        Some("taxi") => {
            let lines: usize = args.get_or("lines", 64)?;
            let reps: usize = args.get_or("replicate", 1)?;
            let w = generate(lines, TaxiGenConfig::default(), seed);
            let bytes = write_taxi_file(&out, &w.text, reps)?;
            println!(
                "wrote {out}: {} line(s) x {reps} replica(s), {} pair(s)/replica, \
                 {bytes} bytes (seed {seed:#x})",
                w.lines.len(),
                w.total_pairs
            );
        }
        other => bail!("unknown gen target {other:?} (use sum|taxi)"),
    }
    Ok(())
}

/// Escape a string into a JSON literal (ASCII-only, matching the
/// vendored parser's expectations).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 || !c.is_ascii() => {
                out.push_str(&format!("\\u{:04x}", (c as u32).min(0xFFFF)));
            }
            c => out.push(c),
        }
    }
    out
}

/// `regatta rgn verify <file> [--json]`: audit a `.rgn` container —
/// per-frame checksums plus footer reconciliation.
///
/// Exit codes (scriptable; CI keys off them):
/// * `0` — container verified clean;
/// * `2` — container was read but failed verification (corrupt frames
///   or footer mismatch; diagnostics on stdout, JSON with `--json`);
/// * `1` — the file could not be audited at all (missing, unreadable,
///   bad usage), reported like every other CLI error.
fn run_rgn(args: &Args) -> Result<()> {
    match args.positional.get(1).map(String::as_str) {
        Some("verify") => {
            let path = args
                .positional
                .get(2)
                .map(String::as_str)
                .or_else(|| args.opt("input"))
                .context("rgn verify needs a file: `regatta rgn verify data.rgn`")?;
            let report = verify_rgn_file(path)?;
            if args.flag("json") {
                let errors: Vec<String> =
                    report.errors.iter().map(|e| format!("\"{}\"", json_escape(e))).collect();
                println!(
                    "{{\"path\": \"{}\", \"ok\": {}, \"regions\": {}, \"items\": {}, \
                     \"corrupt_frames\": {}, \"errors\": [{}]}}",
                    json_escape(path),
                    report.ok(),
                    report.regions,
                    report.items,
                    report.corrupt_frames,
                    errors.join(", ")
                );
            } else {
                println!(
                    "{path}: {} readable region(s), {} item(s), {} corrupt frame(s)",
                    report.regions, report.items, report.corrupt_frames
                );
                for e in &report.errors {
                    println!("  {e}");
                }
                if report.corrupt_frames > report.errors.len() as u64 {
                    println!(
                        "  ... diagnostics capped; {} corrupt frame(s) total",
                        report.corrupt_frames
                    );
                }
            }
            if !report.ok() {
                if !args.flag("json") {
                    eprintln!(
                        "{path} failed verification: {} corrupt frame(s), {} error(s)",
                        report.corrupt_frames,
                        report.errors.len()
                    );
                }
                std::process::exit(2);
            }
            if !args.flag("json") {
                println!("verify: OK");
            }
            Ok(())
        }
        other => bail!("unknown rgn action {other:?} (use verify)"),
    }
}

fn run_bench(args: &Args) -> Result<()> {
    let which = args.positional.get(1).context(
        "bench target required: \
         fig6|fig7|fig8|scale|hotpath|ingest|io|faults|latency|penalty|width|lanectx",
    )?;
    if which == "hotpath" {
        return run_bench_hotpath(args);
    }
    if which == "ingest" {
        return run_bench_ingest(args);
    }
    if which == "io" {
        return run_bench_io(args);
    }
    if which == "faults" {
        return run_bench_faults(args);
    }
    if which == "latency" {
        return run_bench_latency(args);
    }
    let mut cfg = SweepConfig {
        backend: backend(args)?,
        ..Default::default()
    };
    cfg.width = args.get_or("width", cfg.width)?;
    cfg.items = args.get_or("items", 1 << 18)?;
    match which.as_str() {
        "fig6" => {
            figures::fig6(&cfg)?;
        }
        "fig7" => {
            figures::fig7(&cfg)?;
        }
        "fig8" => {
            figures::fig8(&cfg, args.get_or("lines", 32)?, &[1, 2, 4])?;
        }
        "scale" => {
            let workers = args.list_or("workers", &[1usize, 2, 4, 8])?;
            let w = cfg.width;
            let regions = [(w / 8).max(1), w, 8 * w];
            let rows = figures::scaling_shards(&cfg, &workers, &regions)?;
            if let Some(path) = args.opt("json") {
                std::fs::write(path, figures::scaling_to_json(&rows))
                    .with_context(|| format!("writing {path}"))?;
                println!("wrote {path}");
            }
        }
        "penalty" => {
            figures::abstraction_penalty(&cfg)?;
        }
        "width" => {
            figures::ablation_width(&cfg, &[32, 64, 128, 256])?;
        }
        "lanectx" => {
            figures::ablation_lanectx(&cfg)?;
            figures::ablation_policy(&cfg, args.get_or("lines", 32)?)?;
        }
        other => bail!("unknown bench {other:?}"),
    }
    Ok(())
}

/// `bench hotpath`: firing-path + app sweep, JSON artifact, optional
/// baseline regression gate (see `rust/src/bench/hotpath.rs`).
fn run_bench_hotpath(args: &Args) -> Result<()> {
    use regatta::bench::hotpath;
    let mut cfg = if args.flag("smoke") {
        hotpath::HotpathConfig::smoke()
    } else {
        hotpath::HotpathConfig::default()
    };
    cfg.widths = args.list_or("widths", &cfg.widths)?;
    cfg.items = args.get_or("items", cfg.items)?;
    cfg.seed = args.get_or("seed", cfg.seed)?;
    cfg.reuse_granules = args.list_or("reuse-granules", &cfg.reuse_granules)?;
    anyhow::ensure!(
        cfg.reuse_granules.iter().all(|&g| g >= 1),
        "--reuse-granules entries must be >= 1 (regions per shard)"
    );
    if args.opt("policy").is_some() {
        cfg.policies = vec![policy(args)?];
    }
    let report = hotpath::run(&cfg)?;
    let path = args.str_or("json", "BENCH_hotpath.json");
    std::fs::write(&path, hotpath::to_json(&report)).with_context(|| format!("writing {path}"))?;
    println!("wrote {path}");
    if let Some(baseline) = args.opt("check") {
        hotpath::check_against(&report, baseline)?;
    }
    Ok(())
}

/// `bench ingest`: streaming ingest + work stealing vs the legacy cursor
/// across region-size distributions, with a JSON artifact (see
/// `rust/src/bench/ingest.rs`).
fn run_bench_ingest(args: &Args) -> Result<()> {
    use regatta::bench::ingest;
    let mut cfg = if args.flag("smoke") {
        ingest::IngestConfig::smoke()
    } else {
        ingest::IngestConfig::default()
    };
    cfg.width = args.get_or("width", cfg.width)?;
    cfg.items = args.get_or("items", cfg.items)?;
    cfg.workers = args.list_or("workers", &cfg.workers)?;
    cfg.buffer_regions = args.get_or("ingest-buffer", cfg.buffer_regions)?;
    cfg.seed = args.get_or("seed", cfg.seed)?;
    let report = ingest::run(&cfg)?;
    let path = args.str_or("json", "BENCH_ingest.json");
    std::fs::write(&path, ingest::to_json(&report)).with_context(|| format!("writing {path}"))?;
    println!("wrote {path}");
    if let Some(speedup) = ingest::skew_speedup(&report) {
        println!("skewed stream, stealing vs cursor at max workers: {speedup:.2}x");
    }
    if let Some(speedup) = ingest::giant_region_speedup(&report) {
        println!("one giant region, split vs unsplit at max workers: {speedup:.2}x");
    }
    Ok(())
}

/// `bench io`: file-backed vs in-memory streaming ingest throughput
/// across buffer budgets, with a JSON artifact (see
/// `rust/src/bench/io_bench.rs`).
fn run_bench_io(args: &Args) -> Result<()> {
    use regatta::bench::io_bench;
    let mut cfg = if args.flag("smoke") {
        io_bench::IoConfig::smoke()
    } else {
        io_bench::IoConfig::default()
    };
    cfg.width = args.get_or("width", cfg.width)?;
    cfg.items = args.get_or("items", cfg.items)?;
    cfg.workers = args.get_or("workers", cfg.workers)?;
    cfg.budgets = args.list_or("buffers", &cfg.budgets)?;
    anyhow::ensure!(
        cfg.budgets.iter().all(|&b| b >= 1),
        "--buffers entries must be >= 1 (the streaming budget admits at least one region)"
    );
    cfg.seed = args.get_or("seed", cfg.seed)?;
    let report = io_bench::run(&cfg)?;
    let path = args.str_or("json", "BENCH_io.json");
    std::fs::write(&path, io_bench::to_json(&report)).with_context(|| format!("writing {path}"))?;
    println!("wrote {path}");
    if let Some(r) = io_bench::file_vs_mem_ratio(&report) {
        println!("file-backed vs lazy-generator ingest throughput at max budget: {r:.2}x");
    }
    Ok(())
}

/// `bench faults`: seeded fault-injection harness — retry determinism,
/// quarantine accounting, corrupt-frame salvage and watchdog overhead,
/// with a JSON artifact (see `rust/src/bench/faults.rs`).
fn run_bench_faults(args: &Args) -> Result<()> {
    use regatta::bench::faults;
    let mut cfg = if args.flag("smoke") {
        faults::FaultsConfig::smoke()
    } else {
        faults::FaultsConfig::default()
    };
    cfg.width = args.get_or("width", cfg.width)?;
    cfg.items = args.get_or("items", cfg.items)?;
    cfg.workers = args.get_or("workers", cfg.workers)?;
    cfg.fault_rate = args.get_or("fault-rate", cfg.fault_rate)?;
    cfg.seed = args.get_or("seed", cfg.seed)?;
    let report = faults::run(&cfg)?;
    let path = args.str_or("json", "BENCH_faults.json");
    std::fs::write(&path, faults::to_json(&report)).with_context(|| format!("writing {path}"))?;
    println!("wrote {path}");
    if let Some(overhead) = faults::retry_overhead(&report) {
        println!("retry-policy run vs fault-free baseline: {overhead:.2}x elapsed");
    }
    if let Some(savings) = faults::part_retry_savings(&report) {
        println!(
            "part-granular narrowing vs whole-shard retry: {savings:.2}x less region work re-run"
        );
    }
    Ok(())
}

/// `bench latency`: per-region submit→emit latency quantiles under the
/// streamed executor with live metrics, informational JSON artifact (see
/// `rust/src/bench/latency.rs`).
fn run_bench_latency(args: &Args) -> Result<()> {
    use regatta::bench::latency;
    let mut cfg = if args.flag("smoke") {
        latency::LatencyConfig::smoke()
    } else {
        latency::LatencyConfig::default()
    };
    cfg.width = args.get_or("width", cfg.width)?;
    cfg.items = args.get_or("items", cfg.items)?;
    cfg.workers = args.list_or("workers", &cfg.workers)?;
    cfg.budget = args.get_or("ingest-buffer", cfg.budget)?;
    anyhow::ensure!(cfg.budget >= 1, "--ingest-buffer must be >= 1");
    cfg.seed = args.get_or("seed", cfg.seed)?;
    let report = latency::run(&cfg)?;
    let path = args.str_or("json", "BENCH_latency.json");
    std::fs::write(&path, latency::to_json(&report)).with_context(|| format!("writing {path}"))?;
    println!("wrote {path}");
    if let Some(r) = report.rows.last() {
        println!(
            "at {} worker(s): e2e p50 {:.3} ms, p99 {:.3} ms, max {:.3} ms (informational)",
            r.workers, r.e2e_p50_ms, r.e2e_p99_ms, r.e2e_max_ms
        );
    }
    Ok(())
}

fn info() -> Result<()> {
    let store = ArtifactStore::discover()?;
    let m = store.manifest();
    println!("artifact dir : {}", store.dir().display());
    println!("widths       : {:?}", m.widths);
    println!("kernels      : {}", m.entries.join(", "));
    println!("window_len   : {}", m.window_len);
    let engine = Engine::new(store.clone())?;
    println!("PJRT platform: {}", engine.platform_name());
    engine.preload(128)?;
    println!("preload      : all kernels compiled at w=128 OK");
    Ok(())
}
