//! `regatta` — launcher CLI for the REGATTA streaming framework.
//!
//! ```text
//! regatta run sum   [--items N] [--region-size N | --region-max N]
//!                   [--mode enum|tagged] [--shape fused|two-stage]
//!                   [--width W] [--backend xla|native] [--threshold T]
//!                   [--workers K] [--stats]
//! regatta run taxi  [--lines N] [--replicate K] [--variant enum|hybrid|tagged]
//!                   [--width W] [--backend xla|native] [--stats]
//! regatta bench <fig6|fig7|fig8|penalty|width|lanectx> [--items N] [--width W]
//! regatta info      # artifact manifest + platform
//! regatta --config <file.toml>   # load a [run] config (see configs/)
//! ```

use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use regatta::apps::sum::{reference_sums, SumApp, SumConfig, SumMode, SumShape};
use regatta::apps::taxi::{TaxiApp, TaxiConfig, TaxiVariant};
use regatta::bench::figures::{self, BackendSel, SweepConfig};
use regatta::runtime::{ArtifactStore, Engine};
use regatta::simd::{ChunkSource, SimdConfig, SimdMachine};
use regatta::util::cli::Args;
use regatta::util::config::Config;
use regatta::util::stats::{fmt_count, fmt_duration};
use regatta::workload::regions::{chunk_blobs, gen_blobs, RegionSpec};
use regatta::workload::taxi::{generate, replicate, TaxiGenConfig};

const USAGE: &str = "\
regatta — region-based state for streaming computations on SIMD architectures

USAGE:
  regatta run sum   [--items N] [--region-size N | --region-max N]
                    [--mode enum|tagged] [--shape fused|two-stage]
                    [--width W] [--backend xla|native] [--threshold T]
                    [--workers K] [--stats] [--verify]
  regatta run taxi  [--lines N] [--replicate K] [--variant enum|hybrid|tagged]
                    [--width W] [--backend xla|native] [--stats]
  regatta bench <fig6|fig7|fig8|penalty|width|lanectx> [--items N] [--width W]
                    [--backend xla|native]
  regatta info
  regatta --config <file.toml>
";

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        eprintln!("\n{USAGE}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let mut args = Args::from_env()?;
    if let Some(path) = args.opt("config").map(str::to_string) {
        args = config_to_args(&path)?;
    }
    match args.subcommand() {
        Some("run") => match args.positional.get(1).map(String::as_str) {
            Some("sum") => run_sum(&args),
            Some("taxi") => run_taxi(&args),
            other => bail!("unknown app {other:?} (use sum|taxi)"),
        },
        Some("bench") => run_bench(&args),
        Some("info") => info(),
        Some(other) => bail!("unknown subcommand {other:?}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

/// Convert a `[run]` config file into the equivalent CLI arguments.
fn config_to_args(path: &str) -> Result<Args> {
    let cfg = Config::load(path)?;
    let mut argv: Vec<String> = Vec::new();
    let cmd = cfg.str_or("run", "command", "")?;
    if cmd.is_empty() {
        bail!("config {path}: [run] command = \"sum run ...\" is required");
    }
    argv.extend(cmd.split_whitespace().map(str::to_string));
    for key in [
        "items", "region-size", "region-max", "mode", "shape", "width", "backend",
        "threshold", "workers", "lines", "replicate", "variant",
    ] {
        if let Some(v) = cfg.get("run", &key.replace('-', "_")) {
            let vs = match v {
                regatta::util::config::Value::Str(s) => s.clone(),
                regatta::util::config::Value::Int(i) => i.to_string(),
                regatta::util::config::Value::Float(f) => f.to_string(),
                regatta::util::config::Value::Bool(b) => b.to_string(),
                other => bail!("config {path}: bad value {other:?} for {key}"),
            };
            argv.push(format!("--{key}"));
            argv.push(vs);
        }
    }
    if cfg.bool_or("run", "stats", false)? {
        argv.push("--stats".into());
    }
    Args::parse(argv)
}

fn backend(args: &Args) -> Result<BackendSel> {
    args.str_or("backend", "xla").parse()
}

fn run_sum(args: &Args) -> Result<()> {
    let width: usize = args.get_or("width", 128)?;
    let items: usize = args.get_or("items", 1 << 20)?;
    let threshold: f32 = args.get_or("threshold", 0.0)?;
    let workers: usize = args.get_or("workers", 1)?;
    let mode = match args.str_or("mode", "enum").as_str() {
        "enum" => SumMode::Enumerated,
        "tagged" => SumMode::Tagged,
        other => bail!("unknown mode {other:?}"),
    };
    let shape = match args.str_or("shape", "fused").as_str() {
        "fused" => SumShape::Fused,
        "two-stage" => SumShape::TwoStage,
        other => bail!("unknown shape {other:?}"),
    };
    let spec = if let Some(max) = args.get::<usize>("region-max")? {
        RegionSpec::Uniform { max }
    } else {
        RegionSpec::Fixed {
            size: args.get_or("region-size", 128)?,
        }
    };
    let sel = backend(args)?;
    let blobs = gen_blobs(items, spec, args.get_or("seed", 0xF16u64)?);
    let n_regions = blobs.len();
    let cfg = SumConfig {
        width,
        threshold,
        mode,
        shape,
        ..Default::default()
    };

    println!(
        "sum app: {items} items, {n_regions} regions ({spec:?}), width {width}, \
         {mode:?}/{shape:?}, backend {sel:?}, {workers} worker(s)"
    );

    let (outputs, metrics, elapsed) = if workers <= 1 {
        let p = figures::provider(sel, width)?;
        let app = SumApp::new(cfg, p.kernels);
        let report = app.run(&blobs)?;
        (report.outputs, report.metrics, report.elapsed)
    } else {
        // multi-processor machine: workers claim region chunks atomically
        let chunk_items = (items / (workers * 4)).max(width);
        let chunks = chunk_blobs(blobs.clone(), chunk_items);
        let source = ChunkSource::new(chunks);
        let machine = SimdMachine::new(SimdConfig { width, workers });
        let collected: Mutex<Vec<(u64, f64)>> = Mutex::new(Vec::new());
        let merged: Mutex<regatta::coordinator::metrics::PipelineMetrics> =
            Mutex::new(Default::default());
        let t0 = std::time::Instant::now();
        machine.run(source, |_wid, src| {
            let p = figures::provider(sel, width)?; // engine per worker thread
            let app = SumApp::new(cfg, p.kernels);
            while let Some(chunk) = src.claim() {
                let report = app.run(chunk)?;
                collected.lock().unwrap().extend(report.outputs);
                merged.lock().unwrap().merge(&report.metrics);
            }
            Ok(())
        })?;
        let elapsed = t0.elapsed().as_secs_f64();
        let mut outputs = collected.into_inner().unwrap();
        outputs.sort_by_key(|&(id, _)| id);
        (outputs, merged.into_inner().unwrap(), elapsed)
    };

    println!(
        "-> {} region sums in {} ({} items/s)",
        outputs.len(),
        fmt_duration(elapsed),
        fmt_count(items as f64 / elapsed)
    );
    if args.flag("verify") {
        let want = reference_sums(&blobs, threshold);
        anyhow::ensure!(outputs.len() == want.len(), "sum count mismatch");
        for ((gi, gv), (wi, wv)) in outputs.iter().zip(&want) {
            anyhow::ensure!(gi == wi, "region order mismatch");
            anyhow::ensure!(
                (gv - wv).abs() <= 1e-3 * (1.0 + wv.abs()),
                "region {gi}: {gv} vs reference {wv}"
            );
        }
        println!("verify: OK (matches f64 reference)");
    }
    if args.flag("stats") {
        print!("{}", metrics.table());
        println!("mean occupancy: {:.1}%", 100.0 * metrics.occupancy());
    }
    Ok(())
}

fn run_taxi(args: &Args) -> Result<()> {
    let width: usize = args.get_or("width", 128)?;
    let lines: usize = args.get_or("lines", 64)?;
    let reps: usize = args.get_or("replicate", 1)?;
    let variant = match args.str_or("variant", "hybrid").as_str() {
        "enum" => TaxiVariant::Enumerated,
        "hybrid" => TaxiVariant::Hybrid,
        "tagged" => TaxiVariant::Tagged,
        other => bail!("unknown variant {other:?}"),
    };
    let sel = backend(args)?;
    let base = generate(lines, TaxiGenConfig::default(), args.get_or("seed", 0xF16u64)?);
    let w = if reps > 1 { replicate(&base, reps) } else { base };
    let chars: usize = w.lines.iter().map(|l| l.len).sum();
    println!(
        "taxi app: {} lines ({} chars, {} pairs), width {width}, {} variant, backend {sel:?}",
        w.lines.len(),
        fmt_count(chars as f64),
        w.total_pairs,
        variant.label()
    );
    let p = figures::provider(sel, width)?;
    let app = TaxiApp::new(
        TaxiConfig {
            width,
            variant,
            ..Default::default()
        },
        p.kernels,
    );
    let report = app.run(&w)?;
    anyhow::ensure!(
        report.pairs.len() == w.total_pairs,
        "parsed {} of {} pairs",
        report.pairs.len(),
        w.total_pairs
    );
    println!(
        "-> {} pairs parsed in {} ({} chars/s)",
        report.pairs.len(),
        fmt_duration(report.elapsed),
        fmt_count(chars as f64 / report.elapsed)
    );
    if args.flag("stats") {
        print!("{}", report.metrics.table());
    }
    Ok(())
}

fn run_bench(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .context("bench target required: fig6|fig7|fig8|penalty|width|lanectx")?;
    let mut cfg = SweepConfig {
        backend: backend(args)?,
        ..Default::default()
    };
    cfg.width = args.get_or("width", cfg.width)?;
    cfg.items = args.get_or("items", 1 << 18)?;
    match which.as_str() {
        "fig6" => {
            figures::fig6(&cfg)?;
        }
        "fig7" => {
            figures::fig7(&cfg)?;
        }
        "fig8" => {
            figures::fig8(&cfg, args.get_or("lines", 32)?, &[1, 2, 4])?;
        }
        "penalty" => {
            figures::abstraction_penalty(&cfg)?;
        }
        "width" => {
            figures::ablation_width(&cfg, &[32, 64, 128, 256])?;
        }
        "lanectx" => {
            figures::ablation_lanectx(&cfg)?;
            figures::ablation_policy(&cfg, args.get_or("lines", 32)?)?;
        }
        other => bail!("unknown bench {other:?}"),
    }
    Ok(())
}

fn info() -> Result<()> {
    let store = ArtifactStore::discover()?;
    let m = store.manifest();
    println!("artifact dir : {}", store.dir().display());
    println!("widths       : {:?}", m.widths);
    println!("kernels      : {}", m.entries.join(", "));
    println!("window_len   : {}", m.window_len);
    let engine = Engine::new(store.clone())?;
    println!("PJRT platform: {}", engine.platform_name());
    engine.preload(128)?;
    println!("preload      : all kernels compiled at w=128 OK");
    Ok(())
}
