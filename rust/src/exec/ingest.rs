//! Streaming shard ingest: convert regions into shards **as they
//! arrive**, against a bounded in-flight budget.
//!
//! The materialized path ([`ShardPlan::build`](super::plan::ShardPlan))
//! needs the whole region stream up front to balance shards against the
//! total weight. A stream has no total: the [`IngestPlanner`] instead
//! cuts shards online — close the open shard once it holds
//! `shard_regions` regions *or* once its weight reaches `shard_regions ×`
//! the mean weight of previously seen regions (so one huge region closes
//! a shard promptly and becomes a unit of stealing, while runs of tiny
//! regions coalesce). Boundaries depend only on the region sequence, never on
//! worker timing, so shard layout — and therefore merged output order —
//! is deterministic for a given stream.
//!
//! Memory is governed by [`IngestPolicy::buffer_regions`]: the executor
//! stops pulling from the source while `submitted − emitted` regions
//! would exceed the budget (backpressure when workers lag). Shard
//! containers are recycled through a [`ContainerPool`] — workers hand
//! emptied `Vec`s back and the planner refills them — so steady-state
//! ingest performs no per-region heap allocation: the allocation
//! high-water mark is set by the budget, not by stream length
//! (`rust/tests/ingest_stream.rs` proves this with the counting
//! allocator).

use std::collections::VecDeque;
use std::sync::Mutex;

/// Tunables for streaming ingest (see [`ExecConfig::streaming`]).
///
/// [`ExecConfig::streaming`]: super::runner::ExecConfig::streaming
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IngestPolicy {
    /// In-flight budget: the maximum number of regions submitted to the
    /// pool but not yet merged out. Bounds both payload memory and the
    /// reassembly window; ingest blocks (backpressure) at the limit.
    pub buffer_regions: usize,
    /// Regions per streaming shard. `0` = auto: derived from the budget
    /// and worker count so several shards per worker are in flight
    /// (stealing slack). Always clamped to the budget.
    pub shard_regions: usize,
}

impl Default for IngestPolicy {
    fn default() -> Self {
        IngestPolicy {
            buffer_regions: 1024,
            shard_regions: 0,
        }
    }
}

impl IngestPolicy {
    /// Resolve the effective regions-per-shard granule for `workers`.
    pub fn effective_shard_regions(&self, workers: usize) -> usize {
        // A zero budget is rejected upstream (`ExecConfig::validate`,
        // `WorkerPool::run_stream`) as a named error; the floor here only
        // keeps this pure helper total (clamp(1, 0) would panic), it is
        // not a config clamp.
        let budget = self.buffer_regions.max(1);
        let granule = if self.shard_regions == 0 {
            // aim for ~4 in-flight shards per worker within the budget
            budget / (4 * workers.max(1))
        } else {
            self.shard_regions
        };
        granule.clamp(1, budget)
    }
}

/// One streaming shard: a contiguous run of regions, tagged with its
/// stream-order index (the merge key).
#[derive(Debug)]
pub struct ShardTask<T> {
    /// Shard index in stream order (assigned by the planner).
    pub index: usize,
    /// The regions, in stream order. Ownership moves to the worker; the
    /// emptied container comes back through the [`ContainerPool`].
    pub regions: Vec<T>,
    /// Total item weight (the planner's balancing unit).
    pub weight: usize,
    /// Submit stamp, nanoseconds since the run's shared epoch, written by
    /// the ingest driver just before the task enters the deques (0 when
    /// metrics are off — the planner itself never reads a clock). Flows
    /// through to the merge so per-region end-to-end latency can be
    /// measured at in-order emit.
    pub submit_ns: u64,
}

/// Online shard builder. Single-threaded (driven by the ingest thread);
/// all cross-thread coordination lives in the pool.
#[derive(Debug)]
pub struct IngestPlanner<T> {
    shard_regions: usize,
    open: Vec<T>,
    open_weight: usize,
    next_index: usize,
    spare: Vec<Vec<T>>,
    total_regions: u64,
    total_weight: u64,
}

impl<T> IngestPlanner<T> {
    /// Planner closing shards at `shard_regions` regions (or the
    /// equivalent running-mean weight). Use
    /// [`IngestPolicy::effective_shard_regions`] to derive the granule.
    pub fn new(shard_regions: usize) -> IngestPlanner<T> {
        IngestPlanner {
            shard_regions: shard_regions.max(1),
            open: Vec::new(),
            open_weight: 0,
            next_index: 0,
            spare: Vec::new(),
            total_regions: 0,
            total_weight: 0,
        }
    }

    /// Feed one region; returns a closed shard when this region completes
    /// one. The region always lands in the shard returned now or later —
    /// regions are never dropped or reordered.
    pub fn push_region(&mut self, region: T, weight: usize) -> Option<ShardTask<T>> {
        // Weight baseline: the mean of regions seen *before* this one, so
        // an outlier region is measured against the stream's typical
        // weight rather than against a target it inflated itself. No
        // baseline before the first region — the count rule governs.
        let prior_mean = (self.total_regions > 0)
            .then(|| (self.total_weight / self.total_regions).max(1) as usize);
        self.open.push(region);
        self.open_weight += weight;
        self.total_regions += 1;
        self.total_weight += weight as u64;
        let close_by_weight = prior_mean.is_some_and(|mean| {
            self.open_weight >= self.shard_regions.saturating_mul(mean)
        });
        if self.open.len() >= self.shard_regions || close_by_weight {
            self.close_open()
        } else {
            None
        }
    }

    /// Flush the partial shard at end of stream (if any).
    pub fn finish(&mut self) -> Option<ShardTask<T>> {
        if self.open.is_empty() {
            None
        } else {
            self.close_open()
        }
    }

    /// Hand back an emptied shard container for reuse.
    pub fn recycle(&mut self, mut container: Vec<T>) {
        container.clear();
        self.spare.push(container);
    }

    /// Shards emitted so far.
    pub fn shards_planned(&self) -> usize {
        self.next_index
    }

    /// Regions accepted so far.
    pub fn regions_seen(&self) -> u64 {
        self.total_regions
    }

    fn close_open(&mut self) -> Option<ShardTask<T>> {
        let fresh = self.spare.pop().unwrap_or_default();
        let regions = std::mem::replace(&mut self.open, fresh);
        let task = ShardTask {
            index: self.next_index,
            regions,
            weight: self.open_weight,
            submit_ns: 0,
        };
        self.next_index += 1;
        self.open_weight = 0;
        Some(task)
    }
}

/// Cross-thread free-list of emptied shard containers: workers `put`,
/// the ingest driver drains into [`IngestPlanner::recycle`]. Capacity
/// travels with the `Vec`s, which is what makes steady-state ingest
/// allocation-free.
#[derive(Debug)]
pub struct ContainerPool<T> {
    spare: Mutex<VecDeque<Vec<T>>>,
}

impl<T> Default for ContainerPool<T> {
    fn default() -> Self {
        ContainerPool::new()
    }
}

impl<T> ContainerPool<T> {
    /// Create an empty pool.
    pub fn new() -> ContainerPool<T> {
        ContainerPool {
            spare: Mutex::new(VecDeque::new()),
        }
    }

    /// Return an emptied container (called from worker threads).
    pub fn put(&self, mut container: Vec<T>) {
        container.clear();
        let mut spare = lock_ignore_poison(&self.spare);
        spare.push_back(container);
    }

    /// Take one recycled container, if any (called from the driver).
    pub fn take(&self) -> Option<Vec<T>> {
        lock_ignore_poison(&self.spare).pop_front()
    }
}

/// Lock a mutex, proceeding through poisoning: shutdown paths must keep
/// working after a worker panic (the panic itself is reported separately).
pub(crate) fn lock_ignore_poison<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain<T>(planner: &mut IngestPlanner<T>, regions: Vec<(T, usize)>) -> Vec<ShardTask<T>> {
        let mut out = Vec::new();
        for (r, w) in regions {
            if let Some(t) = planner.push_region(r, w) {
                out.push(t);
            }
        }
        out.extend(planner.finish());
        out
    }

    fn check_cover(tasks: &[ShardTask<u32>], n_regions: usize) {
        let mut next_region = 0u32;
        for (i, t) in tasks.iter().enumerate() {
            assert_eq!(t.index, i, "shard indices are sequential");
            assert!(!t.regions.is_empty(), "no empty shards");
            for &r in &t.regions {
                assert_eq!(r, next_region, "regions stay in stream order");
                next_region += 1;
            }
        }
        assert_eq!(next_region as usize, n_regions, "every region lands once");
    }

    #[test]
    fn uniform_regions_close_on_count() {
        let mut p = IngestPlanner::new(4);
        let tasks = drain(&mut p, (0..10u32).map(|i| (i, 5)).collect());
        check_cover(&tasks, 10);
        assert_eq!(tasks.len(), 3, "4 + 4 + 2");
        assert_eq!(tasks[0].weight, 20);
        assert_eq!(tasks[2].regions.len(), 2);
    }

    #[test]
    fn huge_region_closes_a_shard_immediately() {
        let mut p = IngestPlanner::new(8);
        let mut stream: Vec<(u32, usize)> = (0..4u32).map(|i| (i, 1)).collect();
        stream.push((4, 1000)); // giant region: must close the shard now
        stream.extend((5..9u32).map(|i| (i, 1)));
        let tasks = drain(&mut p, stream);
        check_cover(&tasks, 9);
        assert!(
            tasks[0].regions.contains(&4) && *tasks[0].regions.last().unwrap() == 4,
            "giant region terminates shard 0: {:?}",
            tasks[0].regions
        );
    }

    #[test]
    fn empty_stream_plans_nothing() {
        let mut p: IngestPlanner<u32> = IngestPlanner::new(4);
        assert!(p.finish().is_none());
        assert_eq!(p.shards_planned(), 0);
        assert_eq!(p.regions_seen(), 0);
    }

    #[test]
    fn zero_weight_regions_close_on_count_rule() {
        let mut p = IngestPlanner::new(3);
        let tasks = drain(&mut p, (0..7u32).map(|i| (i, 0)).collect());
        check_cover(&tasks, 7);
        assert_eq!(tasks.len(), 3, "3 + 3 + 1");
    }

    #[test]
    fn recycled_containers_are_reused() {
        let mut p = IngestPlanner::new(2);
        assert!(p.push_region(0u32, 1).is_none());
        let t = p.push_region(1, 1).unwrap();
        let ptr_before = t.regions.as_ptr();
        p.recycle(t.regions);
        // shard 1 closes into whatever container was swapped in when
        // shard 0 closed; the recycled one becomes the open shard then
        assert!(p.push_region(2, 1).is_none());
        let t2 = p.push_region(3, 1).unwrap();
        assert_eq!(t2.regions, vec![2, 3]);
        assert_eq!(t2.index, 1);
        // shard 2 lands in the recycled container: same allocation
        assert!(p.push_region(4, 1).is_none());
        let t3 = p.push_region(5, 1).unwrap();
        assert_eq!(t3.regions.as_ptr(), ptr_before, "container is reused");
        assert_eq!(t3.regions, vec![4, 5]);
        assert_eq!(t3.index, 2);
    }

    #[test]
    fn container_pool_round_trips() {
        let pool: ContainerPool<u32> = ContainerPool::new();
        assert!(pool.take().is_none());
        pool.put(vec![1, 2, 3]);
        let v = pool.take().unwrap();
        assert!(v.is_empty(), "put clears");
        assert!(v.capacity() >= 3, "capacity survives");
        assert!(pool.take().is_none());
    }

    #[test]
    fn boundaries_are_deterministic_in_the_stream_prefix() {
        // same stream → same boundaries, independent of anything else
        let stream: Vec<(u32, usize)> =
            (0..100u32).map(|i| (i, (i as usize * 7) % 13 + 1)).collect();
        let a = drain(&mut IngestPlanner::new(5), stream.clone());
        let b = drain(&mut IngestPlanner::new(5), stream);
        let cuts = |ts: &[ShardTask<u32>]| -> Vec<usize> {
            ts.iter().map(|t| t.regions.len()).collect()
        };
        assert_eq!(cuts(&a), cuts(&b));
        check_cover(&a, 100);
    }

    #[test]
    fn effective_shard_regions_respects_budget() {
        let auto = IngestPolicy {
            buffer_regions: 256,
            shard_regions: 0,
        };
        assert_eq!(auto.effective_shard_regions(4), 16);
        assert_eq!(auto.effective_shard_regions(1000), 1, "never zero");
        let explicit = IngestPolicy {
            buffer_regions: 8,
            shard_regions: 64,
        };
        assert_eq!(
            explicit.effective_shard_regions(2),
            8,
            "clamped to the budget"
        );
    }
}
