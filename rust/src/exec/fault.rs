//! Fault tolerance for the sharded executor: recovery policies, the
//! per-shard fault ledger, and a seeded fault-injection harness.
//!
//! The executor's unit of recovery is the **shard**: all cross-item
//! state is region-scoped and regions never span shards, so a failed
//! shard can be re-run (or dropped) without touching any other shard's
//! state. Three policies cover the useful points on that spectrum:
//!
//! * [`FaultPolicy::FailFast`] — the historical behaviour (and still the
//!   default): the first shard panic or error aborts the whole run.
//! * [`FaultPolicy::Retry`] — the worker's persistent pipeline is
//!   discarded (a panic may have left it mid-reset), a fresh one is
//!   built through the factory, and the same shard is re-run, up to
//!   `max_attempts` total attempts. Because a rebuilt pipeline is
//!   bit-identical to a fresh one (the PR 5 reuse ≡ fresh proof), a
//!   recovered run's output is **bit-identical** to a fault-free run.
//! * [`FaultPolicy::Quarantine`] — the shard is given one attempt; on
//!   failure its id and error land in [`ExecReport::faults`] and the
//!   stream-order merge emits an empty slot for it instead of stalling
//!   the runs behind it.
//!
//! The injection harness ([`FaultPlan`] + [`FaultyFactory`]) makes every
//! recovery path deterministically testable: a plan is a list of
//! "shard `k` panics (or errors) on its next `times` attempts",
//! either written explicitly or drawn from a seeded PRNG, and the
//! factory wrapper detonates those shots from inside `run_shard` —
//! upstream of the pool's `catch_unwind` guard, exactly where a real
//! kernel fault would fire.
//!
//! [`ExecReport::faults`]: crate::exec::ExecReport::faults

use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::exec::factory::{PipelineFactory, ShardOutput, ShardWorker};
use crate::exec::ingest::lock_ignore_poison;
use crate::trace::TraceSink;
use crate::util::prng::Prng;

/// What the pool does when a shard's `run_shard` panics or errors.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FaultPolicy {
    /// Abort the whole run on the first shard failure (the default).
    #[default]
    FailFast,
    /// Rebuild the worker's pipeline fresh and re-run the failed shard,
    /// up to `max_attempts` **total** attempts per shard, sleeping
    /// `backoff` between attempts. Recovered output is bit-identical to
    /// a fault-free run; exhausting the attempts fails the run.
    Retry {
        /// Total attempts per shard (>= 1; 1 behaves like fail-fast).
        max_attempts: u32,
        /// Sleep between attempts (transient-fault damping).
        backoff: Duration,
    },
    /// Give each shard one attempt; record failures in
    /// [`ExecReport::faults`](crate::exec::ExecReport::faults) and keep
    /// the run going, emitting an empty slot in stream order.
    Quarantine,
}

impl FaultPolicy {
    /// Retry with no backoff — the common test/CLI shape.
    pub fn retry(max_attempts: u32) -> FaultPolicy {
        FaultPolicy::Retry {
            max_attempts,
            backoff: Duration::ZERO,
        }
    }

    /// Stable CLI/report name.
    pub fn label(&self) -> &'static str {
        match self {
            FaultPolicy::FailFast => "fail-fast",
            FaultPolicy::Retry { .. } => "retry",
            FaultPolicy::Quarantine => "quarantine",
        }
    }

    /// Total attempts a shard gets before the policy gives up on it.
    pub fn max_attempts(&self) -> u32 {
        match self {
            FaultPolicy::FailFast | FaultPolicy::Quarantine => 1,
            FaultPolicy::Retry { max_attempts, .. } => (*max_attempts).max(1),
        }
    }

    /// Sleep between attempts (zero outside `Retry`).
    pub fn backoff(&self) -> Duration {
        match self {
            FaultPolicy::Retry { backoff, .. } => *backoff,
            _ => Duration::ZERO,
        }
    }
}

/// One shard failure recorded by a `Quarantine` run (or surfaced in a
/// report after recovery): where it happened and what the worker said.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultRecord {
    /// Stream-order shard index.
    pub shard: usize,
    /// Worker that ran the failing attempt(s).
    pub worker: usize,
    /// Attempts made (1 = failed on its only attempt).
    pub attempts: u32,
    /// Rendered error (panic payload or `run_shard` error chain).
    pub error: String,
}

/// How an injected fault manifests inside `run_shard`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// `panic!` — exercises the `catch_unwind` guard.
    Panic,
    /// `bail!` — exercises the plain error path.
    Error,
}

/// One planned fault: shard `shard` fails on its next `times` attempts,
/// optionally only when claimed by worker `worker`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultShot {
    /// Stream-order shard index that fails.
    pub shard: usize,
    /// Restrict to one worker id (`None` = whichever worker claims it).
    pub worker: Option<usize>,
    /// Panic or plain error.
    pub kind: FaultKind,
    /// Attempts this shot poisons; a `Retry` run recovers on attempt
    /// `times + 1`.
    pub times: u32,
}

/// A deterministic plan of injected faults. Build one explicitly
/// (`panic_at`, `error_at`) or draw one from a seeded PRNG (`seeded`);
/// thread it through a [`FaultyFactory`] to detonate the shots.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    shots: Vec<FaultShot>,
}

impl FaultPlan {
    /// Create an empty plan.
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Shard `shard` panics on its next attempt (any worker).
    pub fn panic_at(self, shard: usize) -> FaultPlan {
        self.with_shot(FaultShot {
            shard,
            worker: None,
            kind: FaultKind::Panic,
            times: 1,
        })
    }

    /// Shard `shard` errors on its next attempt (any worker).
    pub fn error_at(self, shard: usize) -> FaultPlan {
        self.with_shot(FaultShot {
            shard,
            worker: None,
            kind: FaultKind::Error,
            times: 1,
        })
    }

    /// Shard `shard` panics on its next `times` attempts — `times`
    /// beyond `max_attempts - 1` makes a `Retry` run exhaust and fail.
    pub fn panic_at_times(self, shard: usize, times: u32) -> FaultPlan {
        self.with_shot(FaultShot {
            shard,
            worker: None,
            kind: FaultKind::Panic,
            times,
        })
    }

    /// Append an explicit shot.
    pub fn with_shot(mut self, shot: FaultShot) -> FaultPlan {
        self.shots.push(shot);
        self
    }

    /// Draw a plan from a seeded PRNG: each shard index in
    /// `0..shards` fails once with probability `rate`, panic or error
    /// chosen 50/50. Same seed + shard count → same plan, always.
    pub fn seeded(seed: u64, shards: usize, rate: f64) -> FaultPlan {
        let mut rng = Prng::new(seed);
        let mut plan = FaultPlan::new();
        for shard in 0..shards {
            if rng.chance(rate) {
                let kind = if rng.chance(0.5) {
                    FaultKind::Panic
                } else {
                    FaultKind::Error
                };
                plan.shots.push(FaultShot {
                    shard,
                    worker: None,
                    kind,
                    times: 1,
                });
            }
        }
        plan
    }

    /// Total faults the plan will inject (sum of every shot's `times`).
    pub fn injected(&self) -> usize {
        self.shots.iter().map(|s| s.times as usize).sum()
    }

    /// Distinct shard indices the plan poisons, ascending.
    pub fn shards(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self.shots.iter().filter(|s| s.times > 0).map(|s| s.shard).collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Whether the plan has no remaining shots.
    pub fn is_empty(&self) -> bool {
        self.shots.iter().all(|s| s.times == 0)
    }
}

/// Live shot ledger shared by every worker of one injected run.
type Shots = Arc<Mutex<Vec<FaultShot>>>;

/// Consume one matching shot, if any (first match wins).
fn claim_shot(shots: &Shots, shard: usize, worker: usize) -> Option<FaultKind> {
    let mut shots = lock_ignore_poison(shots);
    for s in shots.iter_mut() {
        if s.shard == shard && s.times > 0 && s.worker.is_none_or(|w| w == worker) {
            s.times -= 1;
            return Some(s.kind);
        }
    }
    None
}

/// A [`PipelineFactory`] wrapper that injects a [`FaultPlan`]'s shots
/// into `run_shard` — the fault-injection harness. Wrapping is
/// transparent when the plan is empty; shots fire from inside the
/// worker, upstream of the pool's `catch_unwind` guard, so every
/// recovery path (retry, quarantine, fail-fast) is exercised exactly
/// where a real kernel fault would fire.
pub struct FaultyFactory<F> {
    inner: F,
    shots: Shots,
}

impl<F: PipelineFactory> FaultyFactory<F> {
    /// Wrap `inner` so the plan's shots fire during shard execution.
    pub fn new(inner: F, plan: &FaultPlan) -> FaultyFactory<F> {
        FaultyFactory {
            inner,
            shots: Arc::new(Mutex::new(plan.shots.clone())),
        }
    }

    /// Shots not yet fired — zero after a run proves the plan landed
    /// exactly (the injection-count reconciliation tests pin this).
    pub fn remaining(&self) -> usize {
        lock_ignore_poison(&self.shots)
            .iter()
            .map(|s| s.times as usize)
            .sum()
    }

    /// The wrapped factory.
    pub fn inner(&self) -> &F {
        &self.inner
    }
}

impl<F: PipelineFactory> PipelineFactory for FaultyFactory<F> {
    type In = F::In;
    type Out = F::Out;
    type Worker = FaultyWorker<F::Worker>;

    fn make_worker(&self, worker_id: usize) -> Result<FaultyWorker<F::Worker>> {
        Ok(FaultyWorker {
            inner: self.inner.make_worker(worker_id)?,
            worker: worker_id,
            shard: usize::MAX,
            shots: self.shots.clone(),
        })
    }

    fn weight(&self, region: &F::In) -> usize {
        self.inner.weight(region)
    }

    fn recycle_region(&self, region: F::In) {
        self.inner.recycle_region(region)
    }

    // splitting delegates wholesale, so fault injection composes with
    // intra-region parallelism: a split run under a FaultPlan cuts,
    // retries and folds exactly like the unwrapped factory would
    fn splittability(&self) -> crate::exec::factory::Splittability {
        self.inner.splittability()
    }

    fn split_region(&self, region: &F::In, max_items: usize) -> Result<Vec<F::In>> {
        self.inner.split_region(region, max_items)
    }

    fn combine(&self, acc: &mut F::Out, part: F::Out) -> Result<()> {
        self.inner.combine(acc, part)
    }
}

/// The worker half of [`FaultyFactory`]: delegates everything to the
/// wrapped worker, except that a planned shot for the shard in flight
/// panics or errors **before** the real `run_shard` touches state.
pub struct FaultyWorker<W> {
    inner: W,
    worker: usize,
    /// Shard in flight (set by `begin_shard`; `usize::MAX` = none).
    shard: usize,
    shots: Shots,
}

impl<W: ShardWorker> ShardWorker for FaultyWorker<W> {
    type In = W::In;
    type Out = W::Out;

    fn begin_shard(&mut self, shard: usize) {
        self.shard = shard;
        self.inner.begin_shard(shard);
    }

    fn run_shard(&mut self, shard: &[W::In]) -> Result<ShardOutput<W::Out>> {
        if let Some(kind) = claim_shot(&self.shots, self.shard, self.worker) {
            match kind {
                FaultKind::Panic => panic!(
                    "injected fault: shard {} panics on worker {}",
                    self.shard, self.worker
                ),
                FaultKind::Error => bail!(
                    "injected fault: shard {} errors on worker {}",
                    self.shard,
                    self.worker
                ),
            }
        }
        self.inner.run_shard(shard)
    }

    fn pipelines_built(&self) -> u64 {
        self.inner.pipelines_built()
    }

    fn set_trace(&mut self, sink: TraceSink) {
        self.inner.set_trace(sink);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_attempts_and_labels() {
        assert_eq!(FaultPolicy::FailFast.max_attempts(), 1);
        assert_eq!(FaultPolicy::Quarantine.max_attempts(), 1);
        assert_eq!(FaultPolicy::retry(3).max_attempts(), 3);
        assert_eq!(FaultPolicy::retry(0).max_attempts(), 1, "clamped, never zero");
        assert_eq!(FaultPolicy::default(), FaultPolicy::FailFast);
        assert_eq!(FaultPolicy::FailFast.label(), "fail-fast");
        assert_eq!(FaultPolicy::retry(2).label(), "retry");
        assert_eq!(FaultPolicy::Quarantine.label(), "quarantine");
    }

    #[test]
    fn seeded_plans_are_deterministic() {
        let a = FaultPlan::seeded(0xBAD, 64, 0.25);
        let b = FaultPlan::seeded(0xBAD, 64, 0.25);
        assert_eq!(a.shots, b.shots, "same seed, same plan");
        assert!(!a.is_empty(), "1/4 rate over 64 shards injects something");
        assert!(a.injected() < 64, "and not everything");
        let c = FaultPlan::seeded(0xF00D, 64, 0.25);
        assert_ne!(a.shots, c.shots, "different seed, different plan");
    }

    #[test]
    fn shots_are_claimed_once_and_respect_worker_filters() {
        let plan = FaultPlan::new()
            .panic_at(3)
            .with_shot(FaultShot {
                shard: 5,
                worker: Some(1),
                kind: FaultKind::Error,
                times: 2,
            });
        let shots: Shots = Arc::new(Mutex::new(plan.shots.clone()));
        assert_eq!(claim_shot(&shots, 3, 0), Some(FaultKind::Panic));
        assert_eq!(claim_shot(&shots, 3, 0), None, "one shot, one fault");
        assert_eq!(claim_shot(&shots, 5, 0), None, "wrong worker");
        assert_eq!(claim_shot(&shots, 5, 1), Some(FaultKind::Error));
        assert_eq!(claim_shot(&shots, 5, 1), Some(FaultKind::Error), "times = 2");
        assert_eq!(claim_shot(&shots, 5, 1), None);
        assert_eq!(plan.injected(), 3);
        assert_eq!(plan.shards(), vec![3, 5]);
    }
}
