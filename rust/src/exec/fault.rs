//! Fault tolerance for the sharded executor: recovery policies, the
//! per-shard fault ledger, and a seeded fault-injection harness.
//!
//! The executor's unit of recovery is the **shard**: all cross-item
//! state is region-scoped and regions never span shards, so a failed
//! shard can be re-run (or dropped) without touching any other shard's
//! state. Three policies cover the useful points on that spectrum:
//!
//! * [`FaultPolicy::FailFast`] — the historical behaviour (and still the
//!   default): the first shard panic or error aborts the whole run.
//! * [`FaultPolicy::Retry`] — the worker's persistent pipeline is
//!   discarded (a panic may have left it mid-reset), a fresh one is
//!   built through the factory, and the same shard is re-run, up to
//!   `max_attempts` total attempts. Because a rebuilt pipeline is
//!   bit-identical to a fresh one (the PR 5 reuse ≡ fresh proof), a
//!   recovered run's output is **bit-identical** to a fault-free run.
//! * [`FaultPolicy::Quarantine`] — the shard is given one attempt; on
//!   failure its id and error land in [`ExecReport::faults`] and the
//!   stream-order merge emits an empty slot for it instead of stalling
//!   the runs behind it.
//!
//! Recovery is **part-granular**: when a failed shard covers several
//! regions (or [`SubShard`](crate::exec::split::SubShard) parts), the
//! pool narrows to the failing slice instead of discarding the whole
//! shard — `Retry` re-runs only what failed, and `Quarantine` records
//! one [`FaultRecord`] per lost region (its in-shard ordinal in
//! [`FaultRecord::part`]) while keeping every surviving region's
//! output. Split runs additionally surface lost parts through the
//! [`PartialRegion`](crate::exec::PartialRegion) salvage ledger.
//!
//! The injection harness ([`FaultPlan`] + [`FaultyFactory`]) makes every
//! recovery path deterministically testable: a plan is a list of
//! "shard `k` panics (or errors) on its next `times` attempts",
//! either written explicitly or drawn from a seeded PRNG, and the
//! factory wrapper detonates those shots from inside `run_shard` —
//! upstream of the pool's `catch_unwind` guard, exactly where a real
//! kernel fault would fire. Beyond the compute domain, a plan can also
//! poison the **ingest** boundary ([`FaultySource`] fails
//! `next_region` pulls, recovered by the driver's bounded
//! retry-with-backoff), the **sink** boundary ([`FaultySink`] fails
//! `write_batch`, surfacing a named error with the `.tmp` sibling
//! cleaned up), and the **rebuild** path (`panic_on_rebuild` kills a
//! worker's recovery build, exercising worker retirement).
//!
//! [`ExecReport::faults`]: crate::exec::ExecReport::faults

use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::exec::factory::{PipelineFactory, ShardOutput, ShardWorker};
use crate::exec::ingest::lock_ignore_poison;
use crate::trace::TraceSink;
use crate::util::prng::Prng;

/// What the pool does when a shard's `run_shard` panics or errors.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FaultPolicy {
    /// Abort the whole run on the first shard failure (the default).
    #[default]
    FailFast,
    /// Rebuild the worker's pipeline fresh and re-run the failed shard,
    /// up to `max_attempts` **total** attempts per shard, sleeping
    /// `backoff` between attempts. Recovered output is bit-identical to
    /// a fault-free run; exhausting the attempts fails the run.
    Retry {
        /// Total attempts per shard (>= 1; 1 behaves like fail-fast).
        max_attempts: u32,
        /// Sleep between attempts (transient-fault damping).
        backoff: Duration,
    },
    /// Give each shard one attempt; record failures in
    /// [`ExecReport::faults`](crate::exec::ExecReport::faults) and keep
    /// the run going, emitting an empty slot in stream order.
    Quarantine,
}

impl FaultPolicy {
    /// Retry with no backoff — the common test/CLI shape.
    pub fn retry(max_attempts: u32) -> FaultPolicy {
        FaultPolicy::Retry {
            max_attempts,
            backoff: Duration::ZERO,
        }
    }

    /// Stable CLI/report name.
    pub fn label(&self) -> &'static str {
        match self {
            FaultPolicy::FailFast => "fail-fast",
            FaultPolicy::Retry { .. } => "retry",
            FaultPolicy::Quarantine => "quarantine",
        }
    }

    /// Total attempts a shard gets before the policy gives up on it.
    pub fn max_attempts(&self) -> u32 {
        match self {
            FaultPolicy::FailFast | FaultPolicy::Quarantine => 1,
            FaultPolicy::Retry { max_attempts, .. } => (*max_attempts).max(1),
        }
    }

    /// Sleep between attempts (zero outside `Retry`).
    pub fn backoff(&self) -> Duration {
        match self {
            FaultPolicy::Retry { backoff, .. } => *backoff,
            _ => Duration::ZERO,
        }
    }
}

/// One shard failure recorded by a `Quarantine` run (or surfaced in a
/// report after recovery): where it happened and what the worker said.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultRecord {
    /// Stream-order shard index.
    pub shard: usize,
    /// Worker that ran the failing attempt(s).
    pub worker: usize,
    /// Attempts made (1 = failed on its only attempt).
    pub attempts: u32,
    /// Rendered error (panic payload or `run_shard` error chain).
    pub error: String,
    /// Granularity of the loss: `Some(i)` means only the region at
    /// in-shard ordinal `i` was dropped (part-granular quarantine);
    /// `None` means the whole shard was lost.
    pub part: Option<u32>,
}

impl FaultRecord {
    /// Human-readable granularity tag for the `fault_table` column.
    pub fn granularity(&self) -> String {
        match self.part {
            Some(i) => format!("part {i}"),
            None => "shard".to_string(),
        }
    }
}

/// How an injected fault manifests inside `run_shard`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// `panic!` — exercises the `catch_unwind` guard.
    Panic,
    /// `bail!` — exercises the plain error path.
    Error,
}

/// One planned fault: shard `shard` fails on its next `times` attempts,
/// optionally only when claimed by worker `worker`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultShot {
    /// Stream-order shard index that fails.
    pub shard: usize,
    /// Restrict to one worker id (`None` = whichever worker claims it).
    pub worker: Option<usize>,
    /// Panic or plain error.
    pub kind: FaultKind,
    /// Attempts this shot poisons; a `Retry` run recovers on attempt
    /// `times + 1`.
    pub times: u32,
}

/// One planned ingest/sink boundary fault: call number `at` (0-based
/// pulls for sources, batches for sinks) fails on its next `times`
/// attempts. `times == u32::MAX` models a permanent fault that no
/// retry budget survives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IoShot {
    /// 0-based call ordinal that fails (source pull / sink batch).
    pub at: usize,
    /// Attempts this shot poisons before the call succeeds again.
    pub times: u32,
}

/// One planned pipeline-rebuild fault: a worker's recovery build (any
/// `make_worker` call after its first) panics on its next `times`
/// firings — the trigger for worker retirement under `Quarantine`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RebuildShot {
    /// Restrict to one worker id (`None` = whichever rebuilds first).
    pub worker: Option<usize>,
    /// Rebuilds this shot poisons.
    pub times: u32,
}

/// A deterministic plan of injected faults. Build one explicitly
/// (`panic_at`, `error_at`, `source_fault_at`, …) or draw one from a
/// seeded PRNG (`seeded`, `seeded_source`); thread it through a
/// [`FaultyFactory`] / [`FaultySource`] / [`FaultySink`] to detonate
/// the shots in their respective domains.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    shots: Vec<FaultShot>,
    source_shots: Vec<IoShot>,
    sink_shots: Vec<IoShot>,
    rebuild_shots: Vec<RebuildShot>,
}

impl FaultPlan {
    /// Create an empty plan.
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Shard `shard` panics on its next attempt (any worker).
    pub fn panic_at(self, shard: usize) -> FaultPlan {
        self.with_shot(FaultShot {
            shard,
            worker: None,
            kind: FaultKind::Panic,
            times: 1,
        })
    }

    /// Shard `shard` errors on its next attempt (any worker).
    pub fn error_at(self, shard: usize) -> FaultPlan {
        self.with_shot(FaultShot {
            shard,
            worker: None,
            kind: FaultKind::Error,
            times: 1,
        })
    }

    /// Shard `shard` panics on its next `times` attempts — `times`
    /// beyond `max_attempts - 1` makes a `Retry` run exhaust and fail.
    pub fn panic_at_times(self, shard: usize, times: u32) -> FaultPlan {
        self.with_shot(FaultShot {
            shard,
            worker: None,
            kind: FaultKind::Panic,
            times,
        })
    }

    /// Append an explicit shot.
    pub fn with_shot(mut self, shot: FaultShot) -> FaultPlan {
        self.shots.push(shot);
        self
    }

    /// Set every compute shot's `times` to `times` — e.g. `times = 2`
    /// makes each poisoned shard fail again during part narrowing, so
    /// the per-part retry path (not just the narrowing pass) runs.
    pub fn with_times(mut self, times: u32) -> FaultPlan {
        for s in &mut self.shots {
            s.times = times;
        }
        self
    }

    /// Source pull `at` fails transiently on its next attempt (the
    /// retried pull succeeds).
    pub fn source_fault_at(mut self, at: usize) -> FaultPlan {
        self.source_shots.push(IoShot { at, times: 1 });
        self
    }

    /// Source pull `at` fails on its next `times` attempts; pass
    /// `u32::MAX` for a permanent fault that exhausts any retry budget.
    pub fn source_fault_at_times(mut self, at: usize, times: u32) -> FaultPlan {
        self.source_shots.push(IoShot { at, times });
        self
    }

    /// Sink batch `at` fails on its next attempt. Sink faults are not
    /// retried — they abort the run with a named error.
    pub fn sink_fault_at(mut self, at: usize) -> FaultPlan {
        self.sink_shots.push(IoShot { at, times: 1 });
        self
    }

    /// The next pipeline **rebuild** (any worker's `make_worker` call
    /// after its first) panics — under `Quarantine` this retires the
    /// worker instead of aborting the run.
    pub fn panic_on_rebuild(mut self) -> FaultPlan {
        self.rebuild_shots.push(RebuildShot {
            worker: None,
            times: 1,
        });
        self
    }

    /// Draw transient source faults from a seeded PRNG: each pull index
    /// in `0..pulls` fails once with probability `rate`. Same seed +
    /// pull count → same plan, always.
    pub fn seeded_source(seed: u64, pulls: usize, rate: f64) -> FaultPlan {
        let mut rng = Prng::new(seed);
        let mut plan = FaultPlan::new();
        for at in 0..pulls {
            if rng.chance(rate) {
                plan.source_shots.push(IoShot { at, times: 1 });
            }
        }
        plan
    }

    /// Transient source faults the plan will inject.
    pub fn injected_source(&self) -> usize {
        self.source_shots.iter().map(|s| s.times as usize).sum()
    }

    /// Draw a plan from a seeded PRNG: each shard index in
    /// `0..shards` fails once with probability `rate`, panic or error
    /// chosen 50/50. Same seed + shard count → same plan, always.
    pub fn seeded(seed: u64, shards: usize, rate: f64) -> FaultPlan {
        let mut rng = Prng::new(seed);
        let mut plan = FaultPlan::new();
        for shard in 0..shards {
            if rng.chance(rate) {
                let kind = if rng.chance(0.5) {
                    FaultKind::Panic
                } else {
                    FaultKind::Error
                };
                plan.shots.push(FaultShot {
                    shard,
                    worker: None,
                    kind,
                    times: 1,
                });
            }
        }
        plan
    }

    /// Total faults the plan will inject (sum of every shot's `times`).
    pub fn injected(&self) -> usize {
        self.shots.iter().map(|s| s.times as usize).sum()
    }

    /// Distinct shard indices the plan poisons, ascending.
    pub fn shards(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self.shots.iter().filter(|s| s.times > 0).map(|s| s.shard).collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Whether the plan has no remaining shots.
    pub fn is_empty(&self) -> bool {
        self.shots.iter().all(|s| s.times == 0)
    }
}

/// Live shot ledger shared by every worker of one injected run.
type Shots = Arc<Mutex<Vec<FaultShot>>>;

/// Shared rebuild-shot ledger plus per-worker build counts (keyed by
/// worker id), used to tell recovery rebuilds apart from first builds.
#[derive(Debug, Default)]
struct RebuildState {
    shots: Vec<RebuildShot>,
    builds: std::collections::HashMap<usize, u32>,
}

/// Consume one matching shot, if any (first match wins).
fn claim_shot(shots: &Shots, shard: usize, worker: usize) -> Option<FaultKind> {
    let mut shots = lock_ignore_poison(shots);
    for s in shots.iter_mut() {
        if s.shard == shard && s.times > 0 && s.worker.is_none_or(|w| w == worker) {
            s.times -= 1;
            return Some(s.kind);
        }
    }
    None
}

/// A [`PipelineFactory`] wrapper that injects a [`FaultPlan`]'s shots
/// into `run_shard` — the fault-injection harness. Wrapping is
/// transparent when the plan is empty; shots fire from inside the
/// worker, upstream of the pool's `catch_unwind` guard, so every
/// recovery path (retry, quarantine, fail-fast) is exercised exactly
/// where a real kernel fault would fire.
pub struct FaultyFactory<F> {
    inner: F,
    shots: Shots,
    rebuilds: Arc<Mutex<RebuildState>>,
}

impl<F: PipelineFactory> FaultyFactory<F> {
    /// Wrap `inner` so the plan's shots fire during shard execution.
    pub fn new(inner: F, plan: &FaultPlan) -> FaultyFactory<F> {
        FaultyFactory {
            inner,
            shots: Arc::new(Mutex::new(plan.shots.clone())),
            rebuilds: Arc::new(Mutex::new(RebuildState {
                shots: plan.rebuild_shots.clone(),
                builds: Default::default(),
            })),
        }
    }

    /// Compute shots not yet fired — zero after a run proves the plan
    /// landed exactly (the injection-count reconciliation tests pin
    /// this). Rebuild shots are not counted here.
    pub fn remaining(&self) -> usize {
        lock_ignore_poison(&self.shots)
            .iter()
            .map(|s| s.times as usize)
            .sum()
    }

    /// The wrapped factory.
    pub fn inner(&self) -> &F {
        &self.inner
    }
}

impl<F: PipelineFactory> PipelineFactory for FaultyFactory<F> {
    type In = F::In;
    type Out = F::Out;
    type Worker = FaultyWorker<F::Worker>;

    fn make_worker(&self, worker_id: usize) -> Result<FaultyWorker<F::Worker>> {
        {
            let mut guard = lock_ignore_poison(&self.rebuilds);
            let state = &mut *guard;
            let builds = state.builds.entry(worker_id).or_insert(0);
            *builds += 1;
            let is_rebuild = *builds > 1;
            // only builds after a worker's first are rebuilds; a planned
            // rebuild shot panics here, inside the pool's guarded
            // rebuild, exactly where a real recovery build would die
            if is_rebuild {
                for s in state.shots.iter_mut() {
                    if s.times > 0 && s.worker.is_none_or(|w| w == worker_id) {
                        s.times -= 1;
                        panic!(
                            "injected fault: worker {worker_id} panics rebuilding its pipeline"
                        );
                    }
                }
            }
        }
        Ok(FaultyWorker {
            inner: self.inner.make_worker(worker_id)?,
            worker: worker_id,
            shard: usize::MAX,
            shots: self.shots.clone(),
        })
    }

    fn weight(&self, region: &F::In) -> usize {
        self.inner.weight(region)
    }

    fn recycle_region(&self, region: F::In) {
        self.inner.recycle_region(region)
    }

    // splitting delegates wholesale, so fault injection composes with
    // intra-region parallelism: a split run under a FaultPlan cuts,
    // retries and folds exactly like the unwrapped factory would
    fn splittability(&self) -> crate::exec::factory::Splittability {
        self.inner.splittability()
    }

    fn split_region(&self, region: &F::In, max_items: usize) -> Result<Vec<F::In>> {
        self.inner.split_region(region, max_items)
    }

    fn combine(&self, acc: &mut F::Out, part: F::Out) -> Result<()> {
        self.inner.combine(acc, part)
    }
}

/// The worker half of [`FaultyFactory`]: delegates everything to the
/// wrapped worker, except that a planned shot for the shard in flight
/// panics or errors **before** the real `run_shard` touches state.
pub struct FaultyWorker<W> {
    inner: W,
    worker: usize,
    /// Shard in flight (set by `begin_shard`; `usize::MAX` = none).
    shard: usize,
    shots: Shots,
}

impl<W: ShardWorker> ShardWorker for FaultyWorker<W> {
    type In = W::In;
    type Out = W::Out;

    fn begin_shard(&mut self, shard: usize) {
        self.shard = shard;
        self.inner.begin_shard(shard);
    }

    fn run_shard(&mut self, shard: &[W::In]) -> Result<ShardOutput<W::Out>> {
        if let Some(kind) = claim_shot(&self.shots, self.shard, self.worker) {
            match kind {
                FaultKind::Panic => panic!(
                    "injected fault: shard {} panics on worker {}",
                    self.shard, self.worker
                ),
                FaultKind::Error => bail!(
                    "injected fault: shard {} errors on worker {}",
                    self.shard,
                    self.worker
                ),
            }
        }
        self.inner.run_shard(shard)
    }

    fn pipelines_built(&self) -> u64 {
        self.inner.pipelines_built()
    }

    fn set_trace(&mut self, sink: TraceSink) {
        self.inner.set_trace(sink);
    }
}

/// A [`RegionSource`](crate::workload::source::RegionSource) wrapper that
/// detonates a plan's **source shots**: pull number `at` fails with a
/// named error instead of touching the inner source, so a retried pull
/// resumes exactly where the stream left off. Transient shots (`times`
/// finite) clear after firing — the ingest driver's bounded
/// retry-with-backoff recovers them; permanent shots (`u32::MAX`)
/// exhaust the budget and fail the run by name.
pub struct FaultySource<S> {
    inner: S,
    shots: Vec<IoShot>,
    /// 0-based pull index of the next `try_next_region` call.
    pulls: usize,
    fired: usize,
}

impl<S> FaultySource<S> {
    /// Wrap `inner` so the plan's source shots fire during ingest.
    pub fn new(inner: S, plan: &FaultPlan) -> FaultySource<S> {
        FaultySource {
            inner,
            shots: plan.source_shots.clone(),
            pulls: 0,
            fired: 0,
        }
    }

    /// Source faults fired so far.
    pub fn fired(&self) -> usize {
        self.fired
    }

    /// Source shots not yet fired.
    pub fn remaining(&self) -> usize {
        self.shots
            .iter()
            .map(|s| if s.times == u32::MAX { 1 } else { s.times as usize })
            .sum()
    }
}

impl<S: crate::workload::source::RegionSource> crate::workload::source::RegionSource for FaultySource<S> {
    type Region = S::Region;

    fn next_region(&mut self) -> Option<S::Region> {
        // the infallible path cannot surface transient faults; shots
        // only fire through try_next_region (the driver's path)
        self.pulls += 1;
        self.inner.next_region()
    }

    fn try_next_region(&mut self) -> Result<Option<S::Region>> {
        let at = self.pulls;
        for s in self.shots.iter_mut() {
            if s.at == at && s.times > 0 {
                if s.times != u32::MAX {
                    s.times -= 1;
                }
                self.fired += 1;
                // the pull index does NOT advance: the retried call
                // re-attempts this same pull
                bail!("injected fault: source pull {at} failed");
            }
        }
        self.pulls += 1;
        self.inner.try_next_region()
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        self.inner.size_hint()
    }

    fn close(&mut self) -> Result<()> {
        self.inner.close()
    }
}

/// A [`ResultSink`](crate::io::ResultSink) wrapper that detonates a
/// plan's **sink shots**: batch number `at` fails `write_batch` with a
/// named error before the inner sink sees it. Sink faults are never
/// retried — the streaming run aborts, and file-backed sinks remove
/// their unpublished `.tmp` sibling on drop.
pub struct FaultySink<S> {
    inner: S,
    shots: Vec<IoShot>,
    batches: usize,
}

impl<S> FaultySink<S> {
    /// Wrap `inner` so the plan's sink shots fire during emission.
    pub fn new(inner: S, plan: &FaultPlan) -> FaultySink<S> {
        FaultySink {
            inner,
            shots: plan.sink_shots.clone(),
            batches: 0,
        }
    }

    /// The wrapped sink (to finish or inspect after a run).
    pub fn into_inner(self) -> S {
        self.inner
    }
}

impl<T, S: crate::io::ResultSink<T>> crate::io::ResultSink<T> for FaultySink<S> {
    fn write_batch(&mut self, outputs: &[T]) -> Result<()> {
        let at = self.batches;
        self.batches += 1;
        for s in self.shots.iter_mut() {
            if s.at == at && s.times > 0 {
                s.times -= 1;
                bail!("injected fault: result sink failed writing batch {at}");
            }
        }
        self.inner.write_batch(outputs)
    }

    fn finish(&mut self) -> Result<crate::io::SinkStats> {
        self.inner.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_attempts_and_labels() {
        assert_eq!(FaultPolicy::FailFast.max_attempts(), 1);
        assert_eq!(FaultPolicy::Quarantine.max_attempts(), 1);
        assert_eq!(FaultPolicy::retry(3).max_attempts(), 3);
        assert_eq!(FaultPolicy::retry(0).max_attempts(), 1, "clamped, never zero");
        assert_eq!(FaultPolicy::default(), FaultPolicy::FailFast);
        assert_eq!(FaultPolicy::FailFast.label(), "fail-fast");
        assert_eq!(FaultPolicy::retry(2).label(), "retry");
        assert_eq!(FaultPolicy::Quarantine.label(), "quarantine");
    }

    #[test]
    fn seeded_plans_are_deterministic() {
        let a = FaultPlan::seeded(0xBAD, 64, 0.25);
        let b = FaultPlan::seeded(0xBAD, 64, 0.25);
        assert_eq!(a.shots, b.shots, "same seed, same plan");
        assert!(!a.is_empty(), "1/4 rate over 64 shards injects something");
        assert!(a.injected() < 64, "and not everything");
        let c = FaultPlan::seeded(0xF00D, 64, 0.25);
        assert_ne!(a.shots, c.shots, "different seed, different plan");
    }

    #[test]
    fn shots_are_claimed_once_and_respect_worker_filters() {
        let plan = FaultPlan::new()
            .panic_at(3)
            .with_shot(FaultShot {
                shard: 5,
                worker: Some(1),
                kind: FaultKind::Error,
                times: 2,
            });
        let shots: Shots = Arc::new(Mutex::new(plan.shots.clone()));
        assert_eq!(claim_shot(&shots, 3, 0), Some(FaultKind::Panic));
        assert_eq!(claim_shot(&shots, 3, 0), None, "one shot, one fault");
        assert_eq!(claim_shot(&shots, 5, 0), None, "wrong worker");
        assert_eq!(claim_shot(&shots, 5, 1), Some(FaultKind::Error));
        assert_eq!(claim_shot(&shots, 5, 1), Some(FaultKind::Error), "times = 2");
        assert_eq!(claim_shot(&shots, 5, 1), None);
        assert_eq!(plan.injected(), 3);
        assert_eq!(plan.shards(), vec![3, 5]);
    }

    #[test]
    fn seeded_source_plans_are_deterministic() {
        let a = FaultPlan::seeded_source(0xBAD, 64, 0.25);
        let b = FaultPlan::seeded_source(0xBAD, 64, 0.25);
        assert_eq!(a.source_shots, b.source_shots, "same seed, same plan");
        assert!(a.injected_source() >= 1, "1/4 rate over 64 pulls injects something");
        assert!(a.injected_source() < 64, "and not everything");
        let c = FaultPlan::seeded_source(0xF00D, 64, 0.25);
        assert_ne!(a.source_shots, c.source_shots, "different seed, different plan");
    }

    #[test]
    fn with_times_rescales_every_compute_shot() {
        let plan = FaultPlan::new().panic_at(1).error_at(4).with_times(3);
        assert_eq!(plan.injected(), 6);
        assert!(plan.shots.iter().all(|s| s.times == 3));
    }

    #[test]
    fn faulty_source_fails_the_planned_pull_then_recovers() {
        use crate::workload::source::{RegionSource, SliceSource};
        let items = vec![10u32, 20, 30];
        let plan = FaultPlan::new().source_fault_at(1);
        let mut src = FaultySource::new(SliceSource::new(&items), &plan);
        assert_eq!(src.try_next_region().unwrap(), Some(10));
        let err = src.try_next_region().unwrap_err();
        assert!(err.to_string().contains("source pull 1 failed"), "{err:#}");
        assert_eq!(src.try_next_region().unwrap(), Some(20), "retried pull resumes in place");
        assert_eq!(src.try_next_region().unwrap(), Some(30));
        assert_eq!(src.try_next_region().unwrap(), None);
        assert_eq!(src.fired(), 1);
        assert_eq!(src.remaining(), 0);
        src.close().unwrap();
    }

    #[test]
    fn permanent_source_fault_never_clears() {
        use crate::workload::source::{RegionSource, SliceSource};
        let items = vec![1u32];
        let plan = FaultPlan::new().source_fault_at_times(0, u32::MAX);
        let mut src = FaultySource::new(SliceSource::new(&items), &plan);
        for _ in 0..4 {
            assert!(src.try_next_region().is_err(), "permanent fault keeps firing");
        }
        assert_eq!(src.remaining(), 1, "a permanent shot never drains");
    }

    #[test]
    fn faulty_sink_fails_the_planned_batch_by_name() {
        use crate::io::{JsonlSink, ResultSink};
        let plan = FaultPlan::new().sink_fault_at(1);
        let mut sink = FaultySink::new(JsonlSink::new(Vec::new()), &plan);
        ResultSink::<(u64, f64)>::write_batch(&mut sink, &[(0, 1.0)]).unwrap();
        let err = ResultSink::<(u64, f64)>::write_batch(&mut sink, &[(1, 2.0)]).unwrap_err();
        assert!(
            err.to_string().contains("sink failed writing batch 1"),
            "{err:#}"
        );
        ResultSink::<(u64, f64)>::write_batch(&mut sink, &[(2, 3.0)]).unwrap();
        let stats = ResultSink::<(u64, f64)>::finish(&mut sink).unwrap();
        assert_eq!(stats.records, 2, "the poisoned batch never reached the sink");
    }

    #[test]
    fn rebuild_shots_spare_first_builds_and_fire_once() {
        use crate::exec::factory::KernelSpawn;
        use crate::apps::sum::{SumConfig, SumFactory};
        let factory = SumFactory::new(
            SumConfig {
                width: 8,
                ..Default::default()
            },
            KernelSpawn::Native,
        );
        let faulty = FaultyFactory::new(factory, &FaultPlan::new().panic_on_rebuild());
        // first build per worker (prewarm) is never a rebuild
        let _w0 = faulty.make_worker(0).unwrap();
        let _w1 = faulty.make_worker(1).unwrap();
        // the first rebuild anywhere panics…
        let died = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = faulty.make_worker(1);
        }));
        assert!(died.is_err(), "planned rebuild shot must panic");
        // …and the shot is consumed: later rebuilds succeed
        let _w1b = faulty.make_worker(1).unwrap();
        let _w0b = faulty.make_worker(0).unwrap();
    }
}
