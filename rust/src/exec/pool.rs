//! The worker pool: one scoped OS thread per worker, each running a
//! private single-threaded pipeline over shards claimed from per-worker
//! deques with LIFO-local / FIFO-steal work stealing (the paper's
//! "pipelines compete to consume data from a common input stream ...
//! atomic operations but no locking", lifted from GPU processors to OS
//! threads — here the competition is stealing whole region-aligned
//! shards, so region state never crosses a worker mid-region).
//!
//! Two execution modes:
//!
//! * [`WorkerPool::run`] — a materialized [`ShardPlan`]: every shard is
//!   dealt round-robin into the deques up front, workers drain and steal.
//!   The original single-atomic-cursor claimer survives as
//!   [`ClaimMode::Cursor`], the `bench ingest` baseline.
//! * [`WorkerPool::run_stream`] — streaming ingest: the calling thread
//!   becomes the ingest driver, pulling regions from a
//!   [`RegionSource`], cutting shards on the fly
//!   ([`IngestPlanner`]), dealing them into the deques under a bounded
//!   in-flight budget, and emitting merged results **in stream order as
//!   shards complete** (not after a global join).
//!
//! Error semantics (both modes) are governed by the pool's
//! [`FaultPolicy`] (default [`FaultPolicy::FailFast`], the historical
//! all-or-nothing behaviour): under fail-fast the first failure flips a
//! stop flag so idle workers quit claiming, and the error (annotated
//! with worker and shard) reaches the caller after all threads join —
//! already-completed shards are discarded. `Retry` discards the failing
//! worker's pipeline, rebuilds it fresh through the factory, and re-runs
//! the shard — after the first failure the re-run **narrows to
//! per-region slices**, so only the regions that keep failing pay
//! further retries (output stays bit-identical, by the reuse ≡ fresh
//! proof plus the shard-granularity invariance). `Quarantine` runs
//! per-region from the start: a poisoned region is dropped by name (its
//! in-shard ordinal lands in [`ShardResult::lost`] and the run's fault
//! table), surviving regions keep their outputs, and a worker whose
//! quarantine *rebuild* also fails retires — its unfinished shard is
//! handed back to the surviving deques and the run completes on N−1
//! workers. Every `run_shard` call sits behind
//! `catch_unwind`, so a panicking kernel is handled exactly like an
//! `Err` — never a poisoned pool. And no blocking wait is unbounded:
//! claims and completion drains carry a watchdog deadline (see
//! [`super::steal`]), so a stuck shard or lost wake-up becomes a named
//! error instead of a hang.
//!
//! ## Prewarm
//!
//! Both modes build every worker's pipeline **eagerly, before the timed
//! region**: workers construct their engines, then rendezvous on a
//! barrier with the coordinating thread, and only then does the
//! claim/ingest phase (and the clock behind
//! [`PoolRun::elapsed`]/[`StreamRun::elapsed`]) start. The first shard
//! never pays graph construction inside the measurement, and under
//! tracing the build shows up as its own `Prewarm` span. A build error
//! or panic still reaches the barrier first, so the coordinator never
//! waits on a worker that already gave up.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Barrier, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use super::factory::{PipelineFactory, ShardOutput, ShardWorker};
use super::fault::FaultPolicy;
use super::ingest::{lock_ignore_poison, ContainerPool, IngestPlanner, IngestPolicy, ShardTask};
use super::merge::StreamMerger;
use super::plan::ShardPlan;
use super::steal::{Claim, ClaimMode, CompletionBuffer, Pulse, StealQueues};
use crate::coordinator::metrics::PipelineMetrics;
use crate::metrics::{Heartbeat, LaneMetrics, MetricsHub, MetricsSpec, ProgressSnapshot};
use crate::trace::{TraceEvent, TraceSink, TraceSpec, WorkerTrace, DRIVER_LANE};
use crate::workload::source::RegionSource;

/// One shard's results, tagged with where it ran.
#[derive(Debug, Clone)]
pub struct ShardResult<T> {
    /// Shard index in plan (= stream) order.
    pub shard: usize,
    /// Worker that executed it.
    pub worker: usize,
    /// Regions the shard spanned.
    pub regions: usize,
    /// Whether the executing worker stole it from another deque.
    pub stolen: bool,
    /// Outputs in the shard's stream order.
    pub outputs: Vec<T>,
    /// The shard pipeline's metrics.
    pub metrics: PipelineMetrics,
    /// Kernel invocations spent on the shard.
    pub invocations: u64,
    /// Wall-clock seconds this shard took on its worker.
    pub elapsed: f64,
    /// The executing worker's cumulative pipeline-build count when this
    /// shard finished ([`ShardWorker::pipelines_built`] plus any
    /// fault-recovery rebuilds) — 1 for every shard of a persistent
    /// (reset-not-rebuild) worker on the fault-free path.
    pub pipelines_built: u64,
    /// Extra attempts this shard needed (0 on the fault-free path; a
    /// `Retry` recovery counts one per rebuild-and-rerun cycle).
    pub retries: u32,
    /// `Some(first error)` if any region of the shard was lost under
    /// [`FaultPolicy::Quarantine`]: `outputs` then holds only the
    /// surviving regions' rows and `lost` names the dropped ordinals.
    pub fault: Option<String>,
    /// In-shard ordinals (0-based, ascending) of regions dropped by a
    /// part-granular quarantine. Empty on every other path — a
    /// quarantined shard keeps its surviving regions' outputs instead
    /// of discarding the whole shard.
    pub lost: Vec<u32>,
    /// Single-region re-runs performed while recovering this shard
    /// under [`FaultPolicy::Retry`] (the part-narrowing pass plus any
    /// per-region retries). 0 when the first whole-slice attempt
    /// succeeded; the fault bench compares this against the whole-shard
    /// rerun cost the narrowing avoided.
    pub rerun_regions: u64,
    /// When this shard was submitted by the streaming ingest driver
    /// (nanoseconds since the run's shared epoch), carried through from
    /// [`ShardTask::submit_ns`] so the stream merger can stamp emit time
    /// and derive per-region end-to-end latency. 0 on materialized runs
    /// and whenever metrics are off.
    pub submit_ns: u64,
}

/// Best-effort text of a thread panic payload (panics carry `&str` or
/// `String` in practice; anything else is reported generically).
fn panic_msg(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

/// Flips the stop flag if its thread unwinds, so a panicking worker
/// halts the rest of the pool just like an `Err` does.
struct StopOnPanic<'a>(&'a AtomicBool);

impl Drop for StopOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.store(true, Ordering::Relaxed);
        }
    }
}

/// Streaming variant of [`StopOnPanic`]: also records a failure in the
/// completion buffer so the (possibly sleeping) ingest driver wakes and
/// aborts instead of waiting forever for a shard that will never finish.
/// Names the worker and the shard in flight (`usize::MAX` = between
/// shards), so an escaped panic — one from outside the `catch_unwind`
/// around `run_shard`, e.g. in region recycling — is still attributable.
struct PanicSignal<'a, R> {
    stop: &'a AtomicBool,
    completion: &'a CompletionBuffer<R>,
    worker: usize,
    shard: &'a AtomicUsize,
}

impl<R> Drop for PanicSignal<'_, R> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.stop.store(true, Ordering::Relaxed);
            let worker = self.worker;
            self.completion.fail(match self.shard.load(Ordering::Relaxed) {
                usize::MAX => anyhow!("worker {worker} panicked between streaming shards"),
                shard => {
                    anyhow!("worker {worker} panicked while running streaming shard {shard}")
                }
            });
        }
    }
}

/// Outcome of [`run_shard_guarded`]: the shard's output (possibly after
/// retries), its part-granular quarantine record, or a retirement
/// notice when the worker lost its pipeline for good.
enum Guarded<T> {
    /// The shard completed; `retries` rebuild-and-rerun cycles preceded
    /// and `rerun_regions` single-region re-runs were paid during the
    /// part-narrowing pass (both 0 on the fault-free path).
    Done {
        out: ShardOutput<T>,
        retries: u32,
        rerun_regions: u64,
    },
    /// [`FaultPolicy::Quarantine`] gave up on part of the shard: `out`
    /// holds the surviving regions' rows in shard order, `lost` the
    /// failed in-shard ordinals (ascending), `error` the first failure.
    Quarantined {
        out: ShardOutput<T>,
        lost: Vec<u32>,
        error: String,
        attempts: u32,
    },
    /// A quarantine rebuild itself failed: the worker has no usable
    /// pipeline left and must retire from the pool.
    Retired { error: String },
}

/// Sleep a retry backoff without starving the pool watchdog: the wait is
/// chunked and the pool [`Pulse`] is beaten between chunks, so a backoff
/// longer than `--watchdog-secs` no longer reads as a stall. `None`
/// (the legacy cursor claimer has no pulse) degrades to a plain sleep.
fn sleep_backoff(backoff: Duration, pulse: Option<&Pulse>) {
    const CHUNK: Duration = Duration::from_millis(50);
    if backoff.is_zero() {
        return;
    }
    let Some(pulse) = pulse else {
        std::thread::sleep(backoff);
        return;
    };
    let mut left = backoff;
    while !left.is_zero() {
        let step = left.min(CHUNK);
        std::thread::sleep(step);
        left -= step;
        pulse.beat();
    }
}

/// Replace a possibly-corrupt pipeline wholesale through the factory,
/// under its own `catch_unwind` (a panicking rebuild must not escape the
/// worker loop — under `Quarantine` it triggers retirement instead of
/// aborting the run). Counted in `rebuilds` so per-worker
/// `pipelines_built` accounting stays exact.
fn rebuild_pipeline<F: PipelineFactory>(
    factory: &F,
    worker_id: usize,
    pipeline: &mut F::Worker,
    rebuilds: &mut u64,
    shard: usize,
    sink: &TraceSink,
) -> Result<()> {
    match catch_unwind(AssertUnwindSafe(|| factory.make_worker(worker_id))) {
        Ok(Ok(p)) => {
            *pipeline = p;
            *rebuilds += 1;
            if sink.enabled() {
                pipeline.set_trace(sink.clone());
            }
            Ok(())
        }
        Ok(Err(e)) => Err(e.context(format!(
            "rebuilding worker {worker_id}'s pipeline to retry shard {shard}"
        ))),
        Err(payload) => Err(anyhow!(
            "worker {worker_id} panicked rebuilding its pipeline to \
             retry shard {shard}: {}",
            panic_msg(&payload)
        )),
    }
}

/// Run one shard under the pool's fault policy. Every attempt goes
/// through `catch_unwind`, so a panicking kernel is handled exactly like
/// an `Err`.
///
/// The execution shape depends on the policy:
///
/// * `FailFast` and the first `Retry` attempt run the whole slice in one
///   `run_shard` call — the fault-free path pays one `catch_unwind`
///   frame and allocates nothing.
/// * After a `Retry` failure the pipeline is rebuilt (a panic may have
///   unwound it mid-reset) and the slice is **narrowed**: each region is
///   re-run alone, so only the regions that keep failing pay further
///   retries instead of the whole shard. Region boundaries are sanctioned
///   shard boundaries, so the per-region re-run is bit-identical to the
///   batched one (the same invariance `--shard-regions` relies on).
/// * `Quarantine` runs per-region slices from the start: the failing
///   region is identified on its first attempt, surviving regions keep
///   their outputs, and only the lost ordinals are dropped. A panicked
///   region's pipeline is rebuilt before the next region; if that
///   rebuild *also* fails the worker returns [`Guarded::Retired`].
#[allow(clippy::too_many_arguments)]
fn run_shard_guarded<F: PipelineFactory>(
    factory: &F,
    worker_id: usize,
    pipeline: &mut F::Worker,
    rebuilds: &mut u64,
    shard: usize,
    regions: &[F::In],
    policy: FaultPolicy,
    sink: &TraceSink,
    pulse: Option<&Pulse>,
) -> Result<Guarded<F::Out>> {
    if matches!(policy, FaultPolicy::Quarantine) {
        return run_shard_quarantine(factory, worker_id, pipeline, rebuilds, shard, regions, sink);
    }

    // Whole-slice first attempt (FailFast's only one).
    pipeline.begin_shard(shard);
    let f0 = sink.now_ns();
    let err = match catch_unwind(AssertUnwindSafe(|| pipeline.run_shard(regions))) {
        Ok(Ok(out)) => {
            return Ok(Guarded::Done {
                out,
                retries: 0,
                rerun_regions: 0,
            });
        }
        Ok(Err(e)) => e,
        Err(payload) => anyhow!(
            "shard {shard} panicked on worker {worker_id} (attempt 1): {}",
            panic_msg(&payload)
        ),
    };
    sink.record(
        f0,
        sink.now_ns(),
        TraceEvent::Fault {
            shard: shard as u32,
            attempt: 1,
        },
    );
    let FaultPolicy::Retry { backoff, .. } = policy else {
        return Err(err);
    };
    let max_attempts = policy.max_attempts();
    if max_attempts <= 1 {
        return Err(err.context(format!(
            "shard {shard} still failing after {max_attempts} attempt(s)"
        )));
    }
    sleep_backoff(backoff, pulse);
    let r0 = sink.now_ns();
    rebuild_pipeline(factory, worker_id, pipeline, rebuilds, shard, sink)?;
    sink.record(
        r0,
        sink.now_ns(),
        TraceEvent::Retry {
            shard: shard as u32,
            attempt: 1,
        },
    );
    let mut attempt = 2u32;

    // Narrowing pass: re-run each region alone so only the failing
    // part(s) pay further retries. `attempt` stays shard-global, so the
    // retry budget bounds total attempts exactly as before.
    let mut outputs = Vec::new();
    let mut metrics = PipelineMetrics::default();
    let mut invocations = 0u64;
    let mut rerun_regions = 0u64;
    for (i, region) in regions.iter().enumerate() {
        let part = i as u32;
        loop {
            pipeline.begin_shard(shard);
            rerun_regions += 1;
            let f0 = sink.now_ns();
            let err = match catch_unwind(AssertUnwindSafe(|| {
                pipeline.run_shard(std::slice::from_ref(region))
            })) {
                Ok(Ok(out)) => {
                    outputs.extend(out.outputs);
                    metrics.merge(&out.metrics);
                    invocations += out.invocations;
                    break;
                }
                Ok(Err(e)) => e,
                Err(payload) => anyhow!(
                    "part {part} of shard {shard} panicked on worker {worker_id} \
                     (attempt {attempt}): {}",
                    panic_msg(&payload)
                ),
            };
            sink.record(
                f0,
                sink.now_ns(),
                TraceEvent::PartFault {
                    shard: shard as u32,
                    part,
                    attempt,
                },
            );
            if attempt >= max_attempts {
                return Err(err.context(format!(
                    "shard {shard} still failing after {max_attempts} attempt(s)"
                )));
            }
            sleep_backoff(backoff, pulse);
            let r0 = sink.now_ns();
            rebuild_pipeline(factory, worker_id, pipeline, rebuilds, shard, sink)?;
            sink.record(
                r0,
                sink.now_ns(),
                TraceEvent::PartRetry {
                    shard: shard as u32,
                    part,
                    attempt,
                },
            );
            attempt += 1;
        }
    }
    Ok(Guarded::Done {
        out: ShardOutput {
            outputs,
            metrics,
            invocations,
        },
        retries: attempt - 1,
        rerun_regions,
    })
}

/// The `Quarantine` execution shape: per-region slices from the start,
/// so a poisoned region is pinpointed on its first attempt and its
/// healthy neighbours keep their outputs (the salvage that
/// [`merge::RegionFolder`] turns into a [`merge::PartialRegion`] ledger
/// for split regions). Never retries — each region gets exactly one
/// shot, matching the policy's one-attempt contract.
fn run_shard_quarantine<F: PipelineFactory>(
    factory: &F,
    worker_id: usize,
    pipeline: &mut F::Worker,
    rebuilds: &mut u64,
    shard: usize,
    regions: &[F::In],
    sink: &TraceSink,
) -> Result<Guarded<F::Out>> {
    let mut outputs = Vec::new();
    let mut metrics = PipelineMetrics::default();
    let mut invocations = 0u64;
    let mut lost: Vec<u32> = Vec::new();
    let mut first_error: Option<String> = None;
    for (i, region) in regions.iter().enumerate() {
        let part = i as u32;
        pipeline.begin_shard(shard);
        let f0 = sink.now_ns();
        let (err, panicked) = match catch_unwind(AssertUnwindSafe(|| {
            pipeline.run_shard(std::slice::from_ref(region))
        })) {
            Ok(Ok(out)) => {
                outputs.extend(out.outputs);
                metrics.merge(&out.metrics);
                invocations += out.invocations;
                continue;
            }
            Ok(Err(e)) => (e, false),
            Err(payload) => (
                anyhow!(
                    "part {part} of shard {shard} panicked on worker {worker_id}: {}",
                    panic_msg(&payload)
                ),
                true,
            ),
        };
        sink.record(
            f0,
            sink.now_ns(),
            TraceEvent::PartFault {
                shard: shard as u32,
                part,
                attempt: 1,
            },
        );
        lost.push(part);
        if first_error.is_none() {
            first_error = Some(format!("{err:#}"));
        }
        // A panic may have unwound the pipeline mid-reset: replace it
        // before touching the remaining regions. A rebuild that fails
        // too leaves this worker pipeline-less — retire it rather than
        // aborting the run (graceful N-1 degradation).
        if panicked {
            if let Err(e) = rebuild_pipeline(factory, worker_id, pipeline, rebuilds, shard, sink) {
                return Ok(Guarded::Retired {
                    error: format!("{e:#}"),
                });
            }
        }
    }
    if lost.is_empty() {
        return Ok(Guarded::Done {
            out: ShardOutput {
                outputs,
                metrics,
                invocations,
            },
            retries: 0,
            rerun_regions: 0,
        });
    }
    Ok(Guarded::Quarantined {
        out: ShardOutput {
            outputs,
            metrics,
            invocations,
        },
        lost,
        error: first_error.unwrap_or_else(|| "quarantined".into()),
        attempts: 1,
    })
}

/// How a materialized run hands out shard indices.
enum ShardClaimer {
    /// Legacy single shared cursor (kept for the `bench ingest` ablation).
    Cursor { next: AtomicUsize, len: usize },
    /// Per-worker deques, LIFO-local / FIFO-steal.
    Deques(StealQueues<usize>),
}

impl ShardClaimer {
    fn for_plan(mode: ClaimMode, threads: usize, shards: usize) -> ShardClaimer {
        match mode {
            ClaimMode::Cursor => ShardClaimer::Cursor {
                next: AtomicUsize::new(0),
                len: shards,
            },
            ClaimMode::Steal | ClaimMode::NoSteal => {
                let queues = StealQueues::new(threads, mode == ClaimMode::Steal);
                for shard in 0..shards {
                    queues.push(shard);
                }
                // the full plan is loaded: close now so claims never block
                queues.close();
                ShardClaimer::Deques(queues)
            }
        }
    }

    /// `(shard index, stolen, claim wait)`, or `None` when the plan is
    /// exhausted. Materialized queues are loaded and closed before
    /// workers start, so claims never block, the wait is zero, and the
    /// watchdog `deadline` is a formality.
    fn next(&self, worker: usize, deadline: Duration) -> Result<Option<(usize, bool, Duration)>> {
        match self {
            ShardClaimer::Cursor { next, len } => {
                let shard = next.fetch_add(1, Ordering::Relaxed);
                Ok((shard < *len).then_some((shard, false, Duration::ZERO)))
            }
            ShardClaimer::Deques(queues) => Ok(match queues.claim(worker, deadline)? {
                Claim::Task {
                    work,
                    stolen,
                    waited,
                } => Some((work, stolen, waited)),
                Claim::Done => None,
            }),
        }
    }

    /// The pool pulse behind the deques, if this claimer has one (the
    /// legacy cursor does not) — lets retry backoffs beat the watchdog.
    fn pulse(&self) -> Option<std::sync::Arc<Pulse>> {
        match self {
            ShardClaimer::Cursor { .. } => None,
            ShardClaimer::Deques(queues) => Some(queues.pulse()),
        }
    }

    /// Hand a retiring worker's unfinished shard back to the pool.
    /// Returns `false` when no surviving sibling can claim it (cursor
    /// claimer, stealing disabled, or this was the last live worker) —
    /// the caller must then abort by name instead.
    fn retire(&self, shard: usize) -> bool {
        match self {
            ShardClaimer::Cursor { .. } => false,
            ShardClaimer::Deques(queues) => queues.push_for_retirement(shard),
        }
    }
}

/// A materialized run's full yield: shard results (in shard order),
/// per-lane traces (empty unless the pool was traced), and the
/// wall-clock seconds of the claim/execute phase — measured from the
/// post-prewarm barrier, so pipeline construction is excluded.
#[derive(Debug)]
pub struct PoolRun<T> {
    /// Per-shard results, in shard order.
    pub results: Vec<ShardResult<T>>,
    /// Per-worker drained trace lanes, sorted by worker id.
    pub traces: Vec<WorkerTrace>,
    /// Seconds spent claiming and executing shards (prewarm excluded).
    pub elapsed: f64,
    /// Every worker's metrics lane, exact-folded; `Some` only when the
    /// pool was metered ([`WorkerPool::with_metrics`]). Materialized
    /// runs have no submit/emit stamps, so the end-to-end histogram and
    /// flow counters stay zero here.
    pub metrics: Option<LaneMetrics>,
    /// Ids of workers that retired mid-run (a `Quarantine` rebuild
    /// failed, their remaining work was re-dealt to survivors), sorted.
    /// Empty on every healthy run.
    pub retired: Vec<usize>,
}

/// A streaming run's yield: results went to the caller's `emit` sink,
/// so only the traces (workers plus the [`DRIVER_LANE`]) and the timed
/// ingest/execute/merge phase remain.
#[derive(Debug)]
pub struct StreamRun {
    /// Drained trace lanes: workers sorted by id, driver lane last.
    pub traces: Vec<WorkerTrace>,
    /// Seconds from the post-prewarm barrier to the last worker join.
    pub elapsed: f64,
    /// Every lane's metrics (workers + the ingest driver's
    /// submit/stall/emit lane), exact-folded; `Some` only when the pool
    /// was metered ([`WorkerPool::with_metrics`]).
    pub metrics: Option<LaneMetrics>,
    /// Ids of workers that retired mid-run (a `Quarantine` rebuild
    /// failed, their unfinished shard was re-dealt to survivors),
    /// sorted. Empty on every healthy run.
    pub retired: Vec<usize>,
}

/// Default watchdog deadline for the pool's blocking waits: long enough
/// that only a genuinely stuck pool — a never-completing shard, a lost
/// wake-up — trips it, never a slow-but-healthy run.
pub const DEFAULT_WATCHDOG: Duration = Duration::from_secs(60);

/// Fixed-size pool of pipeline workers over a shard plan or region
/// stream.
#[derive(Debug, Clone, Copy)]
pub struct WorkerPool {
    workers: usize,
    claim: ClaimMode,
    trace: Option<TraceSpec>,
    metrics: Option<MetricsSpec>,
    progress: Option<Duration>,
    fault: FaultPolicy,
    watchdog: Duration,
}

impl WorkerPool {
    /// Create a pool with `workers` threads and default settings.
    pub fn new(workers: usize) -> WorkerPool {
        WorkerPool {
            workers,
            claim: ClaimMode::default(),
            trace: None,
            metrics: None,
            progress: None,
            fault: FaultPolicy::default(),
            watchdog: DEFAULT_WATCHDOG,
        }
    }

    /// Override the claim discipline (default: [`ClaimMode::Steal`]).
    pub fn with_claim(mut self, claim: ClaimMode) -> WorkerPool {
        self.claim = claim;
        self
    }

    /// Trace this pool's runs: every worker (and the streaming driver)
    /// builds a [`TraceSink`] from `spec` and the collected lanes come
    /// back in [`PoolRun::traces`]/[`StreamRun::traces`]. `None`
    /// (default) disables tracing — the hot path then pays one branch
    /// per event site and nothing else.
    pub fn with_trace(mut self, spec: Option<TraceSpec>) -> WorkerPool {
        self.trace = spec;
        self
    }

    /// Meter this pool's runs: every worker (and the streaming driver)
    /// builds a [`MetricsHub`] from `spec`, and the exact-folded
    /// [`LaneMetrics`] come back in
    /// [`PoolRun::metrics`]/[`StreamRun::metrics`]. Recording never
    /// influences scheduling — metered runs are bit-identical to
    /// unmetered ones. `None` (default) disables metrics; every record
    /// site then costs one branch and reads no clock. When the run is
    /// also traced, hand both specs the same epoch so stamps line up.
    pub fn with_metrics(mut self, spec: Option<MetricsSpec>) -> WorkerPool {
        self.metrics = spec;
        self
    }

    /// Print a machine-parseable progress heartbeat line every `every`
    /// during streaming runs, rendered by the ingest driver from the
    /// same loop that beats the watchdog [`Pulse`](super::steal::Pulse)
    /// — no extra thread. Requires metrics ([`WorkerPool::with_metrics`])
    /// for the so-far quantiles; without them the heartbeat stays
    /// silent. Materialized runs have no driver loop and never tick.
    pub fn with_progress(mut self, every: Option<Duration>) -> WorkerPool {
        self.progress = every;
        self
    }

    /// What happens when a shard panics or errors (default:
    /// [`FaultPolicy::FailFast`]). See [`super::fault`] for the policy
    /// semantics and the determinism argument for `Retry`.
    pub fn with_fault(mut self, fault: FaultPolicy) -> WorkerPool {
        self.fault = fault;
        self
    }

    /// Watchdog deadline for every blocking wait in the pool (default
    /// [`DEFAULT_WATCHDOG`]). Pick it longer than the longest legitimate
    /// shard (and, for streaming, the longest gap between source
    /// regions): the deadline only trips after that long with **no**
    /// progress anywhere in the pool.
    pub fn with_watchdog(mut self, deadline: Duration) -> WorkerPool {
        self.watchdog = deadline;
        self
    }

    /// Worker thread count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run every shard of `plan` over `stream`, one worker pipeline per
    /// thread. Returns all shard results sorted back into shard order.
    /// Convenience wrapper over [`WorkerPool::run_collect`].
    pub fn run<F: PipelineFactory>(
        &self,
        factory: &F,
        stream: &[F::In],
        plan: &ShardPlan,
    ) -> Result<Vec<ShardResult<F::Out>>> {
        Ok(self.run_collect(factory, stream, plan)?.results)
    }

    /// [`WorkerPool::run`] plus the run's traces and post-prewarm
    /// elapsed time: every worker builds its pipeline eagerly, all
    /// workers (and the caller) rendezvous on a barrier, and only then
    /// does the timed claim/execute phase begin.
    ///
    /// With one worker (or one shard) everything runs inline on the
    /// calling thread — no pool overhead, no barrier, bit-identical to
    /// a plain single-threaded run (construction still happens before
    /// the claim phase's clock starts).
    pub fn run_collect<F: PipelineFactory>(
        &self,
        factory: &F,
        stream: &[F::In],
        plan: &ShardPlan,
    ) -> Result<PoolRun<F::Out>> {
        ensure!(
            self.workers >= 1,
            "worker pool misconfigured: workers = 0 (need at least one worker thread)"
        );
        if plan.is_empty() {
            return Ok(PoolRun {
                results: Vec::new(),
                traces: Vec::new(),
                elapsed: 0.0,
                metrics: self.metrics.map(|_| LaneMetrics::default()),
                retired: Vec::new(),
            });
        }
        let threads = self.workers.min(plan.len());
        let claimer = ShardClaimer::for_plan(self.claim, threads, plan.len());
        let retired: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        let stop = AtomicBool::new(false);
        let traces: Mutex<Vec<WorkerTrace>> = Mutex::new(Vec::new());
        let lanes: Mutex<LaneMetrics> = Mutex::new(LaneMetrics::default());
        let spec = self.trace;
        let mspec = self.metrics;
        let (fault, watchdog) = (self.fault, self.watchdog);
        // prewarm rendezvous: absent on the inline path, where the
        // caller IS the worker and a barrier would deadlock
        let barrier = (threads > 1).then(|| Barrier::new(threads + 1));

        // returns this worker's results plus its own claim-phase
        // seconds (used for elapsed on the inline path only)
        let worker_loop = |worker_id: usize| -> Result<(Vec<ShardResult<F::Out>>, f64)> {
            let _guard = StopOnPanic(&stop);
            let sink = match &spec {
                Some(s) => s.sink(),
                None => TraceSink::default(),
            };
            let hub = match &mspec {
                Some(s) => s.hub(),
                None => MetricsHub::disabled(),
            };
            // eager build; an error or panic must still reach the
            // barrier, or the coordinating thread would wait forever
            let p0 = sink.now_ns();
            let built = catch_unwind(AssertUnwindSafe(|| factory.make_worker(worker_id)));
            let p1 = sink.now_ns();
            if let Some(b) = &barrier {
                b.wait();
            }
            let mut pipeline = match built {
                Ok(Ok(p)) => p,
                Ok(Err(e)) => {
                    stop.store(true, Ordering::Relaxed);
                    return Err(e.context(format!("building pipeline for worker {worker_id}")));
                }
                Err(payload) => {
                    stop.store(true, Ordering::Relaxed);
                    return Err(anyhow!(
                        "worker {worker_id} panicked during prewarm: {}",
                        panic_msg(&payload)
                    ));
                }
            };
            if sink.enabled() {
                sink.record(p0, p1, TraceEvent::Prewarm);
                pipeline.set_trace(sink.clone());
            }
            let claim_t0 = Instant::now();
            let pulse = claimer.pulse();
            let mut done = Vec::new();
            let mut rebuilds = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let next = match claimer.next(worker_id, watchdog) {
                    Ok(n) => n,
                    Err(e) => {
                        stop.store(true, Ordering::Relaxed);
                        return Err(e);
                    }
                };
                let Some((shard, stolen, waited)) = next else {
                    break;
                };
                if hub.enabled() && !waited.is_zero() {
                    hub.record_idle(waited.as_nanos() as u64);
                }
                let range = plan.range(shard);
                let s0 = sink.now_ns();
                let t0 = Instant::now();
                let guarded = run_shard_guarded(
                    factory,
                    worker_id,
                    &mut pipeline,
                    &mut rebuilds,
                    shard,
                    &stream[range.clone()],
                    fault,
                    &sink,
                    pulse.as_deref(),
                );
                let took = t0.elapsed();
                match guarded {
                    Ok(Guarded::Done {
                        out,
                        retries,
                        rerun_regions,
                    }) => {
                        sink.record(
                            s0,
                            sink.now_ns(),
                            TraceEvent::Shard {
                                shard: shard as u32,
                                regions: range.len() as u32,
                                stolen,
                            },
                        );
                        if hub.enabled() {
                            // materialized shards never queue: wait is 0
                            hub.record_shard(range.len() as u64, stolen, 0, took.as_nanos() as u64);
                            hub.record_faults(u64::from(retries), u64::from(retries));
                        }
                        done.push(ShardResult {
                            shard,
                            worker: worker_id,
                            regions: range.len(),
                            stolen,
                            outputs: out.outputs,
                            metrics: out.metrics,
                            invocations: out.invocations,
                            elapsed: took.as_secs_f64(),
                            pipelines_built: pipeline.pipelines_built() + rebuilds,
                            retries,
                            fault: None,
                            lost: Vec::new(),
                            rerun_regions,
                            submit_ns: 0,
                        });
                    }
                    Ok(Guarded::Quarantined {
                        out,
                        lost,
                        error,
                        attempts,
                    }) => {
                        if hub.enabled() {
                            hub.record_shard(range.len() as u64, stolen, 0, took.as_nanos() as u64);
                            hub.record_faults(lost.len() as u64, u64::from(attempts - 1));
                        }
                        done.push(ShardResult {
                            shard,
                            worker: worker_id,
                            regions: range.len(),
                            stolen,
                            outputs: out.outputs,
                            metrics: out.metrics,
                            invocations: out.invocations,
                            elapsed: took.as_secs_f64(),
                            pipelines_built: pipeline.pipelines_built() + rebuilds,
                            retries: attempts - 1,
                            fault: Some(error),
                            lost,
                            rerun_regions: 0,
                            submit_ns: 0,
                        });
                    }
                    Ok(Guarded::Retired { error }) => {
                        if claimer.retire(shard) {
                            lock_ignore_poison(&retired).push(worker_id);
                            break;
                        }
                        stop.store(true, Ordering::Relaxed);
                        return Err(anyhow!(
                            "worker {worker_id} lost its pipeline on shard {shard} and no \
                             surviving worker can take over (stealing disabled or pool of \
                             one): {error}"
                        ));
                    }
                    Err(e) => {
                        stop.store(true, Ordering::Relaxed);
                        return Err(e.context(format!(
                            "worker {worker_id} failed on shard {shard}"
                        )));
                    }
                }
            }
            if sink.enabled() {
                let (records, dropped) = sink.take();
                traces.lock().unwrap_or_else(|e| e.into_inner()).push(WorkerTrace {
                    worker: worker_id,
                    records,
                    dropped,
                });
            }
            if hub.enabled() {
                lock_ignore_poison(&lanes).merge(&hub.take());
            }
            Ok((done, claim_t0.elapsed().as_secs_f64()))
        };

        let (per_thread, elapsed): (Vec<Result<(Vec<ShardResult<F::Out>>, f64)>>, f64) =
            if threads <= 1 {
                let r = worker_loop(0);
                let elapsed = match &r {
                    Ok((_, secs)) => *secs,
                    Err(_) => 0.0,
                };
                (vec![r], elapsed)
            } else {
                std::thread::scope(|scope| {
                    let worker_loop = &worker_loop;
                    let handles: Vec<_> = (0..threads)
                        .map(|wid| scope.spawn(move || worker_loop(wid)))
                        .collect();
                    // all workers have built their pipelines once this
                    // returns: the measured region starts here
                    barrier.as_ref().expect("threaded path has a barrier").wait();
                    let t0 = Instant::now();
                    let per_thread: Vec<_> = handles
                        .into_iter()
                        .map(|h| {
                            h.join().unwrap_or_else(|payload| {
                                Err(anyhow!("worker thread panicked: {}", panic_msg(&payload)))
                            })
                        })
                        .collect();
                    (per_thread, t0.elapsed().as_secs_f64())
                })
            };

        let mut all = Vec::with_capacity(plan.len());
        for r in per_thread {
            all.extend(r?.0);
        }
        all.sort_by_key(|r| r.shard);
        ensure!(
            all.len() == plan.len(),
            "pool completed {} of {} shards",
            all.len(),
            plan.len()
        );
        let mut trace_lanes = traces.into_inner().unwrap_or_else(|e| e.into_inner());
        trace_lanes.sort_by_key(|t| t.worker);
        let metrics =
            mspec.map(|_| lanes.into_inner().unwrap_or_else(|e| e.into_inner()));
        let mut retired = retired.into_inner().unwrap_or_else(|e| e.into_inner());
        retired.sort_unstable();
        Ok(PoolRun {
            results: all,
            traces: trace_lanes,
            elapsed,
            metrics,
            retired,
        })
    }

    /// Streaming execution: pull regions from `source` on the calling
    /// thread, cut shards on the fly against `ingest`'s in-flight budget,
    /// execute them on `self.workers` threads with work stealing, and
    /// hand each merged [`ShardResult`] to `emit` **in stream order, as
    /// soon as its prefix is complete**.
    ///
    /// Backpressure: while `submitted − emitted` regions would exceed
    /// [`IngestPolicy::buffer_regions`], the driver stops pulling from
    /// the source and sleeps until workers catch up, so in-flight payload
    /// is bounded by the budget (+ one open shard) regardless of stream
    /// length. Shard containers are recycled through a [`ContainerPool`],
    /// making steady-state ingest allocation-free.
    ///
    /// [`ClaimMode::Cursor`] has no streaming form (there is no global
    /// plan to index); it runs as [`ClaimMode::Steal`].
    /// Convenience wrapper over [`WorkerPool::run_stream_collect`].
    pub fn run_stream<F, S, K>(
        &self,
        factory: &F,
        source: S,
        ingest: &IngestPolicy,
        emit: K,
    ) -> Result<()>
    where
        F: PipelineFactory,
        F::In: Send,
        S: RegionSource<Region = F::In>,
        K: FnMut(ShardResult<F::Out>) -> Result<()>,
    {
        self.run_stream_collect(factory, source, ingest, emit)
            .map(|_| ())
    }

    /// [`WorkerPool::run_stream`] plus the run's traces and post-prewarm
    /// elapsed time. All worker pipelines are built eagerly behind a
    /// barrier before the driver starts pulling from the source, so the
    /// measured region covers ingest + execute + merge but not graph
    /// construction. The driver's own ingest/merge events land in an
    /// extra [`DRIVER_LANE`] trace lane.
    pub fn run_stream_collect<F, S, K>(
        &self,
        factory: &F,
        mut source: S,
        ingest: &IngestPolicy,
        emit: K,
    ) -> Result<StreamRun>
    where
        F: PipelineFactory,
        F::In: Send,
        S: RegionSource<Region = F::In>,
        K: FnMut(ShardResult<F::Out>) -> Result<()>,
    {
        ensure!(
            self.workers >= 1,
            "worker pool misconfigured: workers = 0 (need at least one worker thread)"
        );
        ensure!(
            ingest.buffer_regions >= 1,
            "worker pool misconfigured: ingest buffer_regions = 0 (the in-flight \
             budget must admit at least one region)"
        );
        // Also capped here (not just at ExecConfig::validate): the budget
        // pre-sizes the reassembly ring below, so a unit-mistake value
        // must be a named error, never a giant allocation or an overflow
        // at `budget + 1`.
        ensure!(
            ingest.buffer_regions <= super::runner::MAX_INGEST_BUFFER,
            "worker pool misconfigured: ingest buffer_regions = {} exceeds the \
             sanity cap {} (the budget is counted in regions, not bytes)",
            ingest.buffer_regions,
            super::runner::MAX_INGEST_BUFFER
        );
        let threads = self.workers;
        let budget = ingest.buffer_regions;
        let granule = ingest.effective_shard_regions(threads);
        let queues: StealQueues<ShardTask<F::In>> =
            StealQueues::new(threads, self.claim != ClaimMode::NoSteal);
        // completions share the queues' pulse, so a completing shard
        // defers an idle sibling's claim watchdog (and vice versa)
        let completion: CompletionBuffer<ShardResult<F::Out>> =
            CompletionBuffer::new().with_pulse(queues.pulse());
        let containers: ContainerPool<F::In> = ContainerPool::new();
        let stop = AtomicBool::new(false);
        let retired: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        let traces: Mutex<Vec<WorkerTrace>> = Mutex::new(Vec::new());
        let spec = self.trace;
        // every worker + the driver rendezvous after prewarm
        let barrier = Barrier::new(threads + 1);
        // Created on this thread and cloned into the driver inside the
        // scope (TraceSink is Rc-based and never crosses threads; the
        // scope closure runs right here).
        let driver_sink = match &spec {
            Some(s) => s.sink(),
            None => TraceSink::default(),
        };
        // The driver's metrics lane (Rc-based like the sink: it never
        // leaves this thread). The stream merger shares it so in-order
        // releases stamp emit time; workers get their own hubs.
        let driver_hub = match &self.metrics {
            Some(s) => s.hub(),
            None => MetricsHub::disabled(),
        };
        let lanes: Mutex<LaneMetrics> = Mutex::new(LaneMetrics::default());

        let pool = *self;
        let elapsed = std::thread::scope(|scope| -> Result<f64> {
            let handles: Vec<_> = (0..threads)
                .map(|wid| {
                    let (queues, completion) = (&queues, &completion);
                    let (containers, stop) = (&containers, &stop);
                    let (barrier, traces) = (&barrier, &traces);
                    let (lanes, retired) = (&lanes, &retired);
                    scope.spawn(move || {
                        stream_worker(
                            wid, factory, pool, queues, completion, containers, stop, barrier,
                            traces, lanes, retired,
                        )
                    })
                })
                .collect();

            let mut driver = StreamDriver {
                queues: &queues,
                completion: &completion,
                merger: StreamMerger::with_capacity(budget + 1).with_hub(driver_hub.clone()),
                emit,
                inbox: Vec::new(),
                budget,
                submitted_regions: 0,
                submitted_shards: 0,
                emitted_regions: 0,
                emitted_shards: 0,
                sink: driver_sink.clone(),
                hub: driver_hub.clone(),
                heartbeat: pool.progress.filter(|_| driver_hub.enabled()).map(Heartbeat::new),
                hb_stolen: 0,
                hb_faults: 0,
                watchdog: self.watchdog,
                fault: pool.fault,
            };
            let mut planner: IngestPlanner<F::In> = IngestPlanner::new(granule);
            // all pipelines are built once this returns; the measured
            // region (and the first source pull) starts here
            barrier.wait();
            let t0 = Instant::now();
            let fed = drive_ingest(factory, &mut source, &mut planner, &containers, &mut driver);
            let elapsed = t0.elapsed().as_secs_f64();

            // Shut the pool down whether ingest finished or aborted.
            stop.store(true, Ordering::Relaxed);
            queues.close();
            let mut first_err: Option<anyhow::Error> = None;
            for h in handles {
                if let Err(payload) = h.join() {
                    first_err.get_or_insert_with(|| {
                        anyhow!("worker thread panicked: {}", panic_msg(&payload))
                    });
                }
            }
            // A detailed panic message beats the driver's generic
            // "worker thread panicked" wake-up error; otherwise the
            // driver error is the root cause.
            match (fed, first_err) {
                (Err(e), Some(p)) if e.to_string().contains("panicked") => Err(p),
                (Err(e), _) => Err(e),
                (Ok(()), Some(p)) => Err(p),
                (Ok(()), None) => Ok(elapsed),
            }
        })?;

        let mut trace_lanes = traces.into_inner().unwrap_or_else(|e| e.into_inner());
        if driver_sink.enabled() {
            let (records, dropped) = driver_sink.take();
            trace_lanes.push(WorkerTrace {
                worker: DRIVER_LANE,
                records,
                dropped,
            });
        }
        trace_lanes.sort_by_key(|t| t.worker);
        // Fold the driver lane (submit/emit/e2e/stall accounting) into the
        // worker lanes; merge order is irrelevant because the fold is
        // commutative.
        if driver_hub.enabled() {
            lock_ignore_poison(&lanes).merge(&driver_hub.take());
        }
        let metrics = self
            .metrics
            .map(|_| lanes.into_inner().unwrap_or_else(|e| e.into_inner()));
        let mut retired = retired.into_inner().unwrap_or_else(|e| e.into_inner());
        retired.sort_unstable();
        Ok(StreamRun {
            traces: trace_lanes,
            elapsed,
            metrics,
            retired,
        })
    }
}

/// The ingest side of [`WorkerPool::run_stream`]: source → planner →
/// deques, with completions merged and emitted opportunistically.
fn drive_ingest<F, S, K>(
    factory: &F,
    source: &mut S,
    planner: &mut IngestPlanner<F::In>,
    containers: &ContainerPool<F::In>,
    driver: &mut StreamDriver<'_, F::In, F::Out, K>,
) -> Result<()>
where
    F: PipelineFactory,
    F::In: Send,
    S: RegionSource<Region = F::In>,
    K: FnMut(ShardResult<F::Out>) -> Result<()>,
{
    loop {
        // return emptied shard containers to the planner (the
        // steady-state zero-allocation loop) and emit whatever is ready
        while let Some(container) = containers.take() {
            planner.recycle(container);
        }
        driver.pump()?;

        let Some(region) = pull_region(source, driver)? else {
            break;
        };
        // the driver is alive and pulling: beat the pulse so worker
        // claim watchdogs don't fire across a slow source gap
        driver.queues.beat();
        let weight = factory.weight(&region);
        if let Some(task) = planner.push_region(region, weight) {
            driver.submit(task)?;
        }
    }
    // A fallible source (file reader, decoder) ends its stream on error
    // and reports it here: abort the run instead of merging a silently
    // short prefix as if it were the whole stream.
    source.close()?;
    if let Some(task) = planner.finish() {
        driver.submit(task)?;
    }
    // end of stream: no more work will be dealt; let idle workers exit
    driver.queues.close();
    driver.drain_rest()
}

/// One source pull under the pool's fault policy: a transient
/// [`RegionSource::try_next_region`] error is retried with the same
/// bounded backoff budget as a compute fault (the backoff beats the pool
/// pulse, so worker claim watchdogs never read a source retry as a
/// stall). Under `FailFast`/`Quarantine` — or once the budget is spent —
/// the error aborts ingest by name; a short prefix is never merged as if
/// it were the whole stream.
fn pull_region<S, I, O, K>(
    source: &mut S,
    driver: &mut StreamDriver<'_, I, O, K>,
) -> Result<Option<I>>
where
    S: RegionSource<Region = I>,
    K: FnMut(ShardResult<O>) -> Result<()>,
{
    let FaultPolicy::Retry { backoff, .. } = driver.fault else {
        return source.try_next_region();
    };
    let max_attempts = driver.fault.max_attempts();
    let mut attempt = 1u32;
    loop {
        let err = match source.try_next_region() {
            Ok(region) => return Ok(region),
            Err(e) => e,
        };
        if attempt >= max_attempts {
            return Err(err.context(format!(
                "ingest source still failing after {max_attempts} attempt(s)"
            )));
        }
        driver.hub.record_source_retry();
        let pulse = driver.queues.pulse();
        sleep_backoff(backoff, Some(&*pulse));
        driver.queues.beat();
        attempt += 1;
    }
}

/// Driver-side state for a streaming run: budget accounting, the ordered
/// reassembly window, and the emission sink.
struct StreamDriver<'s, I, O, K> {
    queues: &'s StealQueues<ShardTask<I>>,
    completion: &'s CompletionBuffer<ShardResult<O>>,
    merger: StreamMerger<O>,
    emit: K,
    inbox: Vec<ShardResult<O>>,
    budget: usize,
    submitted_regions: usize,
    submitted_shards: usize,
    emitted_regions: usize,
    emitted_shards: usize,
    sink: TraceSink,
    // Driver-side metrics lane: submit stamps, backpressure stalls,
    // in-flight peaks; the merger shares the same hub for emit latency.
    hub: MetricsHub,
    // Progress heartbeat, present only when metrics are live; ticks from
    // the driver's own pump/absorb loop — no extra thread.
    heartbeat: Option<Heartbeat>,
    // Steal/fault tallies observed on completed shards, kept here (not in
    // the hub) so heartbeat lines don't double-count the worker lanes.
    hb_stolen: u64,
    hb_faults: u64,
    watchdog: Duration,
    // The pool's fault policy, echoed here so ingest-side source pulls
    // share the compute retry budget (`Retry` retries transient source
    // errors; `FailFast`/`Quarantine` propagate them immediately).
    fault: FaultPolicy,
}

impl<I, O, K> StreamDriver<'_, I, O, K>
where
    K: FnMut(ShardResult<O>) -> Result<()>,
{
    /// Non-blocking: absorb any completed shards and emit the ready
    /// prefix.
    fn pump(&mut self) -> Result<()> {
        if let Some(err) = self.completion.drain_into(&mut self.inbox) {
            return Err(err);
        }
        self.absorb()
    }

    /// Blocking: sleep until at least one completion (or a failure)
    /// arrives, then absorb. Bounded by the watchdog; on expiry the
    /// driver annotates the stall with what it alone knows — how many
    /// shards are in flight and which stream slot the merge is stuck on.
    fn pump_wait(&mut self) -> Result<()> {
        match self.completion.wait_drain_into(&mut self.inbox, self.watchdog) {
            Ok(Some(err)) => return Err(err),
            Ok(None) => {}
            Err(stall) => {
                return Err(stall.context(format!(
                    "ingest driver gave up: {} shard(s) ({} region(s)) in flight, \
                     merge waiting on stream slot {}",
                    self.submitted_shards - self.emitted_shards,
                    self.submitted_regions - self.emitted_regions,
                    self.merger.next_expected(),
                )));
            }
        }
        self.absorb()
    }

    fn absorb(&mut self) -> Result<()> {
        for r in self.inbox.drain(..) {
            self.merger.accept(r)?;
        }
        while let Some(r) = self.merger.pop_ready() {
            self.emitted_regions += r.regions;
            self.emitted_shards += 1;
            self.hb_stolen += u64::from(r.stolen);
            self.hb_faults += u64::from(r.retries) + u64::from(r.fault.is_some());
            if self.sink.enabled() {
                let t = self.sink.now_ns();
                self.sink.record(
                    t,
                    t,
                    TraceEvent::Emit {
                        shard: r.shard as u32,
                        regions: r.regions as u32,
                    },
                );
            }
            (self.emit)(r)?;
        }
        self.tick_heartbeat(false);
        Ok(())
    }

    /// Deal one shard into the deques, first waiting out the in-flight
    /// budget (backpressure). An oversized shard (more regions than the
    /// whole budget) is admitted alone, once everything before it has
    /// drained.
    fn submit(&mut self, mut task: ShardTask<I>) -> Result<()> {
        let regions = task.regions.len();
        let mut stalled = false;
        let mut stall_t0 = 0u64;
        let mut stall_m0 = 0u64;
        loop {
            self.pump()?;
            let in_flight = self.submitted_regions - self.emitted_regions;
            if in_flight == 0 || in_flight + regions <= self.budget {
                break;
            }
            if !stalled {
                stalled = true;
                if self.sink.enabled() {
                    stall_t0 = self.sink.now_ns();
                }
                stall_m0 = self.hub.now_ns();
            }
            self.pump_wait()?;
        }
        if stalled {
            if self.sink.enabled() {
                let in_flight = self.submitted_regions - self.emitted_regions;
                self.sink.record(
                    stall_t0,
                    self.sink.now_ns(),
                    TraceEvent::Stall {
                        in_flight: in_flight as u32,
                    },
                );
            }
            if self.hub.enabled() {
                self.hub.record_stall(self.hub.now_ns().saturating_sub(stall_m0));
            }
        }
        self.submitted_regions += regions;
        self.submitted_shards += 1;
        if self.sink.enabled() {
            let t = self.sink.now_ns();
            self.sink.record(
                t,
                t,
                TraceEvent::Submit {
                    shard: task.index as u32,
                    regions: regions as u32,
                },
            );
        }
        if self.hub.enabled() {
            // Stamp against the shared epoch *after* backpressure clears:
            // end-to-end latency measures queue + service + reassembly,
            // not time spent parked at the admission gate.
            task.submit_ns = self.hub.now_ns();
            self.hub.record_submit(regions as u64);
            self.hub
                .note_in_flight((self.submitted_regions - self.emitted_regions) as u64);
        }
        self.queues.push(task);
        Ok(())
    }

    /// After the source is exhausted: wait for every submitted shard to
    /// come back and be emitted.
    fn drain_rest(&mut self) -> Result<()> {
        while self.emitted_shards < self.submitted_shards {
            self.pump_wait()?;
        }
        // Forced final tick: a progress-enabled run always prints at
        // least one line, and the last line always reads `done=true`.
        self.tick_heartbeat(true);
        Ok(())
    }

    /// Emit one progress line if the heartbeat interval has elapsed (or
    /// unconditionally when `done`). Runs on the driver's own loop — one
    /// `println!` per tick, so each line lands atomically even while the
    /// run is racing toward its final tables.
    fn tick_heartbeat(&mut self, done: bool) {
        let Some(hb) = self.heartbeat.as_mut() else {
            return;
        };
        let now = self.hub.now_ns();
        if !done && !hb.due(now) {
            return;
        }
        let (p50_ns, p99_ns) = self
            .hub
            .peek(|m| (m.e2e.quantile_ns(0.5), m.e2e.quantile_ns(0.99)))
            .unwrap_or((0, 0));
        let snap = ProgressSnapshot {
            elapsed_secs: now as f64 / 1e9,
            submitted_regions: self.submitted_regions as u64,
            emitted_regions: self.emitted_regions as u64,
            in_flight_regions: (self.submitted_regions - self.emitted_regions) as u64,
            p50_ns,
            p99_ns,
            stolen: self.hb_stolen,
            faults: self.hb_faults,
            done,
        };
        println!("{}", Heartbeat::render(&snap));
    }
}

/// One streaming worker thread: prewarm (build pipeline, rendezvous on
/// the barrier) → claim → run under the fault policy → recycle container
/// → report completion.
#[allow(clippy::too_many_arguments)]
fn stream_worker<F: PipelineFactory>(
    worker_id: usize,
    factory: &F,
    pool: WorkerPool,
    queues: &StealQueues<ShardTask<F::In>>,
    completion: &CompletionBuffer<ShardResult<F::Out>>,
    containers: &ContainerPool<F::In>,
    stop: &AtomicBool,
    barrier: &Barrier,
    traces: &Mutex<Vec<WorkerTrace>>,
    lanes: &Mutex<LaneMetrics>,
    retired: &Mutex<Vec<usize>>,
) {
    let current_shard = AtomicUsize::new(usize::MAX);
    let _guard = PanicSignal {
        stop,
        completion,
        worker: worker_id,
        shard: &current_shard,
    };
    let sink = match &pool.trace {
        Some(s) => s.sink(),
        None => TraceSink::default(),
    };
    let hub = match &pool.metrics {
        Some(s) => s.hub(),
        None => MetricsHub::disabled(),
    };
    // eager build; errors and panics must still reach the barrier, or
    // the driver (and the other workers) would wait forever
    let p0 = sink.now_ns();
    let built = catch_unwind(AssertUnwindSafe(|| factory.make_worker(worker_id)));
    let p1 = sink.now_ns();
    barrier.wait();
    let mut pipeline = match built {
        Ok(Ok(p)) => p,
        Ok(Err(e)) => {
            stop.store(true, Ordering::Relaxed);
            completion.fail(e.context(format!("building pipeline for worker {worker_id}")));
            return;
        }
        Err(payload) => {
            stop.store(true, Ordering::Relaxed);
            completion.fail(anyhow!(
                "worker {worker_id} panicked during prewarm: {}",
                panic_msg(&payload)
            ));
            return;
        }
    };
    if sink.enabled() {
        sink.record(p0, p1, TraceEvent::Prewarm);
        pipeline.set_trace(sink.clone());
    }
    let mut rebuilds = 0u64;
    let worker_pulse = queues.pulse();
    while !stop.load(Ordering::Relaxed) {
        let (task, stolen) = match queues.claim(worker_id, pool.watchdog) {
            Ok(Claim::Task {
                work,
                stolen,
                waited,
            }) => {
                if hub.enabled() && !waited.is_zero() {
                    hub.record_idle(waited.as_nanos() as u64);
                }
                (work, stolen)
            }
            Ok(Claim::Done) => break,
            Err(e) => {
                stop.store(true, Ordering::Relaxed);
                completion.fail(e.context(format!("worker {worker_id} starved waiting for work")));
                return;
            }
        };
        current_shard.store(task.index, Ordering::Relaxed);
        // Queue wait = claim stamp − submit stamp, both against the shared
        // epoch (the submit side stamped `task.submit_ns` after clearing
        // backpressure, so this isolates time spent parked in the deques).
        let queue_wait = hub.now_ns().saturating_sub(task.submit_ns);
        let s0 = sink.now_ns();
        let t0 = Instant::now();
        let guarded = run_shard_guarded(
            factory,
            worker_id,
            &mut pipeline,
            &mut rebuilds,
            task.index,
            &task.regions,
            pool.fault,
            &sink,
            Some(&*worker_pulse),
        );
        let (outputs, metrics, invocations, retries, fault, lost, rerun_regions) = match guarded {
            Ok(Guarded::Done {
                out,
                retries,
                rerun_regions,
            }) => {
                sink.record(
                    s0,
                    sink.now_ns(),
                    TraceEvent::Shard {
                        shard: task.index as u32,
                        regions: task.regions.len() as u32,
                        stolen,
                    },
                );
                (
                    out.outputs,
                    out.metrics,
                    out.invocations,
                    retries,
                    None,
                    Vec::new(),
                    rerun_regions,
                )
            }
            Ok(Guarded::Quarantined {
                out,
                lost,
                error,
                attempts,
            }) => (
                out.outputs,
                out.metrics,
                out.invocations,
                attempts - 1,
                Some(error),
                lost,
                0,
            ),
            Ok(Guarded::Retired { error }) => {
                // The worker has no pipeline left. Hand the whole task
                // back untouched — a survivor re-runs it from scratch,
                // bit-identically — and leave the pool quietly (the
                // PanicSignal guard sees no panic; traces and metrics
                // flush below like any orderly exit).
                current_shard.store(usize::MAX, Ordering::Relaxed);
                let index = task.index;
                if queues.push_for_retirement(task) {
                    lock_ignore_poison(retired).push(worker_id);
                    break;
                }
                stop.store(true, Ordering::Relaxed);
                completion.fail(anyhow!(
                    "worker {worker_id} lost its pipeline on streaming shard {index} and \
                     no surviving worker can take over (stealing disabled or pool of \
                     one): {error}"
                ));
                return;
            }
            Err(e) => {
                stop.store(true, Ordering::Relaxed);
                completion.fail(e.context(format!(
                    "worker {worker_id} failed on streaming shard {}",
                    task.index
                )));
                return;
            }
        };
        let took = t0.elapsed();
        if hub.enabled() {
            hub.record_shard(task.regions.len() as u64, stolen, queue_wait, took.as_nanos() as u64);
            // Done shards count one fault per retry; quarantined shards
            // one per lost region (`retries` is 0 there, so the terms
            // never double-count).
            hub.record_faults(
                u64::from(retries) + lost.len() as u64,
                u64::from(retries),
            );
        }
        let result = ShardResult {
            shard: task.index,
            worker: worker_id,
            regions: task.regions.len(),
            stolen,
            outputs,
            metrics,
            invocations,
            elapsed: took.as_secs_f64(),
            pipelines_built: pipeline.pipelines_built() + rebuilds,
            retries,
            fault,
            lost,
            rerun_regions,
            submit_ns: task.submit_ns,
        };
        // Hand each region back through the factory (a pooled factory
        // reclaims its element buffers for the ingest driver; the
        // default just drops), then recycle the emptied shard container
        // — quarantined shards included, so a placeholder result still
        // releases its budget and keeps the recycling loop closed.
        let mut regions = task.regions;
        for region in regions.drain(..) {
            factory.recycle_region(region);
        }
        containers.put(regions);
        completion.push(result);
        current_shard.store(usize::MAX, Ordering::Relaxed);
    }
    if sink.enabled() {
        let (records, dropped) = sink.take();
        traces.lock().unwrap_or_else(|e| e.into_inner()).push(WorkerTrace {
            worker: worker_id,
            records,
            dropped,
        });
    }
    if hub.enabled() {
        lock_ignore_poison(lanes).merge(&hub.take());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::fault::{FaultPlan, FaultyFactory};
    use crate::exec::plan::ShardPolicy;
    use crate::workload::source::IterSource;

    /// Toy factory: identity over u32 regions of weight 1, with a
    /// configurable failure item and optional per-item busy sleep.
    struct ToyFactory {
        fail_on: Option<u32>,
        sleep_heavy: Option<u32>,
    }

    impl ToyFactory {
        fn plain() -> ToyFactory {
            ToyFactory {
                fail_on: None,
                sleep_heavy: None,
            }
        }
    }

    struct ToyWorker {
        fail_on: Option<u32>,
        sleep_heavy: Option<u32>,
    }

    impl ShardWorker for ToyWorker {
        type In = u32;
        type Out = u32;

        fn run_shard(&mut self, shard: &[u32]) -> Result<ShardOutput<u32>> {
            if let Some(bad) = self.fail_on {
                if shard.contains(&bad) {
                    anyhow::bail!("poison item {bad}");
                }
            }
            if let Some(heavy) = self.sleep_heavy {
                if shard.contains(&heavy) {
                    std::thread::sleep(std::time::Duration::from_millis(25));
                }
            }
            Ok(ShardOutput {
                outputs: shard.to_vec(),
                metrics: PipelineMetrics::default(),
                invocations: shard.len() as u64,
            })
        }
    }

    impl PipelineFactory for ToyFactory {
        type In = u32;
        type Out = u32;
        type Worker = ToyWorker;

        fn make_worker(&self, _worker_id: usize) -> Result<ToyWorker> {
            Ok(ToyWorker {
                fail_on: self.fail_on,
                sleep_heavy: self.sleep_heavy,
            })
        }
    }

    fn items(n: u32) -> Vec<u32> {
        (0..n).collect()
    }

    #[test]
    fn results_come_back_in_shard_order() {
        let stream = items(1000);
        let weights = vec![1usize; 1000];
        for claim in [ClaimMode::Steal, ClaimMode::NoSteal, ClaimMode::Cursor] {
            for workers in [1usize, 2, 4, 7] {
                let plan = ShardPlan::build(
                    &weights,
                    workers,
                    &ShardPolicy {
                        shards_per_worker: 3,
                        ..ShardPolicy::default()
                    },
                );
                let results = WorkerPool::new(workers)
                    .with_claim(claim)
                    .run(&ToyFactory::plain(), &stream, &plan)
                    .unwrap();
                assert_eq!(results.len(), plan.len());
                let flat: Vec<u32> = results.iter().flat_map(|r| r.outputs.clone()).collect();
                assert_eq!(flat, stream, "workers={workers} claim={claim:?}");
                for (i, r) in results.iter().enumerate() {
                    assert_eq!(r.shard, i);
                    assert!(r.worker < workers);
                    assert_eq!(r.regions, plan.range(i).len());
                }
            }
        }
    }

    #[test]
    fn shard_results_carry_the_worker_build_count() {
        let stream = items(200);
        let weights = vec![1usize; 200];
        let plan = ShardPlan::build(
            &weights,
            3,
            &ShardPolicy {
                shards_per_worker: 4,
                ..ShardPolicy::default()
            },
        );
        let results = WorkerPool::new(3).run(&ToyFactory::plain(), &stream, &plan).unwrap();
        assert!(results.len() > 3, "want several shards per worker");
        for r in &results {
            assert_eq!(r.pipelines_built, 1, "shard {}", r.shard);
        }
    }

    #[test]
    fn worker_errors_are_annotated_and_fatal() {
        let stream = items(100);
        let weights = vec![1usize; 100];
        let plan = ShardPlan::build(&weights, 4, &ShardPolicy::default());
        let err = WorkerPool::new(4)
            .run(
                &ToyFactory {
                    fail_on: Some(50),
                    sleep_heavy: None,
                },
                &stream,
                &plan,
            )
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("poison item 50"), "{msg}");
        assert!(msg.contains("shard"), "{msg}");
    }

    #[test]
    fn empty_plan_is_a_noop() {
        let plan = ShardPlan::build(&[], 4, &ShardPolicy::default());
        let results = WorkerPool::new(4).run(&ToyFactory::plain(), &[], &plan).unwrap();
        assert!(results.is_empty());
    }

    #[test]
    fn zero_workers_is_a_named_error() {
        let plan = ShardPlan::build(&[1], 1, &ShardPolicy::default());
        let err = WorkerPool::new(0).run(&ToyFactory::plain(), &[7], &plan).unwrap_err();
        assert!(err.to_string().contains("workers = 0"), "{err}");
    }

    #[test]
    fn skewed_plan_under_stealing_produces_every_index_exactly_once() {
        // Steal-heavy shape: region 0 is heavy (its shard sleeps), the
        // rest are trivial — idle workers must steal the backlog behind
        // the sleeper, and the merged output must still be exactly the
        // stream, each index exactly once.
        let stream = items(600);
        let mut weights = vec![1usize; 600];
        weights[0] = 500;
        let plan = ShardPlan::build(
            &weights,
            4,
            &ShardPolicy {
                shards_per_worker: 8,
                ..ShardPolicy::default()
            },
        );
        let results = WorkerPool::new(4)
            .with_claim(ClaimMode::Steal)
            .run(
                &ToyFactory {
                    fail_on: None,
                    sleep_heavy: Some(0),
                },
                &stream,
                &plan,
            )
            .unwrap();
        let mut seen = vec![0u32; 600];
        for r in &results {
            for &v in &r.outputs {
                seen[v as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "every index exactly once");
        let stolen = results.iter().filter(|r| r.stolen).count();
        assert!(
            stolen > 0,
            "idle workers must steal behind the sleeping shard"
        );
    }

    #[test]
    fn streaming_emits_in_stream_order_with_bounded_budget() {
        for workers in [1usize, 2, 4] {
            let pool = WorkerPool::new(workers);
            let ingest = IngestPolicy {
                buffer_regions: 16,
                shard_regions: 3,
            };
            let mut got = Vec::new();
            let mut shards = 0usize;
            pool.run_stream(
                &ToyFactory::plain(),
                IterSource::new(0..500u32),
                &ingest,
                |r| {
                    shards += 1;
                    got.extend(r.outputs);
                    Ok(())
                },
            )
            .unwrap();
            assert_eq!(got, items(500), "workers={workers}");
            assert!(shards >= 500 / 3, "workers={workers}: {shards} shards");
        }
    }

    #[test]
    fn streaming_empty_source_is_a_noop() {
        let mut calls = 0usize;
        WorkerPool::new(3)
            .run_stream(
                &ToyFactory::plain(),
                IterSource::new(std::iter::empty::<u32>()),
                &IngestPolicy::default(),
                |_| {
                    calls += 1;
                    Ok(())
                },
            )
            .unwrap();
        assert_eq!(calls, 0);
    }

    #[test]
    fn streaming_worker_error_aborts_the_run() {
        let err = WorkerPool::new(3)
            .run_stream(
                &ToyFactory {
                    fail_on: Some(123),
                    sleep_heavy: None,
                },
                IterSource::new(0..1000u32),
                &IngestPolicy {
                    buffer_regions: 32,
                    shard_regions: 4,
                },
                |_| Ok(()),
            )
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("poison item 123"), "{msg}");
        assert!(msg.contains("streaming shard"), "{msg}");
    }

    #[test]
    fn traced_run_collects_prewarm_and_shard_events() {
        let stream = items(120);
        let weights = vec![1usize; 120];
        for workers in [1usize, 3] {
            let plan = ShardPlan::build(
                &weights,
                workers,
                &ShardPolicy {
                    shards_per_worker: 2,
                    ..ShardPolicy::default()
                },
            );
            let run = WorkerPool::new(workers)
                .with_trace(Some(TraceSpec::new(1 << 12)))
                .run_collect(&ToyFactory::plain(), &stream, &plan)
                .unwrap();
            assert_eq!(run.results.len(), plan.len());
            let trace = crate::trace::Trace {
                workers: run.traces,
                nodes: Vec::new(),
            };
            assert_eq!(trace.dropped(), 0);
            assert_eq!(trace.shards(), plan.len() as u64, "workers={workers}");
            let prewarms = trace
                .workers
                .iter()
                .flat_map(|w| &w.records)
                .filter(|r| matches!(r.event, TraceEvent::Prewarm))
                .count();
            // every lane that shows up prewarmed exactly once
            assert_eq!(prewarms, trace.workers.len());
            assert!(run.elapsed >= 0.0);
        }
    }

    #[test]
    fn traced_streaming_run_reconciles_driver_lane() {
        let run = WorkerPool::new(2)
            .with_trace(Some(TraceSpec::new(1 << 12)))
            .run_stream_collect(
                &ToyFactory::plain(),
                IterSource::new(0..200u32),
                &IngestPolicy {
                    buffer_regions: 16,
                    shard_regions: 4,
                },
                |_| Ok(()),
            )
            .unwrap();
        let trace = crate::trace::Trace {
            workers: run.traces,
            nodes: Vec::new(),
        };
        assert_eq!(trace.dropped(), 0);
        assert!(trace.shards() > 0);
        assert_eq!(trace.submits(), trace.shards());
        assert_eq!(trace.emits(), trace.shards());
        let driver = trace
            .workers
            .iter()
            .find(|w| w.worker == DRIVER_LANE)
            .expect("driver lane present when traced");
        assert!(!driver.records.is_empty());
    }

    #[test]
    fn untraced_run_collects_no_lanes() {
        let stream = items(50);
        let weights = vec![1usize; 50];
        let plan = ShardPlan::build(&weights, 2, &ShardPolicy::default());
        let run = WorkerPool::new(2)
            .run_collect(&ToyFactory::plain(), &stream, &plan)
            .unwrap();
        assert!(run.traces.is_empty());
    }

    #[test]
    fn retry_recovers_injected_faults_bit_identically() {
        let stream = items(300);
        let weights = vec![1usize; 300];
        let plan = ShardPlan::build(
            &weights,
            3,
            &ShardPolicy {
                shards_per_worker: 4,
                ..ShardPolicy::default()
            },
        );
        let clean = WorkerPool::new(3).run(&ToyFactory::plain(), &stream, &plan).unwrap();
        let faults = FaultPlan::new().panic_at(0).error_at(3).panic_at(plan.len() - 1);
        let factory = FaultyFactory::new(ToyFactory::plain(), &faults);
        let results = WorkerPool::new(3)
            .with_fault(FaultPolicy::retry(3))
            .run(&factory, &stream, &plan)
            .unwrap();
        assert_eq!(factory.remaining(), 0, "every planned shot fired");
        let flat = |rs: &[ShardResult<u32>]| -> Vec<u32> {
            rs.iter().flat_map(|r| r.outputs.clone()).collect()
        };
        assert_eq!(flat(&results), flat(&clean), "recovered output is identical");
        let retries: u32 = results.iter().map(|r| r.retries).sum();
        assert_eq!(retries as usize, faults.injected(), "one retry per injected fault");
        for r in &results {
            assert!(r.fault.is_none());
            let faulted = faults.shards().contains(&r.shard);
            assert_eq!(r.retries > 0, faulted, "shard {}", r.shard);
        }
    }

    #[test]
    fn quarantine_skips_the_poisoned_shard_and_reports_it() {
        let stream = items(200);
        let weights = vec![1usize; 200];
        let plan = ShardPlan::build(
            &weights,
            2,
            &ShardPolicy {
                shards_per_worker: 3,
                ..ShardPolicy::default()
            },
        );
        let faults = FaultPlan::new().panic_at(2);
        let factory = FaultyFactory::new(ToyFactory::plain(), &faults);
        let results = WorkerPool::new(2)
            .with_fault(FaultPolicy::Quarantine)
            .run(&factory, &stream, &plan)
            .unwrap();
        assert_eq!(results.len(), plan.len(), "quarantine still fills every slot");
        for r in &results {
            if r.shard == 2 {
                // part-granular: only the region the shot hit (the
                // first per-region attempt) is lost; survivors keep
                // their rows
                assert_eq!(r.lost, vec![0], "exactly the poisoned part is named");
                assert_eq!(
                    r.outputs,
                    stream[plan.range(2)][1..].to_vec(),
                    "surviving regions keep their outputs"
                );
                let msg = r.fault.as_deref().expect("shard 2 is quarantined");
                assert!(msg.contains("injected fault"), "{msg}");
            } else {
                assert_eq!(r.outputs, stream[plan.range(r.shard)].to_vec());
                assert!(r.fault.is_none());
                assert!(r.lost.is_empty());
            }
        }
    }

    #[test]
    fn fail_fast_panic_names_worker_and_shard() {
        let stream = items(100);
        let weights = vec![1usize; 100];
        let plan = ShardPlan::build(&weights, 2, &ShardPolicy::default());
        let faults = FaultPlan::new().panic_at(1);
        let factory = FaultyFactory::new(ToyFactory::plain(), &faults);
        let err = WorkerPool::new(2).run(&factory, &stream, &plan).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("shard 1"), "{msg}");
        assert!(msg.contains("worker"), "{msg}");
        assert!(msg.contains("injected fault"), "{msg}");
    }

    #[test]
    fn retry_exhaustion_fails_the_run() {
        let stream = items(60);
        let weights = vec![1usize; 60];
        let plan = ShardPlan::build(&weights, 2, &ShardPolicy::default());
        let faults = FaultPlan::new().panic_at_times(0, 8);
        let factory = FaultyFactory::new(ToyFactory::plain(), &faults);
        let err = WorkerPool::new(2)
            .with_fault(FaultPolicy::retry(2))
            .run(&factory, &stream, &plan)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("still failing after 2 attempt(s)"), "{msg}");
    }

    #[test]
    fn streaming_retry_recovers_and_emits_in_order() {
        let faults = FaultPlan::new().panic_at(0).error_at(5).panic_at(11);
        let factory = FaultyFactory::new(ToyFactory::plain(), &faults);
        let mut got = Vec::new();
        let mut retries = 0u32;
        WorkerPool::new(3)
            .with_fault(FaultPolicy::retry(3))
            .run_stream(
                &factory,
                IterSource::new(0..400u32),
                &IngestPolicy {
                    buffer_regions: 16,
                    shard_regions: 3,
                },
                |r| {
                    retries += r.retries;
                    got.extend(r.outputs);
                    Ok(())
                },
            )
            .unwrap();
        assert_eq!(got, items(400), "recovered stream is identical and in order");
        assert_eq!(retries as usize, faults.injected());
        assert_eq!(factory.remaining(), 0);
    }

    #[test]
    fn streaming_quarantine_emits_an_empty_slot_in_order() {
        let faults = FaultPlan::new().panic_at(4);
        let factory = FaultyFactory::new(ToyFactory::plain(), &faults);
        let mut slots = Vec::new();
        let mut got = Vec::new();
        WorkerPool::new(2)
            .with_fault(FaultPolicy::Quarantine)
            .run_stream(
                &factory,
                IterSource::new(0..100u32),
                &IngestPolicy {
                    buffer_regions: 8,
                    shard_regions: 2,
                },
                |r| {
                    slots.push((r.shard, r.fault.is_some()));
                    got.extend(r.outputs);
                    Ok(())
                },
            )
            .unwrap();
        for (i, &(shard, _)) in slots.iter().enumerate() {
            assert_eq!(shard, i, "emission stays in stream order");
        }
        let quarantined: Vec<usize> =
            slots.iter().filter(|s| s.1).map(|s| s.0).collect();
        assert_eq!(quarantined, vec![4], "exactly the injected shard is quarantined");
        // shard 4 spans regions 8..10; the part-granular quarantine
        // drops only region 8 (the part the shot hit) and salvages 9
        let expect: Vec<u32> = (0..100u32).filter(|&v| v != 8).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn metrics_lanes_fold_and_reconcile_with_results() {
        let stream = items(200);
        let weights = vec![1usize; 200];
        let plan = ShardPlan::build(
            &weights,
            3,
            &ShardPolicy {
                shards_per_worker: 4,
                ..ShardPolicy::default()
            },
        );
        let run = WorkerPool::new(3)
            .with_metrics(Some(MetricsSpec::new()))
            .run_collect(&ToyFactory::plain(), &stream, &plan)
            .unwrap();
        let m = run.metrics.expect("metered run yields folded lanes");
        assert_eq!(m.shards, plan.len() as u64, "one record per shard");
        assert_eq!(m.regions, 200, "every region counted exactly once");
        assert_eq!(m.service.count, plan.len() as u64);
        assert_eq!(m.queue_wait.count, plan.len() as u64);
        assert_eq!(m.queue_wait.sum_ns, 0, "materialized shards never queue");
        assert_eq!(m.e2e.count, 0, "no submit stamps on materialized runs");
        assert_eq!(m.faults, 0);
        assert_eq!(m.retries, 0);
        assert_eq!(
            m.stolen,
            run.results.iter().filter(|r| r.stolen).count() as u64,
            "steal tally reconciles with per-shard flags"
        );
        assert!(m.busy_ns >= m.service.max_ns, "busy time folds every shard");

        // the same pool without metering reports nothing
        let bare = WorkerPool::new(3)
            .run_collect(&ToyFactory::plain(), &stream, &plan)
            .unwrap();
        assert!(bare.metrics.is_none());
    }

    #[test]
    fn streaming_metrics_record_e2e_and_flow() {
        let run = WorkerPool::new(2)
            .with_metrics(Some(MetricsSpec::new()))
            .run_stream_collect(
                &ToyFactory::plain(),
                IterSource::new(0..200u32),
                &IngestPolicy {
                    buffer_regions: 16,
                    shard_regions: 4,
                },
                |_| Ok(()),
            )
            .unwrap();
        let m = run.metrics.expect("metered streaming run yields lanes");
        assert_eq!(m.submitted_regions, 200);
        assert_eq!(m.emitted_regions, 200, "flow balances at end of stream");
        assert_eq!(m.regions, 200, "worker lanes saw every region");
        assert_eq!(m.submitted_shards, m.emitted_shards);
        assert_eq!(m.shards, m.submitted_shards, "workers ran every shard");
        assert_eq!(m.e2e.count, 200, "one e2e sample per region");
        assert_eq!(m.queue_wait.count, m.shards);
        assert_eq!(m.service.count, m.shards);
        assert!(m.e2e.max_ns > 0, "submit→emit spans real time");
        assert!(
            (1..=16).contains(&m.peak_in_flight),
            "peak in-flight respects the budget: {}",
            m.peak_in_flight
        );
    }

    /// Worker whose shards outlast the test watchdog by far.
    struct StuckFactory;

    struct StuckWorker;

    impl ShardWorker for StuckWorker {
        type In = u32;
        type Out = u32;

        fn run_shard(&mut self, shard: &[u32]) -> Result<ShardOutput<u32>> {
            std::thread::sleep(Duration::from_millis(400));
            Ok(ShardOutput {
                outputs: shard.to_vec(),
                metrics: PipelineMetrics::default(),
                invocations: 0,
            })
        }
    }

    impl PipelineFactory for StuckFactory {
        type In = u32;
        type Out = u32;
        type Worker = StuckWorker;

        fn make_worker(&self, _worker_id: usize) -> Result<StuckWorker> {
            Ok(StuckWorker)
        }
    }

    #[test]
    fn streaming_watchdog_names_the_stall_instead_of_hanging() {
        // one worker stuck inside a 400ms shard, watchdog at 50ms: the
        // backpressured driver must fail with the stall diagnostics
        // instead of sleeping forever on the completion condvar
        let err = WorkerPool::new(1)
            .with_watchdog(Duration::from_millis(50))
            .run_stream(
                &StuckFactory,
                IterSource::new(0..64u32),
                &IngestPolicy {
                    buffer_regions: 4,
                    shard_regions: 2,
                },
                |_| Ok(()),
            )
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("watchdog"), "{msg}");
        assert!(msg.contains("in flight"), "{msg}");
    }

    #[test]
    fn streaming_sink_error_aborts_the_run() {
        let err = WorkerPool::new(2)
            .run_stream(
                &ToyFactory::plain(),
                IterSource::new(0..100u32),
                &IngestPolicy {
                    buffer_regions: 8,
                    shard_regions: 2,
                },
                |_| anyhow::bail!("sink refused"),
            )
            .unwrap_err();
        assert!(err.to_string().contains("sink refused"), "{err}");
    }
}
