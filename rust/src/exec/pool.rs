//! The worker pool: one scoped OS thread per worker, each running a
//! private single-threaded pipeline over shards claimed from an atomic
//! cursor (the paper's "pipelines compete to consume data from a common
//! input stream ... atomic operations but no locking", lifted from GPU
//! processors to OS threads).
//!
//! Error semantics: the first failure flips a stop flag so idle workers
//! quit claiming, and the error (annotated with worker and shard) is
//! returned after all threads join. Already-completed shards are
//! discarded — a sharded run is all-or-nothing.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use super::factory::{PipelineFactory, ShardWorker};
use super::plan::ShardPlan;
use crate::coordinator::metrics::PipelineMetrics;

/// One shard's results, tagged with where it ran.
#[derive(Debug, Clone)]
pub struct ShardResult<T> {
    /// Shard index in plan (= stream) order.
    pub shard: usize,
    /// Worker that executed it.
    pub worker: usize,
    /// Outputs in the shard's stream order.
    pub outputs: Vec<T>,
    /// The shard pipeline's metrics.
    pub metrics: PipelineMetrics,
    /// Kernel invocations spent on the shard.
    pub invocations: u64,
    /// Wall-clock seconds this shard took on its worker.
    pub elapsed: f64,
}

/// Best-effort text of a thread panic payload (panics carry `&str` or
/// `String` in practice; anything else is reported generically).
fn panic_msg(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

/// Fixed-size pool of pipeline workers over a shard plan.
#[derive(Debug, Clone, Copy)]
pub struct WorkerPool {
    workers: usize,
}

impl WorkerPool {
    pub fn new(workers: usize) -> WorkerPool {
        WorkerPool {
            workers: workers.max(1),
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run every shard of `plan` over `stream`, one worker pipeline per
    /// thread. Returns all shard results sorted back into shard order.
    ///
    /// With one worker (or one shard) everything runs inline on the
    /// calling thread — no pool overhead, bit-identical to a plain
    /// single-threaded run.
    pub fn run<F: PipelineFactory>(
        &self,
        factory: &F,
        stream: &[F::In],
        plan: &ShardPlan,
    ) -> Result<Vec<ShardResult<F::Out>>> {
        if plan.is_empty() {
            return Ok(Vec::new());
        }
        let threads = self.workers.min(plan.len());
        let cursor = AtomicUsize::new(0);
        let stop = AtomicBool::new(false);

        /// Flips the stop flag if its thread unwinds, so a panicking
        /// worker halts the rest of the pool just like an `Err` does.
        struct StopOnPanic<'a>(&'a AtomicBool);
        impl Drop for StopOnPanic<'_> {
            fn drop(&mut self) {
                if std::thread::panicking() {
                    self.0.store(true, Ordering::Relaxed);
                }
            }
        }

        let worker_loop = |worker_id: usize| -> Result<Vec<ShardResult<F::Out>>> {
            let _guard = StopOnPanic(&stop);
            let mut done = Vec::new();
            let mut pipeline: Option<F::Worker> = None;
            loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let shard = cursor.fetch_add(1, Ordering::Relaxed);
                if shard >= plan.len() {
                    break;
                }
                if pipeline.is_none() {
                    // Built lazily so workers that never claim a shard
                    // never pay for an engine.
                    match factory.make_worker(worker_id) {
                        Ok(p) => pipeline = Some(p),
                        Err(e) => {
                            stop.store(true, Ordering::Relaxed);
                            return Err(e.context(format!(
                                "building pipeline for worker {worker_id}"
                            )));
                        }
                    }
                }
                let p = pipeline.as_mut().expect("pipeline built above");
                let t0 = Instant::now();
                match p.run_shard(&stream[plan.range(shard)]) {
                    Ok(out) => done.push(ShardResult {
                        shard,
                        worker: worker_id,
                        outputs: out.outputs,
                        metrics: out.metrics,
                        invocations: out.invocations,
                        elapsed: t0.elapsed().as_secs_f64(),
                    }),
                    Err(e) => {
                        stop.store(true, Ordering::Relaxed);
                        return Err(e.context(format!(
                            "worker {worker_id} failed on shard {shard}"
                        )));
                    }
                }
            }
            Ok(done)
        };

        let per_thread: Vec<Result<Vec<ShardResult<F::Out>>>> = if threads <= 1 {
            vec![worker_loop(0)]
        } else {
            std::thread::scope(|scope| {
                let worker_loop = &worker_loop;
                let handles: Vec<_> = (0..threads)
                    .map(|wid| scope.spawn(move || worker_loop(wid)))
                    .collect();
                handles
                    .into_iter()
                    .map(|h| {
                        h.join().unwrap_or_else(|payload| {
                            Err(anyhow!("worker thread panicked: {}", panic_msg(&payload)))
                        })
                    })
                    .collect()
            })
        };

        let mut all = Vec::with_capacity(plan.len());
        for r in per_thread {
            all.extend(r?);
        }
        all.sort_by_key(|r| r.shard);
        ensure!(
            all.len() == plan.len(),
            "pool completed {} of {} shards",
            all.len(),
            plan.len()
        );
        Ok(all)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::factory::ShardOutput;
    use crate::exec::plan::ShardPolicy;

    /// Toy factory: identity over u32 regions of weight 1, with a
    /// configurable failure shard.
    struct ToyFactory {
        fail_on: Option<u32>,
    }

    struct ToyWorker {
        fail_on: Option<u32>,
    }

    impl ShardWorker for ToyWorker {
        type In = u32;
        type Out = u32;

        fn run_shard(&mut self, shard: &[u32]) -> Result<ShardOutput<u32>> {
            if let Some(bad) = self.fail_on {
                if shard.contains(&bad) {
                    anyhow::bail!("poison item {bad}");
                }
            }
            Ok(ShardOutput {
                outputs: shard.to_vec(),
                metrics: PipelineMetrics::default(),
                invocations: shard.len() as u64,
            })
        }
    }

    impl PipelineFactory for ToyFactory {
        type In = u32;
        type Out = u32;
        type Worker = ToyWorker;

        fn make_worker(&self, _worker_id: usize) -> Result<ToyWorker> {
            Ok(ToyWorker {
                fail_on: self.fail_on,
            })
        }
    }

    fn items(n: u32) -> Vec<u32> {
        (0..n).collect()
    }

    #[test]
    fn results_come_back_in_shard_order() {
        let stream = items(1000);
        let weights = vec![1usize; 1000];
        for workers in [1usize, 2, 4, 7] {
            let plan = ShardPlan::build(
                &weights,
                workers,
                &ShardPolicy {
                    shards_per_worker: 3,
                    ..ShardPolicy::default()
                },
            );
            let results = WorkerPool::new(workers)
                .run(&ToyFactory { fail_on: None }, &stream, &plan)
                .unwrap();
            assert_eq!(results.len(), plan.len());
            let flat: Vec<u32> = results.iter().flat_map(|r| r.outputs.clone()).collect();
            assert_eq!(flat, stream, "workers={workers}");
            for (i, r) in results.iter().enumerate() {
                assert_eq!(r.shard, i);
                assert!(r.worker < workers);
            }
        }
    }

    #[test]
    fn worker_errors_are_annotated_and_fatal() {
        let stream = items(100);
        let weights = vec![1usize; 100];
        let plan = ShardPlan::build(&weights, 4, &ShardPolicy::default());
        let err = WorkerPool::new(4)
            .run(&ToyFactory { fail_on: Some(50) }, &stream, &plan)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("poison item 50"), "{msg}");
        assert!(msg.contains("shard"), "{msg}");
    }

    #[test]
    fn empty_plan_is_a_noop() {
        let plan = ShardPlan::build(&[], 4, &ShardPolicy::default());
        let results = WorkerPool::new(4)
            .run(&ToyFactory { fail_on: None }, &[], &plan)
            .unwrap();
        assert!(results.is_empty());
    }
}
