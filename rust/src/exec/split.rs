//! Intra-region sub-shard parallelism: cutting oversized regions into
//! parts that different workers execute concurrently.
//!
//! The executor's planner never splits a region (the region-boundary
//! invariant in [`crate::exec`]), so one heavy-tailed region pins its
//! whole shard to a single worker no matter the pool size — the
//! giant-region straggler. For stages whose region state is an
//! **associative accumulator** (the enumerated sum's running total, not
//! taxi's order-dependent line context), that limit is artificial: the
//! region can be cut into parts, each part reduced independently, and
//! the partials re-folded in part order.
//!
//! The contract that keeps results bit-identical:
//!
//! * The factory advertises a [`Splittability`] and implements
//!   [`PipelineFactory::split_region`] (owned parts, item order
//!   preserved) and, for [`Splittability::RegionFold`],
//!   [`PipelineFactory::combine`].
//! * Parts flow through planning, stealing, retry and tracing as
//!   **first-class regions** — nothing downstream of the cut is
//!   special-cased, so everything already built composes (a part is
//!   retried alone; a part's execution appears as an ordinary shard
//!   span in the trace).
//! * The re-fold is a **fixed-shape left-linear chain in part order**:
//!   part 0's row seeds the accumulator and parts 1..n fold in
//!   ascending index — a pure function of sub-shard identity, never of
//!   completion order. For the fused sum this replays the exact f64
//!   addition sequence of the unsplit pipeline, so the folded result is
//!   bit-identical, not merely approximately equal.
//!
//! [`SubShard`] is the identity (`region`, `part`, `of`) threaded from
//! the cut to the fold; [`SplitQueue`] carries those identities in
//! stream order from the splitter to the
//! [`RegionFolder`](super::merge::RegionFolder); [`SplitSource`] adapts
//! any [`RegionSource`] so streaming runs cut on the fly under the same
//! bounded in-flight budget.
//!
//! [`Splittability`]: super::factory::Splittability
//! [`PipelineFactory::split_region`]: super::factory::PipelineFactory::split_region
//! [`PipelineFactory::combine`]: super::factory::PipelineFactory::combine

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

use anyhow::{Error, Result};

use super::factory::PipelineFactory;
use crate::workload::source::RegionSource;

/// Identity of one part of a (possibly split) region: which region of
/// the stream it belongs to, its position among the region's parts, and
/// how many parts the region was cut into. The reduction shape is a
/// pure function of this identity — `part == 0` seeds the accumulator,
/// `part + 1 == of` completes the region — so the fold is independent
/// of completion order by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubShard {
    /// Stream ordinal of the original region (0-based).
    pub region: u64,
    /// Part index within the region (0-based, item order).
    pub part: u32,
    /// Total parts the region was cut into (`>= 1`; 1 = unsplit).
    pub of: u32,
}

impl SubShard {
    /// True when this part completes its region.
    pub fn is_last(&self) -> bool {
        self.part + 1 == self.of
    }
}

/// Stream-order ledger of [`SubShard`] identities, filled by the
/// splitter (materialized pre-pass or [`SplitSource`]) and drained by
/// the [`RegionFolder`](super::merge::RegionFolder) as shard results
/// emit. With `record = false` only the counters are kept (the
/// [`GlobalFold`](super::factory::Splittability::GlobalFold) path needs
/// no per-part identities), so an unbounded stream never grows the
/// queue.
#[derive(Debug)]
pub struct SplitQueue {
    parts: VecDeque<SubShard>,
    record: bool,
    regions_seen: u64,
    regions_split: usize,
    parts_made: usize,
}

impl SplitQueue {
    /// An empty queue. `record = true` stores per-part identities for
    /// the region fold; `false` keeps counters only.
    pub fn new(record: bool) -> SplitQueue {
        SplitQueue {
            parts: VecDeque::new(),
            record,
            regions_seen: 0,
            regions_split: 0,
            parts_made: 0,
        }
    }

    /// Register the next stream region as cut into `of` parts
    /// (`of == 1` = passed through unsplit). Must be called in stream
    /// order — the queue's ordinals are assigned by arrival.
    pub fn push_region(&mut self, of: u32) {
        debug_assert!(of >= 1, "a region always has at least one part");
        let region = self.regions_seen;
        self.regions_seen += 1;
        self.parts_made += of as usize;
        if of > 1 {
            self.regions_split += 1;
        }
        if self.record {
            for part in 0..of {
                self.parts.push_back(SubShard { region, part, of });
            }
        }
    }

    /// Drain the next part identity in stream order.
    pub fn pop(&mut self) -> Option<SubShard> {
        self.parts.pop_front()
    }

    /// Recorded part identities not yet drained.
    pub fn pending(&self) -> usize {
        self.parts.len()
    }

    /// Regions that were actually cut (`of > 1`).
    pub fn regions_split(&self) -> usize {
        self.regions_split
    }

    /// Total parts produced (split + passthrough).
    pub fn parts_made(&self) -> usize {
        self.parts_made
    }

    /// Regions registered so far.
    pub fn regions_seen(&self) -> u64 {
        self.regions_seen
    }
}

/// Shared handle to a [`SplitQueue`]: the splitter pushes and the
/// folder pops on the same (driver) thread, so a plain `Rc<RefCell<_>>`
/// suffices — no locking on the streaming hot path.
pub type SharedSplitQueue = Rc<RefCell<SplitQueue>>;

/// A [`RegionSource`] adapter that cuts oversized regions on the fly:
/// regions whose [`PipelineFactory::weight`] exceeds `max_items` are
/// replaced by their [`PipelineFactory::split_region`] parts (the
/// original is recycled through the factory); everything else passes
/// through untouched. Part identities land in the shared
/// [`SplitQueue`] in stream order. Split failures are stashed and
/// surfaced by [`RegionSource::close`], the executor's deferred-error
/// convention for fallible sources.
pub struct SplitSource<'f, F: PipelineFactory, S> {
    factory: &'f F,
    inner: S,
    max_items: usize,
    queue: SharedSplitQueue,
    pending: VecDeque<F::In>,
    error: Option<Error>,
}

impl<'f, F: PipelineFactory, S: RegionSource<Region = F::In>> SplitSource<'f, F, S> {
    /// Wrap `inner`, cutting regions heavier than `max_items` (which
    /// must be nonzero — splitting off entirely means not constructing
    /// a `SplitSource` at all).
    pub fn new(
        factory: &'f F,
        inner: S,
        max_items: usize,
        queue: SharedSplitQueue,
    ) -> SplitSource<'f, F, S> {
        debug_assert!(max_items > 0, "SplitSource with splitting disabled");
        SplitSource {
            factory,
            inner,
            max_items,
            queue,
            pending: VecDeque::new(),
            error: None,
        }
    }
}

impl<F: PipelineFactory, S: RegionSource<Region = F::In>> SplitSource<'_, F, S> {
    /// Post-pull half of the pull path: register the region with the
    /// queue, cutting it first if oversized. Split failures stash into
    /// `self.error` (surfaced by `close`) and end the stream.
    fn admit(&mut self, region: F::In) -> Option<F::In> {
        if self.factory.weight(&region) <= self.max_items {
            self.queue.borrow_mut().push_region(1);
            return Some(region);
        }
        match self.factory.split_region(&region, self.max_items) {
            Ok(parts) if parts.is_empty() => {
                self.error = Some(anyhow::anyhow!(
                    "split_region returned no parts for an oversized region"
                ));
                None
            }
            Ok(parts) => {
                self.queue.borrow_mut().push_region(parts.len() as u32);
                self.pending.extend(parts);
                // the original was cloned into parts; send it back the
                // same way an executed region would go
                self.factory.recycle_region(region);
                self.pending.pop_front()
            }
            Err(e) => {
                self.error = Some(e.context("splitting an oversized region"));
                None
            }
        }
    }
}

impl<F: PipelineFactory, S: RegionSource<Region = F::In>> RegionSource for SplitSource<'_, F, S> {
    type Region = F::In;

    fn next_region(&mut self) -> Option<F::In> {
        if let Some(part) = self.pending.pop_front() {
            return Some(part);
        }
        if self.error.is_some() {
            return None;
        }
        let region = self.inner.next_region()?;
        self.admit(region)
    }

    fn try_next_region(&mut self) -> Result<Option<F::In>> {
        if let Some(part) = self.pending.pop_front() {
            return Ok(Some(part));
        }
        if self.error.is_some() {
            return Ok(None);
        }
        // A transient inner failure propagates without touching the
        // split queue, so the driver's retried pull resumes cleanly.
        let Some(region) = self.inner.try_next_region()? else {
            return Ok(None);
        };
        Ok(self.admit(region))
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        // splitting only ever increases the count, so the inner lower
        // bound (plus buffered parts) stays a valid lower bound; the
        // upper bound is unknowable without weighing unseen regions
        let (lower, _) = self.inner.size_hint();
        (lower + self.pending.len(), None)
    }

    fn close(&mut self) -> Result<()> {
        if let Some(e) = self.error.take() {
            return Err(e);
        }
        self.inner.close()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::factory::{ShardOutput, ShardWorker, Splittability};
    use crate::workload::source::SliceSource;

    /// Toy splittable factory: a region is `Vec<u32>`, weight = len,
    /// output = one `(first_item, len)` row per region.
    struct ChunkFactory;

    struct ChunkWorker;

    impl ShardWorker for ChunkWorker {
        type In = Vec<u32>;
        type Out = (u32, usize);

        fn run_shard(&mut self, shard: &[Vec<u32>]) -> Result<ShardOutput<(u32, usize)>> {
            Ok(ShardOutput {
                outputs: shard.iter().map(|r| (r[0], r.len())).collect(),
                metrics: Default::default(),
                invocations: 0,
            })
        }
    }

    impl PipelineFactory for ChunkFactory {
        type In = Vec<u32>;
        type Out = (u32, usize);
        type Worker = ChunkWorker;

        fn make_worker(&self, _worker_id: usize) -> Result<ChunkWorker> {
            Ok(ChunkWorker)
        }

        fn weight(&self, region: &Vec<u32>) -> usize {
            region.len()
        }

        fn splittability(&self) -> Splittability {
            Splittability::RegionFold
        }

        fn split_region(&self, region: &Vec<u32>, max_items: usize) -> Result<Vec<Vec<u32>>> {
            Ok(region.chunks(max_items.max(1)).map(|c| c.to_vec()).collect())
        }

        fn combine(&self, acc: &mut (u32, usize), part: (u32, usize)) -> Result<()> {
            acc.1 += part.1;
            Ok(())
        }
    }

    #[test]
    fn queue_assigns_identities_in_stream_order() {
        let mut q = SplitQueue::new(true);
        q.push_region(1);
        q.push_region(3);
        q.push_region(1);
        assert_eq!(q.regions_seen(), 3);
        assert_eq!(q.regions_split(), 1);
        assert_eq!(q.parts_made(), 5);
        assert_eq!(q.pending(), 5);
        let expect = [
            SubShard { region: 0, part: 0, of: 1 },
            SubShard { region: 1, part: 0, of: 3 },
            SubShard { region: 1, part: 1, of: 3 },
            SubShard { region: 1, part: 2, of: 3 },
            SubShard { region: 2, part: 0, of: 1 },
        ];
        for want in expect {
            let got = q.pop().unwrap();
            assert_eq!(got, want);
            assert_eq!(got.is_last(), got.part + 1 == got.of);
        }
        assert!(q.pop().is_none());
    }

    #[test]
    fn unrecorded_queue_counts_without_storing() {
        let mut q = SplitQueue::new(false);
        for _ in 0..10_000 {
            q.push_region(4);
        }
        assert_eq!(q.pending(), 0, "GlobalFold never buffers identities");
        assert_eq!(q.regions_split(), 10_000);
        assert_eq!(q.parts_made(), 40_000);
        assert!(q.pop().is_none());
    }

    #[test]
    fn split_source_cuts_only_oversized_regions() {
        let regions: Vec<Vec<u32>> = vec![
            vec![1, 2],          // under threshold: passes through
            (10..17).collect(),  // 7 items: 3 parts of <= 3
            vec![99, 98, 97],    // exactly at threshold: passes through
        ];
        let queue: SharedSplitQueue = Rc::new(RefCell::new(SplitQueue::new(true)));
        let mut src = SplitSource::new(&ChunkFactory, SliceSource::new(&regions), 3, queue.clone());
        let mut got = Vec::new();
        while let Some(r) = src.next_region() {
            assert!(r.len() <= 3, "no part exceeds the threshold: {r:?}");
            got.push(r);
        }
        src.close().unwrap();
        let flat: Vec<u32> = got.iter().flatten().copied().collect();
        let want: Vec<u32> = regions.iter().flatten().copied().collect();
        assert_eq!(flat, want, "item order is preserved across the cut");
        assert_eq!(got.len(), 5);
        let q = queue.borrow();
        assert_eq!(q.regions_split(), 1);
        assert_eq!(q.parts_made(), 5);
        assert_eq!(q.pending(), 5, "identities wait for the folder");
    }

    #[test]
    fn split_source_defers_split_errors_to_close() {
        struct Refusing;
        struct NoWorker;
        impl ShardWorker for NoWorker {
            type In = Vec<u32>;
            type Out = ();
            fn run_shard(&mut self, _shard: &[Vec<u32>]) -> Result<ShardOutput<()>> {
                unreachable!()
            }
        }
        impl PipelineFactory for Refusing {
            type In = Vec<u32>;
            type Out = ();
            type Worker = NoWorker;
            fn make_worker(&self, _worker_id: usize) -> Result<NoWorker> {
                Ok(NoWorker)
            }
            fn weight(&self, region: &Vec<u32>) -> usize {
                region.len()
            }
            // splittability stays the default Opaque and split_region
            // the default bail — the source must surface that, not hide
            // a silently truncated stream
        }
        let regions = vec![vec![0u32; 8]];
        let queue: SharedSplitQueue = Rc::new(RefCell::new(SplitQueue::new(true)));
        let mut src = SplitSource::new(&Refusing, SliceSource::new(&regions), 2, queue);
        assert!(src.next_region().is_none(), "error stashes, stream ends");
        let err = src.close().unwrap_err();
        assert!(err.to_string().contains("oversized region"), "{err:#}");
    }
}
